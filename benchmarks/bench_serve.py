"""Serving-path benchmark (PR-6 tentpole): continuous batching vs the
per-request decode loop, plus p50/p99 request latency under a seeded
open-loop traffic generator.

Two measurements:

  * **throughput gate** — the same request set decoded by (a) the
    pre-continuous-batching engine loop (one batch-1 jitted decode per
    active request per token, host sync on every sampled token) and (b) the
    continuous-batching engine (one batched decode over all slots, greedy
    sample fused on device, pipelined dispatch).  Greedy outputs must match
    token-for-token; the CLI exits non-zero when the batched engine is below
    2x tokens/sec at >= 4 concurrent requests.
  * **latency** — an open-loop traffic trace (Poisson arrivals whose times
    do NOT depend on service times, mixed prompt lengths, fixed seed) is
    replayed against the engine in real time; per-request latency is
    completion minus arrival.  Reports p50/p99 latency and sustained
    tokens/sec — the numbers the perf-trend CI job gates run-over-run.
"""
from __future__ import annotations

import argparse
import json
import time
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M
from repro.serve import ServeConfig, ServingEngine

from .common import emit


# ---------------------------------------------------------------------------
# percentile + traffic generator (pure, seeded — unit-tested)
# ---------------------------------------------------------------------------
def percentile(values, q: float) -> float:
    """Linear-interpolation percentile (numpy's default method), q in
    [0, 100].  Implemented locally so the latency math is unit-testable
    without depending on numpy method-name churn."""
    vs = sorted(float(v) for v in values)
    if not vs:
        raise ValueError("percentile of empty sequence")
    if len(vs) == 1:
        return vs[0]
    pos = (len(vs) - 1) * q / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(vs) - 1)
    frac = pos - lo
    return vs[lo] * (1.0 - frac) + vs[hi] * frac


def make_traffic(n: int, rate_per_s: float, prompt_lens, vocab: int,
                 seed: int = 0) -> list[tuple[float, np.ndarray]]:
    """A seeded open-loop request trace: ``n`` requests with Poisson
    arrivals (exponential inter-arrival times at ``rate_per_s``) and prompt
    lengths drawn uniformly from ``prompt_lens``.  Open loop means arrival
    times are fixed by the trace, never by how fast the server drains —
    latency under overload shows up as queueing delay instead of being
    hidden by back-pressure.  Same seed -> identical trace."""
    rng = np.random.default_rng(seed)
    t = 0.0
    trace: list[tuple[float, np.ndarray]] = []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate_per_s))
        ln = int(rng.choice(np.asarray(prompt_lens)))
        trace.append((t, rng.integers(1, vocab, size=ln).astype(np.int32)))
    return trace


# ---------------------------------------------------------------------------
# the pre-PR-6 engine loop, kept as the measured baseline
# ---------------------------------------------------------------------------
def per_request_baseline(cfg, params, scfg: ServeConfig,
                         prompts: list[np.ndarray]) -> dict[int, list[int]]:
    """The old ``ServingEngine.run()``: per-request batch-1 decode with a
    host sync on every sampled token (the loop PR 6 replaced)."""
    decode = jax.jit(partial(M.decode_step, cfg))
    queue = list(enumerate(prompts))
    active: dict[int, list] = {}
    results: dict[int, list[int]] = {}
    while queue or active:
        while queue and len(active) < scfg.batch_slots:
            rid, prompt = queue.pop(0)
            state = M.init_decode_state(cfg, 1, scfg.max_len, ring=False)
            logits, state = decode(params, state, jnp.asarray(prompt[None, :]))
            active[rid] = [state, logits[:, -1], []]
        for rid in list(active):
            st, last, out = active[rid]
            tok = int(np.asarray(last, np.float32)[0].argmax())
            out.append(tok)
            if len(out) >= scfg.max_new_tokens or tok == scfg.eos_id:
                results[rid] = out
                del active[rid]
                continue
            logits, st = decode(params, st, jnp.full((1, 1), tok, jnp.int32))
            active[rid] = [st, logits[:, -1], out]
    return results


def engine_drain(cfg, params, scfg: ServeConfig,
                 prompts: list[np.ndarray]) -> dict[int, list[int]]:
    eng = ServingEngine(cfg, params, scfg)
    for i, p in enumerate(prompts):
        eng.submit(p, rid=i)
    return eng.drain()


# ---------------------------------------------------------------------------
# measurements
# ---------------------------------------------------------------------------
def bench_throughput(cfg, params, scfg: ServeConfig,
                     prompts: list[np.ndarray], repeats: int) -> dict:
    # correctness first: continuous batching must be bit-identical greedy
    base_out = per_request_baseline(cfg, params, scfg, prompts)
    batch_out = engine_drain(cfg, params, scfg, prompts)
    match = base_out == batch_out
    assert match, "continuous-batching output diverged from the per-request loop"

    n_tokens = sum(len(v) for v in base_out.values())

    def timed(fn) -> float:
        runs = []
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            fn()
            runs.append(time.perf_counter() - t0)
        return float(np.median(runs))

    base_s = timed(lambda: per_request_baseline(cfg, params, scfg, prompts))
    batch_s = timed(lambda: engine_drain(cfg, params, scfg, prompts))
    base_tps = n_tokens / base_s
    batch_tps = n_tokens / batch_s
    speedup = batch_tps / base_tps
    emit("serve_per_request", base_s * 1e6, f"{base_tps:.0f} tok/s")
    emit("serve_batched", batch_s * 1e6,
         f"{batch_tps:.0f} tok/s speedup={speedup:.2f}x")
    return {
        "n_requests": len(prompts), "n_tokens": n_tokens,
        "per_request_us": base_s * 1e6, "batched_us": batch_s * 1e6,
        "baseline_tokens_per_sec": base_tps,
        "tokens_per_sec": batch_tps,
        "speedup": speedup, "outputs_match": bool(match),
        "speedup_ok": bool(speedup >= 2.0),
    }


def bench_latency(cfg, params, scfg: ServeConfig,
                  trace: list[tuple[float, np.ndarray]]) -> dict:
    """Replay the open-loop trace in real time; latency per request is
    harvest-of-final-token minus scheduled arrival."""
    eng = ServingEngine(cfg, params, scfg)
    pending: list[tuple[float, object]] = []
    lat_s: list[float] = []
    total_tokens = 0
    i = 0
    t0 = time.perf_counter()
    while i < len(trace) or pending:
        now = time.perf_counter() - t0
        while i < len(trace) and trace[i][0] <= now:
            at, prompt = trace[i]
            pending.append((at, eng.submit(prompt)))
            i += 1
        if not pending:
            # open loop: idle until the next scheduled arrival
            time.sleep(min(max(trace[i][0] - now, 0.0), 0.001))
            continue
        eng.step()
        now = time.perf_counter() - t0
        still = []
        for at, h in pending:
            if h.done:
                lat_s.append(now - at)
                total_tokens += len(h.tokens)
            else:
                still.append((at, h))
        pending = still
    elapsed = time.perf_counter() - t0
    p50, p99 = percentile(lat_s, 50) * 1e6, percentile(lat_s, 99) * 1e6
    tps = total_tokens / elapsed
    emit("serve_latency_p50", p50, f"{tps:.0f} tok/s sustained")
    emit("serve_latency_p99", p99)
    return {
        "n_requests": len(trace), "total_tokens": total_tokens,
        "p50_us": p50, "p99_us": p99,
        "tokens_per_sec": tps, "elapsed_us": elapsed * 1e6,
    }


def run(repeats: int = 3, json_path: str | None = None,
        n_requests: int = 8, batch_slots: int = 4, max_new: int = 24,
        rate_per_s: float = 40.0, seed: int = 0) -> dict:
    cfg = get_config("minicpm-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(batch_slots=batch_slots, max_len=128,
                       max_new_tokens=max_new, seed=seed)
    trace = make_traffic(n_requests, rate_per_s, (4, 8, 12, 24),
                         cfg.vocab, seed=seed)
    prompts = [p for _, p in trace]
    results = {
        "throughput": bench_throughput(cfg, params, scfg, prompts, repeats),
        "latency": bench_latency(cfg, params, scfg, trace),
        "meta": {"batch_slots": batch_slots, "max_new_tokens": max_new,
                 "rate_per_s": rate_per_s, "seed": seed},
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=1)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--rate", type=float, default=40.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, help="write results JSON here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    results = run(repeats=args.repeats, json_path=args.json,
                  n_requests=args.requests, batch_slots=args.slots,
                  max_new=args.max_new, rate_per_s=args.rate, seed=args.seed)
    thr = results["throughput"]
    if not thr["speedup_ok"]:
        raise SystemExit(
            f"continuous-batching speedup {thr['speedup']:.2f}x < 2x over "
            f"the per-request loop at {thr['n_requests']} concurrent requests")


if __name__ == "__main__":
    main()
