# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig1,fig6,fig7,fig9,table1,"
                         "fig11,kernels,roofline,cache,fusion,rewrite,tiling,"
                         "transfer,shard,serve,resilience,online")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()

    from . import (bench_cache, bench_fusion, bench_online, bench_resilience,
                   bench_rewrite, bench_serve, bench_shard, bench_tiling,
                   bench_transfer,
                   fig1_gemm,
                   fig6_robustness, fig7_ablation, fig9_python,
                   fig11_cloudsc_full, kernels_micro, roofline_report,
                   table1_cloudsc)

    suites = {
        "cache": lambda: bench_cache.run(repeats=args.repeats),
        "fusion": lambda: bench_fusion.run(repeats=args.repeats),
        "rewrite": lambda: bench_rewrite.run(repeats=args.repeats),
        "tiling": lambda: bench_tiling.run(repeats=args.repeats),
        "transfer": lambda: bench_transfer.run(repeats=args.repeats),
        "shard": lambda: bench_shard.run(repeats=args.repeats),
        "serve": lambda: bench_serve.run(repeats=args.repeats),
        "resilience": lambda: bench_resilience.run(repeats=args.repeats),
        "online": lambda: bench_online.run(repeats=args.repeats),
        "fig1": lambda: fig1_gemm.run(repeats=args.repeats),
        "fig6": lambda: fig6_robustness.run(repeats=args.repeats),
        "fig7": lambda: fig7_ablation.run(repeats=args.repeats),
        "fig9": lambda: fig9_python.run(repeats=args.repeats),
        "table1": lambda: table1_cloudsc.run(repeats=args.repeats),
        "fig11": lambda: fig11_cloudsc_full.run(repeats=args.repeats),
        "kernels": lambda: kernels_micro.run(repeats=args.repeats),
        "roofline": lambda: roofline_report.run(),
    }
    only = args.only.split(",") if args.only else list(suites)
    unknown = sorted(set(only) - set(suites))
    if unknown:
        ap.error(f"unknown suite(s): {', '.join(unknown)} "
                 f"(valid: {', '.join(suites)})")
    print("name,us_per_call,derived")
    failed = []
    for name in only:
        try:
            suites[name]()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
