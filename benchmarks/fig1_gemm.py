"""Fig. 1 — the motivating GEMM pair: structurally different sources,
divergent baseline performance, equal daisy performance."""
from __future__ import annotations

from repro.core import Daisy
from repro.polybench import BENCHMARKS

from .common import build_baseline, build_daisy, emit, inputs_for, timed


def run(repeats: int = 3, size: str = "bench") -> dict:
    b = BENCHMARKS["gemm"]
    pa, pb = b.make("a", size), b.make("b", size)  # gemm_1 / gemm_2 analogues
    inp = inputs_for(pa)
    daisy = Daisy()
    daisy.seed([pa], search=False)

    t_base_a = timed(build_baseline(pa), inp, repeats)
    t_base_b = timed(build_baseline(pb), inp, repeats)
    fa, _ = build_daisy(daisy, pa)
    fb, _ = build_daisy(daisy, pb)
    t_daisy_a = timed(fa, inp, repeats)
    t_daisy_b = timed(fb, inp, repeats)

    emit("fig1/gemm_1/baseline", t_base_a, "")
    emit("fig1/gemm_2/baseline", t_base_b,
         f"variant_gap=x{max(t_base_a, t_base_b) / min(t_base_a, t_base_b):.2f}")
    emit("fig1/gemm_1/daisy", t_daisy_a, f"x{t_base_a / t_daisy_a:.1f}")
    emit("fig1/gemm_2/daisy", t_daisy_b,
         f"x{t_base_b / t_daisy_b:.1f} "
         f"variant_gap=x{max(t_daisy_a, t_daisy_b) / min(t_daisy_a, t_daisy_b):.2f}")
    return {"base": (t_base_a, t_base_b), "daisy": (t_daisy_a, t_daisy_b)}


if __name__ == "__main__":
    run()
