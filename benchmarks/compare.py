"""Perf-trend comparator: gate every ``bench_*.json`` metric against a
baseline from the previous main-branch run.

The CI ``perf-trend`` job restores the last main-branch bench JSONs from the
actions cache, runs this module against the freshly produced ones, and fails
the build on any metric regressing by more than ``--threshold`` (25% by
default).  Metric direction is inferred from the key:

  * ``*_us`` / ``*us_per_call`` leaves — wall times, **lower** is better;
  * leaves whose name contains ``speedup`` or ends in ``_per_sec``
    (throughputs) — **higher** is better;
  * booleans/counters/shape metadata — ignored (they gate elsewhere).

``--current`` accepts several directories — repeat runs of the same
benchmarks — and gates on the per-metric **median** across them, so a single
noisy shared-runner sample stops tripping the threshold; ``--stat min``
gates on each metric's best sample instead (min for wall times, max for
throughputs) when even the median is too flaky.  The repeat count and the
chosen stat are recorded in the history entry.  ``--history-out`` appends the (medianed)
current metrics to a rolling ``BENCH_history.json`` (one entry per run,
newest last) so the bench trajectory is downloadable as a single artifact
instead of a pile of per-run files.  Pure stdlib on purpose: the comparator
must keep working on a runner where jax is broken — that is exactly the day
it matters.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Any, Iterable

DEFAULT_THRESHOLD = 0.25
HISTORY_KEEP = 200


def flatten_metrics(obj: Any, prefix: str = "") -> dict[str, float]:
    """Numeric leaves of a bench JSON as dotted paths (bools excluded)."""
    out: dict[str, float] = {}
    if isinstance(obj, dict):
        for k, v in sorted(obj.items()):
            out.update(flatten_metrics(v, f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(obj, bool):
        pass
    elif isinstance(obj, (int, float)):
        out[prefix] = float(obj)
    return out


def metric_direction(key: str) -> str | None:
    """'lower' / 'higher' is better, or None for ungated metadata."""
    leaf = key.rsplit(".", 1)[-1]
    if leaf.endswith("_us") or leaf.endswith("us_per_call") or leaf == "us":
        return "lower"
    if "speedup" in leaf or leaf.endswith("_per_sec"):
        return "higher"
    return None


def aggregate_metrics(samples: list[dict[str, float]],
                      stat: str = "median") -> dict[str, float]:
    """Per-metric aggregate across repeat runs; a metric present in only
    some samples aggregates over the samples that have it.

    ``stat='median'`` is the default gate.  ``stat='min'`` takes each gated
    metric's *best* sample — the minimum for lower-is-better wall times, the
    maximum for throughputs/speedups — the flaky-shared-runner stance: a
    run's true capability is its least-interfered sample, so only a
    regression present in every repeat trips the gate.  Ungated metadata
    (direction None) stays at the median either way.
    """
    if stat not in ("median", "min"):
        raise ValueError(f"stat must be median|min, got {stat!r}")
    keys: set[str] = set()
    for s in samples:
        keys.update(s)
    out: dict[str, float] = {}
    for k in sorted(keys):
        vals = sorted(s[k] for s in samples if k in s)
        direction = metric_direction(k)
        if stat == "min" and direction is not None:
            out[k] = vals[0] if direction == "lower" else vals[-1]
            continue
        m = len(vals)
        out[k] = vals[m // 2] if m % 2 else 0.5 * (vals[m // 2 - 1] + vals[m // 2])
    return out


def median_metrics(samples: list[dict[str, float]]) -> dict[str, float]:
    """Back-compat alias: per-metric median across repeat runs."""
    return aggregate_metrics(samples, stat="median")


def collect_dir(path: str) -> dict[str, float]:
    """All metrics of every ``bench_*.json`` under ``path``, keyed
    ``<file-stem>:<dotted.path>``."""
    out: dict[str, float] = {}
    for f in sorted(glob.glob(os.path.join(path, "bench_*.json"))):
        stem = os.path.splitext(os.path.basename(f))[0]
        try:
            with open(f) as fh:
                data = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            print(f"compare: skipping unreadable {f}: {e}", file=sys.stderr)
            continue
        for k, v in flatten_metrics(data).items():
            out[f"{stem}:{k}"] = v
    return out


def load_baseline(path: str) -> dict[str, float]:
    """Baseline metrics from a directory of bench JSONs or a history file
    (the newest entry).  Missing baseline -> empty (first run passes)."""
    if os.path.isdir(path):
        return collect_dir(path)
    if os.path.isfile(path):
        with open(path) as fh:
            hist = json.load(fh)
        if isinstance(hist, list) and hist:
            return {k: float(v) for k, v in hist[-1].get("metrics", {}).items()}
    return {}


def compare(
    baseline: dict[str, float],
    current: dict[str, float],
    threshold: float = DEFAULT_THRESHOLD,
) -> list[dict[str, Any]]:
    """Regressions of ``current`` vs ``baseline`` beyond ``threshold``.

    Only keys present in both sides gate (new benchmarks get a free first
    run; retired ones stop gating); each finding records the ratio by which
    the metric moved in the bad direction.
    """
    bad: list[dict[str, Any]] = []
    for key in sorted(set(baseline) & set(current)):
        direction = metric_direction(key)
        if direction is None:
            continue
        base, cur = baseline[key], current[key]
        if base <= 0 or cur <= 0:
            continue
        ratio = cur / base if direction == "lower" else base / cur
        if ratio > 1.0 + threshold:
            bad.append({"metric": key, "baseline": base, "current": cur,
                        "direction": direction, "ratio": ratio})
    return bad


def merge_history(
    history_path: str,
    metrics: dict[str, float],
    run_id: str,
    keep: int = HISTORY_KEEP,
    repeats: int = 1,
    stat: str = "median",
) -> list[dict[str, Any]]:
    hist: list[dict[str, Any]] = []
    if os.path.isfile(history_path):
        try:
            with open(history_path) as fh:
                loaded = json.load(fh)
            if isinstance(loaded, list):
                hist = loaded
        except (OSError, json.JSONDecodeError):
            hist = []
    hist.append({"run": run_id, "metrics": metrics, "repeats": repeats,
                 "stat": stat})
    hist = hist[-keep:]
    with open(history_path, "w") as fh:
        json.dump(hist, fh, indent=1)
    return hist


def main(argv: Iterable[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True,
                    help="dir of previous bench_*.json, or a BENCH_history.json")
    ap.add_argument("--current", required=True, nargs="+",
                    help="dir(s) holding this run's bench_*.json files; "
                         "several dirs = repeat runs, gated on the median")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative regression that fails the gate (0.25 = 25%%)")
    ap.add_argument("--stat", default="median", choices=("median", "min"),
                    help="repeat-run aggregate to gate on: per-metric median "
                         "(default) or the best sample (min for wall times, "
                         "max for throughputs) — for flaky shared runners")
    ap.add_argument("--history-out", default=None,
                    help="append current metrics to this rolling history JSON")
    ap.add_argument("--run-id", default="local",
                    help="label for the history entry (commit sha)")
    args = ap.parse_args(list(argv) if argv is not None else None)

    samples = [s for s in (collect_dir(d) for d in args.current) if s]
    if not samples:
        print(f"compare: no bench_*.json under {' '.join(args.current)}",
              file=sys.stderr)
        return 2
    current = aggregate_metrics(samples, stat=args.stat)
    if len(samples) > 1:
        print(f"compare: gating on the {args.stat} of {len(samples)} repeat runs")
    baseline = load_baseline(args.baseline)
    if args.history_out:
        merge_history(args.history_out, current, args.run_id,
                      repeats=len(samples), stat=args.stat)
        print(f"history: appended {len(current)} metrics as run '{args.run_id}' "
              f"({args.stat} of {len(samples)} repeats) -> {args.history_out}")
    if not baseline:
        print("compare: no baseline found — first run, all "
              f"{len(current)} metrics recorded, gate passes")
        return 0

    gated = sum(1 for k in set(baseline) & set(current) if metric_direction(k))
    regressions = compare(baseline, current, args.threshold)
    print(f"compare: {gated} gated metrics vs baseline "
          f"({len(current)} current, threshold {args.threshold:.0%})")
    for r in regressions:
        print(f"  REGRESSION {r['metric']}: {r['baseline']:.1f} -> "
              f"{r['current']:.1f} ({r['ratio']:.2f}x worse, "
              f"{r['direction']} is better)")
    if regressions:
        print(f"compare: {len(regressions)} regression(s) beyond "
              f"{args.threshold:.0%}", file=sys.stderr)
        return 1
    print("compare: no regressions")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
