"""Online-adaptation benchmark: a deployment that ships with a stale
pretuned database must tune itself back to speed in-flight.

Scenario (the Performance-Embeddings deployment story):

  * the serving engine fuses a tuned logit post-processing program
    (``repro.autotune.logit_pipeline_program``) into its jitted decode
    step, resolving recipes from a **stale** pretuned database that pins
    every nest to the slow ``sequential`` recipe — the shape of a database
    tuned on different hardware or a different shape regime;
  * the **baseline** run serves traffic with that database untouched (no
    tuner attached: the telemetry hook stays disabled);
  * the **adapting** run attaches a ``SearchSupervisor`` (sync mode, so
    the benchmark is deterministic): step telemetry marks the program hot,
    a deadline-bounded ``evolve_recipe`` search finds the vectorized
    lowering, the validated winner is committed to the live database, and
    the generation-keyed jit cache hot-swaps the step fn mid-traffic.

Gates (CLI exits non-zero on violation):

  * post-adaptation throughput >= 1.2x the never-adapting baseline;
  * served tokens bit-identical between baseline and every adapted round
    — before, across, and after the swap (the logit chain is constructed
    FMA-proof, so every legal lowering produces identical bits);
  * at least one swap actually landed, and the winner survives a
    ``fold_back`` round-trip (fleet database on disk).

Reported metrics (perf-trend gated): ``baseline_tokens_per_sec``,
``adapted_tokens_per_sec``, ``adapt_speedup``; ``time_to_adapt_s`` is
recorded as ungated metadata (it is dominated by one-off jit compiles).
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

import numpy as np

import jax

from repro.autotune import (SearchSupervisor, SwapPolicy,
                            logit_pipeline_program)
from repro.configs import get_config
from repro.core import Daisy, TuningDatabase, fingerprint
from repro.core.embedding import embed_nest
from repro.core.recipes import Recipe
from repro.models import model as M
from repro.serve import ServeConfig, ServingEngine

from .common import emit


def stale_pretuned_db(prog, backend: str = "xla") -> TuningDatabase:
    """Every canonical nest of ``prog`` pinned to ``sequential`` — a
    plausible pretuned artifact from a machine where that recipe won."""
    p = Daisy(backend=backend)._normalized(prog)
    db = TuningDatabase()
    for nest in p.body:
        db.add(fingerprint(nest), embed_nest(p, nest),
               Recipe(kind="sequential", notes="stale"),
               provenance="stale-pretuned", measured_us=2500.0)
    db.meta["backend"] = backend
    return db


def make_prompts(n: int, vocab: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=int(ln)).astype(np.int32)
            for ln in rng.integers(4, 13, size=n)]


def deployment_operands(vocab: int, seed: int = 0) -> dict[str, np.ndarray]:
    """Non-trivial logit-pipeline operands (bias/scale/gain); the floor /
    shift / cap operands stay at the engine's zero-fill defaults."""
    rng = np.random.default_rng(seed)
    return {"B": rng.normal(0.0, 0.5, vocab).astype(np.float32),
            "S": np.full(vocab, 1.1, np.float32),
            "G": np.full(vocab, 0.9, np.float32)}


def drain_round(cfg, params, scfg, prompts, db=None, tuner=None,
                aux=None, prog=None):
    """One closed-loop round: fresh engine (content-keyed jit caches are
    shared across engines, so re-creation costs no retrace), submit every
    prompt, drain.  Returns (results, elapsed_s, tokens)."""
    eng = ServingEngine(cfg, params, scfg, tuning_db=db, tuner=tuner,
                        logit_program=prog, logit_inputs=aux)
    for p in prompts:
        eng.submit(p)
    t0 = time.perf_counter()
    out = eng.drain()
    dt = time.perf_counter() - t0
    return out, dt, sum(len(v) for v in out.values())


def bench_online(cfg, params, scfg, prompts, repeats: int,
                 deadline_s: float = 30.0, seed: int = 0) -> dict:
    prog = logit_pipeline_program(vocab=cfg.vocab, slots=scfg.batch_slots)
    aux = deployment_operands(cfg.vocab, seed=seed)
    kw = dict(aux=aux, prog=prog)

    # -- baseline: the stale database, never adapted -----------------------
    base_db = stale_pretuned_db(prog)
    base_out, _, _ = drain_round(cfg, params, scfg, prompts, db=base_db, **kw)
    base_times = []
    for _ in range(max(1, repeats)):
        out, dt, n_tok = drain_round(cfg, params, scfg, prompts,
                                     db=base_db, **kw)
        assert out == base_out, "baseline run is not deterministic"
        base_times.append(dt)
    # best-of-repeats: scheduler noise only ever inflates a round's wall
    # time, so min is the robust estimator on shared runners (same
    # rationale as `compare.py --stat min`)
    base_s = float(min(base_times))
    base_tps = n_tok / base_s

    # -- adapting: same stale contents, SearchSupervisor attached ----------
    sup = SearchSupervisor(
        stale_pretuned_db(prog), mode="sync", check_every=4,
        iterations=1, population=2, repeats=1, deadline_s=deadline_s,
        policy=SwapPolicy(margin=0.05, min_observations=2))
    t0 = time.perf_counter()
    adapt_rounds = 0
    while not sup.swaps and adapt_rounds < 4:
        out, _, _ = drain_round(cfg, params, scfg, prompts, tuner=sup, **kw)
        adapt_rounds += 1
        assert out == base_out, \
            "tokens diverged from baseline during adaptation"
    time_to_adapt_s = time.perf_counter() - t0
    swapped = len(sup.swaps)

    adapted_times = []
    for _ in range(max(1, repeats)):
        out, dt, _ = drain_round(cfg, params, scfg, prompts, tuner=sup, **kw)
        assert out == base_out, "tokens diverged from baseline after the swap"
        adapted_times.append(dt)
    adapted_s = float(min(adapted_times))
    adapted_tps = n_tok / adapted_s
    speedup = adapted_tps / base_tps

    # -- fleet fold-back round-trip ----------------------------------------
    with tempfile.TemporaryDirectory(prefix="repro-online-") as d:
        fleet = Path(d) / "fleet.json"
        report = sup.fold_back(fleet)
        disk = TuningDatabase.load(fleet)
        folded_ok = bool(
            swapped == 0
            or (disk.meta.get("online_swaps", 0) >= swapped
                and disk.lookup_exact(sup.swaps[0].fingerprint)
                == sup.db.lookup_exact(sup.swaps[0].fingerprint)))

    emit("online_baseline", base_s * 1e6, f"{base_tps:.0f} tok/s (stale db)")
    emit("online_adapted", adapted_s * 1e6,
         f"{adapted_tps:.0f} tok/s speedup={speedup:.2f}x "
         f"swaps={swapped} adapt={time_to_adapt_s:.1f}s")
    return {
        "n_requests": len(prompts), "n_tokens": n_tok,
        "baseline_us": base_s * 1e6, "adapted_us": adapted_s * 1e6,
        "baseline_tokens_per_sec": base_tps,
        "adapted_tokens_per_sec": adapted_tps,
        "adapt_speedup": speedup,
        "speedup_ok": bool(speedup >= 1.2),
        "tokens_match": True,  # asserted on every round above
        "swaps": swapped, "rejected": len(sup.rejected),
        "rolled_back": sum(1 for s in sup.swaps if s.rolled_back),
        "adapt_rounds": adapt_rounds,
        "time_to_adapt_s": time_to_adapt_s,
        "fold_back": dict(report, ok=folded_ok),
    }


def run(repeats: int = 3, json_path: str | None = None, n_requests: int = 8,
        batch_slots: int = 4, max_new: int = 16, deadline_s: float = 30.0,
        seed: int = 0) -> dict:
    cfg = get_config("minicpm-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(batch_slots=batch_slots, max_len=128,
                       max_new_tokens=max_new, seed=seed)
    prompts = make_prompts(n_requests, cfg.vocab, seed=seed)
    results = {
        "online": bench_online(cfg, params, scfg, prompts, repeats,
                               deadline_s=deadline_s, seed=seed),
        "meta": {"batch_slots": batch_slots, "max_new_tokens": max_new,
                 "vocab": cfg.vocab, "seed": seed},
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=1)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--deadline", type=float, default=30.0,
                    help="wall-clock budget per online search (seconds)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, help="write results JSON here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    results = run(repeats=args.repeats, json_path=args.json,
                  n_requests=args.requests, batch_slots=args.slots,
                  max_new=args.max_new, deadline_s=args.deadline,
                  seed=args.seed)
    o = results["online"]
    if o["swaps"] < 1:
        raise SystemExit("online adaptation never swapped a recipe")
    if not o["fold_back"]["ok"]:
        raise SystemExit("fold-back round-trip lost the online winner")
    if not o["speedup_ok"]:
        raise SystemExit(
            f"post-adaptation throughput {o['adapt_speedup']:.2f}x < 1.2x "
            f"the never-adapting baseline")


if __name__ == "__main__":
    main()
