"""Sharded-execution benchmark: 1 vs N forced host devices (PR-5 tentpole).

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the shard
CI job does); with a single device every measurement still runs — the mesh
degenerates and the gate is skipped.

Workloads:

  * **CLOUDSC columns** — the mini scheme at production-ish NPROMA, sharded
    over the horizontal-column axis (the paper's NPROMA posture).  The JK
    recurrence stays a per-shard ``lax.scan``; no collectives at all.  This
    is the gated measurement: ≥1.5x over the 1-device mesh or exit nonzero.
  * **elementwise chain** — a fused multi-stage elementwise nest, the
    bread-and-butter canonical kernel, sharded on its outer iterator.
  * **polybench variants** — gemver (rank-1 updates + two MACs: mixed
    shard/all-reduce plan), atax and bicg (``A^T A x``-style: the psum
    all-reduce path), doitgen; plus jacobi-2d as the *veto demonstration*:
    its time loop carries a cross-shard stencil flow, the planner replicates,
    and the measurement documents parity rather than speedup.

Correctness gates before timing: every workload's sharded lowering is
checked against the ``execute_numpy`` float64 oracle at a reduced size, and
sharded-vs-single outputs are compared at the measured size.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

import jax

from repro.core import Schedule, compile_jax, compile_sharded, execute_numpy
from repro.core.fusion import optimization_pipeline
from repro.core.ir import Array, Computation, Loop, Program, acc
from repro.core.scheduler import random_inputs
from repro.core.util import time_fn
from repro.cloudsc import compile_scheme, mini_cloudsc_program
from repro.cloudsc.scheme import column_mesh, scheme_inputs
from repro.polybench.suite import BENCHMARKS

from .common import emit

PIPE = optimization_pipeline(fuse=True)
SCHED = Schedule(mode="canonical", use_idioms=False, shard_axis="data")


def chain_program(rows: int, cols: int, stages: int = 6,
                  name: str = "shard_chain") -> Program:
    arrays = [Array("X", (rows, cols))] + [
        Array(f"T{s}", (rows, cols)) for s in range(stages)]
    comps = []
    prev = "X"
    for s in range(stages):
        nm = f"T{s}"
        comps.append(Computation(
            f"stage{s}", acc(nm, "i", "j"), (acc(prev, "i", "j"),),
            lambda v, s=s: v * (1.0 + 0.125 * s) + 0.25))
        prev = nm
    nest = Loop("i", rows, body=(Loop("j", cols, body=tuple(comps)),))
    return Program(name, tuple(arrays), (nest,))


def _mesh(n: int):
    return column_mesh(n)


def _check_oracle(norm: Program, mesh, outputs, rtol=1e-4) -> None:
    inp = random_inputs(norm, seed=7, dtype=np.float64)
    ref = execute_numpy(norm, inp)
    fn, _ = compile_sharded(norm, SCHED, mesh=mesh)
    got = jax.jit(fn)({k: np.asarray(v, np.float32) for k, v in inp.items()})
    for k in outputs:
        denom = max(1e-9, np.abs(ref[k]).max())
        rel = np.abs(np.asarray(got[k], np.float64) - ref[k]).max() / denom
        assert rel < rtol, (norm.name, k, rel)


def _measure_pair(norm: Program, mesh, outputs, repeats: int,
                  label: str) -> dict:
    """Single-device vs mesh-sharded wall time for one normalized program."""
    args = {k: v for k, v in random_inputs(norm, dtype=np.float32).items()}
    base = jax.jit(compile_jax(norm, SCHED))
    fn, plan = compile_sharded(norm, SCHED, mesh=mesh)
    fnj = jax.jit(fn)
    r1, rn = base(args), fnj(args)
    for k in outputs:
        denom = max(1e-9, np.abs(np.asarray(r1[k], np.float64)).max())
        rel = np.abs(np.asarray(rn[k], np.float64)
                     - np.asarray(r1[k], np.float64)).max() / denom
        # psum reassociates large fp32 reductions; tolerance, not bit-equal
        assert rel < 1e-3, (label, k, rel)
    t1 = time_fn(lambda: base(args), repeats=repeats)
    tn = time_fn(lambda: fnj(args), repeats=repeats)
    sharded = sum(1 for x in plan.nests if x.iterator is not None)
    speedup = t1 / max(tn, 1e-9)
    emit(f"{label}_1dev", t1)
    emit(f"{label}_{plan.n_shards}dev", tn,
         f"speedup={speedup:.2f}x sharded_nests={sharded}/{len(plan.nests)}")
    return {"single_us": t1, "sharded_us": tn, "speedup": speedup,
            "sharded_nests": sharded, "nests": len(plan.nests)}


def bench_cloudsc(repeats: int, nproma: int, klev: int, mesh) -> dict:
    checks = ("PFPLSL", "TENDQ", "ZTP1")
    small = PIPE.run(mini_cloudsc_program(64, 6))
    sinp = scheme_inputs(64, 6)
    ref = execute_numpy(small, sinp)
    fn_s, _ = compile_scheme(64, 6, mesh=mesh)
    got = fn_s({k: np.asarray(v, np.float32) for k, v in sinp.items()})
    for k in checks:
        denom = max(1e-9, np.abs(ref[k]).max())
        rel = np.abs(np.asarray(got[k], np.float64) - ref[k]).max() / denom
        assert rel < 1e-4, ("cloudsc", k, rel)

    args = {k: np.asarray(v, np.float32)
            for k, v in scheme_inputs(nproma, klev).items()}
    fn1, _ = compile_scheme(nproma, klev, mesh=None)
    fnn, plan = compile_scheme(nproma, klev, mesh=mesh)
    r1, rn = fn1(args), fnn(args)
    out1 = {k: np.asarray(r1[k]) for k in checks}
    outn = {k: np.asarray(rn[k]) for k in checks}
    for k in checks:
        denom = max(1e-9, np.abs(out1[k]).max())
        assert np.abs(outn[k].astype(np.float64)
                      - out1[k].astype(np.float64)).max() / denom < 1e-5
    t1 = time_fn(lambda: fn1(args), repeats=repeats)
    tn = time_fn(lambda: fnn(args), repeats=repeats)
    speedup = t1 / max(tn, 1e-9)
    emit("cloudsc_columns_1dev", t1, "single device")
    emit(f"cloudsc_columns_{plan.n_shards}dev", tn, f"speedup={speedup:.2f}x")
    return {"single_us": t1, "sharded_us": tn, "speedup": speedup,
            "devices": plan.n_shards,
            "speedup_ok": bool(speedup >= 1.5 or plan.n_shards < 2)}


def bench_chain(repeats: int, rows: int, cols: int, mesh) -> dict:
    _check_oracle(PIPE.run(chain_program(32, 48)), mesh, ("T5",))
    norm = PIPE.run(chain_program(rows, cols))
    return _measure_pair(norm, mesh, ("T5",), repeats, "chain")


def bench_polybench(repeats: int, mesh) -> dict:
    out: dict[str, dict] = {}
    n = int(mesh.shape["data"])
    # small shapes for the float64 oracle, bench shapes for timing; the
    # small extents stay divisible by the mesh so the same plan shape
    # (including the all-reduce) is what the oracle validates
    # atax/bicg stay rectangular: with m == n the canonical zero-fill nests
    # of the two vectors fuse into one nest whose shard iterator would need
    # both vectors aligned, while the MAC nests need one of them replicated
    # for the all-reduce — the planner then (correctly) replicates
    # everything.  Distinct extents keep the fills separate and the psum
    # path live, matching the paper's rectangular ATAX/BiCG shapes.
    cases = {
        "gemver": (dict(n=8 * n), dict(n=2048)),
        "atax": (dict(m=8 * n, n=12 * n), dict(m=2048, n=1536)),
        "bicg": (dict(n=8 * n, m=12 * n), dict(n=2048, m=1536)),
        "doitgen": (dict(nr=2 * n, nq=10, np=12), dict(nr=512, nq=32, np=32)),
        "jacobi-2d": (dict(n=14, t=4), dict(n=1000, t=10)),  # veto demo
    }
    for name, (small_sz, bench_sz) in cases.items():
        bench = BENCHMARKS[name]
        make = bench.variants["a"]
        _check_oracle(PIPE.run(make(small_sz)), mesh, (bench.output,))
        norm = PIPE.run(make(bench_sz))
        out[name] = _measure_pair(norm, mesh, (bench.output,), repeats,
                                  name.replace("-", ""))
    return out


def run(repeats: int = 3, json_path: str | None = None,
        nproma: int = 8192, klev: int = 137,
        rows: int = 4096, cols: int = 2048) -> dict:
    n = jax.device_count()
    mesh = _mesh(n)
    results = {
        "devices": n,
        "cloudsc": bench_cloudsc(repeats, nproma, klev, mesh),
        "chain": bench_chain(repeats, rows, cols, mesh),
        "polybench": bench_polybench(repeats, mesh),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=1)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--nproma", type=int, default=8192)
    ap.add_argument("--klev", type=int, default=137)
    ap.add_argument("--rows", type=int, default=4096)
    ap.add_argument("--cols", type=int, default=2048)
    ap.add_argument("--json", default=None, help="write results JSON here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    results = run(repeats=args.repeats, json_path=args.json,
                  nproma=args.nproma, klev=args.klev,
                  rows=args.rows, cols=args.cols)
    cs = results["cloudsc"]
    if not cs["speedup_ok"]:
        raise SystemExit(
            f"sharded CLOUDSC columns speedup {cs['speedup']:.2f}x < 1.5x "
            f"over 1 device ({cs['devices']} devices)")


if __name__ == "__main__":
    main()
