"""Microbenchmark for the COFFEE-style rewrite passes (PR-10 tentpole).

Two workload families, one per rewrite mechanism:

* **LICM** — the multi-species CLOUDSC saturation chain
  (:func:`repro.cloudsc.saturation_chain_program`): four banded JK-carried
  flux recurrences whose wet-bulb source reads per-level ``TREF``/``PREF``
  slices.  XLA cannot hoist the source (it is a per-step ``xs`` slice of
  each ``lax.scan``) nor share it across the four separate scans;
  ``LICMPass`` computes it once into one shared ``(klev, nproma)`` temp.
  This leg is the CI gate: >= 1.3x over the identical pipeline with
  ``rewrite=False``, with the transformed program proven **bit-identical**
  to the untransformed one on the float64 ``execute_numpy`` oracle at a
  reduced size, and the two jitted variants bit-identical to each other at
  the bench size (LICM runs the same float ops, just once).

* **Expansion** — 2mm/gemver variants whose contraction carries a sum
  factor (``(A + E) * (alpha*B)``).  As written the accumulation is not a
  pure product, so idiom detection classifies it ``reduction`` and the
  nest lowers to a broadcast-and-sum; ``ExpandFactorPass`` distributes it
  into pure-product siblings that each dispatch as ``blas3``/``blas2``
  einsums.  Expansion reassociates the additions, so these legs gate on
  the float64 oracle with ``allclose`` and on a scale-relative comparison
  of the two jitted variants (reported, not hard-gated: einsum dispatch is
  measured elsewhere).

CSV rows (plus optional JSON for the CI artifact):

  rewrite_sat_norewrite / rewrite_sat_rewrite       — the gated LICM leg
  rewrite_2mm_norewrite / rewrite_2mm_rewrite       — expansion, blas3
  rewrite_gemver_norewrite / rewrite_gemver_rewrite — expansion, blas2
"""
from __future__ import annotations

import argparse
import json

import numpy as np

import jax

from repro.cloudsc import saturation_chain_inputs, saturation_chain_program
from repro.cloudsc.scheme import SPECIES
from repro.core import (
    Array,
    Computation,
    Loop,
    Program,
    Schedule,
    acc,
    compile_jax,
    execute_numpy,
    optimization_pipeline,
)
from repro.core.ir import Const, Read
from repro.core.passes import PassContext
from repro.core.util import time_fn

from .common import emit

ALPHA, BETA = 1.5, 1.2
ZERO = Const(0.0)
SAT_GATE = 1.3


# ---------------------------------------------------------------------------
# expansion-leg builders: contractions with a sum factor
# ---------------------------------------------------------------------------
def mm2_sum_program(ni: int, nj: int, nk: int, nl: int) -> Program:
    """2mm variant: ``tmp += (A+E) * (alpha*B); D = beta*D + tmp@C2``.

    The first contraction's expression is a *sum* times a matrix, so the
    as-written nest is not multiplicative and cannot idiom-dispatch;
    expansion splits it into two pure-product matmuls.
    """
    arrays = (Array("A", (ni, nk)), Array("E", (ni, nk)), Array("B", (nk, nj)),
              Array("C2", (nj, nl)), Array("D", (ni, nl)),
              Array("tmp", (ni, nj)))
    z = Computation("zero", acc("tmp", "i", "j"), (), ZERO)
    m1 = Computation(
        "m1", acc("tmp", "i", "j"),
        (acc("A", "i", "k"), acc("E", "i", "k"), acc("B", "k", "j")),
        (Read(0) + Read(1)) * (ALPHA * Read(2)), accumulate="+")
    sc = Computation("sc", acc("D", "p", "q"), (acc("D", "p", "q"),),
                     Read(0) * BETA)
    m2 = Computation(
        "m2", acc("D", "p", "q"),
        (acc("tmp", "p", "r"), acc("C2", "r", "q")),
        Read(0) * Read(1), accumulate="+")
    return Program("2mm_sum", arrays, (
        Loop("i", ni, body=(Loop("j", nj, body=(
            z, Loop("k", nk, body=(m1,)))),)),
        Loop("p", ni, body=(Loop("q", nl, body=(
            sc, Loop("r", nj, body=(m2,)))),)),
    ), temps=("tmp",))


def gemver_sum_program(n: int) -> Program:
    """gemver variant: both matvecs read the rank-updated ``A + B2`` sum."""
    arrays = (Array("A", (n, n)), Array("B2", (n, n)), Array("w", (n,)),
              Array("x", (n,)), Array("y", (n,)), Array("z", (n,)))
    x_up = Computation(
        "x_up", acc("x", "j2"),
        (acc("A", "i2", "j2"), acc("B2", "i2", "j2"), acc("y", "i2")),
        (Read(0) + Read(1)) * (BETA * Read(2)), accumulate="+")
    x_z = Computation("x_z", acc("x", "j3"), (acc("x", "j3"), acc("z", "j3")),
                      Read(0) + Read(1))
    w_up = Computation(
        "w_up", acc("w", "i4"),
        (acc("A", "i4", "j4"), acc("B2", "i4", "j4"), acc("x", "j4")),
        (Read(0) + Read(1)) * (ALPHA * Read(2)), accumulate="+")
    return Program("gemver_sum", arrays, (
        Loop("i2", n, body=(Loop("j2", n, body=(x_up,)),)),
        Loop("j3", n, body=(x_z,)),
        Loop("i4", n, body=(Loop("j4", n, body=(w_up,)),)),
    ))


def _sum_inputs(prog: Program, seed: int = 0,
                dtype=np.float64) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    zero = {"w", "x"}
    return {
        a.name: (np.zeros(a.shape, dtype) if a.name in zero
                 else rng.uniform(-1.0, 1.0, size=a.shape).astype(dtype))
        for a in prog.arrays if a.name not in prog.temps
    }


# ---------------------------------------------------------------------------
# measurement helpers
# ---------------------------------------------------------------------------
def _jit_outputs(program: Program, sched: Schedule, outs: list[str]):
    body = compile_jax(program, sched)
    return jax.jit(lambda a: {k: body(a)[k] for k in outs})


def _oracle_bit_identical(build, outs: list[str], inputs: dict) -> None:
    """Reduced-size float64 gate: both pipelines == the untransformed nests."""
    prog = build()
    ref = execute_numpy(prog, dict(inputs))
    for rw in (True, False):
        variant = optimization_pipeline(fuse=True, rewrite=rw).run(prog)
        got = execute_numpy(variant, dict(inputs))
        for k in outs:
            assert np.array_equal(got[k], ref[k]), (prog.name, rw, k)


def _oracle_allclose(build, outs: list[str], inputs: dict) -> None:
    """Reduced-size float64 gate for the reassociating expansion legs."""
    prog = build()
    ref = execute_numpy(prog, dict(inputs))
    got = execute_numpy(
        optimization_pipeline(fuse=True, rewrite=True).run(prog), dict(inputs))
    for k in outs:
        assert np.allclose(got[k], ref[k], rtol=1e-10, atol=1e-12), \
            (prog.name, k)


def _expansion_leg(name: str, prog: Program, outs: list[str],
                   repeats: int) -> dict:
    ctx = PassContext()
    rw = optimization_pipeline(fuse=True, rewrite=True).run(prog, ctx)
    no = optimization_pipeline(fuse=True, rewrite=False).run(prog)
    expanded = ctx.stat("expand_factor", "expanded", 0)
    assert expanded, f"{name}: ExpandFactorPass split nothing"

    sched = Schedule(mode="canonical", use_idioms=True)
    ins = _sum_inputs(prog, dtype=np.float32)
    fn_no = _jit_outputs(no, sched, outs)
    fn_rw = _jit_outputs(rw, sched, outs)
    r_no, r_rw = fn_no(ins), fn_rw(ins)
    for k in outs:
        a, b = np.asarray(r_no[k]), np.asarray(r_rw[k])
        scale = float(np.max(np.abs(a))) or 1.0
        assert np.allclose(a, b, rtol=0.0, atol=1e-5 * scale), (name, k)
    no_us = time_fn(lambda: fn_no(ins), repeats=repeats)
    rw_us = time_fn(lambda: fn_rw(ins), repeats=repeats)
    speedup = no_us / max(rw_us, 1e-9)
    emit(f"rewrite_{name}_norewrite", no_us)
    emit(f"rewrite_{name}_rewrite", rw_us,
         f"expanded={expanded},speedup={speedup:.2f}x")
    return {f"{name}_norewrite_us": no_us, f"{name}_rewrite_us": rw_us,
            f"{name}_expanded": expanded, f"{name}_speedup": speedup}


def run(repeats: int = 5, json_path: str | None = None,
        nproma: int = 2048, klev: int = 137, iters: int = 3) -> dict:
    sat_outs = [f"PFLUX_{nm}" for nm, _, _ in SPECIES] + ["TEND"]

    # -- gated LICM leg: the multi-species saturation chain ------------------
    _oracle_bit_identical(
        lambda: saturation_chain_program(64, 17, iters=iters), sat_outs,
        saturation_chain_inputs(64, 17, seed=1))

    prog = saturation_chain_program(nproma, klev, iters=iters)
    ctx = PassContext()
    rw = optimization_pipeline(fuse=True, rewrite=True).run(prog, ctx)
    no = optimization_pipeline(fuse=True, rewrite=False).run(prog)
    hoisted = ctx.stat("licm", "hoisted", 0)
    reused = ctx.stat("licm", "reused", 0)
    assert hoisted, "LICMPass hoisted nothing from the saturation chain"

    sched = Schedule(mode="canonical", use_idioms=False, scan=True)
    ins = {k: v.astype(np.float32)
           for k, v in saturation_chain_inputs(nproma, klev).items()}
    fn_no = _jit_outputs(no, sched, sat_outs)
    fn_rw = _jit_outputs(rw, sched, sat_outs)
    r_no, r_rw = fn_no(ins), fn_rw(ins)
    for k in sat_outs:  # same float ops, just fewer of them -> bit-identical
        assert np.array_equal(np.asarray(r_no[k]), np.asarray(r_rw[k])), k
    no_us = time_fn(lambda: fn_no(ins), repeats=repeats)
    rw_us = time_fn(lambda: fn_rw(ins), repeats=repeats)
    speedup = no_us / max(rw_us, 1e-9)
    emit("rewrite_sat_norewrite", no_us,
         f"flops={ctx.stat('licm', 'flops_before', 0)}")
    emit("rewrite_sat_rewrite", rw_us,
         f"flops={ctx.stat('licm', 'flops_after', 0)},hoisted={hoisted},"
         f"reused={reused},speedup={speedup:.2f}x")

    results = {
        "nproma": nproma, "klev": klev, "iters": iters,
        "sat_norewrite_us": no_us, "sat_rewrite_us": rw_us,
        "sat_speedup": speedup,
        "licm_hoisted": hoisted, "licm_reused": reused,
        "licm_flops_before": ctx.stat("licm", "flops_before", 0),
        "licm_flops_after": ctx.stat("licm", "flops_after", 0),
        "speedup_ok": bool(speedup >= SAT_GATE),
        "pass_seconds": {r.name: r.seconds for r in ctx.records},
    }

    # -- expansion legs: reported, value-checked -----------------------------
    _oracle_allclose(lambda: mm2_sum_program(8, 9, 10, 11), ["D"],
                     _sum_inputs(mm2_sum_program(8, 9, 10, 11), seed=2))
    _oracle_allclose(lambda: gemver_sum_program(12), ["w", "x"],
                     _sum_inputs(gemver_sum_program(12), seed=3))
    results.update(_expansion_leg(
        "2mm", mm2_sum_program(256, 256, 256, 256), ["D"], repeats))
    results.update(_expansion_leg(
        "gemver", gemver_sum_program(2000), ["w", "x"], repeats))

    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=1)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--nproma", type=int, default=2048)
    ap.add_argument("--klev", type=int, default=137)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--json", default=None, help="write results JSON here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    results = run(repeats=args.repeats, json_path=args.json,
                  nproma=args.nproma, klev=args.klev, iters=args.iters)
    if not results["speedup_ok"]:
        raise SystemExit(
            f"saturation-chain rewrite speedup {results['sat_speedup']:.2f}x "
            f"< {SAT_GATE}x over the no-rewrite pipeline")


if __name__ == "__main__":
    main()
