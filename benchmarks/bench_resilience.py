"""Resilience benchmark: open-loop serving traffic with injected faults.

Replays the same seeded open-loop trace twice against the continuous-
batching engine — once fault-free, once with a seeded ``FaultPlan``
poisoning a fixed fraction of requests (decode errors, one prefill-NaN)
— and gates that the engine degrades *gracefully*:

  * every request untouched by a fault completes **token-for-token
    identical** to the fault-free run (isolation is bit-exact, not just
    "didn't crash");
  * surviving-request throughput (tokens of the surviving subset / that
    run's elapsed time) stays >= ``--min-survivor-tps-ratio`` (default
    0.8x) of the same subset's fault-free throughput;
  * surviving-request p50/p99 latency is reported (``*_us`` metrics join
    the perf-trend gate like every other benchmark).

A third measurement exercises the hot-swap guardrail:
``compile_with_degradation`` with an injected Pallas compile failure must
fall through to the ``xla`` rung, and the degraded compile's wall time is
reported (the cost of a backend fallback during live tuning).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax

from repro.configs import get_config
from repro.fault import Fault, FaultPlan, compile_with_degradation
from repro.models import model as M
from repro.serve import RequestState, ServeConfig, ServingEngine

from .bench_serve import make_traffic, percentile
from .common import emit


def pick_victims(n: int, fault_rate: float, seed: int) -> set[int]:
    """Seeded choice of which rids the fault plan poisons (at least one
    decode victim and one prefill-NaN victim when the trace allows)."""
    rng = np.random.default_rng(seed + 1)
    k = max(2, int(round(n * fault_rate)))
    k = min(k, max(1, n - 1))  # always leave at least one survivor
    return set(int(i) for i in rng.choice(n, size=k, replace=False))


def make_plan(victims: set[int]) -> FaultPlan:
    """One prefill-NaN victim, decode errors for the rest."""
    vs = sorted(victims)
    faults = [Fault("serve.prefill", "nan", key=vs[0])]
    faults += [Fault("serve.decode", "error", key=rid) for rid in vs[1:]]
    return FaultPlan(faults)


def replay(cfg, params, scfg: ServeConfig, trace,
           fault_plan: FaultPlan | None = None) -> dict:
    """Open-loop replay (arrivals fixed by the trace); returns per-request
    outcomes, tokens, latencies, and the run's elapsed time."""
    eng = ServingEngine(cfg, params, scfg, fault_plan=fault_plan)
    pending: list[tuple[float, object]] = []
    done: dict[int, object] = {}
    lat_s: dict[int, float] = {}
    i = 0
    t0 = time.perf_counter()
    while i < len(trace) or pending:
        now = time.perf_counter() - t0
        while i < len(trace) and trace[i][0] <= now:
            at, prompt = trace[i]
            pending.append((at, eng.submit(prompt, rid=i)))
            i += 1
        if not pending:
            time.sleep(min(max(trace[i][0] - now, 0.0), 0.001))
            continue
        eng.step()
        now = time.perf_counter() - t0
        still = []
        for at, h in pending:
            if h.done:
                done[h.rid] = h
                lat_s[h.rid] = now - at
            else:
                still.append((at, h))
        pending = still
    elapsed = time.perf_counter() - t0
    return {
        "handles": done, "latency_s": lat_s, "elapsed_s": elapsed,
        "tokens": {rid: list(h.tokens) for rid, h in done.items()},
        "states": {rid: h.state for rid, h in done.items()},
    }


def bench_traffic(cfg, params, scfg: ServeConfig, trace,
                  fault_rate: float, seed: int, repeats: int) -> dict:
    victims = pick_victims(len(trace), fault_rate, seed)
    survivors = sorted(set(range(len(trace))) - victims)

    def survivor_stats(run: dict) -> tuple[float, list[float]]:
        toks = sum(len(run["tokens"][rid]) for rid in survivors)
        return toks / run["elapsed_s"], [run["latency_s"][rid] for rid in survivors]

    replay(cfg, params, scfg, trace)  # warmup: pay the jit traces untimed

    free_tps, faulty_tps, p50s, p99s, identical = [], [], [], [], True
    failed_as_expected = True
    for _ in range(max(1, repeats)):
        free = replay(cfg, params, scfg, trace)
        faulty = replay(cfg, params, scfg, trace, fault_plan=make_plan(victims))
        # bit-exact isolation: survivors unaffected by their neighbours' faults
        identical &= all(
            faulty["tokens"][rid] == free["tokens"][rid] for rid in survivors)
        failed_as_expected &= all(
            faulty["states"][rid] is RequestState.FAILED for rid in victims)
        f_tps, _ = survivor_stats(free)
        s_tps, s_lat = survivor_stats(faulty)
        free_tps.append(f_tps)
        faulty_tps.append(s_tps)
        p50s.append(percentile(s_lat, 50) * 1e6)
        p99s.append(percentile(s_lat, 99) * 1e6)
    tps_free = float(np.median(free_tps))
    tps_faulty = float(np.median(faulty_tps))
    ratio = tps_faulty / tps_free
    p50, p99 = float(np.median(p50s)), float(np.median(p99s))
    emit("resilience_survivor_tokens_per_sec", 1e6 / max(tps_faulty, 1e-9),
         f"{tps_faulty:.0f} tok/s ({ratio:.2f}x of fault-free)")
    emit("resilience_survivor_p50_us", p50)
    emit("resilience_survivor_p99_us", p99)
    return {
        "n_requests": len(trace), "n_victims": len(victims),
        "survivor_tokens_per_sec": tps_faulty,
        "survivor_tokens_per_sec_fault_free": tps_free,
        "survivor_tps_ratio": ratio,
        "survivor_p50_us": p50, "survivor_p99_us": p99,
        "survivors_identical": bool(identical),
        "victims_failed": bool(failed_as_expected),
    }


def bench_degradation(repeats: int) -> dict:
    """Injected Pallas compile failure -> xla rung; time the fallback."""
    from repro.tools.tune import build_program

    prog = build_program("cloudsc", "erosion", "mini")
    times = []
    for _ in range(max(1, repeats)):
        plan = FaultPlan([Fault("daisy.compile", "error", key="pallas_interpret")])
        t0 = time.perf_counter()
        res = compile_with_degradation(
            prog, backends=("pallas_interpret", "xla"), fault_plan=plan)
        times.append(time.perf_counter() - t0)
        assert res.degraded and res.backend == "xla", (
            f"degradation chain did not fall through: {res.backend}")
    us = float(np.median(times)) * 1e6
    emit("resilience_degraded_compile_us", us, "pallas->xla fallback")
    return {"degraded_compile_us": us, "backend": "xla", "degraded": True}


def run(repeats: int = 3, json_path: str | None = None,
        n_requests: int = 8, batch_slots: int = 4, max_new: int = 16,
        rate_per_s: float = 40.0, fault_rate: float = 0.25,
        min_ratio: float = 0.8, seed: int = 0) -> dict:
    cfg = get_config("minicpm-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(batch_slots=batch_slots, max_len=128,
                       max_new_tokens=max_new, seed=seed)
    trace = make_traffic(n_requests, rate_per_s, (4, 8, 12), cfg.vocab,
                         seed=seed)
    results = {
        "traffic": bench_traffic(cfg, params, scfg, trace, fault_rate, seed,
                                 repeats),
        "degradation": bench_degradation(repeats),
        "meta": {"batch_slots": batch_slots, "max_new_tokens": max_new,
                 "rate_per_s": rate_per_s, "fault_rate": fault_rate,
                 "min_survivor_tps_ratio": min_ratio, "seed": seed},
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=1)
    t = results["traffic"]
    if not t["survivors_identical"]:
        raise SystemExit("survivor outputs diverged from the fault-free run "
                         "— fault isolation is not request-scoped")
    if not t["victims_failed"]:
        raise SystemExit("an injected-fault request did not transition to "
                         "FAILED")
    if t["survivor_tps_ratio"] < min_ratio:
        raise SystemExit(
            f"degraded-mode survivor throughput "
            f"{t['survivor_tokens_per_sec']:.0f} tok/s is "
            f"{t['survivor_tps_ratio']:.2f}x of fault-free "
            f"(< {min_ratio:.2f}x)")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--rate", type=float, default=40.0)
    ap.add_argument("--fault-rate", type=float, default=0.25)
    ap.add_argument("--min-survivor-tps-ratio", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, help="write results JSON here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(repeats=args.repeats, json_path=args.json, n_requests=args.requests,
        batch_slots=args.slots, max_new=args.max_new, rate_per_s=args.rate,
        fault_rate=args.fault_rate, min_ratio=args.min_survivor_tps_ratio,
        seed=args.seed)


if __name__ == "__main__":
    main()
