"""Table 1 — the erosion-of-clouds nest: original vs normalized.

Reports runtime for a single vertical iteration (klev=1) and the full KLEV
sweep, plus the analytic working-set metric (the L1 loads/evicts analogue):
the original keeps every scalar live across the fused body; the normalized
form streams (NPROMA,) arrays per fissioned stage.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.cloudsc import erosion_program
from repro.cloudsc.erosion import physical_inputs
from repro.core import Schedule, compile_jax, normalize
from repro.core.ir import loop_iterators, walk
from repro.core.util import time_fn

from .common import emit

NPROMA, KLEV = 128, 137


def working_set_metric(prog) -> dict:
    """Bytes touched per innermost iteration group (streaming estimate)."""
    n_nests = len(prog.body)
    live = set()
    for nest in prog.body:
        for _, c in walk(nest):
            for a in c.accesses():
                live.add(a.array)
    return {"nests": n_nests, "containers": len(live)}


def run(repeats: int = 3) -> dict:
    out = {}
    for klev, tag in ((1, "single_iter"), (KLEV, "klev_iters")):
        p = erosion_program(nproma=NPROMA, klev=klev)
        pn = normalize(p)
        inp = {k: np.asarray(v, np.float32) for k, v in physical_inputs(NPROMA, klev).items()}
        f_orig = jax.jit(compile_jax(p, Schedule(mode="as_written", use_idioms=False)))
        f_norm = jax.jit(compile_jax(pn, Schedule(mode="canonical", use_idioms=False)))
        r1, r2 = f_orig(inp), f_norm(inp)
        err = float(np.abs(np.asarray(r1["ZTP1"], np.float64)
                           - np.asarray(r2["ZTP1"], np.float64)).max())
        t_orig = time_fn(lambda: f_orig(inp), repeats=repeats)
        t_norm = time_fn(lambda: f_norm(inp), repeats=repeats)
        emit(f"table1/{tag}/original", t_orig, "")
        emit(f"table1/{tag}/normalized", t_norm,
             f"x{t_orig / t_norm:.1f} maxerr={err:.1e}")
        out[tag] = (t_orig, t_norm)
    ws_orig = working_set_metric(erosion_program(nproma=NPROMA, klev=KLEV))
    ws_norm = working_set_metric(normalize(erosion_program(nproma=NPROMA, klev=KLEV)))
    emit("table1/working_set", 0.0,
         f"orig_nests={ws_orig['nests']} norm_nests={ws_norm['nests']} "
         f"(fission exposes per-stage streaming; paper: L1 evicts 963->178)")
    return out


if __name__ == "__main__":
    run()
