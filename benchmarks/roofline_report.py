"""Roofline rows from the dry-run artifacts (one per arch x shape x mesh)."""
from __future__ import annotations

from pathlib import Path

from repro.launch.roofline import load_cells

from .common import emit


def run(outdir: str = "dryrun_out") -> None:
    if not Path(outdir).exists():
        emit("roofline/missing", 0.0, f"no {outdir}/ — run repro.launch.dryrun first")
        return
    for c in load_cells(outdir):
        name = f"roofline/{c.arch}/{c.shape}/{c.mesh}"
        if c.status != "ok":
            emit(name, 0.0, c.status)
            continue
        emit(
            name,
            c.step_time * 1e6,  # the dominant-term step time in us
            f"compute={c.compute_s:.3e}s memory={c.memory_s:.3e}s "
            f"collective={c.collective_s:.3e}s dominant={c.dominant} "
            f"useful={c.useful_ratio:.2f}",
        )


if __name__ == "__main__":
    run()
