"""Fig. 11 — the full mini-CLOUDSC scheme: daisy vs the as-written code.

The paper reports daisy 1.08x over tuned Fortran sequentially; here the
comparison is daisy's normalized+vectorized lowering vs the as-written
lowering of the same IR on the same backend (relative speedups are the
reproduction target, DESIGN.md §8).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.cloudsc import mini_cloudsc_program
from repro.cloudsc.scheme import scheme_inputs
from repro.core import Schedule, compile_jax, normalize
from repro.core.util import time_fn

from .common import emit

NPROMA, KLEV = 128, 137


def run(repeats: int = 3) -> dict:
    p = mini_cloudsc_program(nproma=NPROMA, klev=KLEV)
    pn = normalize(p)
    inp = {k: np.asarray(v, np.float32) for k, v in scheme_inputs(NPROMA, KLEV).items()}
    f_orig = jax.jit(compile_jax(p, Schedule(mode="as_written", use_idioms=False)))
    f_daisy = jax.jit(compile_jax(pn, Schedule(mode="canonical", use_idioms=False)))
    r1, r2 = f_orig(inp), f_daisy(inp)
    err = float(np.abs(np.asarray(r1["TENDQ"], np.float64)
                       - np.asarray(r2["TENDQ"], np.float64)).max())
    t_orig = time_fn(lambda: f_orig(inp), repeats=repeats)
    t_daisy = time_fn(lambda: f_daisy(inp), repeats=repeats)
    emit("fig11/mini_cloudsc/as_written", t_orig, "")
    emit("fig11/mini_cloudsc/daisy", t_daisy, f"x{t_orig / t_daisy:.1f} maxerr={err:.1e}")
    return {"orig": t_orig, "daisy": t_daisy}


if __name__ == "__main__":
    run()
