"""Kernel microbenches: XLA-path wall time on CPU + interpret-mode checks.

Interpret mode executes the kernel body in Python (correctness only); the
wall numbers that matter for the TPU target come from the roofline analysis
(benchmarks/roofline_report.py), not CPU timing.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.core.util import time_fn

from .common import emit


def run(repeats: int = 5) -> None:
    rng = np.random.default_rng(0)
    for m, n, k in ((512, 512, 512), (1024, 1024, 512)):
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        y = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        f = jax.jit(lambda a, b: ref.matmul(a, b))
        t = time_fn(lambda: f(x, y), repeats=repeats)
        flops = 2 * m * n * k
        emit(f"kernels/gemm_xla_{m}x{n}x{k}", t, f"gflops={flops / t / 1e3:.1f}")
    for bh, s, d in ((8, 1024, 64), (8, 2048, 64)):
        q = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.float32)
        f = jax.jit(lambda q: ops.attention(q, q, q, causal=True))
        t = time_fn(lambda: f(q), repeats=repeats)
        emit(f"kernels/attn_xla_bh{bh}_s{s}", t, "")
    # interpret-mode correctness spot checks (already swept in tests/)
    x = rng.normal(size=(128, 96)).astype(np.float32)
    y = rng.normal(size=(96, 64)).astype(np.float32)
    err = float(np.abs(np.asarray(ops.matmul(x, y, backend="pallas_interpret",
                                             tile=(32, 32, 32))) - x @ y).max())
    emit("kernels/gemm_pallas_interpret_err", 0.0, f"maxerr={err:.1e}")


if __name__ == "__main__":
    run()
