"""Fig. 7 — ablation: clang / tuning-only / normalization-only / full daisy.

Shows both components are required: without normalization the database
misses (structure mismatch); without the recipes the canonical form is not
enough to reach the best schedules.
"""
from __future__ import annotations

import numpy as np

from repro.core import Daisy
from repro.polybench import BENCHMARKS

from .common import (
    build_baseline, build_daisy, build_norm_only, build_sched_raw, emit,
    inputs_for, timed,
)

SUBSET = ("gemm", "2mm", "3mm", "bicg", "gemver", "jacobi-2d", "fdtd-2d", "syrk")


def run(repeats: int = 3, size: str = "bench") -> dict:
    daisy = Daisy()
    daisy.seed([BENCHMARKS[n].make("a", size) for n in SUBSET], search=False)
    speedups: dict[str, list[float]] = {"sched_raw": [], "norm_only": [], "daisy": []}
    for name in SUBSET:
        b = BENCHMARKS[name]
        for var in ("a", "b"):
            prog = b.make(var, size)
            inp = inputs_for(prog)
            t_base = timed(build_baseline(prog), inp, repeats)
            t_raw = timed(build_sched_raw(prog), inp, repeats)
            t_norm = timed(build_norm_only(prog), inp, repeats)
            fd, _ = build_daisy(daisy, prog)
            t_daisy = timed(fd, inp, repeats)
            emit(f"fig7/{name}_{var}/clang", t_base, "")
            emit(f"fig7/{name}_{var}/tuning_only", t_raw, f"x{t_base / t_raw:.2f}")
            emit(f"fig7/{name}_{var}/norm_only", t_norm, f"x{t_base / t_norm:.2f}")
            emit(f"fig7/{name}_{var}/daisy", t_daisy, f"x{t_base / t_daisy:.2f}")
            speedups["sched_raw"].append(t_base / t_raw)
            speedups["norm_only"].append(t_base / t_norm)
            speedups["daisy"].append(t_base / t_daisy)
    out = {}
    for k, v in speedups.items():
        gm = float(np.exp(np.mean(np.log(v))))
        out[k] = gm
        emit(f"fig7/SUMMARY/{k}", 0.0, f"geomean_speedup_vs_clang={gm:.2f}")
    return out


if __name__ == "__main__":
    run()
