"""Transfer benchmark — the paper's headline mechanism, end to end.

Every B/np variant of the PolyBench suite is compiled at bench size under:

  * **default**    — default idiom recipes on the program *as authored*:
    Daisy with an empty database and ``normalize_first=False``.  This is
    the deployment without the shipped mechanism — per-nest idiom
    classification and default recipes, but no normalization and no
    transfer database.
  * **normalized** — the pass pipeline + default recipes (empty database).
    Reported, not gated: it splits the mechanism into its two halves
    (normalization vs. transferred recipes).
  * **transfer**   — the full pipeline warm-started from the shipped
    pretuned database (``data/pretuned_xla.json``, tuned offline by
    ``repro.tools.tune`` on the **A variants only**): every canonical nest
    resolves by exact fingerprint or embedding nearest-neighbour.

The B/np variants were never tuned themselves — their speedup is knowledge
transferred from the A variants through normalization + the database (§4).
Correctness is cross-checked per variant (transfer vs default outputs).

Gated variants (CI exits non-zero under the threshold) are the strided
B variants, where the authored composition (k-outer contractions, strided
MAC orders) collapses the default lowering: ``syrk:b``, ``2mm:b``,
``3mm:b``, ``syr2k:b``, ``doitgen:b``, ``gemver:b`` — measured margins are
4-13x, so the 1.3x gate has headroom against 1-core CI noise.  The
spatial-transposed stencil variants (``jacobi-2d:b``, ``fdtd-2d:b``,
``heat-3d:b``) sit at parity by construction in this lowering: the
vectorized whole-array JAX path is insensitive to the authored spatial
loop order, so normalization's stencil wins only appear against the
``as_written`` baseline (fig6/fig7 measure that).  They are reported and
held to the parity floor instead.
"""
from __future__ import annotations

import argparse
import json
from collections import Counter

import numpy as np

from repro.core import Daisy, TuningDatabase
from repro.core.database import default_pretuned_path
from repro.polybench import BENCHMARKS, NAMES

from .common import emit, inputs_for, timed

BACKEND = "xla"
GATES = {"syrk:b": 1.3, "2mm:b": 1.3, "3mm:b": 1.3, "syr2k:b": 1.3,
         "doitgen:b": 1.3, "gemver:b": 1.3}
# Catastrophe floor for ungated variants: a transferred recipe must never
# make a program this much slower than the no-database default.  Loose on
# purpose — it exists to catch a semantically-wrong or pathological recipe
# (order-of-magnitude regressions), while ms-scale variants see +-40%
# run-to-run drift on shared CI cores and fission itself costs ~1.5x on
# the tightly-fused compositions (gesummv's single-loop form).
PARITY = 0.4


def _check_outputs(key: str, got: dict, ref: dict, out_name: str) -> None:
    a = np.asarray(got[out_name], np.float64)
    b = np.asarray(ref[out_name], np.float64)
    denom = max(1e-9, float(np.abs(b).max()))
    rel = float(np.abs(a - b).max()) / denom
    if not rel < 1e-3:
        raise AssertionError(
            f"{key}: transfer and default outputs diverge (rel={rel:.2e}) — "
            "a transferred recipe changed semantics"
        )


def run(repeats: int = 3, size: str = "bench", db_path: str | None = None,
        json_path: str | None = None, names=NAMES,
        gates: dict[str, float] = GATES) -> dict:
    db_path = db_path or default_pretuned_path(BACKEND)
    pre = TuningDatabase.load(db_path)
    d_default = Daisy(db=TuningDatabase(), backend=BACKEND)
    d_transfer = Daisy(db=pre, backend=BACKEND)

    variants: dict[str, dict] = {}
    for name in names:
        b = BENCHMARKS[name]
        measured: dict[int, dict] = {}  # builder id -> row (np often aliases b)
        for var in ("b", "np"):
            builder = b.variants[var]
            key = f"{name}:{var}"
            cached = measured.get(id(builder))
            if cached is not None:
                variants[key] = dict(cached, alias=True)
                continue
            prog = b.make(var, size)
            inp = inputs_for(prog)
            f_def, _ = d_default.compile(prog, normalize_first=False)
            f_norm, _ = d_default.compile(prog)
            f_tr, plan = d_transfer.compile(prog)
            t_def = timed(f_def, inp, repeats)
            t_norm = timed(f_norm, inp, repeats)
            t_tr = timed(f_tr, inp, repeats)
            _check_outputs(key, f_tr(inp), f_def(inp), b.output)
            sources = Counter(p.source.split("(")[0] for p in plan.nests)
            speedup = t_def / max(t_tr, 1e-9)
            row = {"default_us": t_def, "normalized_us": t_norm,
                   "transfer_us": t_tr, "speedup": round(speedup, 3),
                   "sources": dict(sources)}
            measured[id(builder)] = row
            variants[key] = row
            emit(f"transfer/{key}/default", t_def)
            emit(f"transfer/{key}/normalized", t_norm)
            emit(f"transfer/{key}/transfer", t_tr,
                 f"speedup={speedup:.2f}x hits={dict(sources)}")

    gate_rows = {}
    failures = []
    for key, need in gates.items():
        if key not in variants:
            continue
        row = variants[key]
        hit = row["sources"].get("exact", 0) + row["sources"].get("transfer", 0)
        ok = row["speedup"] >= need and hit > 0
        gate_rows[key] = {"required": need, "speedup": row["speedup"],
                          "db_hits": hit, "ok": ok}
        if not ok:
            failures.append(f"{key}: {row['speedup']:.2f}x < {need}x "
                            f"(db hits: {hit})")
    for key, row in variants.items():
        if key not in gates and not row.get("alias") and row["speedup"] < PARITY:
            failures.append(f"{key}: transfer regressed to {row['speedup']:.2f}x "
                            f"of default (parity floor {PARITY}x)")

    results = {"db": str(db_path), "db_meta": pre.meta, "size": size,
               "backend": BACKEND, "repeats": repeats,
               "variants": variants, "gates": gate_rows, "failures": failures}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=1)
    for key, g in gate_rows.items():
        emit(f"transfer/GATE/{key}", 0.0,
             f"speedup={g['speedup']:.2f}x required={g['required']}x ok={g['ok']}")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--size", default="bench", choices=["mini", "bench"])
    ap.add_argument("--db", default=None,
                    help="pretuned database (default: shipped data/pretuned_xla.json)")
    ap.add_argument("--names", default=None, help="comma-separated benchmark subset")
    ap.add_argument("--json", default=None, help="write results JSON here")
    ap.add_argument("--no-gate", action="store_true",
                    help="report only; do not fail on thresholds")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    names = tuple(args.names.split(",")) if args.names else NAMES
    unknown = [n for n in names if n not in BENCHMARKS]
    if unknown:
        ap.error(f"unknown benchmark(s): {', '.join(unknown)} "
                 f"(valid: {', '.join(BENCHMARKS)})")
    results = run(repeats=args.repeats, size=args.size, db_path=args.db,
                  json_path=args.json, names=names)
    if results["failures"] and not args.no_gate:
        raise SystemExit("transfer gate failed:\n  " + "\n  ".join(results["failures"]))


if __name__ == "__main__":
    main()
