"""Microbenchmark for the grid-tiled Pallas lowering + scan recurrences
(PR-3 tentpole).

Three workloads:

  * **over-budget elementwise chain** — a fused stage chain whose iteration
    space exceeds the vectorizer's materialization budget, so the generic
    path demotes the outer axis to a sequential ``fori_loop``.  Measured
    against full-budget whole-array vectorization and the tiled Pallas
    kernel across several tile presets (the reported tiled-vs-vectorize
    curve; interpret-mode Pallas pays a per-grid-step interpreter tax on
    CPU — the curve is the shape data for the TPU deploy story).
  * **2-D stencil sweep** — a parallel 5-point smoothing step: whole-array
    vectorize (slice-based offset reads) vs. tiled Pallas with halo operands.
  * **CLOUDSC vertical recurrence** — the mini scheme's JK-carried chains
    under the scan lowering (leading-axis operands sliced per step, written
    rows stacked) vs. the whole-array-carry ``fori_loop`` baseline.  This is
    the gated measurement: the CLI exits non-zero when the scan speedup
    drops below 1.5x.

Correctness gates: each workload's lowerings are checked against the
``execute_numpy`` float64 oracle at a reduced size before timing.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

import jax

from repro.core import (
    Array,
    Computation,
    Loop,
    Program,
    Schedule,
    acc,
    aff,
    compile_jax,
    execute_numpy,
)
from repro.core.scheduler import random_inputs
from repro.core.util import time_fn
from repro.cloudsc import mini_cloudsc_program
from repro.cloudsc.scheme import scheme_inputs

from .common import emit

# Interpret-mode Pallas pays ~10ms of interpreter tax per grid step on CPU,
# so the measured presets keep grids small (the TPU-shaped (8,128)-multiple
# presets in repro.core.recipes are exercised by the oracle gates and tests).
TILES = ((128, 512), (256, 512), (128, 1024), (256, 1024))


def chain_program(rows: int, cols: int, stages: int = 4,
                  name: str = "tiling_chain") -> Program:
    """One fused nest of dependent elementwise stages over (rows, cols)."""
    arrays = [Array("X", (rows, cols))] + [
        Array(f"T{s}", (rows, cols)) for s in range(stages)]
    comps = []
    prev = "X"
    for s in range(stages):
        nm = f"T{s}"
        comps.append(Computation(
            f"stage{s}", acc(nm, "i", "j"), (acc(prev, "i", "j"),),
            lambda v, s=s: v * (1.0 + 0.125 * s) + 0.25))
        prev = nm
    nest = Loop("i", rows, body=(Loop("j", cols, body=tuple(comps)),))
    return Program(name, tuple(arrays), (nest,))


def stencil_program(n: int, name: str = "tiling_stencil") -> Program:
    st = Computation(
        "st", acc("B", "i", "j"),
        (acc("A", "i", "j"),
         acc("A", aff("i", const=-1), "j"), acc("A", aff("i", const=1), "j"),
         acc("A", "i", aff("j", const=-1)), acc("A", "i", aff("j", const=1))),
        lambda c, nn, ss, ww, ee: 0.2 * (c + nn + ss + ww + ee))
    return Program(name, (Array("A", (n, n)), Array("B", (n, n))),
                   (Loop("i", n - 1, start=1,
                         body=(Loop("j", n - 1, start=1, body=(st,)),)),))


def _jit(prog, sched, out_names):
    body = compile_jax(prog, sched)
    return jax.jit(lambda a: {k: body(a)[k] for k in out_names})


def _oracle_gate(prog, scheds, out_names, rtol=1e-4):
    inp = random_inputs(prog, seed=7, dtype=np.float64)
    ref = execute_numpy(prog, inp)
    args = {k: np.asarray(v, np.float32) for k, v in inp.items()}
    for label, sched in scheds:
        got = _jit(prog, sched, out_names)(args)
        for k in out_names:
            denom = max(1e-9, np.abs(ref[k]).max())
            rel = np.abs(np.asarray(got[k], np.float64) - ref[k]).max() / denom
            assert rel < rtol, (prog.name, label, k, rel)


def bench_chain(repeats: int, rows: int, cols: int) -> dict:
    final = ("T3",)
    small = chain_program(32, 64)
    budget = rows * cols // 4  # force outer-axis demotion in the budget path
    variants = [
        ("chain_vectorize_budget",
         Schedule(mode="canonical", use_idioms=False, vec_budget=budget)),
        ("chain_vectorize_full",
         Schedule(mode="canonical", use_idioms=False, vec_budget=1 << 30)),
    ] + [
        (f"chain_pallas_{t[0]}x{t[1]}",
         Schedule(mode="canonical", use_idioms=False, pallas_nest=True,
                  nest_tile=t))
        for t in TILES
    ]
    _oracle_gate(small, variants, final)

    prog = chain_program(rows, cols)
    args = {k: v for k, v in random_inputs(prog, dtype=np.float32).items()}
    out = {}
    for label, sched in variants:
        us = time_fn(lambda f=_jit(prog, sched, final): f(args), repeats=repeats)
        emit(label, us)
        out[label] = us
    return out


def bench_stencil(repeats: int, n: int) -> dict:
    small = stencil_program(18)
    variants = [
        ("stencil_vectorize",
         Schedule(mode="canonical", use_idioms=False)),
    ] + [
        (f"stencil_pallas_{t[0]}x{t[1]}",
         Schedule(mode="canonical", use_idioms=False, pallas_nest=True,
                  nest_tile=t))
        for t in TILES
    ]
    _oracle_gate(small, variants, ("B",), rtol=1e-5)

    prog = stencil_program(n)
    args = {k: v for k, v in random_inputs(prog, dtype=np.float32).items()}
    out = {}
    for label, sched in variants:
        us = time_fn(lambda f=_jit(prog, sched, ("B",)): f(args), repeats=repeats)
        emit(label, us)
        out[label] = us
    return out


def bench_scan(repeats: int, nproma: int, klev: int) -> dict:
    checks = ("PFPLSL", "TENDQ", "ZTP1")
    scan_s = Schedule(mode="canonical", use_idioms=False, scan=True)
    fori_s = Schedule(mode="canonical", use_idioms=False, scan=False)

    small = mini_cloudsc_program(8, 6)
    sinp = scheme_inputs(8, 6)
    ref = execute_numpy(small, sinp)
    sargs = {k: np.asarray(v, np.float32) for k, v in sinp.items()}
    for label, sched in (("scan", scan_s), ("fori", fori_s)):
        got = _jit(small, sched, checks)(sargs)
        for k in checks:
            denom = max(1e-9, np.abs(ref[k]).max())
            rel = np.abs(np.asarray(got[k], np.float64) - ref[k]).max() / denom
            assert rel < 1e-4, (label, k, rel)

    prog = mini_cloudsc_program(nproma, klev)
    args = {k: np.asarray(v, np.float32)
            for k, v in scheme_inputs(nproma, klev).items()}
    fori_us = time_fn(lambda f=_jit(prog, fori_s, checks): f(args),
                      repeats=repeats)
    scan_us = time_fn(lambda f=_jit(prog, scan_s, checks): f(args),
                      repeats=repeats)
    speedup = fori_us / max(scan_us, 1e-9)
    emit("cloudsc_recurrence_fori", fori_us, "carried-array baseline")
    emit("cloudsc_recurrence_scan", scan_us, f"speedup={speedup:.2f}x")
    return {"fori_us": fori_us, "scan_us": scan_us, "speedup": speedup,
            "speedup_ok": bool(speedup >= 1.5)}


def run(repeats: int = 5, json_path: str | None = None,
        rows: int = 1024, cols: int = 1024, stencil_n: int = 1024,
        nproma: int = 4096, klev: int = 137) -> dict:
    results = {
        "chain": bench_chain(repeats, rows, cols),
        "stencil": bench_stencil(repeats, stencil_n),
        "recurrence": bench_scan(repeats, nproma, klev),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=1)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--rows", type=int, default=1024)
    ap.add_argument("--cols", type=int, default=1024)
    ap.add_argument("--stencil-n", type=int, default=1024)
    ap.add_argument("--nproma", type=int, default=4096)
    ap.add_argument("--klev", type=int, default=137)
    ap.add_argument("--json", default=None, help="write results JSON here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    results = run(repeats=args.repeats, json_path=args.json, rows=args.rows,
                  cols=args.cols, stencil_n=args.stencil_n,
                  nproma=args.nproma, klev=args.klev)
    rec = results["recurrence"]
    if not rec["speedup_ok"]:
        raise SystemExit(
            f"scan recurrence speedup {rec['speedup']:.2f}x < 1.5x over the "
            "carried-array fori baseline")


if __name__ == "__main__":
    main()
