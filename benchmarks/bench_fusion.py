"""Microbenchmark for canonical-form re-fusion (PR-2 tentpole).

Workload: a CLOUDSC-style elementwise chain — K dependent stages over one
(rows, cols) field.  After maximal fission each stage is its own atomic
nest; without re-fusion the compiled program is K kernels making K full
passes over memory with materialized intermediates.  ``FusionPass`` merges
the chain back into one canonical nest -> one kernel.

Three measurements (CSV rows + optional JSON for the CI artifact):

  * fusion_unfused_kernels — one jitted callable per canonical nest,
                             dispatched in sequence (the kernel-per-nest
                             execution model: K dispatches, K memory round
                             trips through materialized intermediates)
  * fusion_unfused_one_jit — the unfused program under a single jit (XLA
                             may re-fuse internally; recorded for honesty)
  * fusion_fused           — the FusionPass program: one kernel

Correctness gate: both pipelines' outputs are checked bit-identical to the
``execute_numpy`` float64 oracle at a reduced size before timing.  The CLI
exits non-zero when the fused/unfused-kernels speedup drops below 1.5x.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

import jax

from repro.core import (
    Array,
    Computation,
    Loop,
    Program,
    Schedule,
    acc,
    compile_jax,
    execute_numpy,
    optimization_pipeline,
)
from repro.core.passes import PassContext
from repro.core.scheduler import nest_program, random_inputs
from repro.core.util import time_fn

from .common import emit

STAGES = 6


def chain_program(rows: int, cols: int, stages: int = STAGES,
                  name: str = "elementwise_chain") -> Program:
    """K dependent elementwise stages: T_s = f_s(T_{s-1}) over (rows, cols).

    Intermediates are declared as plain arrays (not temps) so the unfused
    kernel-per-nest execution model can thread them between kernels exactly
    as a runtime would — materialized in memory.
    """
    arrays = [Array("X", (rows, cols))]
    body = []
    prev = "X"
    for s in range(stages):
        nm = f"T{s}"
        arrays.append(Array(nm, (rows, cols)))
        i, j = f"i{s}", f"j{s}"
        comp = Computation(
            f"stage{s}",
            acc(nm, i, j),
            (acc(prev, i, j),),
            # cheap mul-add keeps the chain memory-bound (the fusion win)
            lambda v, s=s: v * (1.0 + 0.125 * s) + 0.25,
        )
        body.append(Loop(i, rows, body=(Loop(j, cols, body=(comp,)),)))
        prev = nm
    return Program(name, tuple(arrays), tuple(body))


def _written(nest) -> list[str]:
    from repro.core.codegen import _written_arrays

    return _written_arrays(nest)


def _per_kernel_fns(program: Program, sched: Schedule):
    """One jitted callable per canonical nest (kernel-per-nest execution).

    Each kernel returns exactly the arrays its nest writes — the
    materialized intermediate the next kernel reads back from memory.
    """
    fns = []
    for nest in program.body:
        nprog = nest_program(program, nest)
        writes = _written(nest)
        body = compile_jax(nprog, sched)
        fn = jax.jit(lambda a, _b=body, _w=writes: {k: _b(a)[k] for k in _w})
        fns.append((nprog.array_names, fn))
    return fns


def _run_kernels(fns, env: dict) -> dict:
    env = dict(env)
    for names, fn in fns:
        out = fn({k: env[k] for k in names})
        env.update(out)
    return env


def _single_kernel_fn(program: Program, sched: Schedule, final: str):
    """The whole program as one kernel returning only the final stage —
    XLA is free to keep every fused intermediate in registers."""
    body = compile_jax(program, sched)
    return jax.jit(lambda a: {final: body(a)[final]})


def run(repeats: int = 5, json_path: str | None = None,
        rows: int = 1024, cols: int = 2048, stages: int = STAGES) -> dict:
    prog = chain_program(rows, cols, stages)
    fuse_pipe = optimization_pipeline(fuse=True)
    norm_pipe = optimization_pipeline(fuse=False)

    ctx = PassContext()
    fused = fuse_pipe.run(prog, ctx=ctx)
    unfused = norm_pipe.run(prog)
    assert len(fused.body) < len(unfused.body), "fusion merged nothing"

    # correctness gate at a reduced size: bit-identical to the oracle
    small = chain_program(8, 16, stages)
    sinp = random_inputs(small, dtype=np.float64)
    ref = execute_numpy(small, sinp)
    for variant in (fuse_pipe.run(small), norm_pipe.run(small)):
        got = execute_numpy(variant, sinp)
        for k in small.array_names:
            assert np.array_equal(got[k], ref[k]), (variant.name, k)

    sched = Schedule(mode="canonical", use_idioms=False)
    inputs = random_inputs(prog)
    args = {k: np.asarray(v, np.float32) for k, v in inputs.items()}

    final = f"T{stages - 1}"
    kernel_fns = _per_kernel_fns(unfused, sched)
    unfused_kernels_us = time_fn(lambda: _run_kernels(kernel_fns, args),
                                 repeats=repeats)
    one_jit = _single_kernel_fn(unfused, sched, final)
    unfused_one_jit_us = time_fn(lambda: one_jit(args), repeats=repeats)
    fused_fn = _single_kernel_fn(fused, sched, final)
    fused_us = time_fn(lambda: fused_fn(args), repeats=repeats)

    speedup = unfused_kernels_us / max(fused_us, 1e-9)
    emit("fusion_unfused_kernels", unfused_kernels_us,
         f"kernels={len(unfused.body)}")
    emit("fusion_unfused_one_jit", unfused_one_jit_us)
    emit("fusion_fused", fused_us,
         f"kernels={len(fused.body)},speedup={speedup:.2f}x")

    results = {
        "rows": rows, "cols": cols, "stages": stages,
        "kernels_unfused": len(unfused.body),
        "kernels_fused": len(fused.body),
        "nests_merged": ctx.stat("fusion", "fused"),
        "unfused_kernels_us": unfused_kernels_us,
        "unfused_one_jit_us": unfused_one_jit_us,
        "fused_us": fused_us,
        "speedup": speedup,
        "speedup_ok": bool(speedup >= 1.5),
        "pass_seconds": {r.name: r.seconds for r in ctx.records},
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=1)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--rows", type=int, default=1024)
    ap.add_argument("--cols", type=int, default=2048)
    ap.add_argument("--stages", type=int, default=STAGES)
    ap.add_argument("--json", default=None, help="write results JSON here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    results = run(repeats=args.repeats, json_path=args.json,
                  rows=args.rows, cols=args.cols, stages=args.stages)
    if not results["speedup_ok"]:
        raise SystemExit(
            f"fused speedup {results['speedup']:.2f}x < 1.5x over kernel-per-nest"
        )


if __name__ == "__main__":
    main()
