"""Shared benchmark utilities: build the four measured systems + CSV rows.

Measured systems (paper analogues on one toolchain, DESIGN.md §2):
  baseline   — 'as-written' lowering: authored loop order, innermost-only
               vectorization, no idioms (the clang/icc -O3 analogue)
  sched_raw  — scheduled WITHOUT normalization: canonical vectorizer +
               idiom detection applied to the authored structure (the
               non-normalizing auto-scheduler analogue: Polly/Tiramisu)
  norm_only  — normalization WITHOUT the recipe database/idioms
  daisy      — the full pipeline: normalize -> idioms -> transfer-tune
"""
from __future__ import annotations

import numpy as np

import jax

from repro.core import Daisy, Schedule, compile_jax, normalize
from repro.core.scheduler import random_inputs
from repro.core.util import time_fn

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us: float, derived: str = "") -> None:
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def timed(fn, inputs, repeats=5) -> float:
    return time_fn(lambda: fn(inputs), repeats=repeats)


def build_baseline(prog):
    return jax.jit(compile_jax(prog, Schedule(mode="as_written", use_idioms=False)))


def build_sched_raw(prog):
    # scheduled, but on the UN-normalized structure
    return jax.jit(compile_jax(prog, Schedule(mode="canonical", use_idioms=True)))


def build_norm_only(prog):
    return jax.jit(compile_jax(normalize(prog), Schedule(mode="canonical", use_idioms=False)))


def build_daisy(daisy: Daisy, prog):
    fn, plan = daisy.compile(prog)
    return fn, plan


def inputs_for(prog, seed=0):
    return random_inputs(prog, seed=seed, dtype=np.float32)
