"""Fig. 9 — cross-language transfer: NumPy/DaCe-style variants optimized by
the database seeded from the C-style A variants (§4.3).

Also reports the BLAS-idiom hit rate with vs without normalization — the
paper's observation that idiom lifting fails without it (2mm/3mm/gemm).
"""
from __future__ import annotations

import numpy as np

from repro.core import Daisy, Schedule, compile_jax, fingerprint, normalize
from repro.core.idioms import classify_nest
from repro.polybench import BENCHMARKS, NAMES

from .common import build_baseline, build_daisy, emit, inputs_for, timed
import jax

SUBSET = ("gemm", "2mm", "3mm", "syrk", "syr2k", "atax", "bicg", "gesummv",
          "gemver", "jacobi-2d")


def idiom_hits(prog, normalized: bool) -> tuple[int, int]:
    p = normalize(prog) if normalized else prog
    hits = total = 0
    for nest in p.body:
        k = classify_nest(nest).kind
        total += 1
        if k in ("blas3", "blas2", "dot"):
            hits += 1
    return hits, total


def run(repeats: int = 3, size: str = "bench") -> dict:
    daisy = Daisy()
    daisy.seed([BENCHMARKS[n].make("a", size) for n in SUBSET], search=False)
    speed = []
    exact_hits = 0
    n_nests = 0
    for name in SUBSET:
        b = BENCHMARKS[name]
        pnp = b.make("np", size)
        inp = inputs_for(pnp)
        t_base = timed(build_baseline(pnp), inp, repeats)  # "interpreter" analogue
        fd, plan = build_daisy(daisy, pnp)
        t_daisy = timed(fd, inp, repeats)
        exact_hits += sum(1 for p in plan.nests if p.source == "exact")
        n_nests += len(plan.nests)
        speed.append(t_base / t_daisy)
        emit(f"fig9/{name}/np_baseline", t_base, "")
        emit(f"fig9/{name}/np_daisy", t_daisy, f"x{t_base / t_daisy:.2f}")

        h_norm, tot = idiom_hits(pnp, normalized=True)
        h_raw, _ = idiom_hits(pnp, normalized=False)
        emit(f"fig9/{name}/idiom_hits", 0.0,
             f"normalized={h_norm}/{tot} raw={h_raw}/{tot}")
    gm = float(np.exp(np.mean(np.log(speed))))
    emit("fig9/SUMMARY/daisy_vs_np_baseline", 0.0,
         f"geomean_speedup={gm:.2f} exact_db_hits={exact_hits}/{n_nests}")
    return {"geomean": gm, "exact_hits": exact_hits, "n_nests": n_nests}


if __name__ == "__main__":
    run()
