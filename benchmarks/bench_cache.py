"""Microbenchmark for the content-addressed compilation cache (PR-1).

Four measurements, printed as CSV rows and optionally written as JSON (CI
uploads the JSON as the perf-trajectory artifact):

  * cache_first_compile   — cold ``Daisy.compile`` of a polybench program
                            (normalize -> plan -> compile_jax from scratch)
  * cache_repeat_compile  — the same program re-built from its generator and
                            compiled again: the content-addressed hit path
                            (fingerprint + dict lookup).  Must be >= 10x
                            faster than the cold path.
  * seed_cold / seed_warm — ``Daisy.seed`` over polybench A variants, cold
                            vs re-seeding the same programs (indexed
                            ``lookup_exact`` short-circuits every nest)
  * db_indexed / db_linear— ``TuningDatabase.lookup_nearest`` via the stacked
                            embedding matrix vs the seed revision's Python
                            loop, on the seeded database (identical results
                            are asserted, only the time differs)
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import Daisy
from repro.core.embedding import distance

from .common import emit

SEED_PROGRAMS = ("gemm", "2mm", "3mm", "bicg", "doitgen")


def _linear_nearest(db, embedding, k=1):
    """The pre-index reference implementation (O(n) Python loop)."""
    scored = sorted(
        ((distance(embedding, e.embedding), e) for e in db.entries),
        key=lambda t: t[0],
    )
    return [s for s in scored[:k] if s[0] <= db.radius]


def run(repeats: int = 3, json_path: str | None = None) -> dict:
    from repro.polybench import BENCHMARKS

    results: dict = {}

    # -- compile: cold vs content-addressed hit ------------------------------
    daisy = Daisy()
    prog = BENCHMARKS["gemm"].make("a", "mini")
    t0 = time.perf_counter()
    fn_cold, _ = daisy.compile(prog)
    first_s = time.perf_counter() - t0

    repeat_s = float("inf")
    for _ in range(max(1, repeats)):
        rebuilt = BENCHMARKS["gemm"].make("a", "mini")  # fresh, structurally equal
        t0 = time.perf_counter()
        fn_hit, _ = daisy.compile(rebuilt)
        repeat_s = min(repeat_s, time.perf_counter() - t0)
    assert fn_hit is fn_cold, "repeat compile did not hit the cache"
    speedup = first_s / max(repeat_s, 1e-9)
    emit("cache_first_compile", first_s * 1e6)
    emit("cache_repeat_compile", repeat_s * 1e6, f"speedup={speedup:.0f}x")
    results.update(
        first_compile_s=first_s,
        repeat_compile_s=repeat_s,
        repeat_speedup=speedup,
        speedup_ok=bool(speedup >= 10.0),
    )

    # -- seeding: cold vs warm (indexed exact lookups skip every nest) -------
    progs = [BENCHMARKS[n].make("a", "mini") for n in SEED_PROGRAMS]
    fresh = Daisy()
    t0 = time.perf_counter()
    fresh.seed(progs, search=False)
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    fresh.seed([BENCHMARKS[n].make("a", "mini") for n in SEED_PROGRAMS], search=False)
    warm_s = time.perf_counter() - t0
    emit("seed_cold", cold_s * 1e6, f"programs={len(progs)}")
    emit("seed_warm", warm_s * 1e6, f"speedup={cold_s / max(warm_s, 1e-9):.1f}x")
    results.update(seed_cold_s=cold_s, seed_warm_s=warm_s,
                   seed_entries=len(fresh.db.entries))

    # -- database lookup: indexed vs linear ----------------------------------
    db = fresh.db
    probes = [e.embedding + 0.01 * (i % 3) for i, e in enumerate(db.entries)]
    probes += [e.embedding + np.linspace(0, 0.5, e.embedding.size) for e in db.entries]
    for q in probes:  # equivalence first, then timing
        got = db.lookup_nearest(q, k=3)
        want = _linear_nearest(db, q, k=3)
        assert [(round(d, 9), e.fingerprint) for d, e in got] == [
            (round(d, 9), e.fingerprint) for d, e in want
        ], "indexed lookup diverged from the linear reference"

    n_iter = 50
    t0 = time.perf_counter()
    for _ in range(n_iter):
        for q in probes:
            db.lookup_nearest(q, k=3)
    indexed_us = (time.perf_counter() - t0) / (n_iter * len(probes)) * 1e6
    t0 = time.perf_counter()
    for _ in range(n_iter):
        for q in probes:
            _linear_nearest(db, q, k=3)
    linear_us = (time.perf_counter() - t0) / (n_iter * len(probes)) * 1e6
    emit("db_lookup_indexed", indexed_us, f"entries={len(db.entries)}")
    emit("db_lookup_linear", linear_us,
         f"speedup={linear_us / max(indexed_us, 1e-9):.1f}x")
    results.update(db_indexed_us=indexed_us, db_linear_us=linear_us,
                   cache_stats=daisy.cache_stats.as_dict())

    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=1)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--json", default=None, help="write results JSON here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    results = run(repeats=args.repeats, json_path=args.json)
    if not results["speedup_ok"]:
        raise SystemExit(
            f"repeat-compile speedup {results['repeat_speedup']:.1f}x < 10x"
        )


if __name__ == "__main__":
    main()
