"""Fig. 6 — auto-scheduler robustness: A vs B variants across 15 benchmarks.

For each benchmark and each system we report t(A), t(B) and the ratio
t(B)/t(A).  The paper's claim: daisy's ratio stays ~1 (mean 5%, max 14%)
while non-normalizing systems diverge by up to an order of magnitude.
"""
from __future__ import annotations

import numpy as np

from repro.core import Daisy
from repro.polybench import BENCHMARKS, NAMES

from .common import build_baseline, build_daisy, build_sched_raw, emit, inputs_for, timed

SIZE = "bench"


def run(repeats: int = 3, size: str = SIZE, names=NAMES) -> dict:
    daisy = Daisy()
    daisy.seed([BENCHMARKS[n].make("a", size) for n in names], search=False)

    ratios: dict[str, list[float]] = {"baseline": [], "sched_raw": [], "daisy": []}
    for name in names:
        b = BENCHMARKS[name]
        pa, pb = b.make("a", size), b.make("b", size)
        inp = inputs_for(pa)
        t = {}
        for sysname, builder in (
            ("baseline", build_baseline), ("sched_raw", build_sched_raw),
        ):
            for var, prog in (("a", pa), ("b", pb)):
                t[(sysname, var)] = timed(builder(prog), inp, repeats)
        fa, _ = build_daisy(daisy, pa)
        fb, _ = build_daisy(daisy, pb)
        t[("daisy", "a")] = timed(fa, inp, repeats)
        t[("daisy", "b")] = timed(fb, inp, repeats)

        for sysname in ("baseline", "sched_raw", "daisy"):
            ta, tb = t[(sysname, "a")], t[(sysname, "b")]
            ratio = tb / ta
            ratios[sysname].append(max(ratio, 1.0 / ratio))
            emit(f"fig6/{name}/{sysname}_A", ta, f"ratioBA={ratio:.2f}")
            emit(f"fig6/{name}/{sysname}_B", tb, "")
    out = {}
    for sysname, rs in ratios.items():
        gm = float(np.exp(np.mean(np.log(rs))))
        mx = float(np.max(rs))
        out[sysname] = (gm, mx)
        emit(f"fig6/SUMMARY/{sysname}", 0.0,
             f"geomean_AB_divergence={gm:.3f} max={mx:.2f}")
    return out


if __name__ == "__main__":
    run()
