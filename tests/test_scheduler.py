"""daisy scheduler: seeding, transfer lookup, A/B equivalence, persistence."""
import numpy as np
import pytest

from repro.core import Daisy, Recipe, TuningDatabase, execute_numpy, fingerprint, normalize
from repro.core.embedding import embed_nest
from repro.core.idioms import classify_nest
from repro.core.scheduler import nest_program, random_inputs
from repro.polybench import BENCHMARKS


@pytest.fixture(scope="module")
def seeded():
    d = Daisy()
    progs = [BENCHMARKS[n].make("a", "mini") for n in ("gemm", "2mm", "bicg")]
    d.seed(progs, search=False)  # analytic seeding (fast test path)
    return d


def test_seed_creates_entries(seeded):
    assert len(seeded.db.entries) >= 4
    kinds = {e.recipe.kind for e in seeded.db.entries}
    assert "einsum" in kinds  # BLAS-3 idiom recipes present


def test_b_variant_hits_exact_fingerprints(seeded):
    pb = normalize(BENCHMARKS["gemm"].make("b", "mini"))
    hits = 0
    for nest in pb.body:
        if seeded.db.lookup_exact(fingerprint(nest)) is not None:
            hits += 1
    assert hits == len(pb.body)  # every B nest reduces to a seeded A nest


def test_compiled_b_variant_matches_oracle(seeded):
    b = BENCHMARKS["gemm"]
    prog = b.make("b", "mini")
    fn, plan = seeded.compile(prog)
    assert all(p.source == "exact" for p in plan.nests)
    inp = random_inputs(prog, seed=9)
    out = fn(inp)
    ref = execute_numpy(prog, {k: v.astype(np.float64) for k, v in inp.items()})
    np.testing.assert_allclose(
        np.asarray(out[b.output]), ref[b.output], rtol=1e-3, atol=1e-3
    )


def test_idiom_classification():
    gemm = normalize(BENCHMARKS["gemm"].make("a", "mini"))
    kinds = [classify_nest(n).kind for n in gemm.body]
    assert "blas3" in kinds
    jac = normalize(BENCHMARKS["jacobi-2d"].make("a", "mini"))
    kinds = [classify_nest(n).kind for n in jac.body]
    assert "recurrence" in kinds  # time loop carries the dependence
    bicg = normalize(BENCHMARKS["bicg"].make("a", "mini"))
    kinds = [classify_nest(n).kind for n in bicg.body]
    assert "blas2" in kinds


def test_db_persistence_roundtrip(tmp_path, seeded):
    p = tmp_path / "db.json"
    seeded.db.save(p)
    loaded = TuningDatabase.load(p)
    assert len(loaded.entries) == len(seeded.db.entries)
    e0, l0 = seeded.db.entries[0], loaded.entries[0]
    assert e0.fingerprint == l0.fingerprint
    assert e0.recipe == l0.recipe
    np.testing.assert_allclose(e0.embedding, l0.embedding)


def test_transfer_lookup_by_embedding():
    """A near-but-not-identical nest transfers the most similar recipe."""
    db = TuningDatabase(radius=50.0)
    pa = normalize(BENCHMARKS["gemm"].make("a", "mini"))
    mac_nest = pa.body[1]
    db.add(fingerprint(mac_nest), embed_nest(pa, mac_nest),
           Recipe(kind="einsum", notes="seed"), provenance="test")
    # a GEMM with slightly different sizes: different fingerprint, near embed
    from repro.models.lowering import _matmul_program

    probe = normalize(_matmul_program("p", 24, 20, 30))
    nest = probe.body[0]
    assert db.lookup_exact(fingerprint(nest)) is None
    recipe, source = db.lookup(fingerprint(nest), embed_nest(probe, nest))
    assert recipe is not None and source.startswith("transfer")


def test_model_lowering_plans():
    from repro.configs import get_config
    from repro.models.lowering import plan_model

    for arch in ("mixtral-8x7b", "jamba-1.5-large-398b", "xlstm-350m"):
        plans = plan_model(get_config(arch), seq=4096, batch=8)
        assert plans, arch
        assert all(p.idiom == "blas3" for p in plans)
        assert all(p.recipe.kind in ("pallas_gemm", "einsum") for p in plans)
        assert all(p.recipe.tile is not None for p in plans if p.recipe.kind == "pallas_gemm")
        axes = {p.mesh_axis for p in plans}
        assert axes <= {"data", "model"}


def test_seed_measures_under_backend_lowering(monkeypatch):
    """Regression (PR-4): seeding fitness must be taken under the same
    lowering ``Daisy.compile`` executes — under ``backend='pallas'`` no
    interpret-mode Pallas measurement may happen."""
    from repro.core import search as S

    captured = []

    def fake_compile(prog, sched):
        captured.append(sched)
        return lambda args: {}

    monkeypatch.setattr(S, "compile_jax", fake_compile)
    progs = [BENCHMARKS["gemm"].make("a", "mini")]
    Daisy(backend="pallas").seed(progs, search=False)
    assert captured, "seeding measured nothing"
    assert all(s.interpret is False for s in captured)

    captured.clear()
    Daisy(backend="pallas_interpret").seed(progs, search=False)
    assert captured and all(s.interpret is True for s in captured)


def test_seed_dedupes_identical_nests_across_programs(monkeypatch):
    """Identical canonical nests arising from different source programs (the
    paper's central case) are measured once, not once per program."""
    from repro.core import scheduler as SCH

    calls = []

    def counting_measure(nprog, inputs, recipe, repeats=3, interpret=True):
        calls.append(nprog.name)
        return 1.0

    monkeypatch.setattr(SCH, "measure_recipe", counting_measure)
    d = Daisy()
    prog = BENCHMARKS["gemm"].make("a", "mini")
    n_nests = len(d._normalized(prog).body)
    d.seed([prog, BENCHMARKS["gemm"].make("a", "mini")], search=False)
    assert len(calls) == n_nests  # the duplicate program added zero work


def test_reseed_pool_excludes_own_entry():
    """Epoch-2 reseeding must not hand a nest its own recipe back (same
    fingerprint, distance 0)."""
    d = Daisy()
    pa = normalize(BENCHMARKS["gemm"].make("a", "mini"))
    nest = pa.body[1]
    fp, emb = fingerprint(nest), embed_nest(pa, nest)
    d.db.add(fp, emb, Recipe(kind="einsum", notes="SELF"), provenance="self")
    d.db.add("other-near", emb + 0.05, Recipe(kind="vectorize", notes="OTHER"),
             provenance="near")
    pool = d._reseed_pool(fp, emb)
    assert [r.notes for r in pool] == ["OTHER"]


def test_rng_seed_varies_per_nest():
    from repro.core.search import nest_rng_seed

    assert nest_rng_seed("fpA") != nest_rng_seed("fpB")
    assert nest_rng_seed("fpA") == nest_rng_seed("fpA")  # stable across runs
    assert nest_rng_seed("fpA", salt="transfer:") != nest_rng_seed("fpA")


def test_nest_program_randomizes_consumed_temps():
    """A nest consuming a temp produced by an earlier nest must measure on
    randomized data, not the zero-fill (the standalone program treats the
    consumed temp as an input)."""
    p = normalize(BENCHMARKS["2mm"].make("b", "mini"))
    consuming = [n for n in p.body
                 if any("tmp" in {a.array for a in c.reads}
                        for c in _comps(n))]
    assert consuming, "expected a nest reading the tmp temp"
    for nest in consuming:
        nprog = nest_program(p, nest)
        assert "tmp" not in nprog.temps
        inp = random_inputs(nprog)
        assert "tmp" in inp and np.abs(inp["tmp"]).min() > 0


def test_nest_program_keeps_self_defined_temps():
    """A temp fully written by the nest before any read stays a temp."""
    from repro.core import Array, Computation, Loop, Program, acc

    zero = Computation("z", acc("T", "i"), (), lambda: 0.0)
    use = Computation("u", acc("Y", "i"), (acc("T", "i"),), lambda t: t + 1.0)
    p = Program("selfdef", (Array("T", (8,)), Array("Y", (8,))),
                (Loop("i", 8, body=(zero, use)),), temps=("T",))
    nprog = nest_program(p, p.body[0])
    assert nprog.temps == ("T",)
    assert "T" not in random_inputs(nprog)


def _comps(nest):
    from repro.core.ir import nest_computations

    return nest_computations(nest)


def test_measure_recipe_rejects_nonfinite_timing(monkeypatch):
    from repro.core import search as S

    monkeypatch.setattr(S, "time_fn", lambda fn, repeats=3, **kw: float("nan"))
    prog = normalize(BENCHMARKS["gemm"].make("a", "mini"))
    nprog = nest_program(prog, prog.body[0])
    t = S.measure_recipe(nprog, random_inputs(nprog), Recipe(kind="vectorize"))
    assert t == float("inf")


def test_seed_ships_no_entry_for_unmeasurable_nests(monkeypatch):
    """A nest whose every candidate lowering fails (fitness inf) must not
    land in the database — plan() falls back to defaults instead."""
    from repro.core import scheduler as SCH

    monkeypatch.setattr(SCH, "measure_recipe",
                        lambda *a, **k: float("inf"))
    d = Daisy()
    d.seed([BENCHMARKS["gemm"].make("a", "mini")], search=False)
    assert d.db.entries == []


def test_evolutionary_search_returns_usable_recipe():
    """Paper §4 seeding: evolutionary search (mutation+selection, runtime
    fitness) must return a recipe no slower than the analytic seed."""
    from repro.core.search import evolve_recipe, measure_recipe, default_recipe_for
    from repro.core.idioms import classify_nest
    from repro.core.scheduler import nest_program, random_inputs
    from repro.core import normalize

    prog = normalize(BENCHMARKS["gemm"].make("a", "mini"))
    nest = prog.body[1]  # the MAC nest
    nprog = nest_program(prog, nest)
    seed = default_recipe_for(classify_nest(nest))
    inputs = random_inputs(nprog)
    t_seed = measure_recipe(nprog, inputs, seed)
    best, t_best = evolve_recipe(nprog, inputs, seed, iterations=1, population=3)
    # 1-core CI noise makes tight timing asserts flaky; require a finite,
    # runnable winner (the search only ever keeps measured candidates)
    assert t_seed < float("inf") and t_best < float("inf")
    assert best.kind in ("einsum", "vectorize", "pallas_gemm", "sequential",
                         "pallas_nest", "pallas_reduce")
