"""CLOUDSC case study: erosion nest + mini scheme (paper §5)."""
import numpy as np
import pytest
import jax

from repro.cloudsc import erosion_program, mini_cloudsc_program
from repro.cloudsc.erosion import physical_inputs
from repro.cloudsc.scheme import scheme_inputs
from repro.core import Schedule, compile_jax, execute_numpy, normalize
from repro.core.normalize import scalar_expansion


class TestErosion:
    def test_scalar_expansion_promotes_all_temps(self):
        p = erosion_program(nproma=8, klev=4)
        exp = scalar_expansion(p)
        for t in p.temps:
            assert exp.array(t).shape == (8,), t  # expanded over JL only

    def test_normalized_matches_original(self):
        p = erosion_program(nproma=8, klev=4)
        inp = physical_inputs(8, 4)
        ref = execute_numpy(p, inp)
        out = execute_numpy(normalize(p), inp)
        for k in ("ZTP1", "ZQSMIX"):
            np.testing.assert_allclose(out[k], ref[k], rtol=1e-12)

    def test_canonical_jax_matches(self):
        p = erosion_program(nproma=8, klev=4)
        inp = physical_inputs(8, 4)
        ref = execute_numpy(p, inp)
        fn = jax.jit(compile_jax(normalize(p), Schedule(mode="canonical", use_idioms=False)))
        out = fn({k: np.asarray(v, np.float32) for k, v in inp.items()})
        for k in ("ZTP1", "ZQSMIX"):
            rel = np.abs(np.asarray(out[k], np.float64) - ref[k]).max() / np.abs(ref[k]).max()
            assert rel < 1e-4, (k, rel)

    def test_normalization_unlocks_vectorization(self):
        """The paper's §5.1 claim, structurally: before normalization the JL
        loop is serialized by the scalar chain; after, every JL nest
        vectorizes."""
        from repro.core.codegen import _NestEmitter

        p = erosion_program(nproma=8, klev=4)
        em = _NestEmitter(p, Schedule(mode="canonical"))
        plan_before = em.plan(p.body[0])
        assert not plan_before["JL"]  # scalars serialize JL

        pn = normalize(p)
        em2 = _NestEmitter(pn, Schedule(mode="canonical"))
        plan_after = em2.plan(pn.body[0])
        jl_iters = [it for it, v in plan_after.items() if v]
        assert jl_iters  # the (renamed) JL loops are now parallel


class TestMiniScheme:
    def test_flux_recurrence_stays_sequential(self):
        """Stage 3 (precipitation falls down the column) is a JK-carried SCC:
        the normalizer must keep JK sequential while JL vectorizes."""
        from repro.core.codegen import _NestEmitter
        from repro.core.ir import Loop, loop_iterators

        p = mini_cloudsc_program(nproma=8, klev=4)
        pn = normalize(p)
        em = _NestEmitter(pn, Schedule(mode="canonical"))
        # find the nest containing the flux computation (reads PFPLSL[JK-1])
        flux_nests = []
        for nest in pn.body:
            from repro.core.ir import walk

            for _, c in ([] if not isinstance(nest, Loop) else list(walk(nest))):
                for r in c.reads:
                    if r.array == "PFPLSL" and any(ix.const == -1 for ix in r.index):
                        flux_nests.append(nest)
        assert flux_nests
        plan = em.plan(flux_nests[0])
        outer_it = flux_nests[0].iterator
        assert not plan[outer_it]  # JK carried -> sequential

    def test_normalized_matches_original(self):
        p = mini_cloudsc_program(nproma=8, klev=5)
        inp = scheme_inputs(8, 5)
        ref = execute_numpy(p, inp)
        out = execute_numpy(normalize(p), inp)
        for k in ("ZTP1", "ZQSMIX", "ZQL", "ZQI", "PFPLSL", "TENDQ"):
            np.testing.assert_allclose(out[k], ref[k], rtol=1e-12, err_msg=k)

    def test_canonical_jax_matches(self):
        p = mini_cloudsc_program(nproma=8, klev=5)
        inp = scheme_inputs(8, 5)
        ref = execute_numpy(p, inp)
        fn = jax.jit(compile_jax(normalize(p), Schedule(mode="canonical", use_idioms=False)))
        out = fn({k: np.asarray(v, np.float32) for k, v in inp.items()})
        for k in ("TENDQ", "PFPLSL"):
            denom = max(1e-9, np.abs(ref[k]).max())
            rel = np.abs(np.asarray(out[k], np.float64) - ref[k]).max() / denom
            assert rel < 1e-4, (k, rel)
