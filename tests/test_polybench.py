"""All 15 PolyBench benchmarks: variant equivalence through every lowering."""
import numpy as np
import pytest

from repro.core import Schedule, execute_numpy, fingerprint, normalize, run_jax
from repro.core.scheduler import random_inputs
from repro.polybench import BENCHMARKS, NAMES


@pytest.mark.parametrize("name", NAMES)
def test_variants_agree_in_oracle(name):
    b = BENCHMARKS[name]
    pa = b.make("a", "mini")
    inp = random_inputs(pa, seed=3, dtype=np.float64)
    ref = execute_numpy(pa, inp)[b.output]
    for var in ("b", "np"):
        out = execute_numpy(b.make(var, "mini"), inp)[b.output]
        np.testing.assert_allclose(out, ref, rtol=1e-9, atol=1e-11, err_msg=var)


@pytest.mark.parametrize("name", NAMES)
@pytest.mark.parametrize("variant", ["a", "b"])
def test_normalized_canonical_jax_matches(name, variant):
    b = BENCHMARKS[name]
    pa = b.make("a", "mini")
    inp = random_inputs(pa, seed=3, dtype=np.float64)
    ref = execute_numpy(pa, inp)[b.output]
    norm = normalize(b.make(variant, "mini"))
    assert np.allclose(execute_numpy(norm, inp)[b.output], ref, rtol=1e-9)
    out = run_jax(norm, inp, Schedule(mode="canonical", use_idioms=True))[b.output]
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("name", NAMES)
def test_as_written_jax_matches(name):
    b = BENCHMARKS[name]
    pa = b.make("a", "mini")
    inp = random_inputs(pa, seed=5, dtype=np.float64)
    ref = execute_numpy(pa, inp)[b.output]
    out = run_jax(pa, inp, Schedule(mode="as_written", use_idioms=False))[b.output]
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("name", ["gemm", "2mm", "3mm", "atax", "bicg", "gemver"])
def test_a_b_variants_normalize_to_same_fingerprints(name):
    """The paper's core claim: A and B reduce to the same canonical form."""
    b = BENCHMARKS[name]
    fa = sorted(fingerprint(n) for n in normalize(b.make("a", "mini")).body)
    fb = sorted(fingerprint(n) for n in normalize(b.make("b", "mini")).body)
    assert fa == fb
