"""Property-based tests (hypothesis): normalization preserves semantics on
random affine programs, and the scheduled JAX lowerings agree with the
numpy oracle."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (CI installs it via requirements.txt)",
)
from hypothesis import given, settings, strategies as st

from repro.core import (
    Access, Affine, Array, Computation, Loop, Program, Read, acc, aff,
    fingerprint, optimization_pipeline, program_fingerprint,
    Schedule, execute_numpy, normalize, run_jax,
)
from repro.core.scheduler import random_inputs

DIM = 6  # array extent per dim


@st.composite
def computations(draw, iterators, arrays, idx):
    """A computation whose write covers all iterators (deterministic)."""
    n_read = draw(st.integers(1, 2))
    accumulate = draw(st.sampled_from([None, "+", "+"]))
    wr_arr = draw(st.sampled_from([a for a in arrays if len(arrays[a]) == len(iterators)]))
    wr_idx = tuple(
        aff(it, const=draw(st.integers(0, DIM - 5))) for it in iterators
    )
    # permute write dims
    perm = draw(st.permutations(range(len(iterators))))
    wr_idx = tuple(wr_idx[p] for p in perm)
    reads = []
    for _ in range(n_read):
        arr = draw(st.sampled_from(list(arrays)))
        nd = len(arrays[arr])
        ridx = []
        for _ in range(nd):
            kind = draw(st.integers(0, 2))
            if kind == 0:
                ridx.append(aff(const=draw(st.integers(0, DIM - 1))))
            else:
                it = draw(st.sampled_from(list(iterators)))
                ridx.append(aff(it, const=draw(st.integers(0, DIM - 5))))
        reads.append(Access(arr, tuple(ridx)))
    coefs = [draw(st.floats(0.5, 2.0)) for _ in range(n_read)]

    def expr(*vals, _c=tuple(coefs)):
        out = 0.0
        for v, c in zip(vals, _c):
            out = out + c * v
        return out

    return Computation(f"c{idx}", Access(wr_arr, wr_idx), tuple(reads), expr,
                       accumulate=accumulate)


@st.composite
def programs(draw):
    arrays = {"A": (DIM,), "B": (DIM, DIM), "C": (DIM, DIM), "D": (DIM, DIM, DIM)}
    n_nests = draw(st.integers(1, 2))
    body = []
    for n in range(n_nests):
        depth = draw(st.integers(1, 3))
        its = [f"i{n}_{d}" for d in range(depth)]
        n_comps = draw(st.integers(1, 2))
        comps = tuple(
            draw(computations(its, arrays, f"{n}_{k}")) for k in range(n_comps)
        )
        nest = comps
        for it in reversed(its):
            trip = draw(st.integers(2, 4))
            nest = (Loop(it, trip, body=nest),)
        body.append(nest[0])
    return Program(
        "rand", tuple(Array(k, v) for k, v in arrays.items()), tuple(body)
    )


@settings(max_examples=40, deadline=None)
@given(programs())
def test_normalize_preserves_semantics(prog):
    inp = random_inputs(prog, seed=1, dtype=np.float64)
    ref = execute_numpy(prog, inp)
    got = execute_numpy(normalize(prog), inp)
    for name in prog.array_names:
        np.testing.assert_allclose(got[name], ref[name], rtol=1e-9, atol=1e-12)


@settings(max_examples=40, deadline=None)
@given(programs())
def test_normalize_idempotent(prog):
    n1 = normalize(prog)
    n2 = normalize(n1)
    assert [fingerprint(x) for x in n1.body] == [fingerprint(x) for x in n2.body]


@settings(max_examples=25, deadline=None)
@given(programs())
def test_jax_canonical_matches_oracle(prog):
    inp = random_inputs(prog, seed=2, dtype=np.float64)
    ref = execute_numpy(prog, inp)
    norm = normalize(prog)
    out = run_jax(norm, inp, Schedule(mode="canonical", use_idioms=True))
    for name in prog.array_names:
        np.testing.assert_allclose(
            np.asarray(out[name], dtype=np.float64), ref[name], rtol=2e-4, atol=1e-4
        )


@st.composite
def expr_pairs(draw, n_reads=3, depth=3):
    """A symbolic ``Expr`` tree plus the hand-written lambda it denotes,
    built from the same draws."""
    if depth == 0 or draw(st.booleans()):
        if draw(st.booleans()):
            i = draw(st.integers(0, n_reads - 1))
            return Read(i), (lambda *v, _i=i: v[_i])
        c = draw(st.floats(-2.0, 2.0, allow_nan=False))
        from repro.core import Const

        return Const(c), (lambda *v, _c=c: _c)
    op = draw(st.sampled_from(["add", "sub", "mul", "div", "min", "max", "neg"]))
    le, lf = draw(expr_pairs(n_reads=n_reads, depth=depth - 1))
    if op == "neg":
        return -le, (lambda *v, _f=lf: -_f(*v))
    re_, rf = draw(expr_pairs(n_reads=n_reads, depth=depth - 1))
    if op == "div":
        # keep the denominator away from zero
        re_, rf = re_ * re_ + 0.5, (lambda *v, _f=rf: _f(*v) * _f(*v) + 0.5)
    py = {"add": lambda a, b: a + b, "sub": lambda a, b: a - b,
          "mul": lambda a, b: a * b, "div": lambda a, b: a / b,
          "min": min, "max": max}[op]
    from repro.core.ir import emax, emin

    sym = {"add": lambda a, b: a + b, "sub": lambda a, b: a - b,
           "mul": lambda a, b: a * b, "div": lambda a, b: a / b,
           "min": emin, "max": emax}[op]
    return sym(le, re_), (lambda *v, _l=lf, _r=rf, _p=py: _p(_l(*v), _r(*v)))


@settings(max_examples=60, deadline=None)
@given(expr_pairs(), st.integers(0, 2**31 - 1))
def test_expr_to_callable_matches_handwritten_lambda(pair, seed):
    expr, ref = pair
    fn = expr.to_callable()
    vals = np.random.default_rng(seed).uniform(-3.0, 3.0, size=3)
    got, want = fn(*vals), ref(*vals)
    assert np.isclose(got, want, rtol=1e-12, atol=1e-12) or (
        np.isnan(got) and np.isnan(want))


@settings(max_examples=25, deadline=None)
@given(programs())
def test_rewrite_passes_identity_on_opaque_exprs(prog):
    """The generated programs use opaque closures, so licm/expand/cse must
    pass them through untouched: both pipelines land on the same program."""
    rw = optimization_pipeline(fuse=True, rewrite=True).run(prog)
    no = optimization_pipeline(fuse=True, rewrite=False).run(prog)
    assert program_fingerprint(rw) == program_fingerprint(no)


def test_polybench_builders_are_symbolic_and_callable():
    """The migrated builders carry Expr trees whose compiled callables match
    direct node evaluation on every computation."""
    from repro.core.ir import Expr, program_computations
    from repro.polybench import BENCHMARKS

    rng = np.random.default_rng(9)
    for name, bench in BENCHMARKS.items():
        prog = bench.make("a", "mini")
        for _, comp in program_computations(prog):
            assert isinstance(comp.expr, Expr), (name, comp.name)
            vals = rng.uniform(0.5, 2.0, size=len(comp.reads))
            assert np.isclose(comp.expr(*vals), comp.expr.to_callable()(*vals))


@settings(max_examples=15, deadline=None)
@given(programs())
def test_jax_as_written_matches_oracle(prog):
    inp = random_inputs(prog, seed=3, dtype=np.float64)
    ref = execute_numpy(prog, inp)
    out = run_jax(prog, inp, Schedule(mode="as_written", use_idioms=False))
    for name in prog.array_names:
        np.testing.assert_allclose(
            np.asarray(out[name], dtype=np.float64), ref[name], rtol=2e-4, atol=1e-4
        )
