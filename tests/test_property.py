"""Property-based tests (hypothesis): normalization preserves semantics on
random affine programs, and the scheduled JAX lowerings agree with the
numpy oracle."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (CI installs it via requirements.txt)",
)
from hypothesis import given, settings, strategies as st

from repro.core import (
    Access, Affine, Array, Computation, Loop, Program, acc, aff, fingerprint,
    Schedule, execute_numpy, normalize, run_jax,
)
from repro.core.scheduler import random_inputs

DIM = 6  # array extent per dim


@st.composite
def computations(draw, iterators, arrays, idx):
    """A computation whose write covers all iterators (deterministic)."""
    n_read = draw(st.integers(1, 2))
    accumulate = draw(st.sampled_from([None, "+", "+"]))
    wr_arr = draw(st.sampled_from([a for a in arrays if len(arrays[a]) == len(iterators)]))
    wr_idx = tuple(
        aff(it, const=draw(st.integers(0, DIM - 5))) for it in iterators
    )
    # permute write dims
    perm = draw(st.permutations(range(len(iterators))))
    wr_idx = tuple(wr_idx[p] for p in perm)
    reads = []
    for _ in range(n_read):
        arr = draw(st.sampled_from(list(arrays)))
        nd = len(arrays[arr])
        ridx = []
        for _ in range(nd):
            kind = draw(st.integers(0, 2))
            if kind == 0:
                ridx.append(aff(const=draw(st.integers(0, DIM - 1))))
            else:
                it = draw(st.sampled_from(list(iterators)))
                ridx.append(aff(it, const=draw(st.integers(0, DIM - 5))))
        reads.append(Access(arr, tuple(ridx)))
    coefs = [draw(st.floats(0.5, 2.0)) for _ in range(n_read)]

    def expr(*vals, _c=tuple(coefs)):
        out = 0.0
        for v, c in zip(vals, _c):
            out = out + c * v
        return out

    return Computation(f"c{idx}", Access(wr_arr, wr_idx), tuple(reads), expr,
                       accumulate=accumulate)


@st.composite
def programs(draw):
    arrays = {"A": (DIM,), "B": (DIM, DIM), "C": (DIM, DIM), "D": (DIM, DIM, DIM)}
    n_nests = draw(st.integers(1, 2))
    body = []
    for n in range(n_nests):
        depth = draw(st.integers(1, 3))
        its = [f"i{n}_{d}" for d in range(depth)]
        n_comps = draw(st.integers(1, 2))
        comps = tuple(
            draw(computations(its, arrays, f"{n}_{k}")) for k in range(n_comps)
        )
        nest = comps
        for it in reversed(its):
            trip = draw(st.integers(2, 4))
            nest = (Loop(it, trip, body=nest),)
        body.append(nest[0])
    return Program(
        "rand", tuple(Array(k, v) for k, v in arrays.items()), tuple(body)
    )


@settings(max_examples=40, deadline=None)
@given(programs())
def test_normalize_preserves_semantics(prog):
    inp = random_inputs(prog, seed=1, dtype=np.float64)
    ref = execute_numpy(prog, inp)
    got = execute_numpy(normalize(prog), inp)
    for name in prog.array_names:
        np.testing.assert_allclose(got[name], ref[name], rtol=1e-9, atol=1e-12)


@settings(max_examples=40, deadline=None)
@given(programs())
def test_normalize_idempotent(prog):
    n1 = normalize(prog)
    n2 = normalize(n1)
    assert [fingerprint(x) for x in n1.body] == [fingerprint(x) for x in n2.body]


@settings(max_examples=25, deadline=None)
@given(programs())
def test_jax_canonical_matches_oracle(prog):
    inp = random_inputs(prog, seed=2, dtype=np.float64)
    ref = execute_numpy(prog, inp)
    norm = normalize(prog)
    out = run_jax(norm, inp, Schedule(mode="canonical", use_idioms=True))
    for name in prog.array_names:
        np.testing.assert_allclose(
            np.asarray(out[name], dtype=np.float64), ref[name], rtol=2e-4, atol=1e-4
        )


@settings(max_examples=15, deadline=None)
@given(programs())
def test_jax_as_written_matches_oracle(prog):
    inp = random_inputs(prog, seed=3, dtype=np.float64)
    ref = execute_numpy(prog, inp)
    out = run_jax(prog, inp, Schedule(mode="as_written", use_idioms=False))
    for name in prog.array_names:
        np.testing.assert_allclose(
            np.asarray(out[name], dtype=np.float64), ref[name], rtol=2e-4, atol=1e-4
        )
