"""Shared autotune core: telemetry, deadline budgets, swap policy, the
online SearchSupervisor (search -> validate -> hot-swap -> rollback), and
fleet fold-back."""
import math
from types import SimpleNamespace

import jax
import numpy as np
import pytest

import repro.autotune as A
import repro.core.search as S
from repro.autotune import (
    NestTelemetry,
    SearchSupervisor,
    SwapPolicy,
    build_program,
    logit_pipeline_program,
    online_search_task,
    run_supervised,
)
from repro.core import Daisy, TuningDatabase, fingerprint
from repro.core.embedding import embed_nest
from repro.core.recipes import Recipe
from repro.fault import Fault, FaultPlan


def stale_database(prog, backend="xla", measured_us=2500.0):
    """A deliberately mistuned pretuned database: every canonical nest of
    ``prog`` pinned to the slow ``sequential`` recipe."""
    d = Daisy(backend=backend)
    p = d._normalized(prog)
    db = TuningDatabase()
    for nest in p.body:
        db.add(fingerprint(nest), embed_nest(p, nest),
               Recipe(kind="sequential", notes="stale"),
               provenance="stale-pretuned", measured_us=measured_us)
    db.meta["backend"] = backend
    return db


def nest_coords(prog, backend="xla"):
    """(fingerprint, embedding) of the single canonical nest of ``prog``."""
    d = Daisy(backend=backend)
    p = d._normalized(prog)
    assert len(p.body) == 1
    return fingerprint(p.body[0]), embed_nest(p, p.body[0])


def fake_result(fp, emb, cand, cand_us, inc, inc_us, program_key,
                name="logit_pipeline"):
    return {"fingerprint": fp, "embedding": np.asarray(emb).tolist(),
            "recipe": cand.to_json(), "measured_us": cand_us,
            "provenance": "online:test", "incumbent": inc.to_json(),
            "incumbent_us": inc_us, "name": name, "nest_index": 0,
            "program_key": program_key}


class TestTelemetry:
    def test_ema_count_total(self):
        t = NestTelemetry(alpha=0.5)
        t.observe("k", 1.0)
        assert t.ema("k") == 1.0  # first observation seeds the EMA
        t.observe("k", 3.0)
        assert t.ema("k") == pytest.approx(2.0)
        assert t.count("k") == 2
        assert t.snapshot()["k"]["total_s"] == pytest.approx(4.0)

    def test_disabled_is_noop(self):
        t = NestTelemetry(enabled=False)
        t.observe("k", 1.0)
        assert t.ema("k") is None and t.count("k") == 0
        assert t.snapshot() == {}

    def test_hottest_ranks_by_total_time(self):
        t = NestTelemetry()
        for _ in range(10):
            t.observe("warm", 0.01)  # many cheap steps
        t.observe("hot", 1.0)        # one expensive step dominates
        assert [k for k, _ in t.hottest(2)] == ["hot", "warm"]

    def test_reset(self):
        t = NestTelemetry()
        t.observe("k", 1.0)
        t.reset("k")
        assert t.ema("k") is None and t.count("k") == 0


class TestDeadline:
    @staticmethod
    def _fake_measure(calls):
        def fake(nprog, inputs, recipe, repeats=3, interpret=True):
            calls.append(recipe)
            # deterministic pseudo-fitness from the recipe's content
            return 1.0 + (hash(repr(recipe)) % 97) / 10.0
        return fake

    def test_unbounded_and_roomy_deadline_walk_identical_sequences(
            self, monkeypatch):
        seed = Recipe(kind="vectorize")
        calls1, calls2 = [], []
        monkeypatch.setattr(S, "measure_recipe", self._fake_measure(calls1))
        r1 = S.evolve_recipe(None, {}, seed, iterations=3, population=4,
                             rng_seed=5)
        monkeypatch.setattr(S, "measure_recipe", self._fake_measure(calls2))
        r2 = S.evolve_recipe(None, {}, seed, iterations=3, population=4,
                             rng_seed=5, deadline_s=1e6)
        assert r1 == r2
        assert calls1 == calls2  # same RNG walk, same candidates measured

    def test_expired_deadline_returns_partial_best(self, monkeypatch):
        calls = []
        monkeypatch.setattr(S, "measure_recipe", self._fake_measure(calls))
        seed = Recipe(kind="vectorize")
        best, t = S.evolve_recipe(None, {}, seed, iterations=50,
                                  population=8, rng_seed=0, deadline_s=0.0)
        # only the seed was measured before the budget expired
        assert len(calls) == 1 and math.isfinite(t)
        assert best == seed

    def test_seed_nest_threads_deadline(self):
        prog = logit_pipeline_program(vocab=32, slots=2)
        d = Daisy()
        p = d._normalized(prog)
        fp, _emb, recipe, t, prov = d.seed_nest(
            p, p.body[0], search=True, search_iterations=50, population=8,
            repeats=1, deadline_s=0.0)
        # the 50x8 search was cut to the seed measurement: finishes fast
        # and still returns a measured recipe
        assert math.isfinite(t) and recipe is not None


class TestSwapPolicy:
    def test_margin(self):
        p = SwapPolicy(margin=0.1)
        assert p.accepts(89.0, 100.0)        # beats by >10%
        assert not p.accepts(95.0, 100.0)    # inside the margin
        assert not p.accepts(100.0, 100.0)

    def test_non_finite(self):
        p = SwapPolicy()
        assert not p.accepts(float("inf"), 100.0)
        assert not p.accepts(float("nan"), 100.0)
        assert p.accepts(100.0, float("inf"))  # unmeasurable incumbent

    def test_chain(self):
        assert SwapPolicy().chain_for("xla") == ("xla",)
        assert SwapPolicy().chain_for("pallas") == ("pallas", "xla")
        assert SwapPolicy(validate_backends=("xla",)).chain_for("pallas") \
            == ("xla",)


class TestSupervisorDecisions:
    """Swap-policy behaviour driven by synthetic search results (the real
    search path is covered by TestOnlineEndToEnd and the benchmark)."""

    def setup_method(self):
        self.prog = logit_pipeline_program(vocab=32, slots=2)
        self.db = stale_database(self.prog)
        self.fp, self.emb = nest_coords(self.prog)
        self.inc = self.db.lookup_exact(self.fp)

    def _sup(self, **kw):
        kw.setdefault("mode", "sync")
        sup = SearchSupervisor(self.db, **kw)
        key = sup.register(self.prog)
        return sup, key

    def test_winning_candidate_swaps_and_bumps_generation(self):
        sup, key = self._sup(policy=SwapPolicy(margin=0.05))
        gen0 = self.db.generation
        sup._results.put(fake_result(self.fp, self.emb,
                                     Recipe(kind="vectorize"), 100.0,
                                     self.inc, 1000.0, key))
        swaps = sup.poll()
        assert len(swaps) == 1 and not swaps[0].rolled_back
        assert self.db.generation > gen0
        assert self.db.lookup_exact(self.fp).kind == "vectorize"

    def test_worse_candidate_rejected_incumbent_untouched(self):
        sup, key = self._sup(policy=SwapPolicy(margin=0.1))
        gen0 = self.db.generation
        sup._results.put(fake_result(self.fp, self.emb,
                                     Recipe(kind="vectorize"), 990.0,
                                     self.inc, 1000.0, key))
        assert sup.poll() == []
        assert sup.rejected and "margin" in sup.rejected[0]["reason"]
        assert self.db.generation == gen0
        assert self.db.lookup_exact(self.fp).kind == "sequential"

    def test_failing_candidate_rejected_by_validation(self):
        plan = FaultPlan([Fault("daisy.compile", "error", key="xla",
                                times=-1)])
        sup, key = self._sup(policy=SwapPolicy(margin=0.05),
                             fault_plan=plan)
        gen0 = self.db.generation
        sup._results.put(fake_result(self.fp, self.emb,
                                     Recipe(kind="vectorize"), 100.0,
                                     self.inc, 1000.0, key))
        assert sup.poll() == []
        assert sup.rejected and "validation" in sup.rejected[0]["reason"]
        assert self.db.generation == gen0
        assert self.db.lookup_exact(self.fp).kind == "sequential"

    def test_degraded_candidate_records_on_engine_degradations(self):
        # first validation rung (pallas_interpret) faulted -> the candidate
        # validates on the xla rung and the degradation is recorded on the
        # engine, exactly like compile_resilient does
        plan = FaultPlan([Fault("daisy.compile", "error",
                                key="pallas_interpret")])
        db = stale_database(self.prog, backend="pallas_interpret")
        sup = SearchSupervisor(db, backend="pallas_interpret", mode="sync",
                               policy=SwapPolicy(margin=0.05),
                               fault_plan=plan)
        key = sup.register(self.prog)
        engine = SimpleNamespace(degradations=[])
        sup._results.put(fake_result(self.fp, self.emb,
                                     Recipe(kind="vectorize"), 100.0,
                                     self.inc, 1000.0, key))
        swaps = sup.poll(engine=engine)
        assert len(swaps) == 1 and swaps[0].degraded_to == "xla"
        assert engine.degradations == [
            ("logit_pipeline", "pallas_interpret", "xla")]

    def test_post_swap_regression_rolls_back_and_quarantines(self):
        sup, key = self._sup(
            policy=SwapPolicy(margin=0.05, rollback_ratio=1.5,
                              rollback_window=3))
        for _ in range(4):  # pre-swap EMA ~1ms
            sup.telemetry.observe(key, 0.001)
        sup._results.put(fake_result(self.fp, self.emb,
                                     Recipe(kind="vectorize"), 100.0,
                                     self.inc, 1000.0, key))
        [rec] = sup.poll()
        gen_after_swap = self.db.generation
        for _ in range(3):  # post-swap steps regress 10x
            sup.telemetry.observe(key, 0.01)
        assert sup.poll() == []
        assert rec.rolled_back
        assert self.db.lookup_exact(self.fp).kind == "sequential"
        assert self.db.generation > gen_after_swap  # un-swap = another bump
        assert self.fp in sup.quarantined

    def test_healthy_swap_watch_disarms_silently(self):
        sup, key = self._sup(
            policy=SwapPolicy(margin=0.05, rollback_ratio=1.5,
                              rollback_window=3))
        for _ in range(4):
            sup.telemetry.observe(key, 0.001)
        sup._results.put(fake_result(self.fp, self.emb,
                                     Recipe(kind="vectorize"), 100.0,
                                     self.inc, 1000.0, key))
        [rec] = sup.poll()
        for _ in range(3):  # post-swap steps improved, as promised
            sup.telemetry.observe(key, 0.0005)
        sup.poll()
        assert not rec.rolled_back and not sup.quarantined
        assert self.db.lookup_exact(self.fp).kind == "vectorize"

    def test_fold_back_merges_and_counts_swaps(self, tmp_path):
        sup, key = self._sup(policy=SwapPolicy(margin=0.05))
        sup._results.put(fake_result(self.fp, self.emb,
                                     Recipe(kind="vectorize"), 100.0,
                                     self.inc, 1000.0, key))
        sup.poll()
        fleet = tmp_path / "fleet.json"
        report = sup.fold_back(fleet)
        assert report["added"] == len(self.db.entries)
        disk = TuningDatabase.load(fleet)
        assert disk.lookup_exact(self.fp).kind == "vectorize"
        assert disk.meta["online_swaps"] == 1
        # a second deployment folding back the same winner composes
        report2 = sup.fold_back(fleet)
        assert report2["added"] == 0


class TestSupervisedOnlineSearch:
    def test_online_search_task_reports_incumbent_and_candidate(self):
        prog = logit_pipeline_program(vocab=64, slots=2)
        db = stale_database(prog)
        fp, _ = nest_coords(prog)
        task = {"name": prog.name, "nest_index": 0, "backend": "xla",
                "fingerprint": fp, "iterations": 1, "population": 2,
                "repeats": 1, "deadline_s": 30.0, "program_key": "k",
                "incumbent": db.lookup_exact(fp).to_json(), "program": prog}
        results, quarantined = run_supervised(
            [task], jobs=1, verbose=False, worker=online_search_task)
        assert not quarantined and len(results) == 1
        r = results[0]
        assert r["fingerprint"] == fp and r["program_key"] == "k"
        assert math.isfinite(r["incumbent_us"])
        # the sequential incumbent is far off the pace at this shape: the
        # one-iteration search must already beat it
        assert r["measured_us"] < r["incumbent_us"]

    def test_poison_online_search_is_quarantined_not_raised(self):
        prog = logit_pipeline_program(vocab=32, slots=2)
        fp, _ = nest_coords(prog)
        plan = FaultPlan([Fault("tune.worker", "error", key=fp, times=-1)])
        task = {"name": prog.name, "nest_index": 0, "backend": "xla",
                "fingerprint": fp, "iterations": 1, "population": 2,
                "repeats": 1, "program_key": "k", "incumbent": None,
                "program": prog}
        results, quarantined = run_supervised(
            [task], jobs=1, verbose=False, max_task_retries=1,
            fault_plan=plan, worker=online_search_task)
        assert results == [] and fp in quarantined

    def test_supervisor_survives_poison_round(self):
        prog = logit_pipeline_program(vocab=32, slots=2)
        db = stale_database(prog)
        fp, _ = nest_coords(prog)
        plan = FaultPlan([Fault("tune.worker", "error", key=fp, times=-1)])
        sup = SearchSupervisor(db, mode="sync", fault_plan=plan,
                               max_task_retries=1,
                               policy=SwapPolicy(min_observations=1))
        key = sup.register(prog)
        sup.telemetry.observe(key, 0.01)
        assert sup.maybe_launch() == 1
        sup.poll()
        assert fp in sup.quarantined
        # quarantined nests are never re-launched
        assert sup.maybe_launch() == 0


class TestRegistry:
    def test_build_program_import_coordinates(self):
        p = build_program("import", "repro.autotune:logit_pipeline_program",
                          kwargs={"vocab": 32, "slots": 2})
        assert p.name == "logit_pipeline"
        assert dict((a.name, a.shape) for a in p.arrays)["X"] == (32, 2)

    def test_build_program_import_rejects_bad_name(self):
        with pytest.raises(ValueError, match="module:function"):
            build_program("import", "no-colon-here")

    def test_tools_tune_reexports_are_the_shared_core(self):
        import repro.tools.tune as T

        assert T._tune_nest is A.tune_nest_task
        assert T._run_tasks is A.run_supervised
        assert T._task_key is A.task_key
        assert T._PoolStall is A.PoolStall
        assert T.build_program is A.build_program
        assert T.program_specs is A.program_specs

    def test_spawn_registration_requires_builder(self):
        prog = logit_pipeline_program(vocab=32, slots=2)
        sup = SearchSupervisor(stale_database(prog), mode="spawn")
        with pytest.raises(ValueError, match="builder"):
            sup.register(prog)


class TestOnlineEndToEnd:
    """The full loop against a live engine: stale database -> telemetry ->
    sync search -> validated swap -> bit-identical tokens."""

    @pytest.fixture(scope="class")
    def setup(self):
        from repro.configs import get_config
        from repro.models import model as M

        cfg = get_config("minicpm-2b").reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        prog = logit_pipeline_program(vocab=cfg.vocab, slots=2)
        rng = np.random.default_rng(7)
        aux = {"B": rng.normal(0, 0.5, cfg.vocab).astype(np.float32),
               "S": np.full(cfg.vocab, 1.1, np.float32),
               "G": np.full(cfg.vocab, 0.9, np.float32),
               "F": np.full(cfg.vocab, -1e9, np.float32),
               "K": np.full(cfg.vocab, 1e9, np.float32)}
        prompts = [rng.integers(1, cfg.vocab, size=int(n)).astype(np.int32)
                   for n in rng.integers(3, 9, size=6)]
        return cfg, params, prog, aux, prompts

    def _run(self, setup, tuner=None, db=None):
        from repro.serve.engine import ServeConfig, ServingEngine

        cfg, params, prog, aux, prompts = setup
        scfg = ServeConfig(batch_slots=2, max_len=64, max_new_tokens=6)
        eng = ServingEngine(cfg, params, scfg, tuning_db=db,
                            logit_program=prog, logit_inputs=aux,
                            tuner=tuner)
        for p in prompts:
            eng.submit(p)
        return eng, eng.drain()

    def test_adaptive_swap_is_bit_identical(self, setup):
        cfg, params, prog, aux, prompts = setup
        _, baseline = self._run(setup, db=stale_database(prog))

        sup = SearchSupervisor(
            stale_database(prog), mode="sync", check_every=4,
            iterations=1, population=2, repeats=1, deadline_s=30.0,
            policy=SwapPolicy(margin=0.05, min_observations=2))
        eng, adapted = self._run(setup, tuner=sup)
        assert len(sup.swaps) >= 1, \
            f"no swap landed (rejected: {sup.rejected})"
        assert sup.db.lookup_exact(sup.swaps[0].fingerprint).kind != \
            "sequential"
        # the hot-swap changed the lowering, never the tokens
        assert adapted == baseline
        # the engine observed its program's timings under its fingerprint
        assert eng.telemetry.count(eng._telemetry_key) > 0

    def test_tuner_db_mismatch_rejected(self, setup):
        from repro.serve.engine import ServeConfig, ServingEngine

        cfg, params, prog, aux, _ = setup
        sup = SearchSupervisor(stale_database(prog), mode="sync")
        with pytest.raises(ValueError, match="tuner.db"):
            ServingEngine(cfg, params, ServeConfig(batch_slots=2, max_len=64),
                          tuning_db=TuningDatabase(), tuner=sup,
                          logit_program=prog, logit_inputs=aux)

    def test_unknown_logit_input_rejected(self, setup):
        from repro.serve.engine import ServeConfig, ServingEngine

        cfg, params, prog, aux, _ = setup
        bad = dict(aux, TYPO=np.zeros(cfg.vocab, np.float32))
        with pytest.raises(ValueError, match="TYPO"):
            ServingEngine(cfg, params, ServeConfig(batch_slots=2, max_len=64),
                          logit_program=prog, logit_inputs=bad)

    def test_wrong_program_shape_rejected(self, setup):
        from repro.serve.engine import ServeConfig, ServingEngine

        cfg, params, _, _, _ = setup
        wrong = logit_pipeline_program(vocab=cfg.vocab, slots=3)  # != slots
        with pytest.raises(ValueError, match="batch_slots"):
            ServingEngine(cfg, params, ServeConfig(batch_slots=2, max_len=64),
                          logit_program=wrong)


class TestTrainerTelemetry:
    def test_trainer_observes_step_times(self, tmp_path):
        from repro.configs import get_config
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.train.train_loop import Trainer, TrainerConfig

        cfg = get_config("minicpm-2b").reduced()
        tel = NestTelemetry()
        tr = Trainer(cfg, AdamWConfig(),
                     DataConfig(seq_len=16, global_batch=2, vocab=cfg.vocab),
                     TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=100),
                     telemetry=tel)
        tr.run(3)
        assert tel.count(tr._telemetry_key) == 3
        assert tel.ema(tr._telemetry_key) > 0

    def test_trainer_default_telemetry_disabled(self, tmp_path):
        from repro.configs import get_config
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.train.train_loop import Trainer, TrainerConfig

        cfg = get_config("minicpm-2b").reduced()
        tr = Trainer(cfg, AdamWConfig(),
                     DataConfig(seq_len=16, global_batch=2, vocab=cfg.vocab),
                     TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=100))
        tr.run(2)
        assert tr.telemetry.count(tr._telemetry_key) == 0
