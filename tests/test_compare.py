"""benchmarks/compare.py: the perf-trend gate (pure stdlib, no jax)."""
import json

import pytest

from benchmarks import compare as C


def write(path, data):
    path.write_text(json.dumps(data))


@pytest.fixture
def dirs(tmp_path):
    base = tmp_path / "baseline"
    cur = tmp_path / "current"
    base.mkdir()
    cur.mkdir()
    return base, cur


BENCH = {
    "recurrence": {"fori_us": 1000.0, "scan_us": 100.0, "speedup": 10.0,
                   "speedup_ok": True},
    "chain": {"chain_vectorize_full": 50.0},
    "meta": {"devices": 8},
}


class TestMetrics:
    def test_flatten_skips_bools(self):
        flat = C.flatten_metrics(BENCH)
        assert flat["recurrence.scan_us"] == 100.0
        assert "recurrence.speedup_ok" not in flat
        assert flat["meta.devices"] == 8.0

    def test_direction(self):
        assert C.metric_direction("x:recurrence.scan_us") == "lower"
        assert C.metric_direction("x:a.us_per_call") == "lower"
        assert C.metric_direction("x:recurrence.speedup") == "higher"
        assert C.metric_direction("x:throughput.tokens_per_sec") == "higher"
        assert C.metric_direction("x:meta.devices") is None

    def test_median_odd_even_and_partial(self):
        s1 = {"a": 1.0, "b": 10.0}
        s2 = {"a": 3.0, "b": 20.0, "c": 7.0}
        s3 = {"a": 100.0}
        med = C.median_metrics([s1, s2, s3])
        assert med["a"] == 3.0          # odd count -> middle sample
        assert med["b"] == 15.0         # even count -> mean of middle two
        assert med["c"] == 7.0          # present in one sample only

    def test_collect_dir_keys_by_stem(self, dirs):
        base, _ = dirs
        write(base / "bench_tiling.json", BENCH)
        got = C.collect_dir(str(base))
        assert got["bench_tiling:recurrence.scan_us"] == 100.0

    def test_aggregate_min_is_direction_aware(self):
        s1 = {"x:a_us": 100.0, "x:speedup": 2.0, "x:meta.n": 1.0}
        s2 = {"x:a_us": 50.0, "x:speedup": 8.0, "x:meta.n": 3.0}
        s3 = {"x:a_us": 200.0, "x:speedup": 4.0, "x:meta.n": 5.0}
        agg = C.aggregate_metrics([s1, s2, s3], stat="min")
        assert agg["x:a_us"] == 50.0      # lower-better -> min sample
        assert agg["x:speedup"] == 8.0    # higher-better -> max sample
        assert agg["x:meta.n"] == 3.0     # ungated -> stays at the median

    def test_aggregate_median_matches_median_metrics(self):
        samples = [{"x:a_us": 1.0}, {"x:a_us": 3.0}, {"x:a_us": 2.0}]
        assert C.aggregate_metrics(samples) == C.median_metrics(samples)

    def test_aggregate_rejects_unknown_stat(self):
        with pytest.raises(ValueError, match="median|min"):
            C.aggregate_metrics([{"x:a_us": 1.0}], stat="mean")


class TestCompare:
    def test_no_regression_passes(self):
        cur = {"b:t_us": 110.0, "b:speedup": 9.0}
        base = {"b:t_us": 100.0, "b:speedup": 10.0}
        assert C.compare(base, cur, threshold=0.25) == []

    def test_time_regression_detected(self):
        bad = C.compare({"b:t_us": 100.0}, {"b:t_us": 130.0}, threshold=0.25)
        assert len(bad) == 1 and bad[0]["metric"] == "b:t_us"

    def test_speedup_regression_detected(self):
        bad = C.compare({"b:speedup": 10.0}, {"b:speedup": 7.0}, threshold=0.25)
        assert len(bad) == 1 and bad[0]["direction"] == "higher"

    def test_new_and_retired_metrics_do_not_gate(self):
        assert C.compare({"old:t_us": 1.0}, {"new:t_us": 99.0}) == []

    def test_ungated_metadata_ignored(self):
        assert C.compare({"b:devices": 8.0}, {"b:devices": 1.0}) == []


class TestMain:
    def test_injected_regression_exits_nonzero(self, dirs):
        base, cur = dirs
        write(base / "bench_tiling.json", BENCH)
        slow = json.loads(json.dumps(BENCH))
        slow["recurrence"]["scan_us"] = 100.0 * 1.3  # >25% slower
        write(cur / "bench_tiling.json", slow)
        rc = C.main(["--baseline", str(base), "--current", str(cur)])
        assert rc == 1

    def test_within_threshold_passes(self, dirs):
        base, cur = dirs
        write(base / "bench_tiling.json", BENCH)
        ok = json.loads(json.dumps(BENCH))
        ok["recurrence"]["scan_us"] = 100.0 * 1.2  # under 25%
        write(cur / "bench_tiling.json", ok)
        assert C.main(["--baseline", str(base), "--current", str(cur)]) == 0

    def test_missing_baseline_is_first_run(self, dirs):
        base, cur = dirs
        write(cur / "bench_x.json", BENCH)
        assert C.main(["--baseline", str(base), "--current", str(cur)]) == 0

    def test_empty_current_is_an_error(self, dirs):
        base, cur = dirs
        assert C.main(["--baseline", str(base), "--current", str(cur)]) == 2

    def test_history_merges_and_rolls(self, dirs, tmp_path):
        base, cur = dirs
        write(cur / "bench_x.json", BENCH)
        hist = tmp_path / "BENCH_history.json"
        for sha in ("aaa", "bbb"):
            rc = C.main(["--baseline", str(base), "--current", str(cur),
                         "--history-out", str(hist), "--run-id", sha])
            assert rc == 0
        entries = json.loads(hist.read_text())
        assert [e["run"] for e in entries] == ["aaa", "bbb"]
        assert entries[-1]["metrics"]["bench_x:recurrence.scan_us"] == 100.0

    def test_history_as_baseline(self, dirs, tmp_path):
        base, cur = dirs
        write(cur / "bench_x.json", BENCH)
        hist = tmp_path / "BENCH_history.json"
        C.main(["--baseline", str(base), "--current", str(cur),
                "--history-out", str(hist), "--run-id", "aaa"])
        slow = json.loads(json.dumps(BENCH))
        slow["recurrence"]["scan_us"] = 200.0
        write(cur / "bench_x.json", slow)
        rc = C.main(["--baseline", str(hist), "--current", str(cur)])
        assert rc == 1

    def test_repeat_dirs_gate_on_median(self, dirs, tmp_path):
        """One noisy sample out of three must not trip the gate; a majority
        regression must."""
        base, _ = dirs
        write(base / "bench_x.json", BENCH)
        reps = []
        for i, scan_us in enumerate((100.0, 105.0, 400.0)):  # median 105: ok
            d = tmp_path / f"rep{i}"
            d.mkdir()
            noisy = json.loads(json.dumps(BENCH))
            noisy["recurrence"]["scan_us"] = scan_us
            write(d / "bench_x.json", noisy)
            reps.append(str(d))
        assert C.main(["--baseline", str(base), "--current", *reps]) == 0
        # now two of three samples regress -> median regresses -> gate fails
        slow = json.loads(json.dumps(BENCH))
        slow["recurrence"]["scan_us"] = 300.0
        write(tmp_path / "rep1" / "bench_x.json", slow)
        assert C.main(["--baseline", str(base), "--current", *reps]) == 1

    def test_history_records_repeat_count(self, dirs, tmp_path):
        base, _ = dirs
        reps = []
        for i in range(3):
            d = tmp_path / f"r{i}"
            d.mkdir()
            write(d / "bench_x.json", BENCH)
            reps.append(str(d))
        hist = tmp_path / "BENCH_history.json"
        assert C.main(["--baseline", str(base), "--current", *reps,
                       "--history-out", str(hist), "--run-id", "sha1"]) == 0
        entry = json.loads(hist.read_text())[-1]
        assert entry["repeats"] == 3
        assert entry["metrics"]["bench_x:recurrence.scan_us"] == 100.0

    def test_stat_min_survives_majority_noise(self, dirs, tmp_path):
        """Two of three samples interfered-with: the median gate fails but
        --stat min gates on the clean sample and passes; the history entry
        records which stat produced its metrics."""
        base, _ = dirs
        write(base / "bench_x.json", BENCH)
        reps = []
        for i, scan_us in enumerate((100.0, 300.0, 400.0)):
            d = tmp_path / f"rep{i}"
            d.mkdir()
            noisy = json.loads(json.dumps(BENCH))
            noisy["recurrence"]["scan_us"] = scan_us
            write(d / "bench_x.json", noisy)
            reps.append(str(d))
        assert C.main(["--baseline", str(base), "--current", *reps]) == 1
        hist = tmp_path / "BENCH_history.json"
        assert C.main(["--baseline", str(base), "--current", *reps,
                       "--stat", "min", "--history-out", str(hist),
                       "--run-id", "sha2"]) == 0
        entry = json.loads(hist.read_text())[-1]
        assert entry["stat"] == "min"
        assert entry["metrics"]["bench_x:recurrence.scan_us"] == 100.0

    def test_stat_min_still_fails_on_real_regression(self, dirs, tmp_path):
        """A regression present in EVERY repeat trips the gate even at min."""
        base, _ = dirs
        write(base / "bench_x.json", BENCH)
        reps = []
        for i in range(2):
            d = tmp_path / f"rep{i}"
            d.mkdir()
            slow = json.loads(json.dumps(BENCH))
            slow["recurrence"]["scan_us"] = 300.0
            write(d / "bench_x.json", slow)
            reps.append(str(d))
        assert C.main(["--baseline", str(base), "--current", *reps,
                       "--stat", "min"]) == 1

    def test_empty_repeat_dir_skipped(self, dirs, tmp_path):
        """A dir without bench JSONs (e.g. job not run) doesn't poison the
        median — only non-empty sample dirs count."""
        base, cur = dirs
        write(base / "bench_x.json", BENCH)
        write(cur / "bench_x.json", BENCH)
        empty = tmp_path / "empty"
        empty.mkdir()
        assert C.main(["--baseline", str(base),
                       "--current", str(cur), str(empty)]) == 0

    def test_corrupt_baseline_file_skipped(self, dirs):
        base, cur = dirs
        (base / "bench_bad.json").write_text("{not json")
        write(cur / "bench_bad.json", BENCH)
        assert C.main(["--baseline", str(base), "--current", str(cur)]) == 0
