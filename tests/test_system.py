"""End-to-end behaviour tests: the paper's pipeline operating as a system.

The miniature Fig. 6 experiment: seed daisy from the A variants of several
benchmarks, compile the *B* variants through normalization + transfer
tuning, and verify (a) correctness, (b) recipe reuse (every B nest resolves
from the database), (c) A/B schedule equality — the structural form of
"same semantics, same performance".
"""
import numpy as np
import pytest

from repro.core import Daisy, execute_numpy, fingerprint, normalize
from repro.core.scheduler import random_inputs
from repro.polybench import BENCHMARKS

SUBSET = ("gemm", "2mm", "atax", "bicg", "gesummv", "jacobi-2d")


@pytest.fixture(scope="module")
def daisy():
    d = Daisy()
    d.seed([BENCHMARKS[n].make("a", "mini") for n in SUBSET], search=False)
    return d


@pytest.mark.parametrize("name", SUBSET)
def test_b_variant_compiles_correctly_from_a_seeds(daisy, name):
    b = BENCHMARKS[name]
    prog = b.make("b", "mini")
    fn, plan = daisy.compile(prog)
    inp = random_inputs(prog, seed=17)
    out = fn(inp)
    ref = execute_numpy(prog, {k: v.astype(np.float64) for k, v in inp.items()})
    np.testing.assert_allclose(
        np.asarray(out[b.output], np.float64), ref[b.output], rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("name", SUBSET)
def test_a_and_b_get_identical_schedules(daisy, name):
    """Normalization maps both variants to the same canonical nests, so the
    scheduler must produce the same (fingerprint, recipe) plan — the paper's
    robustness claim in its strongest (structural) form."""
    b = BENCHMARKS[name]
    _, plan_a = daisy.compile(b.make("a", "mini"))
    _, plan_b = daisy.compile(b.make("b", "mini"))
    sched_a = sorted((p.fingerprint, p.recipe.kind) for p in plan_a.nests)
    sched_b = sorted((p.fingerprint, p.recipe.kind) for p in plan_b.nests)
    assert sched_a == sched_b


def test_cross_language_variant_reuses_database(daisy):
    """§4.3: the NumPy-style composition resolves against the C-seeded DB."""
    b = BENCHMARKS["gemm"]
    fn, plan = daisy.compile(b.make("np", "mini"))
    assert all(p.source == "exact" for p in plan.nests)
    inp = random_inputs(b.make("np", "mini"), seed=23)
    out = fn(inp)
    ref = execute_numpy(b.make("a", "mini"), {k: v.astype(np.float64) for k, v in inp.items()})
    np.testing.assert_allclose(
        np.asarray(out[b.output], np.float64), ref[b.output], rtol=2e-3, atol=2e-3
    )


def test_database_grows_sublinearly_with_variants():
    """Normalization collapses the variant space: adding B and NumPy variants
    of already-seeded benchmarks must add ~no new entries."""
    d = Daisy()
    d.seed([BENCHMARKS[n].make("a", "mini") for n in ("gemm", "2mm")], search=False)
    n_after_a = len(d.db.entries)
    d.seed([BENCHMARKS[n].make(v, "mini") for n in ("gemm", "2mm") for v in ("b", "np")],
           search=False)
    assert len(d.db.entries) <= n_after_a + 1
