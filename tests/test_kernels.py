"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref.py."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gemm import gemm
from repro.kernels.moe_gmm import grouped_matmul
from repro.kernels.rmsnorm import rmsnorm

RNG = np.random.default_rng(42)


def _tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == np.float16 else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,n,k", [(16, 16, 16), (100, 52, 36), (128, 256, 64),
                                   (33, 17, 9), (8, 8, 200)])
@pytest.mark.parametrize("dt", [np.float32])
def test_gemm_shapes(m, n, k, dt):
    x = RNG.normal(size=(m, k)).astype(dt)
    y = RNG.normal(size=(k, n)).astype(dt)
    out = gemm(x, y, block_m=32, block_n=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), x @ y, **_tol(dt))


def test_gemm_bf16():
    x = RNG.normal(size=(64, 48)).astype(np.float32)
    y = RNG.normal(size=(48, 32)).astype(np.float32)
    xb, yb = jnp.asarray(x, jnp.bfloat16), jnp.asarray(y, jnp.bfloat16)
    out = gemm(xb, yb, block_m=32, block_n=32, block_k=16, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref.matmul(xb, yb), np.float32),
        rtol=5e-2, atol=5e-1,
    )


@pytest.mark.parametrize("bh,bkv,sq,skv,causal,window,off", [
    (4, 4, 32, 32, True, None, 0),
    (4, 2, 64, 64, True, None, 0),      # GQA group 2
    (8, 2, 40, 72, True, 16, 0),        # GQA group 4 + SWA
    (2, 1, 8, 128, True, None, 120),    # decode-like offset
    (2, 2, 48, 48, False, None, 0),     # bidirectional (encoder)
    (2, 2, 17, 33, True, 8, 0),         # ragged, non-multiple shapes
])
def test_flash_attention_sweep(bh, bkv, sq, skv, causal, window, off):
    d = 32
    q = RNG.normal(size=(bh, sq, d)).astype(np.float32)
    k = RNG.normal(size=(bkv, skv, d)).astype(np.float32)
    v = RNG.normal(size=(bkv, skv, d)).astype(np.float32)
    got = flash_attention(q, k, v, causal=causal, window=window, q_offset=off,
                          block_q=16, block_k=16, interpret=True)
    want = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         causal=causal, window=window, q_offset=off)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("e,c,d,f", [(4, 16, 32, 24), (8, 10, 20, 12), (2, 128, 64, 64)])
def test_grouped_matmul_sweep(e, c, d, f):
    x = RNG.normal(size=(e, c, d)).astype(np.float32)
    w = RNG.normal(size=(e, d, f)).astype(np.float32)
    got = grouped_matmul(x, w, block_c=16, block_f=16, block_d=16, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.grouped_matmul(x, w)), rtol=2e-4, atol=2e-4
    )


@pytest.mark.parametrize("r,d", [(8, 64), (100, 96), (256, 128), (5, 32)])
def test_rmsnorm_sweep(r, d):
    x = RNG.normal(size=(r, d)).astype(np.float32)
    g = RNG.normal(size=(d,)).astype(np.float32)
    got = rmsnorm(x, g, block_r=32, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.rmsnorm(x, g)), rtol=1e-5, atol=1e-5
    )


def test_einsum2_contraction_patterns():
    a = RNG.normal(size=(24, 12)).astype(np.float32)
    b = RNG.normal(size=(12, 30)).astype(np.float32)
    got = ops.einsum2("ab", "bc", "ac", jnp.asarray(a), jnp.asarray(b), tile=(16, 16, 16))
    np.testing.assert_allclose(np.asarray(got), a @ b, rtol=2e-4, atol=2e-4)
    got = ops.einsum2("ab", "cb", "ca", jnp.asarray(a), jnp.asarray(b.T.copy()),
                      tile=(16, 16, 16))
    np.testing.assert_allclose(np.asarray(got), (a @ b).T, rtol=2e-4, atol=2e-4)
    with pytest.raises(ValueError):
        ops.einsum2("ab", "bc", "abc", jnp.asarray(a), jnp.asarray(b))  # batch letter


def test_ops_backend_switch():
    x = RNG.normal(size=(32, 16)).astype(np.float32)
    y = RNG.normal(size=(16, 8)).astype(np.float32)
    a = ops.matmul(x, y, backend="xla")
    b = ops.matmul(x, y, backend="pallas_interpret", tile=(16, 8, 16))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("sq,skv,causal,window,off", [
    (64, 64, True, None, 0), (100, 200, True, 32, 0),
    (33, 128, False, None, 0), (8, 96, True, None, 88),
])
def test_chunked_attention_matches_plain(sq, skv, causal, window, off):
    q = RNG.normal(size=(4, sq, 16)).astype(np.float32)
    k = RNG.normal(size=(2, skv, 16)).astype(np.float32)
    v = RNG.normal(size=(2, skv, 16)).astype(np.float32)
    want = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         causal=causal, window=window, q_offset=off)
    got = ref.attention_chunked(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                                causal=causal, window=window, q_offset=off,
                                block_q=16, block_k=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)
