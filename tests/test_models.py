"""Per-arch smoke tests: reduced config forward/train-step/decode, no NaNs."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

# Each per-arch case compiles a reduced model (4-12 s each); the sweep
# dominates suite wall time, so the whole module runs in the slow tier.
pytestmark = pytest.mark.slow

from repro.configs import ARCHS, get_config
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.train_loop import make_train_step


def _batch(cfg, b=2, s=16, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0, cfg.vocab),
    }
    if cfg.frontend:
        batch["embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (b, cfg.frontend_len, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = M.forward(cfg, params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_reduces_loss_and_stays_finite(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=5e-3, warmup_steps=1, total_steps=10)))
    batch = _batch(cfg, b=2, s=16)
    losses = []
    for _ in range(3):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
        assert not bool(metrics["skipped"])
    assert losses[-1] < losses[0]  # same batch: loss must drop


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_forward(arch):
    """Stepwise decode must reproduce the teacher-forced forward logits."""
    cfg = get_config(arch).reduced()
    if cfg.family == "vlm":
        pytest.skip("vlm decode operates post-prefill with image prefix")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 8
    batch = _batch(cfg, b=b, s=s)
    ref = M.forward(cfg, params, batch)
    state = M.init_decode_state(cfg, b, 32, ring=False)
    if cfg.family == "audio":
        state["memory"] = M.encode(cfg, params, batch["embeds"])
    outs = []
    for t in range(s):
        logits, state = M.decode_step(cfg, params, state, batch["tokens"][:, t : t + 1])
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), rtol=2e-3, atol=2e-3
    )


def test_swa_ring_buffer_decode_matches_full_cache():
    """SWA ring cache (window-bounded) must equal a full-length cache."""
    cfg = get_config("h2o-danube-3-4b").reduced()
    assert cfg.window is not None
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    b, steps = 1, 24  # well past the reduced window... window=64 reduced
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, steps), 0, cfg.vocab)
    sr = M.init_decode_state(cfg, b, cfg.window, ring=True)
    sf = M.init_decode_state(cfg, b, 64, ring=False)
    for t in range(steps):
        lr_, sr = M.decode_step(cfg, params, sr, toks[:, t : t + 1])
        lf_, sf = M.decode_step(cfg, params, sf, toks[:, t : t + 1])
        np.testing.assert_allclose(
            np.asarray(lr_, np.float32), np.asarray(lf_, np.float32),
            rtol=2e-3, atol=2e-3,
        )


def test_prefill_then_decode_equals_stepwise():
    cfg = get_config("minicpm-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 0, cfg.vocab)
    # multi-token prefill of the first 6, then 4 decode steps
    s1 = M.init_decode_state(cfg, 1, 32, ring=False)
    lg, s1 = M.decode_step(cfg, params, s1, toks[:, :6])
    outs = [lg[:, -1]]
    for t in range(6, 10):
        lg, s1 = M.decode_step(cfg, params, s1, toks[:, t : t + 1])
        outs.append(lg[:, 0])
    # stepwise from scratch
    s2 = M.init_decode_state(cfg, 1, 32, ring=False)
    outs2 = []
    for t in range(10):
        lg2, s2 = M.decode_step(cfg, params, s2, toks[:, t : t + 1])
        outs2.append(lg2[:, 0])
    got = jnp.stack(outs, 1)
    want = jnp.stack(outs2[5:], 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_pallas_backend_inside_model():
    """Route the reduced model's attention+norm through the Pallas kernels
    (interpret mode) and compare against the XLA path."""
    from repro.kernels import ops

    cfg = get_config("mixtral-8x7b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, b=1, s=16)
    ref = M.forward(cfg, params, batch)
    old = ops.BACKEND
    try:
        ops.BACKEND = "pallas_interpret"
        got = M.forward(cfg, params, batch)
    finally:
        ops.BACKEND = old
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), rtol=5e-3, atol=5e-3
    )


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1 and uniform routing, most tokens survive."""
    from repro.models.layers import moe_ffn, init_moe_ffn

    cfg = get_config("mixtral-8x7b").reduced()
    p = init_moe_ffn(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (64, cfg.d_model), jnp.float32)
    y = moe_ffn(x, p, cfg)
    assert y.shape == x.shape
    nonzero = float(jnp.mean((jnp.abs(y).sum(-1) > 0)))
    assert nonzero > 0.5
