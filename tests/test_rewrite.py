"""COFFEE-style rewrite passes: LICM, expansion/factorization, CSE.

Each transformation is checked against the ``execute_numpy`` float64 oracle
— bit-identical for LICM/CSE (same float ops, just fewer), allclose for
expansion/factorization (reassociation) — plus the pass-stat plumbing the
explain CLI renders.
"""
import numpy as np

from repro.cloudsc import saturation_chain_inputs, saturation_chain_program
from repro.cloudsc.scheme import SPECIES
from repro.core import (
    Array,
    Computation,
    Const,
    FunctionPass,
    Loop,
    PassContext,
    PassPipeline,
    Program,
    Read,
    acc,
    execute_numpy,
    expr_ops,
    optimization_pipeline,
    program_fingerprint,
)
from repro.core.idioms import classify_nest
from repro.core.ir import Call, as_expr, emax, emin
from repro.core.scheduler import random_inputs

SAT_OUTS = [f"PFLUX_{nm}" for nm, _, _ in SPECIES] + ["TEND"]


def _run(prog, rewrite=True, fuse=True):
    ctx = PassContext()
    out = optimization_pipeline(fuse=fuse, rewrite=rewrite).run(prog, ctx)
    return out, ctx


class TestExprCallable:
    def test_tree_matches_python_semantics(self):
        a, b, c = Read(0), Read(1), Read(2)
        e = emin(1.0, emax(a, -b)) * (a + b - c / 2.0) + (-a)
        f = e.to_callable()
        ref = lambda a, b, c: min(1.0, max(a, -b)) * (a + b - c / 2.0) + (-a)  # noqa: E731
        rng = np.random.default_rng(0)
        for _ in range(20):
            x, y, z = rng.uniform(-3, 3, size=3)
            assert np.isclose(f(x, y, z), ref(x, y, z), rtol=1e-12)

    def test_call_nodes_dispatch_the_wrapped_function(self):
        e = Call("np_exp", np.exp, (Read(0) * 2.0,)) + 1.0
        assert np.isclose(e(0.5), np.exp(1.0) + 1.0)
        assert expr_ops(e) >= 2

    def test_dunder_call_equals_to_callable(self):
        e = (Read(0) + 1.5) * Read(1)
        assert e(2.0, 3.0) == e.to_callable()(2.0, 3.0) == 10.5

    def test_const_coercion(self):
        assert isinstance(as_expr(3.0), Const)
        assert (Read(0) + 1.0)(2.0) == 3.0


def _two_nest_invariant_program(write_s: bool) -> Program:
    """Two 3-deep nests sharing a JM-invariant chain over ``S``; optionally
    a leading nest that writes ``S`` (which must block cross-nest sharing)."""
    arrays = [Array("S", (4, 6)), Array("O1", (4, 6, 3)), Array("O2", (4, 6, 5))]
    body = []
    if write_s:
        up = Computation("up", acc("S", "JKU", "JLU"), (acc("S", "JKU", "JLU"),),
                         Read(0) * 2.0)
        body.append(Loop("JKU", 4, body=(Loop("JLU", 6, body=(up,)),)))
    for k, (out, nb) in enumerate((("O1", 3), ("O2", 5))):
        JK, JL, JM = f"JK{k}", f"JL{k}", f"JM{k}"
        comp = Computation(
            f"c{k}", acc(out, JK, JL, JM), (acc("S", JK, JL),),
            (Read(0) + 1.0) * (Read(0) + 1.0) + 0.5)
        body.append(Loop(JK, 4, body=(Loop(JL, 6, body=(
            Loop(JM, nb, body=(comp,)),)),)))
    return Program("inv", tuple(arrays), tuple(body), temps=("O1", "O2"))


class TestLICM:
    def test_saturation_chain_hoists_once_and_shares(self):
        prog = saturation_chain_program(8, 5)
        out, ctx = _run(prog)
        assert ctx.stat("licm", "hoisted") == 1
        assert ctx.stat("licm", "reused") == 3
        assert ctx.stat("licm", "flops_after") < ctx.stat("licm", "flops_before")
        temps = [a.name for a in out.arrays if a.name.startswith("_licm")]
        assert temps == ["_licm0"]
        assert "_licm0" in out.temps

    def test_saturation_chain_bit_identical_to_oracle(self):
        prog = saturation_chain_program(8, 5)
        ins = saturation_chain_inputs(8, 5, seed=4)
        ref = execute_numpy(prog, dict(ins))
        for rewrite in (True, False):
            out, _ = _run(prog, rewrite=rewrite)
            got = execute_numpy(out, dict(ins))
            for k in SAT_OUTS:
                assert np.array_equal(got[k], ref[k]), (rewrite, k)

    def test_cross_nest_sharing_requires_unwritten_sources(self):
        # S is never written: one temp, one reuse
        _, ctx = _run(_two_nest_invariant_program(write_s=False))
        assert ctx.stat("licm", "hoisted") == 1
        assert ctx.stat("licm", "reused") == 1
        # S is written by an earlier nest: each nest gets its own temp
        out, ctx = _run(_two_nest_invariant_program(write_s=True))
        assert ctx.stat("licm", "hoisted") == 2
        assert not ctx.stat("licm", "reused")
        ins = random_inputs(_two_nest_invariant_program(True), seed=5,
                            dtype=np.float64)
        ref = execute_numpy(_two_nest_invariant_program(True), dict(ins))
        got = execute_numpy(out, dict(ins))
        for k in ("O1", "O2"):
            assert np.array_equal(got[k], ref[k])

    def test_cheap_subexpressions_stay_put(self):
        # a single add (1 op, no Call) is below MIN_HOIST_OPS
        comp = Computation("c", acc("O", "i", "j", "m"), (acc("S", "i", "j"),),
                           Read(0) + 1.0)
        prog = Program("cheap", (Array("S", (4, 6)), Array("O", (4, 6, 3))),
                       (Loop("i", 4, body=(Loop("j", 6, body=(
                           Loop("m", 3, body=(comp,)),)),)),), temps=("O",))
        _, ctx = _run(prog)
        assert not ctx.stat("licm", "hoisted")


class TestExpandFactor:
    def _sum_contraction(self, n=6):
        z = Computation("zero", acc("C", "i", "j"), (), Const(0.0))
        m = Computation(
            "m", acc("C", "i", "j"),
            (acc("A", "i", "k"), acc("E", "i", "k"), acc("B", "k", "j")),
            (Read(0) + Read(1)) * (1.5 * Read(2)), accumulate="+")
        return Program("msum", (Array("A", (n, n)), Array("E", (n, n)),
                                Array("B", (n, n)), Array("C", (n, n))),
                       (Loop("i", n, body=(Loop("j", n, body=(
                           z, Loop("k", n, body=(m,)))),)),), temps=("C",))

    def test_expansion_splits_sum_contraction_into_blas3(self):
        prog = self._sum_contraction()
        out, ctx = _run(prog)
        assert ctx.stat("expand_factor", "expanded") >= 1
        kinds = [classify_nest(n).kind for n in out.body]
        assert kinds.count("blas3") == 2
        no, _ = _run(prog, rewrite=False)
        assert "blas3" not in [classify_nest(n).kind for n in no.body]

    def test_expansion_value_preserving(self):
        prog = self._sum_contraction()
        ins = random_inputs(prog, seed=6, dtype=np.float64)
        ref = execute_numpy(prog, dict(ins))
        got = execute_numpy(_run(prog)[0], dict(ins))
        assert np.allclose(got["C"], ref["C"], rtol=1e-12, atol=1e-12)

    def test_factorization_reduces_flops(self):
        # a*b + a*c -> a*(b+c): 3 ops -> 2 ops per point
        comp = Computation(
            "f", acc("O", "i"), (acc("A", "i"), acc("B", "i"), acc("C", "i")),
            Read(0) * Read(1) + Read(0) * Read(2))
        prog = Program("fac", (Array("A", (8,)), Array("B", (8,)),
                               Array("C", (8,)), Array("O", (8,))),
                       (Loop("i", 8, body=(comp,)),), temps=("O",))
        out, ctx = _run(prog)
        assert ctx.stat("expand_factor", "factored") >= 1
        assert ctx.stat("expand_factor", "flops_after") < \
            ctx.stat("expand_factor", "flops_before")
        ins = random_inputs(prog, seed=7, dtype=np.float64)
        ref = execute_numpy(prog, dict(ins))
        got = execute_numpy(out, dict(ins))
        assert np.allclose(got["O"], ref["O"], rtol=1e-12)


class TestCSE:
    def _shared_subexpr_program(self, n=8):
        sub = (Read(0) + 2.0) * (Read(0) - 1.0)
        c1 = Computation("c1", acc("O1", "i"), (acc("X", "i"),), sub * 3.0)
        c2 = Computation("c2", acc("O2", "i"), (acc("X", "i"),), sub + 0.5)
        return Program("share", (Array("X", (n,)), Array("O1", (n,)),
                                 Array("O2", (n,))),
                       (Loop("i", n, body=(c1, c2)),), temps=("O1", "O2"))

    def test_cse_across_fused_computations(self):
        prog = self._shared_subexpr_program()
        out, ctx = _run(prog)
        assert ctx.stat("cse", "eliminated") >= 1
        assert any(a.name.startswith("_cse") for a in out.arrays)

    def test_cse_bit_identical(self):
        prog = self._shared_subexpr_program()
        ins = random_inputs(prog, seed=8, dtype=np.float64)
        ref = execute_numpy(prog, dict(ins))
        got = execute_numpy(_run(prog)[0], dict(ins))
        for k in ("O1", "O2"):
            assert np.array_equal(got[k], ref[k])


class TestOpaqueExprPrograms:
    def test_rewrites_are_identity_on_opaque_callables(self):
        comp = Computation("c", acc("O", "i", "j", "m"), (acc("S", "i", "j"),),
                           lambda v: (v + 1.0) * (v + 1.0) + 0.5)
        prog = Program("opaque", (Array("S", (4, 6)), Array("O", (4, 6, 3))),
                       (Loop("i", 4, body=(Loop("j", 6, body=(
                           Loop("m", 3, body=(comp,)),)),)),), temps=("O",))
        rw, ctx = _run(prog)
        no, _ = _run(prog, rewrite=False)
        assert program_fingerprint(rw) == program_fingerprint(no)
        assert not ctx.stat("licm", "hoisted")
        assert not ctx.stat("expand_factor", "expanded")
        assert not ctx.stat("cse", "eliminated")


class TestStatReporting:
    def test_unknown_custom_stats_pass_through_report(self):
        # regression: the report must render any stat a pass attaches, not
        # just a known-key whitelist
        def mark(p):
            return p

        pipe = PassPipeline([FunctionPass("mypass", mark)])
        ctx = PassContext()
        ctx.add_stat("mypass", "exotic_stat", 42)
        pipe.run(saturation_chain_program(4, 3), ctx=ctx)
        assert "exotic_stat=42" in ctx.report()

    def test_explain_renders_rewrite_stats(self):
        from repro.tools.explain import explain

        text = explain(saturation_chain_program(8, 5))
        assert "licm" in text
        assert "hoisted=1" in text and "reused=3" in text
        assert "flops_before=" in text and "flops_after=" in text

    def test_explain_no_rewrite_drops_the_passes(self):
        from repro.tools.explain import explain

        text = explain(saturation_chain_program(8, 5), rewrite=False)
        assert "licm" not in text and "expand_factor" not in text
