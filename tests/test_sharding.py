"""launch/sharding.py: the framework-level DP/TP/EP/SP spec planner.

The rule functions depend only on ``mesh.shape`` / ``mesh.axis_names``, so a
lightweight fake mesh drives the divisibility and fallback logic at sizes no
host-device mesh could provide; ``NamedSharding`` construction is patched to
pass the spec through.  A final integration test places real parameters on a
real mesh over whatever devices exist.
"""
from dataclasses import dataclass

import numpy as np
import pytest
import jax
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding
from repro.launch.mesh import dp_axes, make_mesh, set_mesh
from repro.launch.sharding import (
    _add_fsdp,
    _param_rule,
    batch_specs,
    param_specs,
    replicated,
    state_specs,
)


@dataclass
class FakeMesh:
    shape: dict
    axis_names: tuple


MESH = FakeMesh({"data": 4, "model": 4}, ("data", "model"))
POD_MESH = FakeMesh({"pod": 2, "data": 4, "model": 4}, ("pod", "data", "model"))


@pytest.fixture
def spec_passthrough(monkeypatch):
    monkeypatch.setattr(sharding, "NamedSharding", lambda mesh, spec: spec)


class Leaf:
    def __init__(self, *shape):
        self.shape = tuple(shape)
        self.ndim = len(shape)


# ---------------------------------------------------------------------------
# dp_axes / mesh helpers
# ---------------------------------------------------------------------------
class TestMeshHelpers:
    def test_dp_axes_without_pod(self):
        assert dp_axes(MESH) == ("data",)

    def test_dp_axes_with_pod(self):
        assert dp_axes(POD_MESH) == ("pod", "data")

    def test_set_mesh_context_manager(self):
        mesh = make_mesh((jax.device_count(),), ("data",))
        with set_mesh(mesh):
            pass  # both the jax.set_mesh and the Mesh-as-context path


# ---------------------------------------------------------------------------
# parameter rules: divisibility fallbacks, EP vs TP
# ---------------------------------------------------------------------------
class TestParamRules:
    def test_column_parallel_divisible(self):
        assert _param_rule("layers/0/wq", (256, 512), MESH) == P(None, "model")

    def test_column_parallel_indivisible_replicates(self):
        assert _param_rule("layers/0/wq", (256, 510), MESH) == P(None, None)

    def test_row_parallel(self):
        assert _param_rule("layers/0/wo", (512, 256), MESH) == P("model", None)

    def test_row_parallel_indivisible_replicates(self):
        assert _param_rule("layers/0/wo", (510, 256), MESH) == P(None, None)

    def test_expert_split_ep_when_divisible(self):
        # E=8 divides model=4 -> expert parallel on the expert dim
        spec = _param_rule("ffn/wg", (8, 256, 1024), MESH)
        assert spec == P("model", None, None)

    def test_expert_split_tp_fallback(self):
        # E=6 does not divide model=4 -> TP on the trailing feature dim
        assert _param_rule("ffn/wg", (6, 256, 1024), MESH) == P(None, None, "model")
        # ... and wd (row-parallel) shards its contracting dim instead
        assert _param_rule("ffn/wd", (6, 1024, 256), MESH) == P(None, "model", None)

    def test_embed_vocab_vs_feature_parallel(self):
        assert _param_rule("embed", (32000, 256), MESH) == P("model", None)
        assert _param_rule("embed", (32001, 256), MESH) == P(None, "model")
        assert _param_rule("embed", (32001, 255), MESH) == P(None, None)

    def test_gqa_head_mismatch_shards_contracting_dim(self):
        cfg = get_config("minicpm-2b").reduced()
        # n_heads not divisible by model axis -> row-parallel wq instead of
        # the head-flat output dim (the involuntary-remat trap)
        mesh = FakeMesh({"data": 1, "model": 3}, ("data", "model"))
        if cfg.n_heads % 3 != 0 and cfg.d_model % 3 == 0:
            spec = _param_rule("layers/0/wq", (cfg.d_model, 512), mesh, cfg)
            assert spec == P("model", None)

    def test_norms_replicated(self):
        assert _param_rule("layers/0/ln1", (256,), MESH) == P(None)

    def test_modelless_mesh_replicates_params(self):
        # a pure-DP mesh (the canonical-program column mesh) has no 'model'
        # axis: every TP rule must fall back to replication, never emit a
        # spec naming the missing axis or crash
        dp_only = FakeMesh({"data": 4}, ("data",))
        cfg = get_config("minicpm-2b").reduced()
        for path, shape in [("layers/0/wq", (256, 512)),
                            ("layers/0/wo", (512, 256)),
                            ("embed", (32000, 256)),
                            ("ffn/wg", (8, 256, 1024))]:
            spec = _param_rule(path, shape, dp_only, cfg)
            assert all(e is None for e in spec), (path, spec)

    def test_fsdp_adds_one_dp_dim(self):
        spec = _add_fsdp(P(None, "model"), (256, 512), MESH)
        assert spec == P("data", "model")

    def test_fsdp_skips_indivisible(self):
        spec = _add_fsdp(P(None, "model"), (253, 512), MESH)
        assert spec == P(None, "model")  # 253 % 4 != 0 and last dim taken

    def test_fsdp_skips_scanned_stack_dim(self):
        # leading dim of a scanned (L, ...) stack must not be sharded
        spec = _add_fsdp(P(None, None, "model"), (4, 256, 512), MESH)
        assert spec == P(None, "data", "model")

    def test_fsdp_pod_mesh_uses_both_dp_axes(self):
        spec = _add_fsdp(P(None, "model"), (256, 512), POD_MESH)
        assert spec == P(("pod", "data"), "model")


# ---------------------------------------------------------------------------
# batch / state specs (SP fallback)
# ---------------------------------------------------------------------------
class TestBatchStateSpecs:
    def test_batch_divisible_shards_leading(self, spec_passthrough):
        specs = batch_specs(None, None, MESH, {"tokens": Leaf(8, 128)})
        assert specs["tokens"] == P(("data",), None)

    def test_batch_indivisible_replicates(self, spec_passthrough):
        specs = batch_specs(None, None, MESH, {"tokens": Leaf(6, 128)})
        assert specs["tokens"] == P(None, None)

    def test_kv_cache_dp_plus_model(self, spec_passthrough):
        # (L, B, S, KV, dh): batch -> data, a divisible feature dim -> model
        specs = state_specs(None, MESH, {"kv": Leaf(2, 8, 64, 4, 32)})
        assert specs["kv"] == P(None, ("data",), None, "model", None)

    def test_kv_cache_sp_fallback_batch1(self, spec_passthrough):
        # batch=1 long-context decode: shard the cache *sequence* over DP
        specs = state_specs(None, MESH, {"kv": Leaf(2, 1, 64, 4, 32)})
        assert specs["kv"] == P(None, None, ("data",), "model", None)

    def test_memory_state(self, spec_passthrough):
        specs = state_specs(None, MESH, {"memory": Leaf(8, 77, 256)})
        assert specs["memory"] == P(("data",), None, "model")

    def test_scalars_replicated(self, spec_passthrough):
        specs = state_specs(None, MESH, {"pos": Leaf()})
        assert specs["pos"] == P()

    def test_replicated_helper(self, spec_passthrough):
        specs = replicated(MESH, {"x": Leaf(3, 4)})
        assert specs["x"] == P(None, None)


# ---------------------------------------------------------------------------
# integration: real mesh, real params, engine/trainer placement
# ---------------------------------------------------------------------------
class TestPlacement:
    def test_param_specs_places_real_params(self):
        from repro.models import model as M

        cfg = get_config("minicpm-2b").reduced()
        n = jax.device_count()
        mesh = make_mesh((1, n), ("data", "model"))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        shapes = jax.eval_shape(lambda p: p, params)
        specs = param_specs(shapes, mesh, cfg=cfg)
        placed = jax.device_put(params, specs)
        leaves = jax.tree_util.tree_leaves(placed)
        assert all(hasattr(l.sharding, "spec") for l in leaves)

    def test_engine_on_dp_only_mesh(self):
        # the mesh the sharded-canonical path hands out (no model axis)
        from repro.models import model as M
        from repro.serve import ServeConfig, ServingEngine

        cfg = get_config("minicpm-2b").reduced()
        mesh = make_mesh((jax.device_count(),), ("data",))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(
            cfg, params, ServeConfig(batch_slots=1, max_len=32,
                                     max_new_tokens=2), mesh=mesh)
        h = eng.submit(np.array([1, 2], np.int32))
        assert len(h.result()) == 2

    def test_engine_with_mesh_generates(self):
        from repro.models import model as M
        from repro.serve import ServeConfig, ServingEngine

        cfg = get_config("minicpm-2b").reduced()
        mesh = make_mesh((1, jax.device_count()), ("data", "model"))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(
            cfg, params, ServeConfig(batch_slots=1, max_len=32,
                                     max_new_tokens=3), mesh=mesh)
        out = eng.submit(np.array([1, 2, 3], np.int32)).result()
        assert len(out) == 3
        # mesh placement must not change greedy decoding
        eng2 = ServingEngine(
            cfg, params, ServeConfig(batch_slots=1, max_len=32,
                                     max_new_tokens=3))
        assert eng2.submit(np.array([1, 2, 3], np.int32)).result() == out

    @pytest.mark.slow
    def test_trainer_with_mesh_steps(self, tmp_path):
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.train.train_loop import Trainer, TrainerConfig

        cfg = get_config("minicpm-2b").reduced()
        mesh = make_mesh((1, jax.device_count()), ("data", "model"))
        dcfg = DataConfig(seq_len=16, global_batch=4, vocab=cfg.vocab, seed=1)
        tr = Trainer(cfg, AdamWConfig(), dcfg,
                     TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=100),
                     mesh=mesh)
        m_leaves = jax.tree_util.tree_leaves(tr.opt_state["m"])
        assert all(hasattr(l, "sharding") for l in m_leaves)
        hist = tr.run(2)
        assert len(hist) == 2 and np.isfinite(hist[-1]["loss"])

    @pytest.mark.slow
    def test_run_resilient_on_mesh_restores_placement(self, tmp_path):
        """restart-from-checkpoint on a sharded mesh: the restored params
        and AdamW moments must come back mesh-placed (not host arrays), and
        the recovered run must reach the target step with finite loss."""
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.train.train_loop import Trainer, TrainerConfig

        cfg = get_config("minicpm-2b").reduced()
        mesh = make_mesh((1, jax.device_count()), ("data", "model"))
        dcfg = DataConfig(seq_len=16, global_batch=4, vocab=cfg.vocab, seed=1)
        tcfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=2)
        tr = Trainer(cfg, AdamWConfig(), dcfg, tcfg, mesh=mesh)
        hist = tr.run_resilient(5, fail_at=3)  # checkpoint at 2, crash at 3
        assert tr.step == 5 and np.isfinite(hist[-1]["loss"])
        # the restore path must hand back mesh-placed arrays: a fresh trainer
        # restored from the surviving checkpoint carries exactly the
        # construction-time shardings (stepping afterwards may legitimately
        # normalize specs, so the assertion sits right after try_restore)
        tr2 = Trainer(cfg, AdamWConfig(), dcfg, tcfg, mesh=mesh)
        want = {l.sharding for l in jax.tree_util.tree_leaves(tr2.params)}
        assert tr2.try_restore() and tr2.step >= 2
        got = {l.sharding for l in jax.tree_util.tree_leaves(tr2.params)}
        assert got == want
        for moments in (tr2.opt_state["m"], tr2.opt_state["v"]):
            for l in jax.tree_util.tree_leaves(moments):
                assert l.sharding in want
