"""Unit tests for the normalization passes (the paper's §2)."""
import numpy as np
import pytest

from repro.core import (
    Access, Affine, Array, Computation, Loop, Program, acc, aff, fingerprint,
    execute_numpy, maximal_fission, normalize, stride_minimization,
)
from repro.core.dependence import (
    DepVector, body_dependence_graph, condense_sccs, nest_direction_vectors,
    permutation_legal,
)
from repro.core.normalize import scalar_expansion
from repro.core.scheduler import random_inputs


def _mac(i="i", j="j", k="k"):
    return Computation(
        "mac", acc("C", i, j), (acc("A", i, k), acc("B", k, j)),
        lambda a, b: a * b, accumulate="+",
    )


def _gemm(order):
    dims = dict(i=6, j=5, k=4)
    nest = (_mac(),)
    for it in reversed(order):
        nest = (Loop(it, dims[it], body=nest),)
    return Program(
        "g", (Array("A", (6, 4)), Array("B", (4, 5)), Array("C", (6, 5))), nest
    )


class TestStrideMinimization:
    def test_gemm_orders_all_canonicalize_identically(self):
        fps = {
            fingerprint(normalize(_gemm(o)).body[0])
            for o in (["i", "j", "k"], ["i", "k", "j"], ["k", "j", "i"],
                      ["j", "i", "k"], ["k", "i", "j"], ["j", "k", "i"])
        }
        assert len(fps) == 1

    def test_gemm_canonical_order_is_ikj(self):
        # row-major: innermost j (C and B stride 1), then k, then i
        norm = normalize(_gemm(["i", "j", "k"]))
        loops = []
        node = norm.body[0]
        while isinstance(node, Loop):
            loops.append(node.trip_count)
            node = node.body[0]
        assert loops == [6, 4, 5]  # i(6), k(4), j(5) innermost

    def test_transposed_copy_keeps_original_order(self):
        # B[j][i] = A[j][i] written under (i,j): permutation legal, and the
        # minimal stride order flips to (j,i)
        cp = Computation("cp", acc("B", "j", "i"), (acc("A", "j", "i"),), lambda a: a)
        prog = Program(
            "t", (Array("A", (8, 9)), Array("B", (8, 9))),
            (Loop("i", 9, body=(Loop("j", 8, body=(cp,)),)),),
        )
        norm = normalize(prog)
        outer = norm.body[0]
        assert outer.trip_count == 8  # j outermost after minimization
        inp = random_inputs(prog, dtype=np.float64)
        assert np.allclose(execute_numpy(norm, inp)["B"], execute_numpy(prog, inp)["B"])

    def test_reduction_self_dep_does_not_block_interchange(self):
        vecs = nest_direction_vectors(
            ["i", "j", "k"], {"i": 4, "j": 4, "k": 4}, [_mac()]
        )
        # associative accumulation: every permutation legal
        import itertools

        for perm in itertools.permutations(range(3)):
            assert permutation_legal(vecs, perm)

    def test_true_recurrence_blocks_interchange(self):
        # C[i][j] += C[i][j-1]: j carried -> j cannot move outward past... it
        # can stay legal only if j's '<' stays first-positive; permutation
        # moving i before j is fine, but reversing dependence is impossible;
        # here we simply check the carried vector exists
        rec = Computation(
            "rec", acc("C", "i", "j"),
            (acc("C", "i", aff("j", const=-1)),), lambda c: c, accumulate="+",
        )
        vecs = nest_direction_vectors(["i", "j"], {"i": 4, "j": 4}, [rec])
        assert any(v.directions != ("=", "=") for v in vecs)


class TestFission:
    def test_independent_computations_split(self):
        c1 = Computation("c1", acc("X", "i"), (acc("A", "i"),), lambda a: a + 1)
        c2 = Computation("c2", acc("Y", "i"), (acc("B", "i"),), lambda b: b * 2)
        prog = Program(
            "f", (Array("A", (8,)), Array("B", (8,)), Array("X", (8,)), Array("Y", (8,))),
            (Loop("i", 8, body=(c1, c2)),),
        )
        out = maximal_fission(prog)
        assert len(out.body) == 2
        inp = random_inputs(prog, dtype=np.float64)
        ref = execute_numpy(prog, inp)
        got = execute_numpy(out, inp)
        for k in ("X", "Y"):
            assert np.allclose(got[k], ref[k])

    def test_flow_dependent_computations_split_in_order(self):
        c1 = Computation("c1", acc("X", "i"), (acc("A", "i"),), lambda a: a + 1)
        c2 = Computation("c2", acc("Y", "i"), (acc("X", "i"),), lambda x: x * 2)
        prog = Program(
            "f2", (Array("A", (8,)), Array("X", (8,)), Array("Y", (8,))),
            (Loop("i", 8, body=(c1, c2)),),
        )
        out = maximal_fission(prog)
        assert len(out.body) == 2  # same-iteration flow dep: legal to split
        inp = random_inputs(prog, dtype=np.float64)
        assert np.allclose(execute_numpy(out, inp)["Y"], execute_numpy(prog, inp)["Y"])

    def test_backward_carried_dependence_stays_fused(self):
        # c1 reads X[i-1] written by c2 at the previous iteration -> cycle
        c1 = Computation(
            "c1", acc("Y", "i"), (acc("X", aff("i", const=-1)),), lambda x: x,
            guards=(aff("i", const=-1),),
        )
        c2 = Computation("c2", acc("X", "i"), (acc("A", "i"), acc("Y", "i")),
                         lambda a, y: a + y)
        prog = Program(
            "f3", (Array("A", (8,)), Array("X", (8,)), Array("Y", (8,))),
            (Loop("i", 8, body=(c1, c2)),),
        )
        out = maximal_fission(prog)
        assert len(out.body) == 1  # SCC: must stay fused
        inp = random_inputs(prog, dtype=np.float64)
        for k in ("X", "Y"):
            assert np.allclose(execute_numpy(out, inp)[k], execute_numpy(prog, inp)[k])

    def test_scc_topological_reorder(self):
        # textual order c_use before c_def, but dependence only flows
        # def -> use across iterations? here: independent arrays, order kept
        adj = [set(), {0}]  # 1 -> 0
        order = condense_sccs(adj)
        assert order == [[1], [0]]


class TestScalarExpansion:
    def test_scalar_promoted_and_semantics_preserved(self):
        s = Computation("s", acc("T"), (acc("A", "i"),), lambda a: a * 2.0)
        u = Computation("u", acc("Y", "i"), (acc("T"),), lambda t: t + 1.0)
        prog = Program(
            "se", (Array("A", (8,)), Array("T", ()), Array("Y", (8,))),
            (Loop("i", 8, body=(s, u)),), temps=("T",),
        )
        exp = scalar_expansion(prog)
        assert exp.array("T").shape == (8,)
        inp = random_inputs(prog, dtype=np.float64)
        assert np.allclose(execute_numpy(exp, inp)["Y"], execute_numpy(prog, inp)["Y"])
        # and fission can now split the two computations
        out = maximal_fission(exp)
        assert len(out.body) == 2

    def test_scalar_used_outside_not_promoted(self):
        s = Computation("s", acc("T"), (acc("A", "i"),), lambda a: a * 2.0)
        u = Computation("u", acc("Y", "j"), (acc("T"),), lambda t: t + 1.0)
        prog = Program(
            "se2", (Array("A", (8,)), Array("T", ()), Array("Y", (8,))),
            (Loop("i", 8, body=(s,)), Loop("j", 8, body=(u,))), temps=("T",),
        )
        exp = scalar_expansion(prog)
        assert exp.array("T").shape == ()  # read outside the writer loop


class TestNormalizePipeline:
    def test_idempotent(self):
        for order in (["i", "j", "k"], ["k", "j", "i"]):
            n1 = normalize(_gemm(order))
            n2 = normalize(n1)
            assert [fingerprint(n) for n in n1.body] == [
                fingerprint(n) for n in n2.body
            ]

    def test_guarded_triangular_nest_preserved(self):
        tri = aff("i", ("j", -1))
        c = Computation("c", acc("C", "i", "j"), (acc("C", "i", "j"),),
                        lambda x: x * 2.0, guards=(tri,))
        prog = Program(
            "tri", (Array("C", (6, 6)),),
            (Loop("i", 6, body=(Loop("j", 6, body=(c,)),)),),
        )
        norm = normalize(prog)
        inp = random_inputs(prog, dtype=np.float64)
        assert np.allclose(execute_numpy(norm, inp)["C"], execute_numpy(prog, inp)["C"])
