"""Training substrate: determinism, checkpoint/restart, schedules, FT."""
import tempfile
import shutil
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataConfig, LMDataPipeline
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, lr_at
from repro.optim.compression import compress_grads, decompress_grads
from repro.fault import Heartbeat, StragglerMonitor
from repro.train.checkpoint import CheckpointManager
from repro.train.train_loop import Trainer, TrainerConfig


def test_train_fault_shim_warns_on_import():
    import importlib
    import sys

    sys.modules.pop("repro.train.fault", None)
    with pytest.warns(DeprecationWarning, match="repro.fault"):
        importlib.import_module("repro.train.fault")


CFG = get_config("minicpm-2b").reduced()


def _dcfg(**kw):
    base = dict(seq_len=16, global_batch=4, vocab=CFG.vocab, seed=11)
    base.update(kw)
    return DataConfig(**base)


class TestData:
    def test_batch_is_pure_function_of_step(self):
        p = LMDataPipeline(_dcfg())
        b1, b2 = p.batch_at(5), p.batch_at(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(p.batch_at(6)["tokens"], b1["tokens"])

    def test_labels_shift(self):
        p = LMDataPipeline(_dcfg(source="synthetic"))
        b = p.batch_at(0)
        assert b["tokens"].shape == b["labels"].shape == (4, 16)

    def test_sharding_partitions_batch(self):
        full = LMDataPipeline(_dcfg()).batch_at(3)["tokens"]
        s0 = LMDataPipeline(_dcfg(shard_index=0, shard_count=2)).batch_at(3)["tokens"]
        s1 = LMDataPipeline(_dcfg(shard_index=1, shard_count=2)).batch_at(3)["tokens"]
        assert s0.shape[0] == s1.shape[0] == 2
        assert not np.array_equal(s0, s1)

    def test_prefetch_iterator_order(self):
        p = LMDataPipeline(_dcfg())
        p.start(7)
        steps = [p.next()[0] for _ in range(3)]
        p.stop()
        assert steps == [7, 8, 9]

    def test_memmap_source(self, tmp_path):
        toks = np.arange(10_000, dtype=np.uint32) % 97
        f = tmp_path / "tokens.bin"
        toks.tofile(f)
        p = LMDataPipeline(_dcfg(source="memmap", path=str(f)))
        b = p.batch_at(0)
        assert b["tokens"].max() < CFG.vocab


class TestOptim:
    def test_wsd_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule="wsd")
        lrs = [float(lr_at(cfg, s)) for s in range(100)]
        assert lrs[0] < 0.2            # warmup starts low
        assert abs(lrs[50] - 1.0) < 1e-5   # stable plateau
        assert lrs[99] < lrs[89]       # decay at the end

    def test_nan_grads_skip_step(self):
        p = {"w": jnp.ones((4,))}
        st = adamw_init(p)
        g = {"w": jnp.full((4,), jnp.nan)}
        cfg = AdamWConfig()
        p2, st2, m = adamw_update(cfg, p, g, st)
        assert bool(m["skipped"])
        np.testing.assert_array_equal(np.asarray(p2["w"]), np.ones(4))

    def test_grad_clip(self):
        p = {"w": jnp.zeros((4,))}
        st = adamw_init(p)
        g = {"w": jnp.full((4,), 100.0)}
        _, _, m = adamw_update(AdamWConfig(grad_clip=1.0), p, g, st)
        assert float(m["grad_norm"]) > 1.0  # reported pre-clip

    def test_int8_compression_error_feedback(self):
        g = {"w": jnp.linspace(-1, 1, 128)}
        comp, scales, res = compress_grads(g, None, "int8")
        deco = decompress_grads(comp, scales, "int8")
        err = float(jnp.abs(deco["w"] - g["w"]).max())
        assert err < 1e-2
        assert res is not None and float(jnp.abs(res["w"]).max()) < 1e-2

    def test_bf16_compression(self):
        g = {"w": jnp.linspace(-1, 1, 64)}
        comp, _, _ = compress_grads(g, None, "bf16")
        assert comp["w"].dtype == jnp.bfloat16


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        mgr.save(10, tree)
        step, got, _ = mgr.restore(tree)
        assert step == 10
        np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))

    def test_keep_last_k(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        t = {"a": jnp.zeros(())}
        for s in (1, 2, 3, 4):
            mgr.save(s, t)
        assert mgr.steps() == [3, 4]

    def test_missing_key_raises(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"a": jnp.zeros((2,))})
        with pytest.raises(KeyError):
            mgr.restore({"a": jnp.zeros((2,)), "b": jnp.zeros((3,))})


@pytest.mark.slow
class TestTrainerFT:
    def test_resume_is_bit_exact(self, tmp_path):
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        tcfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=4, log_every=100)
        t1 = Trainer(CFG, ocfg, _dcfg(), tcfg)
        t1.run(8)  # checkpoints at 4 and 8
        ref = jax.tree_util.tree_map(np.asarray, t1.params)

        t2 = Trainer(CFG, ocfg, _dcfg(), tcfg)
        assert t2.try_restore()
        assert t2.step == 8
        # continue both for 2 steps: identical trajectories
        t1.run(2)
        t2.run(2)
        for a, b in zip(jax.tree_util.tree_leaves(t1.params),
                        jax.tree_util.tree_leaves(t2.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_injected_failure_recovers(self, tmp_path):
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        tcfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=3)
        t = Trainer(CFG, ocfg, _dcfg(), tcfg)
        hist = t.run_resilient(8, fail_at=5)
        assert t.step == 8

    def test_straggler_monitor(self):
        m = StragglerMonitor(threshold=2.0)
        for s in range(10):
            m.observe(s, 1.0)
        assert m.observe(10, 5.0)
        assert m.flagged and m.flagged[0][0] == 10

    def test_heartbeat(self, tmp_path):
        hb = Heartbeat(tmp_path / "hb.json", interval=0.05)
        hb.start()
        time.sleep(0.2)
        hb.stop()
        age = Heartbeat.age(tmp_path / "hb.json")
        assert age is not None and age < 5.0


@pytest.mark.slow
def test_elastic_remesh_subprocess():
    """Save on a (2,2) mesh, restore + lower onto (2,4): checkpoints are
    device-count agnostic (elastic scaling)."""
    import subprocess, sys, textwrap, os

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import model as M
        from repro.launch.sharding import param_specs
        from repro.train.checkpoint import CheckpointManager

        cfg = get_config('minicpm-2b').reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d)
        mgr.save(5, params)

        for shape, axes in [((2, 2), ('data','model')), ((2, 4), ('data','model')),
                            ((2, 2, 2), ('pod','data','model'))]:
            mesh = jax.make_mesh(shape, axes, devices=jax.devices()[:int(np.prod(shape))])
            specs = param_specs(jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0))), mesh)
            _, restored, _ = mgr.restore(params)
            placed = jax.tree_util.tree_map(jax.device_put, restored, specs)
            batch = {'tokens': jnp.zeros((4, 8), jnp.int32)}
            from repro.launch.mesh import set_mesh
            with set_mesh(mesh):
                logits = jax.jit(lambda p, b: M.forward(cfg, p, b))(placed, batch)
            assert logits.shape == (4, 8, cfg.vocab)
            print('mesh', shape, 'ok')
        print('ELASTIC_OK')
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=540, env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "ELASTIC_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]
