"""TuningDatabase persistence (v1 + v2), merge composition, reporting."""
import json

import numpy as np
import pytest

from repro.core import Recipe, TuningDatabase


def _db(entries, radius=6.0, meta=None):
    db = TuningDatabase(radius=radius, meta=meta or {})
    for fp, emb, recipe, prov, us in entries:
        db.add(fp, np.asarray(emb, np.float64), recipe, provenance=prov,
               measured_us=us)
    return db


def test_save_load_roundtrip_v2(tmp_path):
    db = _db(
        [("fpA", [1.0, 2.0], Recipe(kind="einsum", notes="a"), "p1:idiom", 12.5),
         ("fpB", [3.0, 4.0], Recipe(kind="pallas_nest", tile=(8, 128)), "p1:search", 7.0),
         ("fpC", [5.0, 6.0], Recipe(kind="vectorize"), "p2:search", None)],
        radius=9.5, meta={"suite": "polybench", "backend": "xla"},
    )
    p = tmp_path / "db.json"
    db.save(p)
    raw = json.loads(p.read_text())
    assert raw["version"] == 2 and raw["meta"]["suite"] == "polybench"

    loaded = TuningDatabase.load(p)
    assert loaded.radius == 9.5
    assert loaded.meta == {"suite": "polybench", "backend": "xla"}
    assert len(loaded.entries) == 3
    for e, l in zip(db.entries, loaded.entries):
        assert e.fingerprint == l.fingerprint
        assert e.recipe == l.recipe  # includes the tile tuple round-trip
        assert e.provenance == l.provenance
        assert e.measured_us == l.measured_us
        np.testing.assert_allclose(e.embedding, l.embedding)
    # loaded database is queryable immediately (index rebuilt)
    assert loaded.lookup_exact("fpB").kind == "pallas_nest"


def test_load_v1_unversioned_file(tmp_path):
    p = tmp_path / "v1.json"
    p.write_text(json.dumps({
        "radius": 4.0,
        "entries": [{"fingerprint": "old", "embedding": [1.0, 1.0],
                     "recipe": Recipe(kind="einsum").to_json()}],
    }))
    db = TuningDatabase.load(p)
    assert db.radius == 4.0 and db.meta == {}
    assert db.lookup_exact("old").kind == "einsum"
    assert db.entries[0].measured_us is None  # v1 carried no measurement


def test_load_rejects_newer_version(tmp_path):
    p = tmp_path / "future.json"
    p.write_text(json.dumps({"version": 99, "entries": []}))
    with pytest.raises(ValueError, match="newer than supported"):
        TuningDatabase.load(p)


def test_merge_composes_and_reports():
    base = _db([("fpA", [0.0, 0.0], Recipe(kind="einsum"), "run1", 10.0),
                ("fpB", [1.0, 1.0], Recipe(kind="vectorize"), "run1", 20.0)])
    incoming = _db([
        ("fpB", [1.0, 1.0], Recipe(kind="einsum"), "run2", 5.0),     # better
        ("fpA", [0.0, 0.0], Recipe(kind="sequential"), "run2", 50.0),  # worse
        ("fpC", [2.0, 2.0], Recipe(kind="pallas_gemm", tile=(128, 128, 128)),
         "run2", 3.0),                                               # new
    ], meta={"suite": "cloudsc"})
    gen = base.generation
    report = base.merge(incoming)
    assert report == {"added": 1, "improved": 1, "kept": 1}
    assert len(base.entries) == 3
    # the better-measured recipe won; the worse one was kept out
    assert base.lookup_exact("fpB").kind == "einsum"
    assert base.lookup_exact("fpA").kind == "einsum"
    assert base.lookup_exact("fpC").kind == "pallas_gemm"
    assert base.generation > gen  # cached plans against the old contents expire
    assert base.meta["suite"] == "cloudsc"  # missing meta keys fill in


def test_merge_refuses_backend_mismatch():
    a = _db([("f1", [0.0], Recipe(), "t", 1.0)], meta={"backend": "xla"})
    b = _db([("f2", [1.0], Recipe(), "t", 1.0)], meta={"backend": "pallas"})
    with pytest.raises(ValueError, match="different backends"):
        a.merge(b)
    assert len(a.entries) == 1  # refused before touching entries


def test_merge_concatenates_run_history():
    a = _db([("f1", [0.0], Recipe(), "t", 1.0)],
            meta={"backend": "xla", "runs": [{"suite": "polybench"}]})
    b = _db([("f2", [1.0], Recipe(), "t", 1.0)],
            meta={"backend": "xla", "runs": [{"suite": "cloudsc"}]})
    a.merge(b)
    assert a.meta["runs"] == [{"suite": "polybench"}, {"suite": "cloudsc"}]


def test_merge_roundtrips_through_files(tmp_path):
    """The tune CLI's incremental path: load, merge, save, load."""
    a, b = tmp_path / "a.json", tmp_path / "b.json"
    _db([("fp1", [0.0], Recipe(kind="einsum"), "t1", 1.0)]).save(a)
    _db([("fp2", [9.0], Recipe(kind="vectorize"), "t2", 2.0)]).save(b)
    db = TuningDatabase.load(a)
    db.merge(TuningDatabase.load(b))
    db.save(a)
    final = TuningDatabase.load(a)
    assert {e.fingerprint for e in final.entries} == {"fp1", "fp2"}


def test_add_returns_action():
    db = TuningDatabase()
    assert db.add("f", np.array([0.0]), Recipe(), measured_us=2.0) == "added"
    assert db.add("f", np.array([0.0]), Recipe(kind="einsum"),
                  measured_us=1.0) == "replaced"
    assert db.add("f", np.array([0.0]), Recipe(kind="sequential"),
                  measured_us=9.0) == "kept"
    assert db.lookup_exact("f").kind == "einsum"


def test_save_sanitizes_nonfinite_measurements(tmp_path):
    """inf/nan must never reach the JSON file (json would emit the
    non-standard 'Infinity' token, breaking strict parsers)."""
    db = _db([("f", [0.0], Recipe(), "x", float("inf"))])
    p = tmp_path / "db.json"
    db.save(p)
    assert "Infinity" not in p.read_text()
    assert TuningDatabase.load(p).entries[0].measured_us is None


def test_database_uid_is_unique_per_instance():
    a, b = TuningDatabase(), TuningDatabase()
    assert a.uid != b.uid
    assert TuningDatabase().uid > b.uid  # monotone: never reused


def test_summary_reports_size_and_provenance():
    db = _db([("f1", [0.0], Recipe(kind="einsum"), "gemm:idiom", 1.0),
              ("f2", [1.0], Recipe(kind="einsum"), "gemm:search", 2.0),
              ("f3", [2.0], Recipe(kind="vectorize"), "bicg:search+transfer", None)])
    s = db.summary()
    assert s["entries"] == 3 and s["measured"] == 2
    assert s["kinds"] == {"einsum": 2, "vectorize": 1}
    assert s["provenance"] == {"idiom": 1, "search": 1, "search+transfer": 1}
