"""PR-1 cache subsystem: hit/miss semantics, fingerprint stability, indexed
TuningDatabase equivalence with the seed revision's linear scans, and the
versioned JSON round-trip."""
import json

import numpy as np
import pytest

from repro.core import (
    Array,
    CompilationCache,
    Computation,
    Daisy,
    Loop,
    Program,
    Recipe,
    TuningDatabase,
    acc,
    fingerprint,
    program_fingerprint,
)
from repro.core.cache import fingerprint_obj
from repro.core.database import SCHEMA_VERSION, Entry
from repro.core.embedding import DIM, distance, embed_nest
from repro.core.ir import rename_nest
from repro.polybench import BENCHMARKS


def _tiny_program(name="p", expr=lambda a, b: a * b):
    c = Computation("c", acc("C", "i", "j"), (acc("A", "i", "k"), acc("B", "k", "j")),
                    expr, accumulate="+")
    nest = Loop("i", 4, body=(Loop("j", 4, body=(Loop("k", 4, body=(c,)),)),))
    arrays = (Array("A", (4, 4)), Array("B", (4, 4)), Array("C", (4, 4)))
    return Program(name, arrays, (nest,))


# ---------------------------------------------------------------------------
# CompilationCache semantics
# ---------------------------------------------------------------------------
class TestCompilationCache:
    def test_hit_miss_and_stats(self):
        c = CompilationCache(capacity=4)
        assert c.get("k") is None
        assert c.stats.misses == 1 and c.stats.hits == 0
        c.put("k", 42)
        assert c.get("k") == 42
        assert c.stats.hits == 1 and c.stats.misses == 1
        assert c.stats.hit_rate == 0.5

    def test_get_or_build_builds_once(self):
        c = CompilationCache()
        calls = []
        for _ in range(3):
            v = c.get_or_build("x", lambda: calls.append(1) or "built")
        assert v == "built" and len(calls) == 1

    def test_lru_eviction(self):
        c = CompilationCache(capacity=2)
        c.put("a", 1)
        c.put("b", 2)
        c.get("a")          # refresh 'a': 'b' becomes the LRU victim
        c.put("c", 3)
        assert "a" in c and "c" in c and "b" not in c
        assert c.stats.evictions == 1

    def test_invalidate(self):
        c = CompilationCache()
        c.put("a", 1)
        c.put("b", 2)
        c.invalidate("a")
        assert "a" not in c and "b" in c
        c.invalidate()
        assert len(c) == 0


# ---------------------------------------------------------------------------
# Program fingerprint
# ---------------------------------------------------------------------------
class TestProgramFingerprint:
    def test_stable_across_iterator_renaming_and_name(self):
        p1 = _tiny_program("alpha")
        p2 = Program("beta", p1.arrays,
                     tuple(rename_nest(n, "_renamed") for n in p1.body))
        assert [fingerprint(n) for n in p1.body] == [fingerprint(n) for n in p2.body]
        assert program_fingerprint(p1) == program_fingerprint(p2)

    def test_distinguishes_expr_content(self):
        p1 = _tiny_program(expr=lambda a, b: a * b)
        p2 = _tiny_program(expr=lambda a, b: a * b * 2.0)
        # structure identical, math different: nest fingerprints collide ...
        assert fingerprint(p1.body[0]) == fingerprint(p2.body[0])
        # ... but the compile-cache key must not
        assert program_fingerprint(p1) != program_fingerprint(p2)

    def test_distinguishes_threshold_exprs(self):
        # piecewise exprs that agree at small probe values must not collide
        # (caught in review: a 3-point probe saw a*b == a*b + relu(a - 2))
        p1 = _tiny_program(expr=lambda a, b: a * b)
        p2 = _tiny_program(expr=lambda a, b: a * b + max(a - 2.0, 0.0))
        assert program_fingerprint(p1) != program_fingerprint(p2)

    def test_identical_lambdas_rebuilt_still_hit(self):
        # two separately-constructed but identical closures must collide
        # (otherwise generator-rebuilt programs would never cache-hit)
        def make(scale):
            return _tiny_program(expr=lambda a, b: scale * a * b)

        assert program_fingerprint(make(1.5)) == program_fingerprint(make(1.5))
        assert program_fingerprint(make(1.5)) != program_fingerprint(make(2.5))

    def test_distinguishes_shapes_and_temps(self):
        p1 = _tiny_program()
        bigger = tuple(Array(a.name, (8, 8)) for a in p1.arrays)
        p2 = Program(p1.name, bigger, p1.body)
        assert program_fingerprint(p1) != program_fingerprint(p2)
        p3 = Program(p1.name, p1.arrays, p1.body, temps=("C",))
        assert program_fingerprint(p1) != program_fingerprint(p3)

    def test_fingerprint_obj_config_content(self):
        from repro.configs import get_config

        a, b = get_config("mixtral-8x7b"), get_config("mixtral-8x7b")
        assert fingerprint_obj(a) == fingerprint_obj(b)
        assert fingerprint_obj(a) != fingerprint_obj(get_config("qwen1.5-32b"))


# ---------------------------------------------------------------------------
# Daisy compile cache
# ---------------------------------------------------------------------------
class TestDaisyCache:
    def test_repeat_compile_hits(self):
        d = Daisy()
        fn1, plan1 = d.compile(BENCHMARKS["gemm"].make("a", "mini"))
        fn2, plan2 = d.compile(BENCHMARKS["gemm"].make("a", "mini"))  # fresh object
        assert fn1 is fn2 and plan1 is plan2
        assert d.cache_stats.hits >= 1

    def test_different_programs_miss(self):
        d = Daisy()
        fn1, _ = d.compile(BENCHMARKS["gemm"].make("a", "mini"))
        fn2, _ = d.compile(BENCHMARKS["bicg"].make("a", "mini"))
        assert fn1 is not fn2

    def test_db_mutation_invalidates_plans(self):
        d = Daisy()
        prog = BENCHMARKS["gemm"].make("a", "mini")
        fn1, plan1 = d.compile(prog)
        assert all(p.source.startswith("default") for p in plan1.nests)
        d.seed([prog], search=False)  # bumps db.generation
        fn2, plan2 = d.compile(prog)
        assert plan2 is not plan1
        assert all(p.source == "exact" for p in plan2.nests)

    def test_shared_cache_isolates_databases(self):
        # two Daisy instances sharing one CompilationCache but holding
        # different databases must not exchange plans (caught in review)
        shared = CompilationCache()
        d1 = Daisy(cache=shared)
        prog = BENCHMARKS["gemm"].make("a", "mini")
        d1.seed([prog], search=False)
        _, plan1 = d1.compile(prog)
        assert all(p.source == "exact" for p in plan1.nests)
        d2 = Daisy(cache=shared)  # empty database
        _, plan2 = d2.compile(BENCHMARKS["gemm"].make("a", "mini"))
        assert plan2 is not plan1
        assert all(p.source.startswith("default") for p in plan2.nests)

    def test_cached_fn_still_correct(self):
        from repro.core import execute_numpy
        from repro.core.scheduler import random_inputs

        d = Daisy()
        prog = BENCHMARKS["gemm"].make("a", "mini")
        d.compile(prog)
        fn, _ = d.compile(BENCHMARKS["gemm"].make("a", "mini"))
        inp = random_inputs(prog, seed=3)
        out = fn(inp)
        ref = execute_numpy(prog, {k: v.astype(np.float64) for k, v in inp.items()})
        np.testing.assert_allclose(np.asarray(out["C"]), ref["C"], rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Indexed TuningDatabase
# ---------------------------------------------------------------------------
def _linear_exact(db, fp):
    for e in db.entries:
        if e.fingerprint == fp:
            return e.recipe
    return None


def _linear_nearest(db, embedding, k=1):
    scored = sorted(
        ((distance(embedding, e.embedding), e) for e in db.entries),
        key=lambda t: t[0],
    )
    return [s for s in scored[:k] if s[0] <= db.radius]


@pytest.fixture(scope="module")
def seeded_db():
    d = Daisy()
    d.seed([BENCHMARKS[n].make("a", "mini") for n in ("gemm", "2mm", "bicg")],
           search=False)
    return d.db


class TestIndexedDatabase:
    def test_exact_matches_linear(self, seeded_db):
        for e in seeded_db.entries:
            assert seeded_db.lookup_exact(e.fingerprint) is _linear_exact(seeded_db, e.fingerprint)
        assert seeded_db.lookup_exact("no-such-nest") is None

    def test_nearest_matches_linear(self, seeded_db):
        rng = np.random.default_rng(0)
        probes = [e.embedding for e in seeded_db.entries]
        probes += [e.embedding + rng.normal(0, 0.1, DIM) for e in seeded_db.entries]
        probes.append(np.full(DIM, 1e6))  # far outside radius -> empty
        for q in probes:
            for k in (1, 3, len(seeded_db.entries)):
                got = seeded_db.lookup_nearest(q, k=k)
                want = _linear_nearest(seeded_db, q, k=k)
                assert [(pytest.approx(dist), e.fingerprint) for dist, e in want] == [
                    (dist, e.fingerprint) for dist, e in got
                ]

    def test_add_dedup_keeps_better_measurement(self):
        db = TuningDatabase()
        emb = np.zeros(DIM)
        db.add("fp", emb, Recipe(kind="einsum"), measured_us=100.0)
        db.add("fp", emb, Recipe(kind="vectorize"), measured_us=200.0)  # worse: ignored
        assert len(db.entries) == 1 and db.lookup_exact("fp").kind == "einsum"
        db.add("fp", emb, Recipe(kind="sequential"), measured_us=50.0)  # better: replaces
        assert db.lookup_exact("fp").kind == "sequential"

    def test_generation_bumps_on_mutation(self):
        db = TuningDatabase()
        g0 = db.generation
        db.add("a", np.zeros(DIM), Recipe())
        assert db.generation > g0
        g1 = db.generation
        db.add("a", np.zeros(DIM), Recipe())  # duplicate, no improvement
        assert db.generation == g1
        # direct appends (legacy style) are detected and reindexed
        db.entries.append(Entry("b", np.ones(DIM), Recipe()))
        assert db.lookup_exact("b") is not None
        assert db.generation > g1
        # same-length in-place replacement needs an explicit reindex()
        db.entries[0] = Entry("c", np.zeros(DIM), Recipe(kind="einsum"))
        db.reindex()
        assert db.lookup_exact("a") is None
        assert db.lookup_exact("c").kind == "einsum"


# ---------------------------------------------------------------------------
# Versioned persistence
# ---------------------------------------------------------------------------
class TestPersistence:
    def test_roundtrip_is_versioned(self, tmp_path, seeded_db):
        p = tmp_path / "db.json"
        seeded_db.save(p)
        raw = json.loads(p.read_text())
        assert raw["version"] == SCHEMA_VERSION
        loaded = TuningDatabase.load(p)
        assert len(loaded.entries) == len(seeded_db.entries)
        for e, l in zip(seeded_db.entries, loaded.entries):
            assert e.fingerprint == l.fingerprint and e.recipe == l.recipe
            np.testing.assert_allclose(e.embedding, l.embedding)
        # the loaded database is fully indexed
        for e in seeded_db.entries:
            assert loaded.lookup_exact(e.fingerprint) == _linear_exact(loaded, e.fingerprint)

    def test_loads_v1_files(self, tmp_path):
        legacy = {
            "radius": 4.5,
            "entries": [{
                "fingerprint": "fp1",
                "embedding": [0.0] * DIM,
                "recipe": Recipe(kind="einsum").to_json(),
                "provenance": "legacy",
                "measured_us": 12.0,
            }],
        }
        p = tmp_path / "v1.json"
        p.write_text(json.dumps(legacy))
        db = TuningDatabase.load(p)
        assert db.radius == 4.5
        assert db.lookup_exact("fp1").kind == "einsum"

    def test_rejects_future_versions(self, tmp_path):
        p = tmp_path / "future.json"
        p.write_text(json.dumps({"version": SCHEMA_VERSION + 1, "entries": []}))
        with pytest.raises(ValueError, match="newer than supported"):
            TuningDatabase.load(p)
