"""Dry-run machinery: collective parser units + small-mesh lower/compile in a
subprocess (so the main test process keeps its single CPU device)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.dryrun import _shape_bytes, collective_bytes

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestCollectiveParser:
    def test_shape_bytes(self):
        assert _shape_bytes("bf16[128,256]") == 128 * 256 * 2
        assert _shape_bytes("f32[16]{0}") == 64
        assert _shape_bytes("(bf16[8,8], f32[4])") == 128 + 16
        assert _shape_bytes("pred[10]") == 10

    def test_collective_classification(self):
        hlo = textwrap.dedent("""
          %ar = bf16[1024]{0} all-reduce(%x), replica_groups={}
          %ag.1 = f32[512,16]{1,0} all-gather(%y), dimensions={1}
          %rs = f32[64]{0} reduce-scatter(%z), dimensions={0}
          %a2a = (f32[32]{0}, f32[32]{0}) all-to-all(%p, %q)
          %cp = bf16[16,16]{1,0} collective-permute(%w)
          %dot = f32[8,8]{1,0} dot(%a, %b)
        """)
        out = collective_bytes(hlo)
        assert out["all-reduce"] == 2 * 1024 * 2  # 2x ring convention
        assert out["all-gather"] == 512 * 16 * 4
        assert out["reduce-scatter"] == 64 * 4
        assert out["all-to-all"] == 2 * 32 * 4
        assert out["collective-permute"] == 16 * 16 * 2


@pytest.mark.slow
def test_small_mesh_train_lowering_subprocess():
    """Lower + compile a reduced arch's train step on an 8-device (2,4) mesh
    and on a (2,2,2) pod mesh; assert collectives exist and it compiles."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import model as M
        from repro.optim.adamw import AdamWConfig, adamw_init
        from repro.train.train_loop import make_train_step
        from repro.launch.sharding import param_specs, batch_specs
        from repro.launch.dryrun import collective_bytes
        from repro.configs.base import SHAPES

        cfg = get_config('mixtral-8x7b').reduced()
        for shape, axes in [((2,4), ('data','model')), ((2,2,2), ('pod','data','model'))]:
            mesh = jax.make_mesh(shape, axes)
            params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
            opt = jax.eval_shape(partial(adamw_init), params)
            pspecs = param_specs(params, mesh)
            ospecs = {'m': pspecs, 'v': pspecs, 'step': NamedSharding(mesh, P())}
            batch = {'tokens': jax.ShapeDtypeStruct((8, 32), jnp.int32),
                     'labels': jax.ShapeDtypeStruct((8, 32), jnp.int32)}
            bspecs = batch_specs(cfg, SHAPES['train_4k'], mesh, batch)
            step = make_train_step(cfg, AdamWConfig())
            ws = lambda t, s: jax.tree_util.tree_map(
                lambda a, b: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=b), t, s)
            from repro.launch.mesh import set_mesh
            with set_mesh(mesh):
                lowered = jax.jit(step).lower(ws(params, pspecs), ws(opt, ospecs), ws(batch, bspecs))
                compiled = lowered.compile()
            mem = compiled.memory_analysis()
            assert mem is not None
            coll = collective_bytes(compiled.as_text())
            assert coll['all-reduce'] > 0, coll  # DP grad sync must appear
            print(shape, 'collectives:', {k: v for k, v in coll.items() if v})
        print('DRYRUN_SMALL_OK')
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=540, env=env, cwd=REPO)
    assert "DRYRUN_SMALL_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-3000:]


@pytest.mark.slow
def test_decode_small_mesh_subprocess():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from functools import partial
        from repro.configs import get_config
        from repro.models import model as M
        from repro.launch.sharding import param_specs, state_specs

        cfg = get_config('h2o-danube-3-4b').reduced()
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
        state = jax.eval_shape(lambda: M.init_decode_state(cfg, 4, 128))
        pspecs = param_specs(params, mesh)
        sspecs = state_specs(cfg, mesh, state)
        ws = lambda t, s: jax.tree_util.tree_map(
            lambda a, b: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=b), t, s)
        tok = jax.ShapeDtypeStruct((4, 1), jnp.int32)
        from repro.launch.mesh import set_mesh
        with set_mesh(mesh):
            lowered = jax.jit(partial(M.decode_step, cfg)).lower(
                ws(params, pspecs), ws(state, sspecs), tok)
            lowered.compile()
        print('DECODE_SMALL_OK')
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=540, env=env, cwd=REPO)
    assert "DECODE_SMALL_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-3000:]
