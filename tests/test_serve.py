"""Serving engine: batched generation, determinism, continuous admission."""
import numpy as np
import pytest
import jax

from repro.configs import get_config
from repro.models import model as M
from repro.serve import ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("minicpm-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_generates(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, ServeConfig(batch_slots=2, max_len=64, max_new_tokens=5))
    eng.submit(0, np.array([1, 2, 3], np.int32))
    eng.submit(1, np.array([9, 8, 7, 6], np.int32))
    eng.submit(2, np.array([4, 4], np.int32))  # more requests than slots
    out = eng.run()
    assert set(out) == {0, 1, 2}
    assert all(len(v) == 5 for v in out.values())


def test_greedy_is_deterministic(setup):
    cfg, params = setup
    outs = []
    for _ in range(2):
        eng = ServingEngine(cfg, params, ServeConfig(max_new_tokens=4))
        eng.submit(0, np.array([5, 6, 7], np.int32))
        outs.append(eng.run()[0])
    assert outs[0] == outs[1]


def test_greedy_matches_manual_decode(setup):
    cfg, params = setup
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    eng = ServingEngine(cfg, params, ServeConfig(max_new_tokens=3, max_len=64))
    eng.submit(0, prompt)
    got = eng.run()[0]

    # manual: prefill + greedy argmax loop
    st = M.init_decode_state(cfg, 1, 64, ring=False)
    logits, st = M.decode_step(cfg, params, st, prompt[None, :])
    toks = []
    last = logits[:, -1]
    import jax.numpy as jnp

    for _ in range(3):
        t = int(jnp.argmax(last[0]))
        toks.append(t)
        last, st = M.decode_step(cfg, params, st, jnp.full((1, 1), t, jnp.int32))
        last = last[:, -1]
    assert got == toks


def test_audio_engine_runs():
    cfg = get_config("seamless-m4t-large-v2").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    eng = ServingEngine(cfg, params, ServeConfig(max_new_tokens=3, max_len=32))
    eng.submit(0, np.array([1, 2], np.int32))
    out = eng.run()
    assert len(out[0]) == 3
