"""Serving engine: request handles, batched continuous decode, bucketed
admission, pipelined dispatch, traffic generator + percentile math."""
import warnings
from functools import partial

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M
from repro.serve import RequestHandle, ServeConfig, ServingEngine, prefill_buckets


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("minicpm-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def greedy_reference(cfg, params, prompt, n, max_len=64):
    """The old per-request loop: exact-length batch-1 prefill + greedy
    argmax decode with a host sync per token."""
    decode = jax.jit(partial(M.decode_step, cfg))
    st = M.init_decode_state(cfg, 1, max_len, ring=False)
    logits, st = decode(params, st, jnp.asarray(prompt[None, :]))
    toks, last = [], logits[:, -1]
    for _ in range(n):
        t = int(jnp.argmax(last[0]))
        toks.append(t)
        last, st = decode(params, st, jnp.full((1, 1), t, jnp.int32))
        last = last[:, -1]
    return toks


# ---------------------------------------------------------------------------
# request lifecycle API
# ---------------------------------------------------------------------------
class TestHandles:
    def test_submit_returns_handle(self, setup):
        cfg, params = setup
        eng = ServingEngine(cfg, params,
                            ServeConfig(batch_slots=2, max_len=64, max_new_tokens=3))
        h = eng.submit(np.array([1, 2, 3], np.int32))
        assert isinstance(h, RequestHandle)
        assert not h.done and h.tokens == []
        assert h.result() == h.tokens and h.done and len(h.tokens) == 3

    def test_step_and_drain(self, setup):
        cfg, params = setup
        eng = ServingEngine(cfg, params,
                            ServeConfig(batch_slots=2, max_len=64, max_new_tokens=4))
        h1 = eng.submit(np.array([1, 2], np.int32))
        h2 = eng.submit(np.array([3, 4, 5], np.int32))
        assert eng.step() > 0  # something live after one iteration
        out = eng.drain()
        assert h1.done and h2.done
        assert out[h1.rid] == h1.tokens and out[h2.rid] == h2.tokens

    def test_streaming_callback(self, setup):
        cfg, params = setup
        eng = ServingEngine(cfg, params,
                            ServeConfig(batch_slots=2, max_len=64, max_new_tokens=4))
        seen = []
        h = eng.submit(np.array([5, 6, 7], np.int32),
                       on_token=lambda hh, t: seen.append((hh.rid, t)))
        got = h.result()
        assert [t for _, t in seen] == got
        assert all(r == h.rid for r, _ in seen)

    def test_legacy_submit_and_run_deprecated(self, setup):
        cfg, params = setup
        eng = ServingEngine(cfg, params,
                            ServeConfig(batch_slots=2, max_len=64, max_new_tokens=5))
        with pytest.warns(DeprecationWarning):
            eng.submit(0, np.array([1, 2, 3], np.int32))
        eng.submit(np.array([9, 8, 7, 6], np.int32), rid=1)
        eng.submit(np.array([4, 4], np.int32), rid=2)  # more requests than slots
        with pytest.warns(DeprecationWarning):
            out = eng.run()
        assert set(out) == {0, 1, 2}
        assert all(len(v) == 5 for v in out.values())

    def test_auto_rids_unique(self, setup):
        cfg, params = setup
        eng = ServingEngine(cfg, params,
                            ServeConfig(batch_slots=2, max_len=64, max_new_tokens=2))
        hs = [eng.submit(np.array([i + 1], np.int32)) for i in range(3)]
        assert len({h.rid for h in hs}) == 3
        out = eng.drain()
        assert set(out) == {h.rid for h in hs}


# ---------------------------------------------------------------------------
# batched decode correctness
# ---------------------------------------------------------------------------
class TestBatchedDecode:
    def test_greedy_matches_per_request_loop(self, setup):
        """Continuous batching must be token-for-token identical to the old
        per-request batch-1 loop (bucketed prefill + vmap decode are
        bit-exact)."""
        cfg, params = setup
        prompts = [np.array([3, 1, 4, 1, 5], np.int32),
                   np.array([9, 8, 7], np.int32),
                   np.array([2, 2, 2, 2, 2, 2, 2], np.int32),
                   np.array([6], np.int32),
                   np.array([1, 2, 3, 4], np.int32)]  # > batch_slots
        eng = ServingEngine(cfg, params,
                            ServeConfig(batch_slots=4, max_len=64, max_new_tokens=6))
        hs = [eng.submit(p) for p in prompts]
        eng.drain()
        for h, p in zip(hs, prompts):
            assert h.tokens == greedy_reference(cfg, params, p, 6), h.rid

    def test_greedy_is_deterministic(self, setup):
        cfg, params = setup
        outs = []
        for _ in range(2):
            eng = ServingEngine(cfg, params, ServeConfig(max_len=64, max_new_tokens=4))
            outs.append(eng.submit(np.array([5, 6, 7], np.int32)).result())
        assert outs[0] == outs[1]

    def test_pipeline_depth_invariant(self, setup):
        """The dispatch-ahead distance must not change greedy outputs."""
        cfg, params = setup
        prompt = np.array([3, 1, 4], np.int32)
        outs = []
        for depth in (0, 1, 3):
            eng = ServingEngine(
                cfg, params,
                ServeConfig(batch_slots=2, max_len=64, max_new_tokens=5,
                            pipeline_depth=depth))
            outs.append(eng.submit(prompt).result())
        assert outs[0] == outs[1] == outs[2]

    def test_eos_slot_refill_mid_stream(self, setup):
        """eos in one slot while others continue: the finished slot is
        refilled from the queue and nobody else's tokens change."""
        cfg, params = setup
        prompts = [np.array([3, 1, 4, 1, 5], np.int32),
                   np.array([9, 8, 7], np.int32),
                   np.array([2, 7, 1, 8], np.int32)]
        refs = [greedy_reference(cfg, params, p, 8) for p in prompts]
        # pick the token request 0 emits mid-stream as the eos id; requests
        # 1/2 must not emit it anywhere or they'd legitimately stop early
        eos = refs[0][3]
        assert eos not in refs[1] and eos not in refs[2], "test prompt collision"
        eng = ServingEngine(cfg, params,
                            ServeConfig(batch_slots=2, max_len=64,
                                        max_new_tokens=8, eos_id=eos))
        hs = [eng.submit(p) for p in prompts]
        out = eng.drain()
        assert hs[0].tokens == refs[0][:4]  # stopped at the eos token
        assert hs[1].tokens == refs[1]      # unaffected neighbours
        assert hs[2].tokens == refs[2]      # admitted into the freed slot
        assert set(out) == {h.rid for h in hs}

    def test_audio_engine_runs(self):
        cfg = get_config("seamless-m4t-large-v2").reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(1))
        eng = ServingEngine(cfg, params, ServeConfig(max_new_tokens=3, max_len=32))
        assert len(eng.submit(np.array([1, 2], np.int32)).result()) == 3

    def test_temperature_sampling_path(self, setup):
        cfg, params = setup
        eng = ServingEngine(
            cfg, params,
            ServeConfig(batch_slots=2, max_len=64, max_new_tokens=4,
                        temperature=1.0, seed=7))
        h1 = eng.submit(np.array([5, 6, 7], np.int32))
        h2 = eng.submit(np.array([1, 2], np.int32))
        out = eng.drain()
        assert len(h1.tokens) == 4 and len(h2.tokens) == 4
        assert out[h1.rid] == h1.tokens


# ---------------------------------------------------------------------------
# bucketed admission
# ---------------------------------------------------------------------------
class TestBuckets:
    def test_bucket_table(self):
        assert prefill_buckets(512, 16) == (16, 32, 64, 128, 256, 512)
        assert prefill_buckets(96, 16) == (16, 32, 64, 96)
        assert prefill_buckets(8, 16) == (8,)

    def test_prompt_longer_than_largest_bucket(self, setup):
        cfg, params = setup
        eng = ServingEngine(cfg, params,
                            ServeConfig(batch_slots=2, max_len=32, max_new_tokens=2))
        with pytest.raises(ValueError, match="exceeds the largest prefill bucket"):
            eng.submit(np.arange(1, 40, dtype=np.int32))

    def test_prompt_plus_max_new_overflows_cache(self, setup):
        cfg, params = setup
        eng = ServingEngine(cfg, params,
                            ServeConfig(batch_slots=2, max_len=32, max_new_tokens=16))
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit(np.arange(1, 30, dtype=np.int32))

    def test_empty_prompt_rejected(self, setup):
        cfg, params = setup
        eng = ServingEngine(cfg, params, ServeConfig(max_len=64))
        with pytest.raises(ValueError, match="non-empty"):
            eng.submit(np.array([], np.int32))

    def test_empty_queue_is_idle(self, setup):
        cfg, params = setup
        eng = ServingEngine(cfg, params, ServeConfig(max_len=64, max_new_tokens=2))
        assert eng.step() == 0
        assert eng.drain() == {}

    def test_bucketed_prefill_matches_exact(self, setup):
        """A prompt that needs padding up to a bucket must decode exactly
        like the exact-length prefill (padded rows masked + overwritten)."""
        cfg, params = setup
        prompt = np.array([11, 3, 9], np.int32)  # pads to the 16 bucket
        eng = ServingEngine(cfg, params,
                            ServeConfig(batch_slots=1, max_len=64, max_new_tokens=6))
        assert eng.submit(prompt).result() == greedy_reference(cfg, params, prompt, 6)

    def test_recurrent_families_prefill_exact(self):
        """hybrid/ssm carry token-recurrent state: padded prompt tokens
        would pollute it, so admission uses the exact length."""
        cfg = get_config("xlstm-350m").reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(2))
        eng = ServingEngine(cfg, params, ServeConfig(max_len=32, max_new_tokens=2))
        assert eng._bucket_for(5) == 5
        assert len(eng.submit(np.array([1, 2, 3, 4, 5], np.int32)).result()) == 2


# ---------------------------------------------------------------------------
# deployment context (shared engine/trainer boilerplate)
# ---------------------------------------------------------------------------
class TestDeploymentContext:
    def test_engine_and_trainer_share_warm_db(self, setup, tmp_path):
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.train.train_loop import Trainer, TrainerConfig

        cfg, params = setup
        eng = ServingEngine(cfg, params, ServeConfig(max_len=32))
        tr = Trainer(cfg, AdamWConfig(),
                     DataConfig(seq_len=8, global_batch=2, vocab=cfg.vocab),
                     TrainerConfig(ckpt_dir=str(tmp_path)))
        # both fall back to the one shared per-backend deployment database
        assert eng.tuning_db is tr.tuning_db

    def test_jitted_fns_shared_across_engines(self, setup):
        cfg, params = setup
        e1 = ServingEngine(cfg, params, ServeConfig(max_len=32))
        e2 = ServingEngine(cfg, params, ServeConfig(max_len=32))
        assert e1._step_greedy is e2._step_greedy
        assert e1._decode is e2._decode

    def test_place_without_mesh_is_identity(self, setup):
        from repro.models.lowering import deployment_context

        cfg, params = setup
        ctx = deployment_context(cfg, params)
        assert ctx.params is params
        tree = {"x": jnp.ones((2,))}
        assert ctx.place(tree) is tree


# ---------------------------------------------------------------------------
# traffic generator + percentile math (bench_serve units)
# ---------------------------------------------------------------------------
class TestTraffic:
    def test_traffic_deterministic_under_seed(self):
        from benchmarks.bench_serve import make_traffic

        a = make_traffic(12, 50.0, (4, 8, 16), 100, seed=3)
        b = make_traffic(12, 50.0, (4, 8, 16), 100, seed=3)
        c = make_traffic(12, 50.0, (4, 8, 16), 100, seed=4)
        assert [t for t, _ in a] == [t for t, _ in b]
        assert all((pa == pb).all() for (_, pa), (_, pb) in zip(a, b))
        assert [t for t, _ in a] != [t for t, _ in c]
        # open loop: arrivals strictly increasing, lengths from the mix
        times = [t for t, _ in a]
        assert times == sorted(times) and times[0] > 0
        assert {len(p) for _, p in a} <= {4, 8, 16}

    def test_percentile_math(self):
        from benchmarks.bench_serve import percentile

        vals = [10.0, 20.0, 30.0, 40.0]
        assert percentile(vals, 50) == 25.0  # linear interpolation
        assert percentile(vals, 0) == 10.0
        assert percentile(vals, 100) == 40.0
        assert percentile(vals, 99) == pytest.approx(39.7)
        assert percentile([7.0], 99) == 7.0
        assert percentile(np.arange(1, 101, dtype=float), 50) == 50.5
        assert percentile(np.arange(1, 101, dtype=float), 99) == pytest.approx(99.01)
        with pytest.raises(ValueError):
            percentile([], 50)

    def test_handle_result_on_idle_engine_raises(self, setup):
        cfg, params = setup
        h = RequestHandle(rid=0, prompt=np.array([1], np.int32))
        with pytest.raises(RuntimeError, match="idle"):
            h.result()


def test_no_deprecation_from_new_api(setup):
    """The new lifecycle must be warning-free (run()/legacy submit are the
    only deprecated surfaces)."""
    cfg, params = setup
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        eng = ServingEngine(cfg, params, ServeConfig(max_len=64, max_new_tokens=2))
        h = eng.submit(np.array([1, 2], np.int32))
        eng.drain()
    assert h.done
