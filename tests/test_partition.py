"""Partition planner + sharded execution (repro.core.partition).

The planner units are pure (explicit ``n_shards``, no devices needed); the
execution tests build a 1-D mesh over whatever devices exist — under the CI
shard job (``--xla_force_host_platform_device_count=8``) they exercise real
multi-shard ``shard_map`` + collectives, on a single device they degrade to
the documented replication fallback and still must be oracle-identical.
"""
import numpy as np
import pytest
import jax

from repro.core import (
    Daisy,
    Schedule,
    compile_jax,
    compile_sharded,
    execute_numpy,
    plan_program_partition,
    run_sharded,
)
from repro.core.ir import Array, Computation, Loop, Program, acc, aff
from repro.core.fusion import optimization_pipeline
from repro.core.partition import local_program
from repro.core.recipes import Recipe
from repro.core.scheduler import random_inputs
from repro.core.search import _mutate, schedule_from_recipe
from repro.cloudsc import compile_scheme, mini_cloudsc_program
from repro.cloudsc.scheme import column_mesh, scheme_inputs
from repro.launch.mesh import make_mesh
from repro.polybench.suite import BENCHMARKS

PIPE = optimization_pipeline(fuse=True)
SCHED = Schedule(mode="canonical", use_idioms=False, shard_axis="data")


def elementwise(rows=16, cols=8) -> Program:
    c = Computation("ew", acc("B", "i", "j"), (acc("A", "i", "j"),),
                    lambda a: a * 2.0 + 1.0)
    return Program("ew", (Array("A", (rows, cols)), Array("B", (rows, cols))),
                   (Loop("i", rows, body=(Loop("j", cols, body=(c,)),)),))


def reduction(m=8, n=12) -> Program:
    """s[j] += A[i,j] * r[i] in (i, j) order: sharding i must all-reduce s."""
    mac = Computation("mac", acc("s", "j"), (acc("A", "i", "j"), acc("r", "i")),
                      lambda a, r: a * r, accumulate="+")
    return Program("red", (Array("A", (m, n)), Array("r", (m,)),
                           Array("s", (n,))),
                   (Loop("i", m, body=(Loop("j", n, body=(mac,)),)),))


def _oracle_check(program: Program, fn, outputs, rtol=1e-4, seed=3):
    inp = random_inputs(program, seed=seed, dtype=np.float64)
    ref = execute_numpy(program, inp)
    got = jax.jit(fn)({k: np.asarray(v, np.float32) for k, v in inp.items()})
    for k in outputs:
        denom = max(1e-9, np.abs(ref[k]).max())
        rel = np.abs(np.asarray(got[k], np.float64) - ref[k]).max() / denom
        assert rel < rtol, (program.name, k, rel)


def data_mesh():
    return make_mesh((jax.device_count(),), ("data",))


# ---------------------------------------------------------------------------
# planner units (pure — no devices)
# ---------------------------------------------------------------------------
class TestPlanner:
    def test_elementwise_shards_outermost(self):
        plan = plan_program_partition(elementwise(), 4)
        assert plan.nests[0].iterator == "i"
        assert plan.array_dims == {"A": 0, "B": 0}
        assert plan.sharded

    def test_reduction_all_reduces(self):
        plan = plan_program_partition(reduction(), 4)
        assert plan.nests[0].iterator == "i"
        assert plan.nests[0].reduces == (("s", "+"),)
        assert plan.array_dims == {"A": 0, "r": 0, "s": None}

    def test_carried_recurrence_vetoed(self):
        # flux-style 1-D recurrence: A[t] reads A[t-1] (guarded at t=0)
        base = Computation("f0", acc("A", "t"), (acc("X", "t"),),
                           lambda x: x, guards=(aff(("t", -1)),))
        rec = Computation("fl", acc("A", "t"),
                          (acc("A", aff("t", const=-1)), acc("X", "t")),
                          lambda a, x: 0.5 * a + x,
                          guards=(aff("t", const=-1),))
        p = Program("recur", (Array("A", (12,)), Array("X", (12,))),
                    (Loop("t", 12, body=(base, rec)),))
        plan = plan_program_partition(p, 4)
        assert not plan.sharded
        assert "carried dependence" in plan.nests[0].reason

    def test_column_recurrence_shards_the_parallel_dim(self):
        # A[i,j] reads A[i-1,j]: i carried, j parallel -> shard j (CLOUDSC)
        st = Computation("st", acc("A", "i", "j"),
                         (acc("A", aff("i", const=-1), "j"),),
                         lambda a: 0.5 * a, guards=(aff("i", const=-1),))
        p = Program("col", (Array("A", (6, 8)),),
                    (Loop("i", 6, body=(Loop("j", 8, body=(st,)),)),))
        plan = plan_program_partition(p, 4)
        assert plan.nests[0].iterator == "j"
        assert plan.array_dims == {"A": 1}

    def test_offset_access_is_cross_shard_flow(self):
        c = Computation("sh", acc("B", "i"),
                        (acc("A", aff("i", const=1)),), lambda a: a)
        p = Program("off", (Array("A", (13,)), Array("B", (12,))),
                    (Loop("i", 12, body=(c,)),))
        plan = plan_program_partition(p, 4)
        assert not plan.sharded
        assert "cross-shard" in plan.nests[0].reason

    def test_guard_on_shard_iterator_vetoes(self):
        c = Computation("tri", acc("B", "i", "j"), (acc("A", "i", "j"),),
                        lambda a: a, guards=(aff("i", ("j", -1)),))  # j <= i
        p = Program("tri", (Array("A", (8, 8)), Array("B", (8, 8))),
                    (Loop("i", 8, body=(Loop("j", 8, body=(c,)),)),))
        plan = plan_program_partition(p, 4)
        assert not plan.sharded
        assert "guard" in plan.nests[0].reason

    def test_non_reducible_accumulate_vetoed(self):
        c = Computation("pr", acc("S"), (acc("r", "i"),),
                        lambda r: r, accumulate="*")
        p = Program("prod", (Array("r", (8,)), Array("S", ())),
                    (Loop("i", 8, body=(c,)),), temps=("S",))
        plan = plan_program_partition(p, 4)
        assert not plan.sharded
        assert "all-reducible" in plan.nests[0].reason

    def test_padded_reduction_vetoed(self):
        c = Computation("dot", acc("S"), (acc("r", "i"),),
                        lambda r: r, accumulate="+")
        p = Program("dot", (Array("r", (10,)), Array("S", ())),
                    (Loop("i", 10, body=(c,)),), temps=("S",))
        plan = plan_program_partition(p, 4)
        assert not plan.sharded
        assert "padded extent" in plan.nests[0].reason

    def test_replication_unlocks_later_nest(self):
        # the s-fill shards s first; the MAC can only shard i if s is whole
        # (it reads s[j] under i), and its j-reduce alternative is vetoed by
        # the non-dividing extent — the planner must re-plan with s pinned
        # replicated instead of losing the (heavy) MAC nest
        zs = Computation("zs", acc("s", "k"), (), lambda: 0.0)
        mac = Computation("mac", acc("w", "i"),
                          (acc("A2", "i", "j"), acc("s", "j")),
                          lambda a, s: a * s, accumulate="+")
        p = Program("mv", (Array("s", (10,)), Array("A2", (8, 10)),
                           Array("w", (8,))),
                    (Loop("k", 10, body=(zs,)),
                     Loop("i", 8, body=(Loop("j", 10, body=(mac,)),))))
        plan = plan_program_partition(p, 4)
        assert plan.nests[0].iterator is None  # fill replicated after restart
        assert "conflict" in plan.nests[0].reason
        assert plan.nests[1].iterator == "i"
        assert plan.array_dims == {"s": None, "A2": 0, "w": 0}

    def test_reduce_target_read_inside_nest_vetoed(self):
        # imperfect nest: the accumulate runs under p, but a sibling at the
        # outer level reads the target before the post-nest all-reduce —
        # sharding p would expose per-shard partial sums
        mac = Computation("mac", acc("T", "j"), (acc("A", "p", "j"),),
                          lambda a: a, accumulate="+")
        use = Computation("use", acc("B", "j"), (acc("T", "j"),),
                          lambda t: 2.0 * t)
        p = Program("partial", (Array("A", (8, 2)), Array("T", (2,)),
                                Array("B", (2,))),
                    (Loop("j", 2, body=(Loop("p", 8, body=(mac,)), use)),),
                    temps=("T",))
        plan = plan_program_partition(p, 4)
        assert not plan.sharded  # j too small, p must veto
        from repro.core.partition import _candidate

        cand = _candidate(p, p.body[0], "p", 4)
        assert isinstance(cand, str) and "partial sums" in cand
        # and the compiled fallback stays oracle-identical
        fn, _ = compile_sharded(p, SCHED, mesh=data_mesh())
        _oracle_check(p, fn, ("B",))

    def test_disabled_nest_stays_replicated(self):
        plan = plan_program_partition(elementwise(), 4, enabled=[False])
        assert not plan.sharded
        assert "disabled" in plan.nests[0].reason

    def test_local_program_pads_and_divides(self):
        p = elementwise(rows=10, cols=8)
        plan = plan_program_partition(p, 4)
        assert plan.padded_extent(10) == 12
        local = local_program(p, plan)
        assert local.array("A").shape == (3, 8)
        assert local.body[0].stop == 3

    def test_small_extent_not_sharded(self):
        plan = plan_program_partition(elementwise(rows=3, cols=64), 4)
        # outer too small -> planner moves inward to the full-width j
        assert plan.nests[0].iterator == "j"
        assert plan.array_dims == {"A": 1, "B": 1}

    def test_describe_mentions_every_nest(self):
        plan = plan_program_partition(reduction(), 4)
        text = plan.describe()
        assert "shard i" in text and "all-reduce(s,+)" in text


# ---------------------------------------------------------------------------
# sharded execution vs the numpy oracle (mesh over available devices)
# ---------------------------------------------------------------------------
class TestExecution:
    def test_elementwise_matches_oracle(self):
        n = jax.device_count()
        p = elementwise(rows=8 * n, cols=16)
        fn, plan = compile_sharded(p, SCHED, mesh=data_mesh())
        assert plan.sharded == (n > 1)
        _oracle_check(p, fn, ("B",))

    def test_padding_matches_oracle(self):
        n = jax.device_count()
        p = elementwise(rows=3 * n + 1, cols=8)  # never divides n > 1
        fn, plan = compile_sharded(p, SCHED, mesh=data_mesh())
        _oracle_check(p, fn, ("B",))

    def test_all_reduce_matches_oracle(self):
        n = jax.device_count()
        p = reduction(m=4 * n, n=6)
        fn, plan = compile_sharded(p, SCHED, mesh=data_mesh())
        if n > 1:
            assert plan.nests[0].reduces == (("s", "+"),)
        _oracle_check(p, fn, ("s",))

    @pytest.mark.parametrize("op,expr", [("max", max), ("min", min)])
    def test_minmax_all_reduce(self, op, expr):
        n = jax.device_count()
        c = Computation("mm", acc("S", "j"), (acc("A", "i", "j"),),
                        lambda a: a, accumulate=op)
        p = Program("mm", (Array("A", (4 * n, 8)), Array("S", (8,))),
                    (Loop("i", 4 * n, body=(Loop("j", 8, body=(c,)),)),),
                    temps=("S",))
        fn, plan = compile_sharded(p, SCHED, mesh=data_mesh())
        if n > 1:
            assert plan.nests[0].reduces == (("S", op),)
        _oracle_check(p, fn, ("S",))

    @pytest.mark.parametrize("name", ["gemm", "doitgen", "gesummv", "bicg"])
    def test_polybench_matches_oracle(self, name):
        n = jax.device_count()
        sizes = {
            "gemm": None,  # suite mini
            "doitgen": dict(nr=2 * n, nq=10, np=12),
            "gesummv": dict(n=8 * n),
            "bicg": dict(n=8 * n, m=12 * n),
        }[name]
        bench = BENCHMARKS[name]
        prog = bench.variants["a"](sizes) if sizes else bench.make("a", "mini")
        norm = PIPE.run(prog)
        fn, plan = compile_sharded(norm, SCHED, mesh=data_mesh())
        _oracle_check(norm, fn, (bench.output,), rtol=1e-3)

    def test_cloudsc_columns_match_oracle(self):
        mesh = column_mesh()
        nproma = 8 * jax.device_count()
        fn, plan = compile_scheme(nproma, 5, mesh=mesh)
        if jax.device_count() > 1:
            assert plan.sharded
            assert all(x.iterator is not None for x in plan.nests)
            assert all(not x.reduces for x in plan.nests)  # zero collectives
        norm = PIPE.run(mini_cloudsc_program(nproma, 5))
        inp = scheme_inputs(nproma, 5)
        ref = execute_numpy(norm, inp)
        got = fn({k: np.asarray(v, np.float32) for k, v in inp.items()})
        for k in ("PFPLSL", "TENDQ", "ZTP1"):
            denom = max(1e-9, np.abs(ref[k]).max())
            rel = np.abs(np.asarray(got[k], np.float64) - ref[k]).max() / denom
            assert rel < 1e-4, (k, rel)

    def test_sharded_equals_unsharded_bitwise_when_no_reduce(self):
        # no collectives -> same op order per element -> bit-identical
        n = jax.device_count()
        p = elementwise(rows=8 * n, cols=16)
        inp = {k: np.asarray(v, np.float32)
               for k, v in random_inputs(p, seed=5).items()}
        ref = jax.jit(compile_jax(p, SCHED))(inp)
        got = run_sharded(p, inp, data_mesh(), SCHED)
        np.testing.assert_array_equal(np.asarray(ref["B"]), np.asarray(got["B"]))

    def test_shard_axis_none_disables(self):
        fn, plan = compile_sharded(
            elementwise(), Schedule(shard_axis=None), mesh=data_mesh())
        assert not plan.sharded


# ---------------------------------------------------------------------------
# scheduler plumbing
# ---------------------------------------------------------------------------
class TestDaisyMesh:
    def test_daisy_compile_sharded_cloudsc(self):
        n = jax.device_count()
        mesh = data_mesh()
        d = Daisy(backend="xla", mesh=mesh)
        prog = mini_cloudsc_program(8 * n, 5)
        fn, plan = d.compile(prog)
        assert plan.partition is not None
        assert plan.partition.sharded == (n > 1)
        inp = scheme_inputs(8 * n, 5)
        ref = execute_numpy(prog, inp)
        got = fn({k: np.asarray(v, np.float32) for k, v in inp.items()})
        denom = max(1e-9, np.abs(ref["TENDQ"]).max())
        rel = np.abs(np.asarray(got["TENDQ"], np.float64)
                     - ref["TENDQ"]).max() / denom
        assert rel < 1e-4

    def test_mesh_enters_cache_key(self):
        prog = elementwise()
        cache_hits = []
        d1 = Daisy(backend="xla")
        d2 = Daisy(backend="xla", mesh=data_mesh(), cache=d1.cache, db=d1.db)
        fn1, _ = d1.compile(prog)
        fn2, _ = d2.compile(prog)
        cache_hits.append(fn1 is fn2)
        fn2b, _ = d2.compile(prog)
        assert not cache_hits[0]  # mesh/no-mesh must not share a slot
        assert fn2b is fn2        # same mesh signature re-hits

    def test_recipe_parallelize_threads_into_schedule(self):
        s = schedule_from_recipe(Recipe(kind="vectorize", parallelize="data"))
        assert s.shard_axis == "data"
        s = schedule_from_recipe(Recipe(kind="vectorize"), shard_axis="data")
        assert s.shard_axis == "data"
        s = schedule_from_recipe(Recipe(kind="vectorize"))
        assert s.shard_axis is None
        # the 'none' sentinel disables sharding even under a scheduler default
        s = schedule_from_recipe(Recipe(kind="vectorize", parallelize="none"),
                                 shard_axis="data")
        assert s.shard_axis is None

    def test_mutation_reaches_parallelize_knob(self):
        import random

        rng = random.Random(0)
        seen = set()
        r = Recipe(kind="vectorize")
        for _ in range(400):
            r2 = _mutate(r, rng)
            seen.add(r2.parallelize)
            if r2.parallelize != r.parallelize:
                r = r2  # walk the cycle: default -> pinned -> off
        assert {"data", "none"} <= seen  # pin and disable both reachable
