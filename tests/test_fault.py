"""Fault-tolerance layer: seeded injection, request-scoped serving
isolation, tune-pool supervision, durable databases, degradation chain."""
import json
import time
from concurrent.futures import CancelledError

import numpy as np
import pytest
import jax

from repro.configs import get_config
from repro.core import DatabaseCorruption, TuningDatabase
from repro.core.recipes import Recipe
from repro.fault import (
    Fault,
    FaultInjected,
    FaultPlan,
    Heartbeat,
    compile_with_degradation,
    truncate_file,
)
from repro.models import model as M
from repro.serve import RequestState, ServeConfig, ServingEngine


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------
class TestFaultPlan:
    def test_explicit_fault_fires_once(self):
        plan = FaultPlan([Fault("site.a", "error", key=1)])
        assert plan.fire("site.a", key=2) is None  # key mismatch
        f = plan.fire("site.a", key=1)
        assert f is not None and f.kind == "error"
        assert plan.fire("site.a", key=1) is None  # times=1 burned out
        assert plan.count("site.a") == 1

    def test_times_budget(self):
        plan = FaultPlan([Fault("s", "crash", times=2)])
        assert plan.fire("s") is not None
        assert plan.fire("s") is not None
        assert plan.fire("s") is None

    def test_unlimited_times(self):
        plan = FaultPlan([Fault("s", times=-1)])
        for _ in range(5):
            assert plan.fire("s") is not None

    def test_maybe_raise_error_kind(self):
        plan = FaultPlan([Fault("s", "error")])
        with pytest.raises(FaultInjected):
            plan.maybe_raise("s")

    def test_maybe_raise_returns_non_error(self):
        plan = FaultPlan([Fault("s", "nan")])
        f = plan.maybe_raise("s")
        assert f is not None and f.kind == "nan"

    def test_rate_based_is_seeded(self):
        fires = []
        for _ in range(2):
            plan = FaultPlan(seed=7, rate=0.5, sites=("s",))
            fires.append([plan.fire("s", key=i) is not None for i in range(20)])
        assert fires[0] == fires[1]  # same seed -> same schedule
        assert any(fires[0]) and not all(fires[0])

    def test_plan_does_not_mutate_caller_faults(self):
        f = Fault("s", times=1)
        plan = FaultPlan([f])
        plan.fire("s")
        assert f.times == 1  # the plan owns a copy


# ---------------------------------------------------------------------------
# Heartbeat atomic stamps
# ---------------------------------------------------------------------------
class TestHeartbeatAtomic:
    def test_stamp_is_atomic_and_parseable(self, tmp_path):
        hb = Heartbeat(tmp_path / "hb.json", interval=0.02)
        hb.start()
        deadline = time.time() + 0.5
        seen = 0
        while time.time() < deadline:
            # concurrent reader: an atomic writer never exposes a partial file
            if not (tmp_path / "hb.json").exists():
                continue
            json.loads((tmp_path / "hb.json").read_text())  # must always parse
            age = Heartbeat.age(tmp_path / "hb.json")
            assert age is not None and age < 60.0
            seen += 1
        hb.stop()
        assert seen > 0
        assert not list(tmp_path.glob(".hb.json.*.tmp"))  # no tmp debris


# ---------------------------------------------------------------------------
# TuningDatabase durability
# ---------------------------------------------------------------------------
def _mini_db() -> TuningDatabase:
    db = TuningDatabase()
    db.add("fp-a", np.zeros(4), Recipe(kind="einsum"), measured_us=2.0)
    db.add("fp-b", np.ones(4), Recipe(kind="vectorize"), measured_us=3.0)
    return db


class TestDatabaseDurability:
    def test_save_writes_checksum_and_bak(self, tmp_path):
        p = tmp_path / "db.json"
        _mini_db().save(p)
        raw = json.loads(p.read_text())
        assert raw["version"] == 2 and "checksum" in raw
        assert (tmp_path / "db.json.bak").exists()
        assert not list(tmp_path.glob(".db.json.*.tmp"))

    def test_truncated_primary_recovers_from_bak(self, tmp_path):
        p = tmp_path / "db.json"
        _mini_db().save(p)
        truncate_file(p, 0.4)  # the torn write a crash leaves behind
        db = TuningDatabase.load(p)
        assert len(db.entries) == 2

    def test_checksum_detects_silent_tamper(self, tmp_path):
        p = tmp_path / "db.json"
        _mini_db().save(p)
        p.write_text(p.read_text().replace('"measured_us": 2.0',
                                           '"measured_us": 99.0'))
        db = TuningDatabase.load(p)  # valid JSON, bad checksum -> .bak
        assert db.entries[0].measured_us == 2.0

    def test_both_corrupt_raises(self, tmp_path):
        p = tmp_path / "db.json"
        _mini_db().save(p)
        truncate_file(p, 0.3)
        truncate_file(tmp_path / "db.json.bak", 0.3)
        with pytest.raises(DatabaseCorruption):
            TuningDatabase.load(p)

    def test_corrupt_without_bak_raises(self, tmp_path):
        p = tmp_path / "db.json"
        p.write_text("{not json")
        with pytest.raises(DatabaseCorruption):
            TuningDatabase.load(p)

    def test_newer_version_is_not_corruption(self, tmp_path):
        p = tmp_path / "db.json"
        _mini_db().save(p)
        raw = json.loads(p.read_text())
        raw["version"] = 99
        raw["checksum"] = TuningDatabase._checksum(raw)
        p.write_text(json.dumps(raw))
        with pytest.raises(ValueError, match="newer than supported"):
            TuningDatabase.load(p)

    def test_legacy_file_without_checksum_loads(self, tmp_path):
        p = tmp_path / "db.json"
        _mini_db().save(p)
        raw = json.loads(p.read_text())
        del raw["checksum"]
        p.write_text(json.dumps(raw))
        (tmp_path / "db.json.bak").unlink()
        assert len(TuningDatabase.load(p).entries) == 2


# ---------------------------------------------------------------------------
# backend degradation chain
# ---------------------------------------------------------------------------
class TestDegradation:
    def _prog(self):
        from repro.tools.tune import build_program

        return build_program("polybench", "gemm", "mini")

    def test_first_rung_wins_when_healthy(self):
        res = compile_with_degradation(self._prog(),
                                       backends=("pallas_interpret", "xla"))
        assert res.backend == "pallas_interpret" and not res.degraded

    def test_injected_failure_degrades_to_xla(self):
        plan = FaultPlan([Fault("daisy.compile", "error",
                                key="pallas_interpret")])
        res = compile_with_degradation(self._prog(),
                                       backends=("pallas_interpret", "xla"),
                                       fault_plan=plan)
        assert res.degraded and res.backend == "xla"
        assert [b for b, _ in res.errors] == ["pallas_interpret"]

    def test_all_rungs_fail_raises_first_error(self):
        plan = FaultPlan([Fault("daisy.compile", "error", key="pallas_interpret"),
                          Fault("daisy.compile", "error", key="xla")])
        with pytest.raises(RuntimeError, match="all backends failed"):
            compile_with_degradation(self._prog(),
                                     backends=("pallas_interpret", "xla"),
                                     fault_plan=plan)


# ---------------------------------------------------------------------------
# serving: request-scoped isolation
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def serve_setup():
    cfg = get_config("minicpm-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(batch_slots=2, max_len=96, max_new_tokens=6)
    prompts = {i: (np.arange(1, 5 + i) % cfg.vocab).astype(np.int32)
               for i in range(4)}
    eng = ServingEngine(cfg, params, scfg)
    for i, p in prompts.items():
        eng.submit(p, rid=i)
    reference = dict(eng.drain())
    return cfg, params, scfg, prompts, reference


class TestServingIsolation:
    def test_survivors_are_token_identical(self, serve_setup):
        cfg, params, scfg, prompts, ref = serve_setup
        plan = FaultPlan([Fault("serve.decode", "error", key=1),
                          Fault("serve.prefill", "nan", key=2)])
        eng = ServingEngine(cfg, params, scfg, fault_plan=plan)
        hs = {i: eng.submit(p, rid=i) for i, p in prompts.items()}
        res = eng.drain()
        for i in (1, 2):
            assert hs[i].state is RequestState.FAILED
            assert i not in res and i in eng.failed
        for i in (0, 3):  # untouched requests: bit-exact vs fault-free
            assert hs[i].state is RequestState.COMPLETED
            assert res[i] == ref[i]
        assert plan.count() == 2

    def test_failed_handle_raises_captured_error(self, serve_setup):
        cfg, params, scfg, prompts, _ = serve_setup
        plan = FaultPlan([Fault("serve.decode", "error", key=0)])
        eng = ServingEngine(cfg, params, scfg, fault_plan=plan)
        h = eng.submit(prompts[0], rid=0)
        eng.drain()
        assert h.error is not None
        with pytest.raises(FaultInjected):
            h.result()

    def test_nan_prefill_fails_only_that_request(self, serve_setup):
        cfg, params, scfg, prompts, ref = serve_setup
        plan = FaultPlan([Fault("serve.prefill", "nan", key=0)])
        eng = ServingEngine(cfg, params, scfg, fault_plan=plan)
        h0 = eng.submit(prompts[0], rid=0)
        h1 = eng.submit(prompts[1], rid=1)
        res = eng.drain()
        assert h0.state is RequestState.FAILED
        assert "non-finite" in str(h0.error)
        assert res[1] == ref[1]

    def test_step_level_failure_keeps_engine_usable(self, serve_setup):
        cfg, params, scfg, prompts, ref = serve_setup
        plan = FaultPlan([Fault("serve.step", "error")])
        eng = ServingEngine(cfg, params, scfg, fault_plan=plan)
        ha = eng.submit(prompts[0], rid=0)
        hb = eng.submit(prompts[3], rid=3)
        # queued beyond the 2 slots: decodes after the batch failure
        hc = eng.submit(prompts[1], rid=10)
        res = eng.drain()
        assert ha.state is RequestState.FAILED
        assert hb.state is RequestState.FAILED
        assert hc.state is RequestState.COMPLETED and res[10] == ref[1]

    def test_timeout_while_queued(self, serve_setup):
        cfg, params, scfg, prompts, _ = serve_setup
        eng = ServingEngine(cfg, params, scfg)
        h = eng.submit(prompts[0], timeout_s=-1.0)  # already overdue
        eng.step()
        assert h.state is RequestState.TIMED_OUT
        with pytest.raises(TimeoutError):
            h.result()
        eng.drain()

    def test_timeout_mid_decode_frees_slot(self, serve_setup):
        cfg, params, scfg, prompts, _ = serve_setup
        eng = ServingEngine(cfg, params, scfg)
        h = eng.submit(prompts[0], rid=0, timeout_s=0.05)
        eng.step()  # admitted + first decode dispatched
        time.sleep(0.1)
        eng.drain()
        assert h.state is RequestState.TIMED_OUT
        assert all(s is None for s in eng._slots)

    def test_cancel_queued_and_running(self, serve_setup):
        cfg, params, scfg, prompts, ref = serve_setup
        eng = ServingEngine(cfg, params, scfg)
        hq = eng.submit(prompts[0], rid=0)
        assert hq.cancel() is True
        assert hq.state is RequestState.CANCELLED
        assert hq.cancel() is False  # already terminal
        hr = eng.submit(prompts[1], rid=1)
        eng.step()
        assert hr.state is RequestState.RUNNING
        assert hr.cancel() is True
        res = eng.drain()
        assert hr.state is RequestState.CANCELLED and 1 not in res
        with pytest.raises(CancelledError):
            hr.result()

    def test_duplicate_inflight_rid_rejected(self, serve_setup):
        cfg, params, scfg, prompts, _ = serve_setup
        eng = ServingEngine(cfg, params, scfg)
        eng.submit(prompts[0], rid=5)
        with pytest.raises(ValueError, match="already in flight"):
            eng.submit(prompts[1], rid=5)
        eng.drain()

    def test_submit_after_drain_rejected(self, serve_setup):
        cfg, params, scfg, prompts, _ = serve_setup
        eng = ServingEngine(cfg, params, scfg)
        eng.submit(prompts[0])
        eng.drain()
        with pytest.raises(RuntimeError, match="shut down"):
            eng.submit(prompts[1])

    def test_shutdown_cancels_and_closes(self, serve_setup):
        cfg, params, scfg, prompts, _ = serve_setup
        eng = ServingEngine(cfg, params, scfg)
        h = eng.submit(prompts[0])
        eng.shutdown()
        assert h.state is RequestState.CANCELLED
        with pytest.raises(RuntimeError, match="shut down"):
            eng.submit(prompts[1])

    def test_compile_resilient_records_degradation(self, serve_setup):
        cfg, params, scfg, _, _ = serve_setup
        from repro.tools.tune import build_program

        plan = FaultPlan([Fault("daisy.compile", "error",
                                key="pallas_interpret")])
        eng = ServingEngine(cfg, params, scfg, fault_plan=plan)
        res = eng.compile_resilient(build_program("polybench", "gemm", "mini"),
                                    backends=("pallas_interpret", "xla"))
        assert res.backend == "xla"
        assert eng.degradations and eng.degradations[0][2] == "xla"


# ---------------------------------------------------------------------------
# tune pool supervision (inline path; the spawn-pool path is tested under
# the slow marker below)
# ---------------------------------------------------------------------------
class TestTuneSupervisionInline:
    def _tune(self, tmp_path, **kw):
        from repro.tools.tune import tune

        kw.setdefault("suite", "polybench")
        kw.setdefault("names", ["gemm"])
        kw.setdefault("size", "mini")
        kw.setdefault("jobs", 1)
        kw.setdefault("iterations", 1)
        kw.setdefault("population", 2)
        kw.setdefault("repeats", 1)
        kw.setdefault("verbose", False)
        kw.setdefault("out", tmp_path / "db.json")
        return tune(**kw)

    def _fingerprints(self, tmp_path):
        db, _ = self._tune(tmp_path, out=tmp_path / "ref.json")
        return [e.fingerprint for e in db.entries]

    def test_transient_error_is_retried_to_success(self, tmp_path):
        fps = self._fingerprints(tmp_path)
        plan = FaultPlan([Fault("tune.worker", "error", key=fps[0], times=1)])
        db, _ = self._tune(tmp_path, fault_plan=plan, max_task_retries=1)
        assert db.lookup_exact(fps[0]) is not None
        assert "quarantined" not in db.meta

    def test_persistent_failure_quarantines_and_salvages(self, tmp_path):
        fps = self._fingerprints(tmp_path)
        plan = FaultPlan([Fault("tune.worker", "error", key=fps[0], times=-1)])
        db, out = self._tune(tmp_path, fault_plan=plan, max_task_retries=1)
        assert fps[0] in db.meta["quarantined"]
        for fp in fps[1:]:  # the rest of the run survived the poison nest
            assert db.lookup_exact(fp) is not None
        # checkpointing: the on-disk file already holds the salvaged nests
        on_disk = TuningDatabase.load(out)
        assert all(on_disk.lookup_exact(fp) is not None for fp in fps[1:])

    def test_resume_skips_quarantined(self, tmp_path):
        fps = self._fingerprints(tmp_path)
        plan = FaultPlan([Fault("tune.worker", "error", key=fps[0], times=-1)])
        self._tune(tmp_path, fault_plan=plan, max_task_retries=0)
        # no fault plan now, but the quarantine record keeps it skipped
        db, _ = self._tune(tmp_path)
        assert db.lookup_exact(fps[0]) is None
        assert fps[0] in db.meta["quarantined"]

    def test_retry_quarantined_gives_second_chance(self, tmp_path):
        fps = self._fingerprints(tmp_path)
        plan = FaultPlan([Fault("tune.worker", "error", key=fps[0], times=-1)])
        self._tune(tmp_path, fault_plan=plan, max_task_retries=0)
        db, _ = self._tune(tmp_path, retry_quarantined=True)
        assert db.lookup_exact(fps[0]) is not None
        assert "quarantined" not in db.meta


@pytest.mark.slow
class TestTunePoolCrash:
    def test_worker_crash_quarantines_culprit_and_salvages_rest(self, tmp_path):
        """A nest whose worker hard-crashes (os._exit) twice is quarantined;
        co-scheduled innocents are isolated, re-run solo and survive."""
        from repro.tools.tune import tune

        kw = dict(suite="polybench", names=["gemm", "bicg"], size="mini",
                  iterations=1, population=2, repeats=1, verbose=False)
        ref, _ = tune(jobs=1, out=tmp_path / "ref.json", **kw)
        fps = [e.fingerprint for e in ref.entries]
        bad = fps[0]
        plan = FaultPlan([Fault("tune.worker", "crash", key=bad, times=2)])
        db, out = tune(jobs=2, out=tmp_path / "db.json", fault_plan=plan,
                       max_task_retries=1, **kw)
        assert bad in db.meta["quarantined"]
        for fp in fps:
            if fp != bad:
                assert db.lookup_exact(fp) is not None, \
                    "an innocent nest was lost or quarantined by association"
        # resume against the same out tunes nothing new and keeps the record
        db2, _ = tune(jobs=1, out=out, **kw)
        assert bad in db2.meta["quarantined"]
        assert len(db2.entries) == len(db.entries)
