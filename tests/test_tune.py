"""The offline tuning CLI: database production, pretuned loading, composition."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core import Daisy, TuningDatabase, fingerprint, normalize
from repro.polybench import BENCHMARKS
from repro.tools import tune as T

REPO = Path(__file__).resolve().parents[1]


def test_program_specs_validates_names():
    assert ("polybench", "gemm") in T.program_specs("polybench")
    assert ("cloudsc", "scheme") in T.program_specs("all")
    assert T.program_specs("cloudsc") == [("cloudsc", "erosion"), ("cloudsc", "scheme")]
    with pytest.raises(SystemExit, match="unknown benchmark"):
        T.program_specs("polybench", ["gemm", "nope"])


def test_tune_produces_pretuned_database(tmp_path):
    out = tmp_path / "tuned.json"
    db, path = T.tune(suite="polybench", size="mini", backend="xla", out=out,
                      names=["gemm", "bicg"], jobs=0, search=False,
                      repeats=1, verbose=False)
    assert path == out and out.exists()
    assert db.meta["suite"] == "polybench" and db.meta["backend"] == "xla"
    assert all(e.measured_us is not None for e in db.entries)

    # Daisy.pretuned loads it and the B variant resolves via exact transfer
    d = Daisy.pretuned(backend="xla", path=out)
    fn, plan = d.compile(BENCHMARKS["gemm"].make("b", "mini"))
    assert all(p.source == "exact" for p in plan.nests)
    from repro.core.scheduler import random_inputs

    prog = BENCHMARKS["gemm"].make("b", "mini")
    out_arrays = fn(random_inputs(prog))
    assert out_arrays["C"].shape == (20, 24)


def test_tune_incremental_runs_compose(tmp_path):
    out = tmp_path / "tuned.json"
    db1, _ = T.tune(suite="polybench", size="mini", backend="xla", out=out,
                    names=["gemm"], jobs=0, search=False, repeats=1,
                    verbose=False)
    fps1 = {e.fingerprint for e in db1.entries}
    db2, _ = T.tune(suite="polybench", size="mini", backend="xla", out=out,
                    names=["gemm", "bicg"], jobs=0, search=False, repeats=1,
                    verbose=False)
    fps2 = {e.fingerprint for e in db2.entries}
    assert fps1 < fps2  # first run's entries survive, second adds bicg's
    # already-tuned fingerprints are skipped, not re-measured: the gemm
    # entries are byte-identical across runs
    for e1 in db1.entries:
        e2 = db2.entries[db2._by_fp[e1.fingerprint]]
        assert (e1.recipe, e1.measured_us) == (e2.recipe, e2.measured_us)


def test_tune_main_cli(tmp_path):
    out = tmp_path / "cli.json"
    T.main(["--suite", "polybench", "--names", "gemm", "--size", "mini",
            "--backend", "xla", "--jobs", "0", "--no-search", "--repeats", "1",
            "--out", str(out)])
    db = TuningDatabase.load(out)
    assert db.entries and db.meta["size"] == "mini"


def test_worker_task_matches_parent_enumeration():
    """The pool worker re-normalizes from registry coordinates and must land
    on the same canonical nest the parent enumerated."""
    p = normalize(BENCHMARKS["gemm"].make("a", "mini"))
    task = {"source": "polybench", "name": "gemm", "size": "mini",
            "nest_index": 1, "backend": "xla", "search": False,
            "iterations": 1, "population": 2, "repeats": 1,
            "fingerprint": fingerprint(p.body[1])}
    r = T._tune_nest(task)
    assert r["fingerprint"] == fingerprint(p.body[1])
    assert r["measured_us"] is not None and r["recipe"]["kind"]


def test_default_pretuned_path_env_override(tmp_path, monkeypatch):
    from repro.core.database import default_pretuned_path

    monkeypatch.setenv("REPRO_PRETUNED_DIR", str(tmp_path))
    with pytest.raises(FileNotFoundError, match="repro.tools.tune"):
        default_pretuned_path("xla")
    (tmp_path / "pretuned_xla.json").write_text("{}")
    assert default_pretuned_path("xla") == tmp_path / "pretuned_xla.json"


def test_shipped_pretuned_database_covers_polybench():
    """The repo ships data/pretuned_xla.json (bench-size A variants +
    CLOUDSC); every canonical nest of a strided B variant must hit it."""
    from repro.core.database import try_load_pretuned

    db = try_load_pretuned("xla")
    assert db is not None, "shipped data/pretuned_xla.json missing"
    assert len(db.entries) >= 40
    assert all(e.measured_us is not None for e in db.entries)
    p = Daisy(backend="xla")._normalized(BENCHMARKS["syrk"].make("b", "bench"))
    assert all(db.lookup_exact(fingerprint(n)) is not None for n in p.body)


@pytest.mark.slow
def test_tune_process_pool_matches_inline(tmp_path):
    """jobs>1 (spawn pool) lands the same fingerprints as the inline path."""
    inline, _ = T.tune(suite="polybench", size="mini", backend="xla",
                       out=tmp_path / "inline.json", names=["gemm"], jobs=0,
                       search=False, repeats=1, verbose=False)
    pooled, _ = T.tune(suite="polybench", size="mini", backend="xla",
                       out=tmp_path / "pooled.json", names=["gemm"], jobs=2,
                       search=False, repeats=1, verbose=False)
    assert ({e.fingerprint for e in inline.entries}
            == {e.fingerprint for e in pooled.entries})


@pytest.mark.slow
def test_bench_run_rejects_unknown_only():
    """benchmarks/run.py must list valid suites instead of a bare KeyError."""
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "nope"],
        capture_output=True, text=True, cwd=REPO,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    assert r.returncode == 2
    assert "unknown suite(s): nope" in r.stderr
    assert "transfer" in r.stderr and "fig1" in r.stderr
