"""Grid-tiled Pallas lowering + scan recurrence lowering (PR-3 tentpole).

Covers: the tiling planner (tile clamping, VMEM budget, rejection of
recurrences), oracle equivalence of the interpret-mode ``pallas_nest`` /
``pallas_reduce`` paths and the ``lax.scan`` recurrence path across every
PolyBench A+B variant and both CLOUDSC programs, guard/halo edge cases, and
the search/probe memoization satellites.
"""
import numpy as np
import pytest

from repro.core import (
    Schedule,
    TilingError,
    compile_jax,
    execute_numpy,
    normalize,
    optimization_pipeline,
    plan_nest_tiling,
)
from repro.core import codegen
from repro.core.ir import (
    Array,
    Computation,
    Loop,
    Program,
    acc,
    aff,
    nest_computations,
)
from repro.core.recipes import Recipe
from repro.core.scheduler import random_inputs
from repro.core.search import schedule_from_recipe
from repro.cloudsc import erosion_program, mini_cloudsc_program
from repro.cloudsc.erosion import physical_inputs
from repro.cloudsc.scheme import scheme_inputs
from repro.kernels import nest_kernel
from repro.polybench import BENCHMARKS, NAMES

# Small tiles at mini sizes force multi-tile grids, partial tiles, and
# mask/halo handling — the interesting paths.
PALLAS = Schedule(mode="canonical", use_idioms=False, pallas_nest=True,
                  pallas_reduce=True, nest_tile=(4, 8), scan=True)
PIPE = optimization_pipeline(fuse=True)


def run_f32(program, sched, inputs):
    fn = compile_jax(program, sched)
    return fn({k: np.asarray(v, np.float32) for k, v in inputs.items()})


def max_rel(out, ref):
    denom = max(1e-9, float(np.abs(ref).max()))
    return float(np.abs(np.asarray(out, np.float64) - ref).max()) / denom


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------
class TestPlanner:
    def test_gemm_mac_is_reduce_with_clamped_tiles(self):
        p = normalize(BENCHMARKS["gemm"].make("a", "mini"))
        mac = p.body[1]
        plan = plan_nest_tiling(p, mac, tile=(8, 16, 4))
        assert plan.kind == "reduce"
        assert plan.reduce_grid is not None and plan.reduce_grid.tile == 4
        # tiles are clamped to the (mini) extents
        assert all(a.tile <= a.trip for a in plan.axes)
        assert plan.grid == tuple(a.n_tiles for a in plan.parallel) + (
            plan.reduce_grid.n_tiles,)

    def test_recurrence_rejected(self):
        p = normalize(BENCHMARKS["jacobi-2d"].make("a", "mini"))
        nest = p.body[0]  # the time-carried SCC
        with pytest.raises(TilingError):
            plan_nest_tiling(p, nest)

    def test_vmem_budget_shrinks_tiles(self):
        n = 4096
        comp = Computation("cp", acc("B", "i", "j"), (acc("A", "i", "j"),),
                           lambda v: v * 2.0)
        prog = Program("big", (Array("A", (n, n)), Array("B", (n, n))),
                       (Loop("i", n, body=(Loop("j", n, body=(comp,)),)),))
        plan = plan_nest_tiling(prog, prog.body[0], vmem_budget=1 << 20)
        assert plan.vmem_bytes <= 1 << 20
        tiles = [a.tile for a in plan.parallel]
        assert any(t < n for t in tiles)
        # auto-chosen tiles stay VPU-aligned (sublane 8 / lane 128 multiples)
        assert tiles[-1] % 128 == 0 and tiles[-2] % 8 == 0

    def test_halo_covers_stencil_offsets(self):
        n = 10
        st = Computation(
            "st", acc("B", "i", "j"),
            (acc("A", aff("i", const=-1), "j"), acc("A", aff("i", const=1), "j"),
             acc("A", "i", aff("j", const=-1)), acc("A", "i", aff("j", const=1))),
            lambda a, b, c, d: 0.25 * (a + b + c + d))
        prog = Program("st", (Array("A", (n, n)), Array("B", (n, n))),
                       (Loop("i", n - 1, start=1,
                             body=(Loop("j", n - 1, start=1, body=(st,)),)),))
        plan = plan_nest_tiling(prog, prog.body[0], tile=(3, 3))
        (alo, ahi), (blo, bhi) = plan.halo["A"]
        assert alo == 0 and blo == 0  # start=1 absorbs the -1 offset
        # +1 offset plus 3x3 tile rounding (span 9 from origin 2) overhangs
        # the extent-10 dims by 1
        assert ahi == 1 and bhi == 1


# ---------------------------------------------------------------------------
# oracle equivalence: polybench A+B and CLOUDSC through pallas + scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", NAMES)
@pytest.mark.parametrize("variant", ["a", "b"])
def test_polybench_pallas_matches_oracle(name, variant):
    b = BENCHMARKS[name]
    prog = b.make(variant, "mini")
    inp = random_inputs(prog, seed=3, dtype=np.float64)
    ref = execute_numpy(prog, inp)[b.output]
    norm = PIPE.run(prog)
    before = dict(nest_kernel.EMITTED)
    out = run_f32(norm, PALLAS, inp)[b.output]
    assert max_rel(out, ref) < 2e-4
    # parallel/reduction nests must actually lower through Pallas; only
    # time-carried stencils (jacobi/heat/fdtd) are all-recurrence programs
    emitted = sum(nest_kernel.EMITTED.values()) - sum(before.values())
    if name not in ("jacobi-2d", "heat-3d", "fdtd-2d"):
        assert emitted > 0, "no nest took the Pallas path"


@pytest.mark.parametrize("maker,inputs_fn,checks", [
    (erosion_program, physical_inputs, ("ZTP1", "ZQSMIX")),
    (mini_cloudsc_program, scheme_inputs,
     ("ZTP1", "ZQSMIX", "ZQL", "ZQI", "PFPLSL", "TENDQ")),
])
def test_cloudsc_pallas_scan_matches_oracle(maker, inputs_fn, checks):
    p = maker(8, 6)
    inp = inputs_fn(8, 6)
    ref = execute_numpy(p, inp)
    norm = PIPE.run(p)
    scans0 = codegen.LOWERING_STATS["scan"]
    out = run_f32(norm, PALLAS, inp)
    for k in checks:
        assert max_rel(out[k], ref[k]) < 1e-4, k
    # the vertical (JK-carried) chains stream through lax.scan
    assert codegen.LOWERING_STATS["scan"] > scans0


def test_mini_cloudsc_parallel_stages_take_pallas():
    p = mini_cloudsc_program(8, 6)
    norm = PIPE.run(p)
    before = dict(nest_kernel.EMITTED)
    run_f32(norm, PALLAS, scheme_inputs(8, 6))
    assert nest_kernel.EMITTED["pallas_nest"] > before["pallas_nest"]


# ---------------------------------------------------------------------------
# guard / halo edge cases
# ---------------------------------------------------------------------------
def _stencil_program(n):
    st = Computation(
        "st", acc("B", "i", "j"),
        (acc("A", "i", "j"),
         acc("A", aff("i", const=-1), "j"), acc("A", aff("i", const=1), "j"),
         acc("A", "i", aff("j", const=-1)), acc("A", "i", aff("j", const=1))),
        lambda c, nn, ss, ww, ee: c + 0.2 * (nn + ss + ww + ee))
    return Program("stencil", (Array("A", (n, n)), Array("B", (n, n))),
                   (Loop("i", n - 1, start=1,
                         body=(Loop("j", n - 1, start=1, body=(st,)),)),))


@pytest.mark.parametrize("tile", [(3, 3), (4, 8), (16, 16)])
def test_stencil_halo_partial_tiles(tile):
    prog = _stencil_program(10)
    inp = random_inputs(prog, seed=1, dtype=np.float64)
    ref = execute_numpy(prog, inp)
    before = nest_kernel.EMITTED["pallas_nest"]
    sched = Schedule(mode="canonical", use_idioms=False, pallas_nest=True,
                     nest_tile=tile)
    out = run_f32(prog, sched, inp)
    assert nest_kernel.EMITTED["pallas_nest"] == before + 1
    assert max_rel(out["B"], ref["B"]) < 1e-6
    # untouched boundary rows keep their original content (bit-exact in f32)
    np.testing.assert_array_equal(
        np.asarray(out["B"])[0], inp["B"][0].astype(np.float32))


def test_triangular_guarded_write_partial_tiles():
    n = 11
    tri = aff("i", ("j", -1))  # j <= i
    sc = Computation("sc", acc("C", "i", "j"), (acc("C", "i", "j"),),
                     lambda c: c * 3.0, guards=(tri,))
    prog = Program("tri", (Array("C", (n, n)),),
                   (Loop("i", n, body=(Loop("j", n, body=(sc,)),)),))
    inp = random_inputs(prog, seed=2, dtype=np.float64)
    ref = execute_numpy(prog, inp)
    sched = Schedule(mode="canonical", use_idioms=False, pallas_nest=True,
                     nest_tile=(4, 4))
    out = run_f32(prog, sched, inp)
    assert max_rel(out["C"], ref["C"]) < 1e-6  # upper triangle untouched


@pytest.mark.parametrize("unroll", [1, 2, 4])
def test_guarded_reduction_with_unroll(unroll):
    """Triangular MAC through pallas_reduce; the recipe's unroll knob splits
    the in-tile reduction into sequentially accumulated chunks."""
    n, m = 9, 16
    tri = aff("i", ("j", -1))
    mac = Computation("mac", acc("C", "i", "j"),
                      (acc("A", "i", "k"), acc("A", "j", "k")),
                      lambda a, b: a * b, accumulate="+", guards=(tri,))
    prog = Program("syrk1", (Array("A", (n, m)), Array("C", (n, n))),
                   (Loop("i", n, body=(Loop("j", n, body=(
                       Loop("k", m, body=(mac,)),)),)),))
    inp = random_inputs(prog, seed=4, dtype=np.float64)
    ref = execute_numpy(prog, inp)
    before = nest_kernel.EMITTED["pallas_reduce"]
    sched = Schedule(mode="canonical", use_idioms=False, pallas_reduce=True,
                     nest_tile=(4, 4, 8), unroll=unroll)
    out = run_f32(prog, sched, inp)
    assert nest_kernel.EMITTED["pallas_reduce"] == before + 1
    assert max_rel(out["C"], ref["C"]) < 1e-5


def test_unroll_flows_from_recipe_to_schedule():
    sched = schedule_from_recipe(Recipe(kind="pallas_reduce", tile=(8, 128, 128),
                                        unroll=4))
    assert sched.pallas_reduce and sched.unroll == 4 and sched.nest_tile == (8, 128, 128)
    sched = schedule_from_recipe(Recipe(kind="pallas_nest", tile=(8, 128)))
    assert sched.pallas_nest and sched.nest_tile == (8, 128)


# ---------------------------------------------------------------------------
# scan recurrence lowering
# ---------------------------------------------------------------------------
def _recurrence_program(n, rows, lookback=1):
    reads = [acc("X", "t", "j")] + [
        acc("F", aff("t", const=-d), "j") for d in range(1, lookback + 1)]
    weights = [0.5 / d for d in range(1, lookback + 1)]
    comp = Computation(
        "rec", acc("F", "t", "j"), tuple(reads),
        lambda x, *fs: x + sum(w * f for w, f in zip(weights, fs)))
    return Program("rec", (Array("X", (n, rows)), Array("F", (n, rows))),
                   (Loop("t", n, body=(Loop("j", rows, body=(comp,)),)),),
                   temps=("F",))


@pytest.mark.parametrize("lookback", [1, 2])
def test_scan_recurrence_matches_oracle(lookback):
    prog = _recurrence_program(7, 5, lookback)
    inp = random_inputs(prog, seed=5, dtype=np.float64)
    ref = execute_numpy(prog, inp)
    scans0 = codegen.LOWERING_STATS["scan"]
    out = run_f32(prog, Schedule(mode="canonical", use_idioms=False), inp)
    assert codegen.LOWERING_STATS["scan"] == scans0 + 1
    assert max_rel(out["F"], ref["F"]) < 1e-6


def test_scan_disabled_falls_back_to_fori():
    prog = _recurrence_program(7, 5)
    inp = random_inputs(prog, seed=5, dtype=np.float64)
    ref = execute_numpy(prog, inp)
    fori0 = codegen.LOWERING_STATS["fori"]
    out = run_f32(prog, Schedule(mode="canonical", use_idioms=False,
                                 scan=False), inp)
    assert codegen.LOWERING_STATS["fori"] > fori0
    assert max_rel(out["F"], ref["F"]) < 1e-6


def test_scan_guarded_first_row():
    """CLOUDSC-flux shape: guarded init at t==0, lookback elsewhere."""
    n, rows = 6, 4
    pfl = Computation("pfl", acc("F", "t", "j"),
                      (acc("F", aff("t", const=-1), "j"), acc("X", "t", "j")),
                      lambda f, x: 0.8 * f + x,
                      guards=(aff("t", const=-1),))           # t >= 1
    pfl0 = Computation("pfl0", acc("F", "t", "j"), (acc("X", "t", "j"),),
                       lambda x: x, guards=(aff(("t", -1)),))  # t == 0
    prog = Program("flux", (Array("X", (n, rows)), Array("F", (n, rows))),
                   (Loop("t", n, body=(Loop("j", rows, body=(pfl, pfl0)),)),),
                   temps=("F",))
    inp = random_inputs(prog, seed=6, dtype=np.float64)
    ref = execute_numpy(prog, inp)
    scans0 = codegen.LOWERING_STATS["scan"]
    out = run_f32(prog, Schedule(mode="canonical", use_idioms=False), inp)
    assert codegen.LOWERING_STATS["scan"] == scans0 + 1
    assert max_rel(out["F"], ref["F"]) < 1e-6


# ---------------------------------------------------------------------------
# scheduler plumbing: pallas recipes through Daisy + backend selection
# ---------------------------------------------------------------------------
def test_daisy_compiles_pallas_recipes_from_db():
    from repro.core import Daisy, TuningDatabase, fingerprint
    from repro.core.embedding import embed_nest

    b = BENCHMARKS["gemm"]
    prog = b.make("a", "mini")
    db = TuningDatabase()
    d = Daisy(db=db, backend="pallas_interpret")
    norm = d.plan(prog).program
    for nest in norm.body:
        kind = ("pallas_reduce"
                if any(c.accumulate for c in nest_computations(nest))
                else "pallas_nest")
        db.add(fingerprint(nest), embed_nest(norm, nest),
               Recipe(kind=kind, tile=(4, 8, 8)), provenance="test")
    before = dict(nest_kernel.EMITTED)
    fn, plan = d.compile(prog, jit=False)
    assert all(p.recipe.kind.startswith("pallas") for p in plan.nests)
    inp = random_inputs(prog, seed=8, dtype=np.float64)
    ref = execute_numpy(prog, inp)[b.output]
    out = fn({k: np.asarray(v, np.float32) for k, v in inp.items()})[b.output]
    assert max_rel(out, ref) < 2e-4
    assert sum(nest_kernel.EMITTED.values()) > sum(before.values())


def test_daisy_backend_xla_degrades_pallas_kinds():
    from repro.core import Daisy

    d = Daisy(backend="xla")
    assert d._backend_recipe(Recipe(kind="pallas_nest", tile=(8, 128))).kind == "vectorize"
    assert d._backend_recipe(Recipe(kind="pallas_reduce")).kind == "vectorize"
    assert d._backend_recipe(Recipe(kind="pallas_gemm")).kind == "einsum"
    assert d._backend_recipe(Recipe(kind="einsum")).kind == "einsum"
    assert Daisy(backend="pallas").interpret is False
    with pytest.raises(ValueError):
        Daisy(backend="tpu")


# ---------------------------------------------------------------------------
# satellites: memoization
# ---------------------------------------------------------------------------
def test_evolve_recipe_measures_each_candidate_once(monkeypatch):
    from repro.core import search

    calls = []
    monkeypatch.setattr(
        search, "measure_recipe",
        lambda prog, inputs, r, repeats=3, interpret=True: calls.append(r) or 1.0)
    prog = normalize(BENCHMARKS["gemm"].make("a", "mini"))
    from repro.core.scheduler import nest_program

    nprog = nest_program(prog, prog.body[0])
    inp = random_inputs(nprog)
    search.evolve_recipe(nprog, inp, Recipe(kind="vectorize"),
                         iterations=3, population=4)
    assert len(calls) == len(set(calls)), "a recipe was re-measured"


def test_is_multiplicative_probe_memoized(monkeypatch):
    probes = [0]
    real = codegen._is_multiplicative_probe

    def counting(expr, n_reads):
        probes[0] += 1
        return real(expr, n_reads)

    monkeypatch.setattr(codegen, "_is_multiplicative_probe", counting)
    f = lambda a, b: a * b  # noqa: E731
    assert codegen._is_multiplicative(f, 2) == 1.0
    assert codegen._is_multiplicative(f, 2) == 1.0
    assert probes[0] == 1
