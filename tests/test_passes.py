"""The compiler pass pipeline: protocol, context/report, caching, consumers."""
import numpy as np

from repro.core import (
    CompilationCache,
    Daisy,
    FunctionPass,
    PassContext,
    Program,
    fingerprint,
    normalization_pipeline,
    normalize,
    optimization_pipeline,
)
from repro.core.scheduler import random_inputs
from repro.polybench import BENCHMARKS


def _gemm():
    return BENCHMARKS["gemm"].make("a", "mini")


class TestPipelineStructure:
    def test_normalize_equals_pipeline_run(self):
        p = _gemm()
        a = normalize(p)
        b = normalization_pipeline().run(p)
        assert [fingerprint(n) for n in a.body] == [fingerprint(n) for n in b.body]

    def test_pass_names_in_order(self):
        assert normalization_pipeline().names == (
            "scalar_expansion", "maximal_fission",
            "stride_minimization", "canonical_rename",
        )
        assert optimization_pipeline(fuse=True).names == (
            "scalar_expansion", "maximal_fission", "stride_minimization",
            "licm", "expand_factor", "fusion", "cse", "canonical_rename",
        )
        assert optimization_pipeline(fuse=True, rewrite=False).names == (
            "scalar_expansion", "maximal_fission",
            "stride_minimization", "fusion", "canonical_rename",
        )

    def test_with_pass_insertion_and_removal(self):
        pipe = normalization_pipeline()
        marker = FunctionPass("marker", lambda p: p)
        assert pipe.with_pass(marker, after="maximal_fission").names[2] == "marker"
        assert pipe.with_pass(marker, before="maximal_fission").names[1] == "marker"
        assert pipe.with_pass(marker).names[-1] == "marker"
        assert "fusion" not in optimization_pipeline().without_pass("fusion").names

    def test_duplicate_pass_name_rejected(self):
        import pytest

        pipe = normalization_pipeline()
        with pytest.raises(ValueError):
            pipe.with_pass(FunctionPass("fusion", lambda p: p)).with_pass(
                FunctionPass("fusion", lambda p: p)
            )


class TestPassContext:
    def test_records_timing_and_counts(self):
        ctx = PassContext()
        out = normalization_pipeline().run(_gemm(), ctx=ctx)
        assert [r.name for r in ctx.records] == list(normalization_pipeline().names)
        assert all(r.seconds >= 0 for r in ctx.records)
        # gemm_a fissions into scale + MAC nests
        assert ctx["maximal_fission"].nests_after == len(out.body) == 2
        assert ctx.stat("maximal_fission", "iterations") >= 1
        assert ctx.total_seconds == sum(r.seconds for r in ctx.records)

    def test_report_renders_every_pass(self):
        ctx = PassContext()
        optimization_pipeline().run(_gemm(), ctx=ctx)
        report = ctx.report()
        for name in optimization_pipeline().names:
            assert name in report
        assert "fused=" in report

    def test_snapshots_keep_ir(self):
        ctx = PassContext(snapshots=True)
        normalization_pipeline().run(_gemm(), ctx=ctx)
        rec = ctx["stride_minimization"]
        assert isinstance(rec.before, Program) and isinstance(rec.after, Program)
        # default context drops the IR
        ctx2 = PassContext()
        normalization_pipeline().run(_gemm(), ctx=ctx2)
        assert ctx2["stride_minimization"].before is None


class TestStageCaching:
    def test_second_run_hits_every_stage(self):
        cache = CompilationCache()
        pipe = normalization_pipeline()
        out1 = pipe.run(_gemm(), cache=cache)
        ctx = PassContext()
        out2 = pipe.run(_gemm(), ctx=ctx, cache=cache)
        assert all(r.cached for r in ctx.records)
        assert [fingerprint(n) for n in out1.body] == [fingerprint(n) for n in out2.body]

    def test_convergent_programs_share_stage_work(self):
        """A and B variants converge after fission; downstream stages of B
        must be served from A's cached stage outputs."""
        cache = CompilationCache()
        pipe = normalization_pipeline()
        pipe.run(BENCHMARKS["gemm"].make("a", "mini"), cache=cache)
        ctx = PassContext()
        pipe.run(BENCHMARKS["gemm"].make("b", "mini"), ctx=ctx, cache=cache)
        assert any(r.cached for r in ctx.records)


class TestDaisyIntegration:
    def test_explain_reports_pipeline(self):
        d = Daisy()
        ctx = d.explain(_gemm())
        assert [r.name for r in ctx.records] == list(d.pipeline.names)
        assert "fusion" in d.pipeline.names
        assert "fusion" not in Daisy(fuse=False).pipeline.names

    def test_fuse_flag_scopes_cached_plans(self):
        cache = CompilationCache()
        d1 = Daisy(cache=cache, fuse=True)
        d2 = Daisy(db=d1.db, cache=cache, fuse=False)
        _, plan1 = d1.compile(_gemm())
        _, plan2 = d2.compile(_gemm())
        assert plan1 is not plan2  # fuse flag is part of the plan key

    def test_compile_matches_oracle_with_fusion_on_and_off(self):
        from repro.core import execute_numpy

        prog = _gemm()
        inp = random_inputs(prog, seed=11)
        ref = execute_numpy(prog, {k: v.astype(np.float64) for k, v in inp.items()})
        for fuse in (True, False):
            fn, _ = Daisy(fuse=fuse).compile(prog)
            out = fn(inp)
            np.testing.assert_allclose(
                np.asarray(out["C"], np.float64), ref["C"], rtol=1e-3, atol=1e-3
            )


class TestCompileJaxSignature:
    def test_single_schedule_broadcasts(self):
        from repro.core import Schedule, compile_jax

        prog = normalize(_gemm())
        fn = compile_jax(prog, Schedule(mode="canonical", use_idioms=False))
        inp = random_inputs(prog)
        out = fn(inp)
        assert out["C"].shape == prog.array("C").shape

    def test_per_nest_length_mismatch_raises(self):
        import pytest

        from repro.core import Schedule, compile_jax

        prog = normalize(_gemm())
        assert len(prog.body) == 2
        with pytest.raises(ValueError):
            compile_jax(prog, [Schedule()])


class TestModelConsumers:
    def test_kernel_report_renders(self):
        from repro.configs import get_config
        from repro.models.lowering import kernel_report

        rep = kernel_report(get_config("minicpm-2b").reduced(), seq=64, batch=2)
        assert "pass pipeline" in rep
        assert "q_proj" in rep and "lm_head" in rep
        assert "canonical_rename" in rep

    def test_serving_engine_explain_kernels(self):
        import jax

        from repro.configs import get_config
        from repro.models import model as M
        from repro.serve import ServeConfig, ServingEngine

        cfg = get_config("minicpm-2b").reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, params, ServeConfig(max_len=32))
        rep = eng.explain_kernels()
        assert "contraction plans:" in rep
        # content-cached: a re-created engine shares the identical report
        eng2 = ServingEngine(cfg, params, ServeConfig(max_len=32))
        assert eng2.explain_kernels() is rep
