"""Canonical-form re-fusion: legality, idiom guards, oracle equivalence."""
import numpy as np
import pytest

from repro.core import (
    Array,
    Computation,
    Loop,
    PassContext,
    Program,
    Schedule,
    acc,
    aff,
    execute_numpy,
    fuse_program,
    normalize,
    optimization_pipeline,
    run_jax,
)
from repro.core.fusion import domains_match, fusion_legal
from repro.core.scheduler import random_inputs
from repro.polybench import BENCHMARKS, NAMES


def elementwise_chain(n=12, stages=5):
    """stages dependent elementwise nests T_s = f_s(T_{s-1})."""
    arrays = [Array("X", (n,))]
    body = []
    prev = "X"
    for s in range(stages):
        nm = f"T{s}"
        arrays.append(Array(nm, (n,)))
        it = f"i{s}"
        body.append(Loop(it, n, body=(
            Computation(f"c{s}", acc(nm, it), (acc(prev, it),),
                        lambda v, s=s: v * 0.5 + s),
        )))
        prev = nm
    return Program("chain", tuple(arrays), tuple(body))


def two_nests(read_offset=0, n2=8):
    """producer A[i] = X[i]; consumer B[j] = A[j + read_offset]."""
    p = Loop("i", 8, body=(
        Computation("prod", acc("A", "i"), (acc("X", "i"),), lambda x: x + 1.0),
    ))
    c = Loop("j", n2, body=(
        Computation("cons", acc("B", "j"),
                    (acc("A", aff("j", const=read_offset)),), lambda a: a * 2.0,
                    guards=(aff("j", const=-max(0, -read_offset)),) if read_offset < 0 else
                           ((aff(("j", -1), const=7 - read_offset),) if read_offset > 0 else ())),
    ))
    return Program(
        "pc", (Array("X", (8,)), Array("A", (8,)), Array("B", (max(8, n2),))), (p, c)
    )


class TestLegality:
    def test_same_iteration_dependence_fuses(self):
        prog = two_nests(read_offset=0)
        assert fusion_legal(prog.body[0], prog.body[1])
        fused = fuse_program(prog)
        assert len(fused.body) == 1

    def test_forward_carried_dependence_fuses(self):
        # consumer reads A[j-1]: producer instance runs strictly earlier
        prog = two_nests(read_offset=-1)
        assert fusion_legal(prog.body[0], prog.body[1])

    def test_backward_dependence_rejected(self):
        # consumer reads A[j+1]: would need a producer instance that has not
        # run yet at fused iteration j -> fusion-preventing dependence
        prog = two_nests(read_offset=1)
        assert not fusion_legal(prog.body[0], prog.body[1])
        ctx = PassContext()
        optimization_pipeline(fuse=True).run(prog, ctx=ctx)
        assert ctx.stat("fusion", "dependence_blocked", 0) >= 1

    def test_domain_mismatch_rejected(self):
        prog = two_nests(read_offset=0, n2=6)  # consumer trips 6 != 8
        assert not domains_match(prog.body[0], prog.body[1])
        assert not fusion_legal(prog.body[0], prog.body[1])
        fused = fuse_program(prog)
        assert len(fused.body) == 2

    def test_oracle_equivalence_of_legal_fusions(self):
        for off in (0, -1):
            prog = two_nests(read_offset=off)
            fused = fuse_program(prog)
            assert len(fused.body) == 1
            inp = random_inputs(prog, dtype=np.float64)
            ref = execute_numpy(prog, inp)
            got = execute_numpy(fused, inp)
            for k in prog.array_names:
                assert np.array_equal(ref[k], got[k]), (off, k)


class TestIdiomGuards:
    def test_blas3_nest_stays_standalone(self):
        prog = BENCHMARKS["gemm"].make("a", "mini")
        norm = normalize(prog)
        ctx = PassContext()
        fused = optimization_pipeline(fuse=True).run(prog, ctx=ctx)
        # scale + MAC survive as separate kernels (MAC is the library call)
        assert len(fused.body) == len(norm.body) == 2
        from repro.core.idioms import classify_nest

        assert {classify_nest(n).kind for n in fused.body} == {"elementwise", "blas3"}


class TestKernelCountReduction:
    def test_chain_collapses_to_one_kernel(self):
        """Acceptance: a >=4-stage elementwise chain emits fewer kernels."""
        prog = elementwise_chain(stages=5)
        norm = normalize(prog)
        assert len(norm.body) == 5
        ctx = PassContext()
        fused = optimization_pipeline(fuse=True).run(prog, ctx=ctx)
        assert len(fused.body) == 1
        assert ctx.stat("fusion", "fused") == 4

    def test_fused_chain_matches_oracle_bit_identical(self):
        prog = elementwise_chain(stages=5)
        fused = optimization_pipeline(fuse=True).run(prog)
        inp = random_inputs(prog, dtype=np.float64)
        ref = execute_numpy(prog, inp)
        got = execute_numpy(fused, inp)
        for k in prog.array_names:
            assert np.array_equal(ref[k], got[k]), k

    def test_fused_chain_jax_matches_oracle(self):
        prog = elementwise_chain(stages=5)
        fused = optimization_pipeline(fuse=True).run(prog)
        inp = random_inputs(prog, dtype=np.float64)
        ref = execute_numpy(prog, inp)
        out = run_jax(fused, inp, Schedule(mode="canonical", use_idioms=False))
        np.testing.assert_allclose(
            np.asarray(out["T4"], np.float64), ref["T4"], rtol=1e-5, atol=1e-6
        )


class TestPropertyOracleEquivalence:
    """Property-style acceptance sweep: FusionPass on vs off must be
    oracle-equivalent (bit-identical in float64) across the polybench suite
    and the CLOUDSC erosion scheme."""

    @pytest.mark.parametrize("name", NAMES)
    @pytest.mark.parametrize("variant", ["a", "b"])
    def test_polybench_fusion_on_off_equivalent(self, name, variant):
        b = BENCHMARKS[name]
        prog = b.make(variant, "mini")
        inp = random_inputs(prog, seed=7, dtype=np.float64)
        unfused = optimization_pipeline(fuse=False).run(prog)
        fused = optimization_pipeline(fuse=True).run(prog)
        ref = execute_numpy(unfused, inp)
        got = execute_numpy(fused, inp)
        assert np.array_equal(ref[b.output], got[b.output], equal_nan=True)

    def test_cloudsc_erosion_fusion_on_off_equivalent(self):
        from repro.cloudsc import erosion_program
        from repro.cloudsc.erosion import physical_inputs

        prog = erosion_program(nproma=8, klev=4)
        inp = physical_inputs(8, 4)
        ctx = PassContext()
        fused = optimization_pipeline(fuse=True).run(prog, ctx=ctx)
        assert ctx.stat("fusion", "fused") > 0  # the scalar chain re-fuses
        ref = execute_numpy(optimization_pipeline(fuse=False).run(prog), inp)
        got = execute_numpy(fused, inp)
        for k in ("ZTP1", "ZQSMIX"):
            assert np.array_equal(ref[k], got[k]), k

    def test_cloudsc_scheme_fusion_on_off_equivalent(self):
        from repro.cloudsc import mini_cloudsc_program
        from repro.cloudsc.scheme import scheme_inputs

        prog = mini_cloudsc_program(nproma=8, klev=5)
        inp = scheme_inputs(8, 5)
        ref = execute_numpy(optimization_pipeline(fuse=False).run(prog), inp)
        got = execute_numpy(optimization_pipeline(fuse=True).run(prog), inp)
        for k in ("ZTP1", "ZQSMIX", "ZQL", "ZQI", "PFPLSL", "TENDQ"):
            assert np.array_equal(ref[k], got[k]), k
