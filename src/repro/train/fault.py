"""Compatibility shim: the fault-tolerance scaffolding grew beyond the
trainer (serving error isolation, tune-pool supervision, fault injection)
and now lives in :mod:`repro.fault`.  Import from there; these re-exports
keep the PR-6 import paths working for one more release."""
import warnings

from ..fault import (  # noqa: F401
    Fault,
    FaultInjected,
    FaultPlan,
    Heartbeat,
    RestartPolicy,
    StragglerMonitor,
)

warnings.warn(
    "repro.train.fault is a compatibility shim; import from repro.fault "
    "instead", DeprecationWarning, stacklevel=2)
