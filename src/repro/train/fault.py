"""Fault-tolerance scaffolding: heartbeats, straggler detection, restarts.

On a 1000+ node cluster the failure model is: (a) hard node loss — detected
by missed heartbeats, handled by restart-from-checkpoint on a re-formed mesh
(elastic: the checkpoint is device-count agnostic); (b) stragglers — detected
by per-step wall time exceeding a multiple of the EMA, handled by flagging
the host for the scheduler (synchronous SPMD cannot proceed without it, so
the mitigation is replacement, not work stealing); (c) numeric poison —
NaN/inf gradients, handled *inside* the jitted step (see adamw_update: the
step is skipped, not crashed).
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path


class Heartbeat:
    """Background thread stamping a file; a supervisor (or test) detects a
    dead/stuck process by file age."""

    def __init__(self, path: str | Path, interval: float = 1.0):
        self.path = Path(path)
        self.interval = interval
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self) -> None:
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while not self._stop.is_set():
            self.path.write_text(json.dumps({"t": time.time(), "pid": os.getpid()}))
            self._stop.wait(self.interval)

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)

    @staticmethod
    def age(path: str | Path) -> float | None:
        p = Path(path)
        if not p.exists():
            return None
        try:
            return time.time() - json.loads(p.read_text())["t"]
        except Exception:
            return None


@dataclass
class StragglerMonitor:
    """EMA step-time tracker; flags steps slower than ``threshold`` x EMA."""

    threshold: float = 3.0
    alpha: float = 0.1
    ema: float | None = None
    flagged: list[tuple[int, float]] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = self.ema is not None and dt > self.threshold * self.ema
        if is_straggler:
            self.flagged.append((step, dt))
        # don't fold outliers into the EMA
        if not is_straggler:
            self.ema = dt if self.ema is None else (1 - self.alpha) * self.ema + self.alpha * dt
        return is_straggler


@dataclass
class RestartPolicy:
    """Bounded retry-from-checkpoint loop (used by Trainer.run_resilient)."""

    max_restarts: int = 3
    backoff_s: float = 0.0
    restarts: int = 0

    def should_restart(self, exc: Exception) -> bool:
        self.restarts += 1
        if self.restarts > self.max_restarts:
            return False
        if self.backoff_s:
            time.sleep(self.backoff_s * self.restarts)
        return True
