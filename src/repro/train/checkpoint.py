"""Sharded, atomic checkpointing with deterministic resume.

Layout: ``<dir>/step_<k>/proc_<i>.npz`` + ``meta.json``; a checkpoint only
counts once ``meta.json`` exists (written last, atomically via rename), so a
node failure mid-save can never leave a half checkpoint that restore would
pick up.  Arrays are saved as host numpy keyed by pytree path — restore is
device-count agnostic, which is what makes **elastic re-meshing** work: save
on a 256-chip mesh, restore onto 512 (tested across device counts in
tests/test_train.py via subprocess meshes).

On a real multi-host pod each process saves its addressable shards
(``process_index`` keys the filename); this container is single-process so
proc_0 holds everything.  Keep-last-k garbage collection included.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np

Pytree = Any


def _flatten(tree: Pytree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub":  # ml_dtypes (bf16) -> raw view + tag
            key = key + f"::{arr.dtype.name}"
            arr = arr.view(np.uint16) if arr.dtype.itemsize == 2 else arr.view(np.uint8)
        flat[key] = arr
    return flat


def _unflatten(template: Pytree, flat: dict[str, np.ndarray]) -> Pytree:
    import ml_dtypes

    decoded: dict[str, np.ndarray] = {}
    for key, val in flat.items():
        if "::" in key:
            key, dt = key.rsplit("::", 1)
            val = val.view(np.dtype(getattr(ml_dtypes, dt)))
        decoded[key] = val
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves[0]:
        key = "/".join(str(p) for p in path)
        if key not in decoded:
            raise KeyError(f"checkpoint missing {key}")
        val = decoded[key]
        if tuple(val.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {val.shape} != template {leaf.shape}")
        leaves.append(val.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(paths_leaves[1], leaves)


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    def save(self, step: int, tree: Pytree, extra: dict | None = None) -> Path:
        proc = jax.process_index()
        tmp = self.dir / f".tmp_step_{step:08d}_{proc}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(tree)
        np.savez(tmp / f"proc_{proc}.npz", **flat)
        meta = {
            "step": step,
            "time": time.time(),
            "n_arrays": len(flat),
            "extra": extra or {},
        }
        (tmp / "meta.json").write_text(json.dumps(meta))
        final = self._step_dir(step)
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._gc()
        return final

    def steps(self) -> list[int]:
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if (p / "meta.json").exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template: Pytree, step: int | None = None) -> tuple[int, Pytree, dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        meta = json.loads((d / "meta.json").read_text())
        flat: dict[str, np.ndarray] = {}
        for f in sorted(d.glob("proc_*.npz")):
            with np.load(f) as z:
                for k in z.files:
                    flat[k] = z[k]
        return step, _unflatten(template, flat), meta.get("extra", {})

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
