from .checkpoint import CheckpointManager  # noqa: F401
from ..fault import Heartbeat, RestartPolicy, StragglerMonitor  # noqa: F401
from .train_loop import Trainer, TrainerConfig, make_train_step  # noqa: F401
