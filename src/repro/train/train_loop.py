"""The training driver: jitted step, grad accumulation, checkpoints, FT.

``make_train_step`` builds the pure step (loss -> grads -> psum via pjit ->
AdamW) with donated params/opt-state.  ``Trainer`` owns the loop: data
prefetch, periodic atomic checkpoints, heartbeat, straggler monitor, and
``run_resilient`` which survives injected failures by restoring the last
checkpoint (deterministic data makes the recovery bit-exact).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.cache import fingerprint_obj
from ..core.database import TuningDatabase
from ..data.pipeline import DataConfig, LMDataPipeline
from ..models import model as M
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from ..optim.compression import compress_grads, decompress_grads
from ..fault import Heartbeat, RestartPolicy, StragglerMonitor
from .checkpoint import CheckpointManager


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE, vocab-parallel safe.

    The logits' vocab dim is model-sharded (Megatron-style); both the
    logsumexp and the label-logit extraction are expressed as reductions
    over that dim (XLA inserts the psum) — no gather that would force an
    all-gather of the (B, S, V) tensor.  fp32 math on the sharded values.
    """
    from ..models.layers import constrain

    lf = constrain(logits.astype(jnp.float32), ("pod", "data"), None, "model")
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    ll = jnp.sum(jnp.where(iota == labels[..., None], lf, 0.0), axis=-1)
    return jnp.mean(lse - ll)


def loss_fn(cfg: ModelConfig, params, batch) -> jax.Array:
    logits = M.forward(cfg, params, batch)
    return cross_entropy(logits, batch["labels"])


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    accum_steps: int = 1,
    grad_codec: str = "none",
    pod_axis: str | None = None,
):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``accum_steps`` > 1 splits the batch into microbatches (sequential
    lax.scan) — activation memory drops by the factor, FLOPs unchanged.
    ``grad_codec``+``pod_axis`` compress the cross-pod gradient all-reduce
    (bf16/int8 w/ error feedback) when the step runs under shard_map with an
    explicit pod axis; under plain pjit the psum is implicit and the codec
    applies to the values feeding it.
    """

    def grads_of(params, batch):
        return jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)

    def step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = grads_of(params, batch)
        else:
            b = batch["tokens"].shape[0]
            assert b % accum_steps == 0
            mb = b // accum_steps
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(accum_steps, mb, *x.shape[1:]), batch
            )

            def acc_fn(carry, mbatch):
                loss_i, g_i = grads_of(params, mbatch)
                gsum, lsum = carry
                return (
                    jax.tree_util.tree_map(jnp.add, gsum, g_i),
                    lsum + loss_i,
                ), None

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(acc_fn, (zero, 0.0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, gsum)
            loss = lsum / accum_steps

        if grad_codec != "none" and pod_axis is not None:
            comp, scales, _ = compress_grads(grads, None, grad_codec)
            comp = jax.lax.pmean(comp, pod_axis)
            grads = decompress_grads(comp, scales, grad_codec)

        params, opt_state, metrics = adamw_update(opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step


@dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    heartbeat: str | None = None
    accum_steps: int = 1


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg: AdamWConfig,
        data_cfg: DataConfig,
        tcfg: TrainerConfig,
        seed: int = 0,
        tuning_db: TuningDatabase | None = None,
        mesh=None,
        telemetry=None,
    ):
        """``mesh`` places parameters (and hence the AdamW moments derived
        from them) with ``launch.sharding.param_specs`` before the step jit
        is built — gradients then reduce across the mesh's data axes via the
        committed shardings (pjit), no step-function changes needed.
        ``telemetry`` (a ``repro.autotune.NestTelemetry``, e.g. a
        ``SearchSupervisor``'s) receives per-step wall times so the online
        tuner can rank training among its heat sources; without one the
        observations hit a disabled no-op sink."""
        from ..models.lowering import deployment_context

        self.cfg, self.opt_cfg, self.tcfg = cfg, opt_cfg, tcfg
        self.mesh = mesh
        # Shared deployment boilerplate (mesh placement + warm pretuned
        # tuning DB + fingerprint-keyed jit lookups) — same helper the
        # ServingEngine constructor uses.
        self._ctx = deployment_context(
            cfg, M.init_params(cfg, jax.random.PRNGKey(seed)),
            mesh=mesh, tuning_db=tuning_db, telemetry=telemetry)
        self.tuning_db = self._ctx.tuning_db
        self.telemetry = self._ctx.telemetry
        self._telemetry_key = f"train.step:{fingerprint_obj(cfg)[:12]}"
        self.data = LMDataPipeline(data_cfg)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.monitor = StragglerMonitor()
        self.hb = Heartbeat(tcfg.heartbeat) if tcfg.heartbeat else None
        self.params = self._ctx.params
        self.opt_state = adamw_init(self.params)
        # the AdamW moments are parameter-shaped: place them with the same
        # specs so optimizer state scales with the mesh too
        self.opt_state["m"] = self._ctx.place(self.opt_state["m"])
        self.opt_state["v"] = self._ctx.place(self.opt_state["v"])
        # Keyed by config content: a Trainer re-created with equal configs
        # (checkpoint-resume, fault-tolerant restarts) reuses the jitted
        # step and its traces instead of rebuilding and recompiling.
        self.step_fn = self._ctx.jitted(
            "train.step",
            lambda: jax.jit(
                make_train_step(cfg, opt_cfg, accum_steps=tcfg.accum_steps),
                donate_argnums=(0, 1),
            ),
            fingerprint_obj(opt_cfg), tcfg.accum_steps,
        )
        self.step = 0
        self.history: list[dict] = []

    def explain_kernels(self) -> str:
        """Pass-pipeline + contraction-plan report at this trainer's data
        shape (content-cached: restarted trainers share one pipeline run)."""
        from ..models.lowering import kernel_report

        dcfg = self.data.cfg
        return self._ctx.jitted(
            "train.kernel_report",
            lambda: kernel_report(
                self.cfg, seq=dcfg.seq_len, batch=dcfg.global_batch,
                db=self.tuning_db,
            ),
            dcfg.seq_len, dcfg.global_batch,
            self.tuning_db.uid, self.tuning_db.generation,
        )

    # -- checkpoint plumbing --------------------------------------------------
    def _tree(self):
        return {"params": self.params, "opt": self.opt_state}

    def save(self) -> None:
        self.ckpt.save(self.step, self._tree(), extra={"step": self.step})

    def try_restore(self) -> bool:
        if self.ckpt.latest_step() is None:
            return False
        try:
            step, tree, _ = self.ckpt.restore(self._tree())
        except (KeyError, ValueError):
            return False  # incompatible checkpoint (e.g. config changed)
        # checkpoints restore as host arrays: re-place on the mesh with the
        # same sharding specs the constructor used, or the first post-restore
        # step would run unsharded (and donation would fail on a re-formed
        # mesh with a different device count)
        self.params = self._ctx.place(tree["params"])
        self.opt_state = tree["opt"]
        self.opt_state["m"] = self._ctx.place(self.opt_state["m"])
        self.opt_state["v"] = self._ctx.place(self.opt_state["v"])
        self.step = step
        return True

    # -- loops ----------------------------------------------------------------
    def run(self, n_steps: int, fail_at: int | None = None) -> list[dict]:
        """Train n_steps from the current position. ``fail_at`` injects a
        crash (tests the restart path)."""
        if self.hb:
            self.hb.start()
        self.data.start(self.step)
        try:
            target = self.step + n_steps
            while self.step < target:
                step_id, batch = self.data.next()
                assert step_id == self.step, (step_id, self.step)
                if fail_at is not None and self.step == fail_at:
                    raise RuntimeError(f"injected failure at step {self.step}")
                t0 = time.perf_counter()
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch
                )
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                self.monitor.observe(self.step, dt)
                self.telemetry.observe(self._telemetry_key, dt)
                self.step += 1
                rec = {"step": self.step, "loss": loss, "dt": dt,
                       "lr": float(metrics["lr"]), "skipped": bool(metrics["skipped"])}
                self.history.append(rec)
                if self.step % self.tcfg.ckpt_every == 0:
                    self.save()
            return self.history
        finally:
            self.data.stop()
            if self.hb:
                self.hb.stop()

    def run_resilient(self, n_steps: int, fail_at: int | None = None,
                      policy: RestartPolicy | None = None) -> list[dict]:
        """run() wrapped in restore-and-retry (the supervisor loop a cluster
        scheduler would drive)."""
        policy = policy or RestartPolicy()
        target = self.step + n_steps
        while True:
            try:
                self.run(target - self.step, fail_at=fail_at)
                return self.history
            except RuntimeError as e:
                if not policy.should_restart(e):
                    raise
                fail_at = None  # the injected failure happens once
                restored = self.try_restore()
                if not restored:  # no checkpoint yet: restart from scratch
                    self.params = self._ctx.place(
                        M.init_params(self.cfg, jax.random.PRNGKey(0)))
                    self.opt_state = adamw_init(self.params)
                    self.opt_state["m"] = self._ctx.place(self.opt_state["m"])
                    self.opt_state["v"] = self._ctx.place(self.opt_state["v"])
                    self.step = 0
