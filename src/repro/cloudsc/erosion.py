"""CLOUDSC §5.1 — the erosion-of-clouds loop nest (paper Fig. 10a) in the IR.

The nest updates ``ZTP1`` (temperature) and ``ZQSMIX`` (mixed saturation)
over the NPROMA dimension ``JL`` inside the vertical loop ``JK``, computing
several scalar intermediates per point via the IFS thermodynamic functions
FOEEWM / FOEDEM / FOELDCPM.  Constants are the published IFS values.

Memory layout note: the Fortran code accesses ``ZTP1(JL,JK)`` column-major,
so JL is the contiguous dimension.  The row-major IR therefore declares the
arrays ``(KLEV, NPROMA)`` and indexes ``[JK, JL]`` — identical locality.

The scalars (ZQP, ZQSAT, ZCOR, ZCOND, ZCOND1) are genuine 0-d containers;
the normalizer's scalar expansion promotes them to ``_0(JL)`` arrays exactly
as in Fig. 10b, which unlocks maximal fission and JL vectorization.
"""
from __future__ import annotations

import numpy as np

from ..core.ir import Array, Call, Computation, Expr, Loop, Program, Read, acc, as_expr, emin

# IFS surrogate constants (physically plausible; ratios match the paper)
RTT = 273.16
R2ES = 611.21 * 0.621981
R3LES, R3IES = 17.502, 22.587
R4LES, R4IES = 32.19, -0.7
RTWAT = RTT
RTICE = RTT - 23.0
RTWAT_RTICE_R = 1.0 / (RTWAT - RTICE)
RETV = 0.608
RCPD = 1004.709
RLVTT, RLSTT = 2.5008e6, 2.8345e6
RALVDCP, RALSDCP = RLVTT / RCPD, RLSTT / RCPD
R5LES = R3LES * (RTT - R4LES)
R5IES = R3IES * (RTT - R4IES)
R5ALVCP = R5LES * RALVDCP
R5ALSCP = R5IES * RALSDCP


def _alpha(t, xp):
    """liquid fraction weight: MIN(1, ((MAX(RTICE,MIN(RTWAT,T))-RTICE)*R)**2)."""
    clip = xp.maximum(RTICE, xp.minimum(RTWAT, t))
    w = ((clip - RTICE) * RTWAT_RTICE_R) ** 2
    return xp.minimum(1.0, w)


def _xp(t):
    import jax.numpy as jnp

    return np if isinstance(t, (float, np.floating, np.ndarray)) else jnp


def foeewm(t):
    xp = _xp(t)
    a = _alpha(t, xp)
    return R2ES * (
        a * xp.exp(R3LES * (t - RTT) / (t - R4LES))
        + (1.0 - a) * xp.exp(R3IES * (t - RTT) / (t - R4IES))
    )


def foedem(t):
    xp = _xp(t)
    a = _alpha(t, xp)
    return a * R5ALVCP * (1.0 / (t - R4LES) ** 2) + (1.0 - a) * R5ALSCP * (
        1.0 / (t - R4IES) ** 2
    )


def foeldcpm(t):
    xp = _xp(t)
    a = _alpha(t, xp)
    return a * RALVDCP + (1.0 - a) * RALSDCP


def _ecall(fn, *args) -> Expr:
    """A symbolic ``Call`` node over one of the thermodynamic helpers."""
    return Call(fn.__name__, fn, tuple(as_expr(a) for a in args))


def erosion_program(nproma: int = 128, klev: int = 137, name: str = "cloudsc_erosion") -> Program:
    """The Fig. 10a loop nest: DO JK / DO JL / <scalar chain>."""
    A = lambda n: acc(n, "JK", "JL")  # noqa: E731
    S = lambda n: acc(n)  # 0-d scalar  # noqa: E731

    def comp(nm, write, reads, expr, accumulate=None):
        return Computation(nm, write, tuple(reads), expr, accumulate)

    qs_expr = _ecall(foeewm, Read(0)) * Read(1)
    cor_expr = 1.0 / (1.0 - RETV * Read(0))
    cond_expr = (Read(0) - Read(1)) / (
        1.0 + Read(1) * Read(2) * _ecall(foedem, Read(3)))
    tup_expr = Read(0) + _ecall(foeldcpm, Read(0)) * Read(1)
    body = (
        comp("zqp", S("ZQP"), [A("PAP")], 1.0 / Read(0)),
        # first saturation pass
        comp("qs1", S("ZQSAT"), [A("ZTP1"), S("ZQP")], qs_expr),
        comp("qs1c", S("ZQSAT"), [S("ZQSAT")], emin(0.5, Read(0))),
        comp("cor1", S("ZCOR"), [S("ZQSAT")], cor_expr),
        comp("qs1m", S("ZQSAT"), [S("ZQSAT"), S("ZCOR")], Read(0) * Read(1)),
        comp(
            "cond1",
            S("ZCOND"),
            [A("ZQSMIX"), S("ZQSAT"), S("ZCOR"), A("ZTP1")],
            cond_expr,
        ),
        comp("t1", A("ZTP1"), [A("ZTP1"), S("ZCOND")], tup_expr),
        comp("q1", A("ZQSMIX"), [A("ZQSMIX"), S("ZCOND")], Read(0) - Read(1)),
        # second saturation pass
        comp("qs2", S("ZQSAT"), [A("ZTP1"), S("ZQP")], qs_expr),
        comp("qs2c", S("ZQSAT"), [S("ZQSAT")], emin(0.5, Read(0))),
        comp("cor2", S("ZCOR"), [S("ZQSAT")], cor_expr),
        comp("qs2m", S("ZQSAT"), [S("ZQSAT"), S("ZCOR")], Read(0) * Read(1)),
        comp(
            "cond2",
            S("ZCOND1"),
            [A("ZQSMIX"), S("ZQSAT"), S("ZCOR"), A("ZTP1")],
            cond_expr,
        ),
        comp("t2", A("ZTP1"), [A("ZTP1"), S("ZCOND1")], tup_expr),
        comp("q2", A("ZQSMIX"), [A("ZQSMIX"), S("ZCOND1")], Read(0) - Read(1)),
    )
    nest = Loop("JK", klev, body=(Loop("JL", nproma, body=body),))
    arrays = (
        Array("PAP", (klev, nproma)),
        Array("ZTP1", (klev, nproma)),
        Array("ZQSMIX", (klev, nproma)),
        Array("ZQP", ()),
        Array("ZQSAT", ()),
        Array("ZCOR", ()),
        Array("ZCOND", ()),
        Array("ZCOND1", ()),
    )
    return Program(name, arrays, (nest,),
                   temps=("ZQP", "ZQSAT", "ZCOR", "ZCOND", "ZCOND1"))


def physical_inputs(nproma: int = 128, klev: int = 137, seed: int = 0) -> dict[str, np.ndarray]:
    """Physically plausible fields: T ~ 200-300K, p ~ 5e3-1e5 Pa, q ~ 0-0.02."""
    rng = np.random.default_rng(seed)
    return {
        "PAP": rng.uniform(5e3, 1e5, size=(klev, nproma)),
        "ZTP1": rng.uniform(200.0, 300.0, size=(klev, nproma)),
        "ZQSMIX": rng.uniform(0.0, 0.02, size=(klev, nproma)),
    }
