from .erosion import erosion_program  # noqa: F401
from .scheme import column_mesh, compile_scheme, mini_cloudsc_program  # noqa: F401
