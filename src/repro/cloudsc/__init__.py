from .erosion import erosion_program  # noqa: F401
from .scheme import mini_cloudsc_program  # noqa: F401
