from .erosion import erosion_program, physical_inputs  # noqa: F401
from .scheme import (  # noqa: F401
    column_mesh,
    compile_scheme,
    mini_cloudsc_program,
    saturation_chain_inputs,
    saturation_chain_program,
)
