"""A mini CLOUDSC vertical scheme (paper §5.2 analogue).

Several physics stages inside one vertical loop, modeled after the structure
of the real scheme:

  1. saturation/erosion update (the Fig. 10 nest, scalar chain over JL),
  2. condensate source split into liquid/ice by the alpha weight,
  3. precipitation flux accumulated *down the column* — a genuine JK-carried
     recurrence (fluxes fall), which normalization must keep sequential,
  4. final tendency update from the flux divergence.

Stage 3 proves the normalizer's legality machinery on a real pattern: the
JK-carried SCC stays atomic while every JL loop fissions and vectorizes.
"""
from __future__ import annotations

import numpy as np

from ..core.ir import Affine, Array, Computation, Loop, Program, acc, aff
from .erosion import _xp, foedem, foeewm, foeldcpm, RETV

RG_DT = 0.75     # g*dt/dp surrogate
RAUTO = 1.0e-3   # autoconversion rate
RFALL = 0.8      # fall-speed weight


def mini_cloudsc_program(nproma: int = 128, klev: int = 137) -> Program:
    A = lambda n: acc(n, "JK", "JL")  # noqa: E731
    Am1 = lambda n: acc(n, aff("JK", const=-1), "JL")  # noqa: E731
    S = lambda n: acc(n)  # noqa: E731

    def comp(nm, write, reads, expr, accumulate=None, guards=()):
        return Computation(nm, write, tuple(reads), expr, accumulate, tuple(guards))

    # -- stage 1: saturation adjustment (scalar chain, as in erosion) --------
    sat = (
        comp("zqp", S("ZQP"), [A("PAP")], lambda p: 1.0 / p),
        comp("qs", S("ZQSAT"), [A("ZTP1"), S("ZQP")], lambda t, qp: foeewm(t) * qp),
        comp("qsc", S("ZQSAT"), [S("ZQSAT")], lambda q: _xp(q).minimum(0.5, q)),
        comp("cor", S("ZCOR"), [S("ZQSAT")], lambda q: 1.0 / (1.0 - RETV * q)),
        comp("qsm", S("ZQSAT"), [S("ZQSAT"), S("ZCOR")], lambda q, c: q * c),
        comp(
            "cond",
            S("ZCOND"),
            [A("ZQSMIX"), S("ZQSAT"), S("ZCOR"), A("ZTP1")],
            lambda qm, qs, cor, t: (qm - qs) / (1.0 + qs * cor * foedem(t)),
        ),
        comp("tu", A("ZTP1"), [A("ZTP1"), S("ZCOND")], lambda t, c: t + foeldcpm(t) * c),
        comp("qu", A("ZQSMIX"), [A("ZQSMIX"), S("ZCOND")], lambda q, c: q - c),
    )
    # -- stage 2: split condensate into liquid & ice, autoconversion ---------
    split = (
        comp(
            "liq",
            A("ZQL"),
            [A("ZQL"), A("ZQSMIX"), A("ZTP1")],
            lambda ql, q, t: ql + RAUTO * q * foeldcpm(t) / (foeldcpm(t) + 1.0),
        ),
        comp(
            "ice",
            A("ZQI"),
            [A("ZQI"), A("ZQSMIX"), A("ZTP1")],
            lambda qi, q, t: qi + RAUTO * q * (1.0 - foeldcpm(t) / (foeldcpm(t) + 1.0)),
        ),
    )
    # -- stage 3: precipitation flux falls down the column (JK-carried) ------
    flux = (
        comp(
            "pfl",
            A("PFPLSL"),
            [Am1("PFPLSL"), A("ZQL")],
            lambda fup, ql: RFALL * fup + RAUTO * ql,
            guards=(aff("JK", const=-1),),  # JK >= 1 (no level above at JK=0)
        ),
        comp(
            "pfl0",
            A("PFPLSL"),
            [A("ZQL")],
            lambda ql: RAUTO * ql,
            guards=(aff(("JK", -1)),),  # JK == 0  (−JK >= 0)
        ),
    )
    # -- stage 4: tendency from flux divergence ------------------------------
    tend = (
        comp(
            "dq",
            A("TENDQ"),
            [A("PFPLSL"), A("ZQSMIX")],
            lambda f, q: RG_DT * (q - f),
        ),
    )
    nest = Loop(
        "JK",
        klev,
        body=(
            Loop("JL", nproma, body=sat),
            Loop("JL2", nproma, body=tuple(c.rename({"JL": "JL2"}) for c in split)),
            Loop("JL3", nproma, body=tuple(c.rename({"JL": "JL3"}) for c in flux)),
            Loop("JL4", nproma, body=tuple(c.rename({"JL": "JL4"}) for c in tend)),
        ),
    )
    arrays = (
        Array("PAP", (klev, nproma)),
        Array("ZTP1", (klev, nproma)),
        Array("ZQSMIX", (klev, nproma)),
        Array("ZQL", (klev, nproma)),
        Array("ZQI", (klev, nproma)),
        Array("PFPLSL", (klev, nproma)),
        Array("TENDQ", (klev, nproma)),
        Array("ZQP", ()),
        Array("ZQSAT", ()),
        Array("ZCOR", ()),
        Array("ZCOND", ()),
    )
    return Program(
        "mini_cloudsc", arrays, (nest,),
        temps=("ZQP", "ZQSAT", "ZCOR", "ZCOND", "PFPLSL", "TENDQ"),
    )


def column_mesh(n_devices: int | None = None, axis: str = "data"):
    """A 1-D mesh over the horizontal-column axis — the paper's NPROMA
    posture: CLOUDSC is embarrassingly parallel over grid columns (JL), so
    the whole scheme data-parallelizes across ``axis`` with zero collectives
    (the JK recurrence stays inside each shard's ``lax.scan``)."""
    import jax

    from ..launch.mesh import make_mesh

    n = n_devices if n_devices is not None else len(jax.devices())
    return make_mesh((n,), (axis,))


def compile_scheme(
    nproma: int = 128,
    klev: int = 137,
    mesh=None,
    schedule=None,
    fuse: bool = True,
):
    """Normalize + compile the mini scheme, column-sharded when ``mesh`` is
    given.  Returns ``(jitted_fn, ProgramPartition | None)``; the partition
    planner discovers the JL column iterator of every canonical nest and
    shards it over the mesh's ``data`` axis (all (klev, nproma) fields split
    along columns, scalar-expanded temporaries along their JL extent)."""
    import jax

    from ..core.codegen import Schedule, compile_jax
    from ..core.fusion import optimization_pipeline
    from ..core.partition import compile_sharded

    prog = mini_cloudsc_program(nproma, klev)
    norm = optimization_pipeline(fuse=fuse).run(prog)
    sched = schedule if schedule is not None else Schedule(
        mode="canonical", use_idioms=False, scan=True, shard_axis="data")
    if mesh is None:
        return jax.jit(compile_jax(norm, sched)), None
    fn, partition = compile_sharded(norm, sched, mesh=mesh, axis="data")
    return jax.jit(fn), partition


def scheme_inputs(nproma: int = 128, klev: int = 137, seed: int = 0) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    return {
        "PAP": rng.uniform(5e3, 1e5, size=(klev, nproma)),
        "ZTP1": rng.uniform(200.0, 300.0, size=(klev, nproma)),
        "ZQSMIX": rng.uniform(0.0, 0.02, size=(klev, nproma)),
        "ZQL": rng.uniform(0.0, 1e-3, size=(klev, nproma)),
        "ZQI": rng.uniform(0.0, 1e-3, size=(klev, nproma)),
    }
