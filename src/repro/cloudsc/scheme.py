"""A mini CLOUDSC vertical scheme (paper §5.2 analogue).

Several physics stages inside one vertical loop, modeled after the structure
of the real scheme:

  1. saturation/erosion update (the Fig. 10 nest, scalar chain over JL),
  2. condensate source split into liquid/ice by the alpha weight,
  3. precipitation flux accumulated *down the column* — a genuine JK-carried
     recurrence (fluxes fall), which normalization must keep sequential,
  4. final tendency update from the flux divergence.

Stage 3 proves the normalizer's legality machinery on a real pattern: the
JK-carried SCC stays atomic while every JL loop fissions and vectorizes.
"""
from __future__ import annotations

import numpy as np

from ..core.ir import (
    Array,
    Call,
    Computation,
    Expr,
    Loop,
    Program,
    Read,
    acc,
    aff,
    as_expr,
    emin,
)
from .erosion import foedem, foeewm, foeldcpm, RETV

RG_DT = 0.75     # g*dt/dp surrogate
RAUTO = 1.0e-3   # autoconversion rate
RFALL = 0.8      # fall-speed weight


def _call(fn, *args) -> Expr:
    """A symbolic ``Call`` of one of the IFS thermodynamic helpers."""
    return Call(fn.__name__, fn, tuple(as_expr(a) for a in args))


def mini_cloudsc_program(nproma: int = 128, klev: int = 137) -> Program:
    A = lambda n: acc(n, "JK", "JL")  # noqa: E731
    Am1 = lambda n: acc(n, aff("JK", const=-1), "JL")  # noqa: E731
    S = lambda n: acc(n)  # noqa: E731

    def comp(nm, write, reads, expr, accumulate=None, guards=()):
        return Computation(nm, write, tuple(reads), expr, accumulate, tuple(guards))

    # -- stage 1: saturation adjustment (scalar chain, as in erosion) --------
    _foel = _call(foeldcpm, Read(2))  # shared liquid-fraction weight
    sat = (
        comp("zqp", S("ZQP"), [A("PAP")], 1.0 / Read(0)),
        comp("qs", S("ZQSAT"), [A("ZTP1"), S("ZQP")],
             _call(foeewm, Read(0)) * Read(1)),
        comp("qsc", S("ZQSAT"), [S("ZQSAT")], emin(0.5, Read(0))),
        comp("cor", S("ZCOR"), [S("ZQSAT")], 1.0 / (1.0 - RETV * Read(0))),
        comp("qsm", S("ZQSAT"), [S("ZQSAT"), S("ZCOR")], Read(0) * Read(1)),
        comp(
            "cond",
            S("ZCOND"),
            [A("ZQSMIX"), S("ZQSAT"), S("ZCOR"), A("ZTP1")],
            (Read(0) - Read(1))
            / (1.0 + Read(1) * Read(2) * _call(foedem, Read(3))),
        ),
        comp("tu", A("ZTP1"), [A("ZTP1"), S("ZCOND")],
             Read(0) + _call(foeldcpm, Read(0)) * Read(1)),
        comp("qu", A("ZQSMIX"), [A("ZQSMIX"), S("ZCOND")], Read(0) - Read(1)),
    )
    # -- stage 2: split condensate into liquid & ice, autoconversion ---------
    split = (
        comp(
            "liq",
            A("ZQL"),
            [A("ZQL"), A("ZQSMIX"), A("ZTP1")],
            Read(0) + RAUTO * Read(1) * _foel / (_foel + 1.0),
        ),
        comp(
            "ice",
            A("ZQI"),
            [A("ZQI"), A("ZQSMIX"), A("ZTP1")],
            Read(0) + RAUTO * Read(1) * (1.0 - _foel / (_foel + 1.0)),
        ),
    )
    # -- stage 3: precipitation flux falls down the column (JK-carried) ------
    flux = (
        comp(
            "pfl",
            A("PFPLSL"),
            [Am1("PFPLSL"), A("ZQL")],
            RFALL * Read(0) + RAUTO * Read(1),
            guards=(aff("JK", const=-1),),  # JK >= 1 (no level above at JK=0)
        ),
        comp(
            "pfl0",
            A("PFPLSL"),
            [A("ZQL")],
            RAUTO * Read(0),
            guards=(aff(("JK", -1)),),  # JK == 0  (−JK >= 0)
        ),
    )
    # -- stage 4: tendency from flux divergence ------------------------------
    tend = (
        comp(
            "dq",
            A("TENDQ"),
            [A("PFPLSL"), A("ZQSMIX")],
            RG_DT * (Read(1) - Read(0)),
        ),
    )
    nest = Loop(
        "JK",
        klev,
        body=(
            Loop("JL", nproma, body=sat),
            Loop("JL2", nproma, body=tuple(c.rename({"JL": "JL2"}) for c in split)),
            Loop("JL3", nproma, body=tuple(c.rename({"JL": "JL3"}) for c in flux)),
            Loop("JL4", nproma, body=tuple(c.rename({"JL": "JL4"}) for c in tend)),
        ),
    )
    arrays = (
        Array("PAP", (klev, nproma)),
        Array("ZTP1", (klev, nproma)),
        Array("ZQSMIX", (klev, nproma)),
        Array("ZQL", (klev, nproma)),
        Array("ZQI", (klev, nproma)),
        Array("PFPLSL", (klev, nproma)),
        Array("TENDQ", (klev, nproma)),
        Array("ZQP", ()),
        Array("ZQSAT", ()),
        Array("ZCOR", ()),
        Array("ZCOND", ()),
    )
    return Program(
        "mini_cloudsc", arrays, (nest,),
        temps=("ZQP", "ZQSAT", "ZCOR", "ZCOND", "PFPLSL", "TENDQ"),
    )


# (name, fall-speed weight, band extent) per hydrometeor species.  The band
# extents deliberately differ so the per-species JK nests cannot fuse — each
# compiles to its own lax.scan, which is what defeats cross-scan sharing.
SPECIES = (("rain", 0.82, 2), ("snow", 0.64, 3), ("liq", 0.45, 4), ("ice", 0.31, 5))


def _sat_source(i_t: int, i_p: int, iters: int) -> Expr:
    """Wet-bulb relaxation source over reference fields — the hoist target.

    ``iters`` Newton-style corrections of the wet-bulb temperature
    (``tw -= (esat(tw)/p - q*) * dL/cp * k``), then the autoconversion
    source at the converged value.  Every iteration costs two ``exp``-based
    IFS calls, so the chain dominates the cheap flux recurrence around it.

    The reads are ``TREF``/``PREF`` level slices — per-step ``xs`` of the
    enclosing JK scan — so XLA's while-loop ICM *cannot* hoist the chain
    (it is syntactically step-dependent in HLO), and the four species scans
    are separate while ops, so XLA cannot share it across them either.
    ``LICMPass`` sees the band-axis (JM) invariance in the IR and computes
    the chain once into a shared ``(klev, nproma)`` temp.
    """
    tw, p = Read(i_t), Read(i_p)
    for _ in range(iters):
        tw = tw - (_call(foeewm, tw) / p - 0.01) * _call(foeldcpm, tw) * 1e-5
    return RAUTO * _call(foeewm, tw) / p


def saturation_chain_program(
    nproma: int = 128, klev: int = 137, iters: int = 3,
) -> Program:
    """A multi-species CLOUDSC saturation→flux chain (`bench_rewrite` gate).

    For each hydrometeor species in :data:`SPECIES`, a banded precipitation
    flux ``PFLUX_<sp>(JK, JL, JM)`` falls down the column — a genuine
    JK-carried recurrence (``lax.scan`` after normalization) whose source
    term :func:`_sat_source` reads only ``(JK, JL)`` fields, i.e. is
    invariant along the species band axis ``JM``.  A final nest folds the
    rain flux into a tendency.

    Without the rewrite passes the wet-bulb chain is recomputed for every
    band element of every species — ``sum(extents) = 14`` evaluations per
    grid point; ``LICMPass`` hoists it into one shared ``(klev, nproma)``
    temp (the reads are never-written inputs, so one temp serves all four
    nests), bit-identically.  XLA cannot recover this on its own: the chain
    reads per-step scan slices and spans four separate while ops.
    """
    body: list[Loop] = []
    arrays = [
        Array("TREF", (klev, nproma)),
        Array("PREF", (klev, nproma)),
        Array("QCOL", (klev, nproma)),
        Array("TEND", (klev, nproma)),
    ]
    temps = ["TEND"]
    for k, (nm, rfall, nb) in enumerate(SPECIES):
        JK, JL, JM = f"JK{k}", f"JL{k}", f"JM{k}"
        P, W = f"PFLUX_{nm}", f"W_{nm}"
        arrays += [Array(P, (klev, nproma, nb)), Array(W, (nb,))]
        temps.append(P)
        A3 = acc(P, JK, JL, JM)
        pfl = Computation(
            f"pfl_{nm}",
            A3,
            (acc(P, aff(JK, const=-1), JL, JM), acc("TREF", JK, JL),
             acc("PREF", JK, JL), acc(W, JM), acc("QCOL", JK, JL)),
            rfall * Read(0) + Read(3) * _sat_source(1, 2, iters) + RAUTO * Read(4),
            guards=(aff(JK, const=-1),),  # JK >= 1
        )
        pfl0 = Computation(
            f"pfl0_{nm}",
            A3,
            (acc("TREF", JK, JL), acc("PREF", JK, JL), acc(W, JM),
             acc("QCOL", JK, JL)),
            Read(2) * _sat_source(0, 1, iters) + RAUTO * Read(3),
            guards=(aff((JK, -1)),),  # JK == 0
        )
        body.append(Loop(JK, klev, body=(Loop(JL, nproma, body=(
            Loop(JM, nb, body=(pfl, pfl0)),)),)))
    dq = Computation(
        "dq",
        acc("TEND", "JKD", "JLD"),
        (acc("QCOL", "JKD", "JLD"),
         acc("PFLUX_rain", "JKD", "JLD", aff(const=0))),
        RG_DT * (Read(0) - Read(1)),
    )
    body.append(Loop("JKD", klev, body=(Loop("JLD", nproma, body=(dq,)),)))
    return Program(
        "saturation_chain", tuple(arrays), tuple(body), temps=tuple(temps))


def saturation_chain_inputs(
    nproma: int = 128, klev: int = 137, seed: int = 0,
) -> dict[str, np.ndarray]:
    """Random physical-range inputs for :func:`saturation_chain_program`."""
    rng = np.random.default_rng(seed)
    out = {
        "TREF": rng.uniform(250.0, 300.0, size=(klev, nproma)),
        "PREF": rng.uniform(5e3, 1e5, size=(klev, nproma)),
        "QCOL": rng.uniform(0.0, 0.02, size=(klev, nproma)),
    }
    for nm, _, nb in SPECIES:
        out[f"W_{nm}"] = rng.uniform(0.2, 1.0, size=(nb,))
    return out


def column_mesh(n_devices: int | None = None, axis: str = "data"):
    """A 1-D mesh over the horizontal-column axis — the paper's NPROMA
    posture: CLOUDSC is embarrassingly parallel over grid columns (JL), so
    the whole scheme data-parallelizes across ``axis`` with zero collectives
    (the JK recurrence stays inside each shard's ``lax.scan``)."""
    import jax

    from ..launch.mesh import make_mesh

    n = n_devices if n_devices is not None else len(jax.devices())
    return make_mesh((n,), (axis,))


def compile_scheme(
    nproma: int = 128,
    klev: int = 137,
    mesh=None,
    schedule=None,
    fuse: bool = True,
):
    """Normalize + compile the mini scheme, column-sharded when ``mesh`` is
    given.  Returns ``(jitted_fn, ProgramPartition | None)``; the partition
    planner discovers the JL column iterator of every canonical nest and
    shards it over the mesh's ``data`` axis (all (klev, nproma) fields split
    along columns, scalar-expanded temporaries along their JL extent)."""
    import jax

    from ..core.codegen import Schedule, compile_jax
    from ..core.fusion import optimization_pipeline
    from ..core.partition import compile_sharded

    prog = mini_cloudsc_program(nproma, klev)
    norm = optimization_pipeline(fuse=fuse).run(prog)
    sched = schedule if schedule is not None else Schedule(
        mode="canonical", use_idioms=False, scan=True, shard_axis="data")
    if mesh is None:
        return jax.jit(compile_jax(norm, sched)), None
    fn, partition = compile_sharded(norm, sched, mesh=mesh, axis="data")
    return jax.jit(fn), partition


def scheme_inputs(nproma: int = 128, klev: int = 137, seed: int = 0) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    return {
        "PAP": rng.uniform(5e3, 1e5, size=(klev, nproma)),
        "ZTP1": rng.uniform(200.0, 300.0, size=(klev, nproma)),
        "ZQSMIX": rng.uniform(0.0, 0.02, size=(klev, nproma)),
        "ZQL": rng.uniform(0.0, 1e-3, size=(klev, nproma)),
        "ZQI": rng.uniform(0.0, 1e-3, size=(klev, nproma)),
    }
