"""jax version compatibility shims for the Pallas TPU kernels.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in newer
jax releases; the kernels are written against the current name and this shim
resolves whichever the installed jax provides.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
