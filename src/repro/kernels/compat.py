"""jax version compatibility shims for the Pallas TPU kernels and meshes.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in newer
jax releases; the kernels are written against the current name and this shim
resolves whichever the installed jax provides.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
(and its replication-check keyword was renamed ``check_rep`` ->
``check_vma``); ``shard_map_compat`` resolves the callable once and hides the
keyword drift so the partition planner builds the same wrapper on every
supported jax.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def shard_map_compat(f, mesh, in_specs, out_specs) -> Any:
    """``shard_map`` across jax versions, replication checks disabled.

    The partition planner emits replicated out-specs for arrays that every
    shard computes redundantly (and for all-reduced accumulators); the
    static replication checker cannot always prove those, and its keyword
    was renamed between releases — so the checks are uniformly off and the
    planner's own veto analysis is the soundness argument.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        for kw in ({"check_vma": False}, {"check_rep": False}, {}):
            try:
                return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
            except TypeError:
                continue
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)
