"""Pallas TPU kernels for the perf-critical compute of the model stack.

Each kernel module pairs with an oracle in ``ref.py``; ``ops.py`` exposes the
backend-switching public API (xla / pallas_interpret / pallas).
"""
from . import ops, ref  # noqa: F401
from .flash_attention import flash_attention  # noqa: F401
from .gemm import gemm  # noqa: F401
from .moe_gmm import grouped_matmul  # noqa: F401
from .rmsnorm import rmsnorm  # noqa: F401
