"""Jit'd public wrappers around the Pallas kernels.

Backend selection (``repro.kernels.ops.BACKEND`` or per-call ``backend=``):
  * ``'xla'``               — pure-jnp reference path (default for dry-run/
                              training on this CPU container; XLA fuses it)
  * ``'pallas_interpret'``  — Pallas kernels executed in interpret mode
                              (CPU correctness validation)
  * ``'pallas'``            — Pallas compiled for TPU (the deploy target)

``einsum2`` is the hook the daisy codegen uses to route the BLAS-3 idiom of
a canonical nest into the Pallas GEMM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention as _flash
from .gemm import gemm as _gemm
from .moe_gmm import grouped_matmul as _gmm
from .rmsnorm import rmsnorm as _rmsnorm

BACKEND = "xla"


def _use_pallas(backend):
    b = backend or BACKEND
    return b in ("pallas", "pallas_interpret"), b == "pallas_interpret"


def matmul(x, y, *, tile=None, backend=None):
    pallas, interp = _use_pallas(backend)
    if not pallas:
        return ref.matmul(x, y)
    bm, bn, bk = tile or (128, 128, 128)
    return _gemm(x, y, block_m=bm, block_n=bn, block_k=bk, interpret=interp)


# Above this many score elements (Sq*Skv) the XLA path switches to the
# chunked online-softmax formulation (bounded HBM working set).
CHUNKED_ATTN_THRESHOLD = 1 << 22


def attention(q, k, v, *, causal=True, window=None, q_offset=0,
              tile=None, backend=None):
    pallas, interp = _use_pallas(backend)
    if not pallas:
        if q.shape[1] * k.shape[1] > CHUNKED_ATTN_THRESHOLD and q.shape[1] > 1:
            return ref.attention_chunked(
                q, k, v, causal=causal, window=window, q_offset=q_offset)
        return ref.attention(q, k, v, causal=causal, window=window, q_offset=q_offset)
    bq, bk_ = tile or (128, 128)
    return _flash(q, k, v, causal=causal, window=window, q_offset=q_offset,
                  block_q=bq, block_k=bk_, interpret=interp)


def grouped_matmul(x, w, *, tile=None, backend=None):
    pallas, interp = _use_pallas(backend)
    if not pallas:
        return ref.grouped_matmul(x, w)
    bc, bf, bd = tile or (128, 128, 128)
    return _gmm(x, w, block_c=bc, block_f=bf, block_d=bd, interpret=interp)


def rmsnorm(x, gamma, *, eps=1e-6, backend=None):
    pallas, interp = _use_pallas(backend)
    if not pallas:
        return ref.rmsnorm(x, gamma, eps=eps)
    shape = x.shape
    out = _rmsnorm(x.reshape(-1, shape[-1]), gamma, eps=eps, interpret=interp)
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# daisy codegen hook: 2-operand einsum -> Pallas GEMM
# ---------------------------------------------------------------------------
def einsum2(sub_a: str, sub_b: str, sub_out: str, a, b, *, tile=None,
            interpret: bool = True):
    """Lower a clean 2-operand contraction to the tiled GEMM kernel.

    Only handles the no-batch-dim case (every letter is either contracted or
    appears in the output exactly once); anything else raises so the caller
    falls back to jnp.einsum.
    """
    letters = set(sub_a) | set(sub_b)
    contracted = [l for l in letters if l in sub_a and l in sub_b and l not in sub_out]
    kept_a = [l for l in sub_a if l in sub_out]
    kept_b = [l for l in sub_b if l in sub_out and l not in kept_a]
    if (
        len(set(sub_a)) != len(sub_a)
        or len(set(sub_b)) != len(sub_b)
        or sorted(sub_out) != sorted(kept_a + kept_b)
        or not contracted
    ):
        raise ValueError("not a clean 2-operand contraction")

    # move contracted letters last in a, first in b; flatten to 2-D
    perm_a = [sub_a.index(l) for l in kept_a] + [sub_a.index(l) for l in contracted]
    perm_b = [sub_b.index(l) for l in contracted] + [sub_b.index(l) for l in kept_b]
    a2 = jnp.transpose(a, perm_a)
    b2 = jnp.transpose(b, perm_b)
    ka = 1
    for l in kept_a:
        ka *= a.shape[sub_a.index(l)]
    kc = 1
    for l in contracted:
        kc *= a.shape[sub_a.index(l)]
    kb = 1
    for l in kept_b:
        kb *= b.shape[sub_b.index(l)]
    a2 = a2.reshape(ka, kc)
    b2 = b2.reshape(kc, kb)
    bm, bn, bk = tile or (128, 128, 128)
    out = _gemm(a2, b2, block_m=bm, block_n=bn, block_k=bk, interpret=interpret)
    # reshape/transpose to the requested output order
    out = out.reshape([a.shape[sub_a.index(l)] for l in kept_a]
                      + [b.shape[sub_b.index(l)] for l in kept_b])
    cur = kept_a + kept_b
    perm_o = [cur.index(l) for l in sub_out]
    return jnp.transpose(out, perm_o)
