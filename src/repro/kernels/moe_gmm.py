"""Pallas TPU grouped matmul — the expert-FFN compute of MoE layers.

Capacity-bucketed formulation: tokens are dispatched to ``x: (E, C, D)``
(E experts, C capacity) and each expert applies its own weight ``w: (E, D, F)``.
Grid ``(E, C/bc, F/bf, D/bd)``; the expert dimension is 'parallel' (it is the
EP-sharded axis on the mesh), D innermost accumulating in VMEM scratch.

This is the TPU adaptation of MegaBlocks-style grouped GEMM: instead of
CSR-indexed block sparsity (a GPU-shared-memory pattern), the canonical form
is a dense per-expert batch — XLA SPMD then shards E across the mesh.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_d: int):
    d = pl.program_id(3)

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0], w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(d == n_d - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul(
    x: jax.Array,  # (E, C, D)
    w: jax.Array,  # (E, D, F)
    *,
    block_c: int = 128,
    block_f: int = 128,
    block_d: int = 128,
    interpret: bool = True,
) -> jax.Array:
    e, c, d = x.shape
    e2, d2, f = w.shape
    assert e == e2 and d == d2
    bc, bf, bd = min(block_c, c), min(block_f, f), min(block_d, d)
    pc, pf, pd = (-c) % bc, (-f) % bf, (-d) % bd
    if pc or pd:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, pd)))
    if pd or pf:
        w = jnp.pad(w, ((0, 0), (0, pd), (0, pf)))
    C, D, F = x.shape[1], x.shape[2], w.shape[2]
    n_d = D // bd

    out = pl.pallas_call(
        functools.partial(_gmm_kernel, n_d=n_d),
        grid=(e, C // bc, F // bf, n_d),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda ei, i, j, k: (ei, i, k)),
            pl.BlockSpec((1, bd, bf), lambda ei, i, j, k: (ei, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda ei, i, j, k: (ei, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, w)
    return out[:, :c, :f]
