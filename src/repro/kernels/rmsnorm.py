"""Pallas TPU fused RMSNorm: one HBM pass per row block (read x, write y).

Grid over row blocks; the feature dimension stays whole in VMEM (d_model up
to ~12k fp32 = 48KB/row — a (8, d) block is well within VMEM).  Fusing the
mean-square reduction with the scale keeps the memory term at 2*bytes(x)
instead of 3-4 passes for the unfused chain.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * g_ref[...]).astype(o_ref.dtype)


def rmsnorm(
    x: jax.Array,  # (R, D)
    gamma: jax.Array,  # (D,)
    *,
    eps: float = 1e-6,
    block_r: int = 256,
    interpret: bool = True,
) -> jax.Array:
    r, d = x.shape
    br = min(block_r, r)
    pad = (-r) % br
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    R = x.shape[0]
    g2 = gamma.reshape(1, d)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), x.dtype),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, g2)
    return out[:r]
