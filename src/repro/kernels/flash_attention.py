"""Pallas TPU flash attention (fwd) — causal / sliding-window / GQA.

Blockwise online-softmax attention (FlashAttention-style, adapted to the TPU
memory hierarchy): grid ``(batch*q_heads, Sq/bq, Skv/bk)`` with the KV block
dimension innermost ('arbitrary'); running max/denominator/accumulator live
in VMEM scratch.  GQA is handled *inside the index map* — the K/V BlockSpecs
divide the head index by the group size, so KV blocks are fetched once per
group without materializing repeated heads in HBM.

Fully-masked KV blocks are skipped with ``pl.when`` (the TPU analogue of the
paper's guard-aware scheduling: the canonical form knows the mask structure
a priori, so the schedule can prune the iteration space).

Backward uses the XLA reference (jax.custom_vjp); the dry-run/training path
is pure XLA and differentiates natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, window: int | None, q_offset: int,
    block_q: int, block_k: int, n_kv: int, kv_len: int,
):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = q_offset + i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    def _process():
        q = q_ref[0]  # (bq, d)
        k = k_ref[0]  # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        mask = k_pos < kv_len  # padded keys are never attended
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32
        )
        m_ref[...], l_ref[...] = m_new, l_new

    # prune KV blocks that are fully masked for this q tile (a-priori
    # schedule pruning: the canonical form exposes the mask structure)
    live = j * block_k < kv_len
    if causal:
        live &= (j * block_k) <= (q_offset + (i + 1) * block_q - 1)
    if window is not None:
        live &= ((j + 1) * block_k - 1) > (q_offset + i * block_q) - window
    pl.when(live)(_process)

    @pl.when(j == n_kv - 1)
    def _done():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k", "interpret"),
)
def _flash_fwd(
    q, k, v, *, causal, window, q_offset, block_q, block_k, interpret
):
    """q: (BHq, Sq, D); k, v: (BHkv, Skv, D) -> (BHq, Sq, D)."""
    bhq, sq, d = q.shape
    bhkv, skv, _ = k.shape
    assert bhq % bhkv == 0
    group = bhq // bhkv
    scale = 1.0 / (d ** 0.5)

    bq = min(block_q, sq)
    bk = min(block_k, skv)
    pad_q, pad_k = (-sq) % bq, (-skv) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # pad keys so padded positions are masked out by q_pos >= k_pos only
        # for causal; for safety always mask via an explicit validity test
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    Sq, Skv = q.shape[1], k.shape[1]
    n_kv = Skv // bk

    kern = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, q_offset=q_offset,
        block_q=bq, block_k=bk, n_kv=n_kv, kv_len=skv,
    )
    out = pl.pallas_call(
        kern,
        grid=(bhq, Sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, grp=group: (b // grp, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, grp=group: (b // grp, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq, :]


def flash_attention(
    q, k, v, *, causal=True, window=None, q_offset=0,
    block_q=128, block_k=128, interpret=True,
):
    """Flash attention over (BH, S, D) tensors (GQA via BHq = g * BHkv)."""
    return _flash_fwd(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
