"""Grid-tiled Pallas lowering for canonical nests (the planner's back half).

``emit_nest`` turns one canonical nest — planned by
``repro.core.tiling.plan_nest_tiling`` — into a single ``pl.pallas_call``:

* every distinct affine access map becomes its own operand: the array is
  padded by the plan's halo and shifted so the access's origin
  (loop start + constant offset) lands on element 0 of the view, which makes
  each *view* exactly block-aligned — the BlockSpec is then read straight off
  the access map (tile sizes as the block shape, grid indices as the index
  map).  Overlapping stencil reads are separate operands of the same padded
  array, the standard Pallas way to express halos without losing pipelining;
* written arrays are passed twice — once as an input aliased onto the output
  (``input_output_aliases``) so the kernel can blend new values with old
  content under guard/bounds masks and partial tiles never clobber rows they
  do not own;
* reductions accumulate through a VMEM scratch block across an innermost
  'arbitrary' grid dimension (the GEMM pattern generalized to +, *, max,
  min), with the recipe's ``unroll`` factor splitting the in-tile reduction
  into sequentially accumulated chunks;
* guards and bounds become an in-kernel mask over broadcasted iotas; masked
  lanes keep old content (assignments) or contribute the accumulate's
  neutral element (reductions).

Everything is validated on CPU with ``interpret=True`` against the
``execute_numpy`` oracle; ``interpret=False`` targets TPU (grid dims are
declared parallel/arbitrary accordingly).
"""
from __future__ import annotations

import functools
from typing import Any, Mapping

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.codegen import _ACC_INIT, _ACC_REDUCE, _combine
from ..core.ir import Access, Computation, Node, Program
from ..core.tiling import TilePlan, TilingError, plan_nest_tiling
from .compat import CompilerParams

# trace-time lowering counters (tests assert the Pallas path actually ran)
EMITTED = {"pallas_nest": 0, "pallas_reduce": 0}


def emit_nest(
    program: Program,
    nest: Node,
    env: dict[str, Any],
    schedule,
) -> dict[str, Any]:
    """Lower one canonical nest via ``pl.pallas_call``; raises ``TilingError``
    (an ``Unsupported``) when the nest is outside the tiled class."""
    plan = plan_nest_tiling(
        program, nest, tile=schedule.nest_tile, vmem_budget=schedule.vmem_budget
    )
    if plan.kind == "reduce" and not schedule.pallas_reduce:
        raise TilingError("reduction nest but pallas_reduce disabled")
    if plan.kind == "parallel" and not schedule.pallas_nest:
        raise TilingError("parallel nest but pallas_nest disabled")

    emitter = _KernelBuilder(program, plan, env,
                             unroll=max(1, int(schedule.unroll)),
                             interpret=schedule.interpret)
    out_env = emitter.build()
    EMITTED["pallas_nest" if plan.kind == "parallel" else "pallas_reduce"] += 1
    return out_env


class _KernelBuilder:
    def __init__(self, program: Program, plan: TilePlan, env, *, unroll, interpret):
        self.p = program
        self.plan = plan
        self.env = env
        self.unroll = unroll
        self.interpret = interpret
        self.axes = plan.axes
        self.axis_of = plan.axis_of
        self.iter_of = plan.iter_of
        self.n_par = len(plan.parallel)
        self._padded: dict[str, Any] = {}

    # -- host-side operand views --------------------------------------------
    def _padded_array(self, name: str):
        if name not in self._padded:
            arr = self.env[name]
            pads = self.plan.halo.get(name, ((0, 0),) * arr.ndim)
            self._padded[name] = jnp.pad(arr, pads) if any(
                lo or hi for lo, hi in pads) else arr
        return self._padded[name]

    def _view_and_spec(self, a: Access):
        """Shifted view of the padded array + the BlockSpec read off the
        access map.  Inside the view, grid block ``g`` of iterator ``it``
        covers exactly elements ``[g*tile, (g+1)*tile)``."""
        base = self._padded_array(a.array)
        pads = self.plan.halo.get(a.array, ((0, 0),) * base.ndim)
        starts, sizes, blocks, srcs = [], [], [], []
        for d, dm in enumerate(self.plan.access_dims(a)):
            lo = pads[d][0]
            if dm.iterator is None:
                starts.append(lo + dm.const)
                sizes.append(1)
                blocks.append(1)
                srcs.append(None)
            else:
                ti = self.iter_of[dm.iterator]
                starts.append(lo + ti.start + dm.const)
                sizes.append(ti.n_tiles * ti.tile)
                blocks.append(ti.tile if ti.role != "reduce_inner" else ti.trip)
                if ti.role == "parallel":
                    srcs.append(self.plan.parallel.index(ti))
                elif ti.role == "reduce_grid":
                    srcs.append(self.n_par)
                else:
                    srcs.append(None)
        view = lax.slice(base, starts, [s + z for s, z in zip(starts, sizes)])

        def index_map(*gids, _srcs=tuple(srcs)):
            return tuple(gids[s] if s is not None else 0 for s in _srcs)

        return view, pl.BlockSpec(tuple(blocks), index_map)

    # -- in-kernel helpers ---------------------------------------------------
    def _slab_shape(self, used: set[str]) -> tuple[int, ...]:
        return tuple(
            (ax.tile if ax.role != "reduce_inner" else ax.trip)
            if ax.name in used else 1
            for ax in self.axes
        )

    def _align(self, block, dims, used: set[str]):
        """Reorder a loaded block (array-dim order) into the canonical slab
        axis order, singleton-broadcasting the axes it does not own."""
        keep = [d for d, dm in enumerate(dims) if dm.iterator is not None]
        block = block.reshape([block.shape[d] for d in keep])
        order = sorted(range(len(keep)),
                       key=lambda i: self.axis_of[dims[keep[i]].iterator])
        if order != list(range(len(keep))):
            block = jnp.transpose(block, order)
        shape = [1] * len(self.axes)
        for d in keep:
            ti = self.iter_of[dims[d].iterator]
            shape[self.axis_of[ti.name]] = (
                ti.tile if ti.role != "reduce_inner" else ti.trip)
        return block.reshape(shape)

    def _to_write_layout(self, slab, wdims):
        """Project a full-rank slab onto a write block (array-dim order)."""
        w_axes = [self.axis_of[dm.iterator] for dm in wdims if dm.iterator]
        drop = [k for k in range(len(self.axes)) if k not in w_axes]
        slab = slab.reshape([s for k, s in enumerate(slab.shape) if k not in drop])
        order_axes = sorted(w_axes)
        perm = [order_axes.index(self.axis_of[dm.iterator])
                for dm in wdims if dm.iterator]
        if perm != list(range(len(perm))):
            slab = jnp.transpose(slab, perm)
        # re-insert size-1 dims for constant write subscripts
        shape = []
        it_dims = iter(range(slab.ndim))
        for dm in wdims:
            shape.append(slab.shape[next(it_dims)] if dm.iterator else 1)
        return slab.reshape(shape)

    def _iota(self, gids, it_name: str, shape):
        ti = self.iter_of[it_name]
        ax = self.axis_of[it_name]
        if ti.role == "parallel":
            base = ti.start + gids[self.plan.parallel.index(ti)] * ti.tile
        elif ti.role == "reduce_grid":
            base = ti.start + gids[self.n_par] * ti.tile
        else:
            base = ti.start
        return base + lax.broadcasted_iota(jnp.int32, shape, ax)

    def _mask(self, gids, comp: Computation, used: set[str], shape):
        m = None
        for it in used:
            ti = self.iter_of[it]
            cur = self._iota(gids, it, shape) < ti.stop
            m = cur if m is None else m & cur
        for g in comp.guards:
            val = g.const
            for it, c in g.coeffs:
                val = val + c * self._iota(gids, it, shape)
            cur = val >= 0
            m = cur if m is None else m & cur
        return m

    # -- assembly ------------------------------------------------------------
    def build(self) -> dict[str, Any]:
        plan = self.plan
        in_views, in_specs, op_of = [], [], {}

        def operand(a: Access) -> int:
            key = (a.array, a.index)
            if key not in op_of:
                view, spec = self._view_and_spec(a)
                op_of[key] = len(in_views)
                in_views.append(view)
                in_specs.append(spec)
            return op_of[key]

        written: list[str] = []
        write_acc: dict[str, Access] = {}
        for c in plan.comps:
            for r in c.reads:
                operand(r)
            if c.write.array not in written:
                written.append(c.write.array)
                write_acc[c.write.array] = c.write
        # old-content operands, aliased onto the outputs
        aliases = {}
        out_shapes, out_specs = [], []
        for oi, name in enumerate(written):
            w = write_acc[name]
            idx = operand(w)
            aliases[idx] = oi
            view, spec = self._view_and_spec(w)
            out_shapes.append(jax.ShapeDtypeStruct(view.shape, view.dtype))
            out_specs.append(spec)

        n_in = len(in_views)
        n_grid = len(plan.grid)
        scratch = []
        if plan.kind == "reduce":
            wdims = plan.access_dims(plan.comps[0].write)
            acc_shape = tuple(
                self.iter_of[dm.iterator].tile if dm.iterator else 1
                for dm in wdims
            )
            scratch.append(pltpu.VMEM(acc_shape, jnp.float32))

        kernel = functools.partial(self._kernel, n_in=n_in, n_out=len(written),
                                   written=tuple(written),
                                   write_acc=write_acc, op_of=dict(op_of),
                                   n_grid=n_grid)
        semantics = ["parallel"] * self.n_par
        if plan.reduce_grid is not None:
            semantics.append("arbitrary")
        outs = pl.pallas_call(
            kernel,
            grid=plan.grid,
            in_specs=in_specs,
            out_specs=out_specs if len(out_specs) > 1 else out_specs[0],
            out_shape=out_shapes if len(out_shapes) > 1 else out_shapes[0],
            scratch_shapes=scratch,
            input_output_aliases=aliases,
            compiler_params=CompilerParams(
                dimension_semantics=tuple(semantics)),
            interpret=self.interpret,
        )(*in_views)
        if len(written) == 1:
            outs = [outs]

        # write the valid region of each output view back into the array
        env = dict(self.env)
        for name, out in zip(written, outs):
            arr = env[name]
            w = write_acc[name]
            starts, sizes = [], []
            for d, dm in enumerate(self.plan.access_dims(w)):
                if dm.iterator is None:
                    starts.append(dm.const)
                    sizes.append(1)
                else:
                    ti = self.iter_of[dm.iterator]
                    starts.append(ti.start + dm.const)
                    sizes.append(ti.trip)
            valid = lax.slice(out, [0] * out.ndim, sizes)
            env[name] = lax.dynamic_update_slice(
                arr, valid.astype(arr.dtype), starts)
        return env

    # -- the kernel body -----------------------------------------------------
    def _kernel(self, *refs, n_in, n_out, written, write_acc, op_of, n_grid):
        ins = refs[:n_in]
        outs = refs[n_in:n_in + n_out]
        acc_ref = refs[n_in + n_out] if len(refs) > n_in + n_out else None
        gids = [pl.program_id(d) for d in range(n_grid)]
        plan = self.plan
        slab_env: dict[str, tuple[tuple, Any]] = {}  # array -> (index, slab)

        def load(a: Access, used: set[str]):
            if a.array in slab_env and slab_env[a.array][0] == a.index:
                return slab_env[a.array][1]
            block = ins[op_of[(a.array, a.index)]][...]
            return self._align(block, plan.access_dims(a), used)

        for comp in plan.comps:
            used = {it for it in comp.iterators() if it in self.axis_of}
            shape = self._slab_shape(used)
            rvals = [load(r, used) for r in comp.reads]
            val = comp.expr(*rvals)
            val = jnp.broadcast_to(val, jnp.broadcast_shapes(jnp.shape(val), shape))
            mask = self._mask(gids, comp, used, shape)
            wdims = plan.access_dims(comp.write)
            oi = written.index(comp.write.array)

            if plan.kind == "reduce":
                self._emit_reduce(comp, val, mask, wdims, gids, outs[oi], acc_ref)
                continue

            old = load(comp.write, used)
            new = val if comp.accumulate is None else _combine(
                comp.accumulate, old, val)
            merged = jnp.where(mask, new, old) if mask is not None else new
            outs[oi][...] = self._to_write_layout(merged, wdims).astype(
                outs[oi].dtype)
            slab_env[comp.write.array] = (comp.write.index, merged)

    def _emit_reduce(self, comp, val, mask, wdims, gids, o_ref, acc_ref):
        plan = self.plan
        op = comp.accumulate
        neutral = _ACC_INIT[op]
        if mask is not None:
            val = jnp.where(mask, val, neutral)
        red_axes = [self.axis_of[a.name] for a in plan.reduce_inner]
        g_ax = self.axis_of[plan.reduce_grid.name]
        redfn = _ACC_REDUCE[op]
        if red_axes:
            val = redfn(val, axis=tuple(red_axes), keepdims=True)
        # recipe's unroll knob: accumulate the grid-tiled reduction axis in
        # `unroll` sequentially combined chunks
        tile_r = val.shape[g_ax]
        u = self.unroll if tile_r % max(1, self.unroll) == 0 else 1
        if u > 1:
            chunk = tile_r // u
            parts = None
            for k in range(u):
                piece = lax.slice_in_dim(val, k * chunk, (k + 1) * chunk,
                                         axis=g_ax)
                piece = redfn(piece, axis=g_ax, keepdims=True)
                parts = piece if parts is None else _combine(op, parts, piece)
            val = parts
        else:
            val = redfn(val, axis=g_ax, keepdims=True)
        partial = self._to_write_layout(val, wdims).astype(jnp.float32)

        k_red = gids[self.n_par]
        n_red = plan.reduce_grid.n_tiles

        @pl.when(k_red == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref) + neutral

        acc_ref[...] = _combine(op, acc_ref[...], partial)

        @pl.when(k_red == n_red - 1)
        def _done():
            o_ref[...] = _combine(op, o_ref[...],
                                  acc_ref[...]).astype(o_ref.dtype)
