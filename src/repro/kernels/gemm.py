"""Pallas TPU GEMM — the "library call" target of the BLAS-3 idiom.

Classic MXU-tiled matmul: grid ``(M/bm, N/bn, K/bk)`` with the K dimension
innermost ('arbitrary' semantics) accumulating into a VMEM fp32 scratch
block; M/N blocks are 'parallel'.  Block sizes come from the daisy recipe
database (stride minimization already made the operands row-major-contiguous
along the lane axis, so blocks are (sublane, lane)-aligned by construction).

Target: TPU v5e (MXU 128x128, VMEM ~16MB/core).  Validated on CPU with
``interpret=True`` against ``ref.matmul``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _gemm_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output block; accumulates over the K grid dimension."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm(
    x: jax.Array,
    y: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """``x @ y`` with explicit VMEM tiling. Shapes padded to block multiples."""
    assert x.ndim == 2 and y.ndim == 2 and x.shape[1] == y.shape[0]
    m, k = x.shape
    _, n = y.shape
    bm, bn, bk = (min(block_m, m), min(block_n, n), min(block_k, k))

    pad_m, pad_n, pad_k = (-m) % bm, (-n) % bn, (-k) % bk
    if pad_m or pad_k:
        x = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        y = jnp.pad(y, ((0, pad_k), (0, pad_n)))
    M, K = x.shape
    N = y.shape[1]
    n_k = K // bk

    out = pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=n_k),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, y)
    return out[:m, :n]
