"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def attention(q, k, v, *, causal=True, window=None, q_offset=0):
    """q: (BH, Sq, D); k, v: (BHkv, Skv, D), GQA by head-group repetition."""
    bhq, sq, d = q.shape
    bhkv, skv, _ = k.shape
    group = bhq // bhkv
    if group > 1:
        k = jnp.repeat(k, group, axis=0)
        v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (d ** 0.5)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None], p, 0.0)  # rows with no visible keys -> 0
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def attention_chunked(q, k, v, *, causal=True, window=None, q_offset=0,
                      block_q=512, block_k=1024):
    """Online-softmax attention with bounded HBM working set (the XLA
    analogue of the Pallas flash kernel): double scan over q/kv blocks keeps
    the live scores tensor at (BH, bq, bk) instead of (BH, Sq, Skv).

    Exactly matches ``attention`` (tested); used automatically by ops.attention
    when Sq*Skv is large.
    """
    bhq, sq, d = q.shape
    bhkv, skv, _ = k.shape
    group = bhq // bhkv
    if group > 1:
        k = jnp.repeat(k, group, axis=0)
        v = jnp.repeat(v, group, axis=0)
    scale = 1.0 / (d ** 0.5)

    bq = min(block_q, sq)
    bk = min(block_k, skv)
    pad_q, pad_k = (-sq) % bq, (-skv) % bk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0))) if pad_k else v
    nq, nk = qp.shape[1] // bq, kp.shape[1] // bk
    kb = kp.reshape(bhq, nk, bk, d).transpose(1, 0, 2, 3)  # (nk, BH, bk, d)
    vb = vp.reshape(bhq, nk, bk, d).transpose(1, 0, 2, 3)

    def q_block(qi):
        qc = jax.lax.dynamic_slice_in_dim(qp, qi * bq, bq, axis=1)  # (BH,bq,d)
        q_pos = q_offset + qi * bq + jnp.arange(bq)[:, None]

        def kv_step(carry, inp):
            m, l, acc = carry
            kc, vc, kj = inp
            s = jnp.einsum("bqd,bkd->bqk", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            k_pos = kj * bk + jnp.arange(bk)[None, :]
            mask = k_pos < skv
            if causal:
                mask &= q_pos >= k_pos
            if window is not None:
                mask &= (q_pos - k_pos) < window
            s = jnp.where(mask[None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.where(mask[None], jnp.exp(s - m_safe[..., None]), 0.0)
            alpha = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
            l_new = alpha * l + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bqk,bkd->bqd", p, vc.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((bhq, bq), -jnp.inf, jnp.float32),
            jnp.zeros((bhq, bq), jnp.float32),
            jnp.zeros((bhq, bq, d), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init,
                                      (kb, vb, jnp.arange(nk)))
        safe = jnp.where(l == 0.0, 1.0, l)
        return (acc / safe[..., None]).astype(q.dtype)  # (BH, bq, d)

    # flash-style remat: the backward recomputes each q block's kv scan
    # instead of saving the (BH, bq, Skv) score residuals — without this,
    # autodiff through the scan retains the full O(Sq*Skv) probabilities.
    q_block = jax.checkpoint(q_block)
    out = jax.lax.map(q_block, jnp.arange(nq))  # (nq, BH, bq, d)
    out = out.transpose(1, 0, 2, 3).reshape(bhq, nq * bq, d)
    return out[:, :sq]


def grouped_matmul(x, w):
    """x: (E, C, D); w: (E, D, F)."""
    return jnp.einsum(
        "ecd,edf->ecf", x.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(x.dtype)


def rmsnorm(x, gamma, *, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * gamma).astype(x.dtype)
