"""Shared autotune core: one search/measurement machine for offline tuning
(the ``repro.tools.tune`` CLI) and online adaptive tuning in deployment.

Offline half (moved here from ``tools/tune.py`` — that module re-exports
the old names so the CLI and its tests are unchanged):

  * :func:`program_specs` / :func:`build_program` — registry coordinates of
    tunable programs, rebuildable inside spawn workers (IR computations
    hold lambdas, which do not pickle);
  * :func:`tune_nest_task` — the per-nest epoch-1 search worker;
  * :func:`run_supervised` — the PR-7 supervised pool: per-task progress
    timeouts, bounded retries with solo-isolation crash forensics, and
    fingerprint-keyed quarantine, over either an in-process queue
    (``jobs <= 1``) or a spawn ``ProcessPoolExecutor``.

Online half (the Performance-Embeddings deployment story: transfer *at
deployment*, not just offline):

  * :class:`NestTelemetry` — per-key EMA wall times observed from real
    ``ServingEngine.step()`` / ``Trainer`` steps, keyed by program
    fingerprint; a disabled instance is a no-op so tuner-less deployments
    pay nothing;
  * :class:`SearchSupervisor` — launches :func:`online_search_task`
    searches (``evolve_recipe`` under a wall-clock ``deadline_s``) on the
    hottest registered programs through the same supervised pool, then
    applies the :class:`SwapPolicy`: a candidate must beat the incumbent
    by a configurable margin AND validate through
    ``fault.compile_with_degradation`` (compile + execute-once per backend
    rung) before it is committed to the live :class:`TuningDatabase` —
    whose ``generation`` bump is what hot-swaps the deployment's jitted
    fns (their cache keys carry ``(db.uid, db.generation)``);
  * automatic **rollback**: each swap arms a telemetry watch; if the
    post-swap EMA regresses beyond ``rollback_ratio`` within
    ``rollback_window`` observations, the incumbent entry is restored
    verbatim (another generation bump) and the nest is quarantined;
  * :meth:`SearchSupervisor.fold_back` — winners merge into the deployment
    database file via atomic checksummed ``merge()`` + ``save()`` so the
    fleet learns across restarts.

A poison candidate can never take down serving: searches run off the
serving thread (``mode='thread'``/``'spawn'``), worker crashes / hangs /
errors are retried then quarantined by the pool, and nothing reaches the
live database without an executed validation.

The telemetry -> search -> swap lifecycle, end to end::

    from repro.autotune import SearchSupervisor, SwapPolicy, logit_pipeline_program

    prog = logit_pipeline_program(vocab=cfg.vocab, slots=8)
    sup = SearchSupervisor(db, mode="thread",        # searches off-thread
                           policy=SwapPolicy(margin=0.1))
    eng = ServingEngine(cfg, params, scfg, tuner=sup,
                        logit_program=prog, logit_inputs={"B": bias})
    while serving:
        eng.step()          # times each busy step into sup.telemetry and
                            # drives maybe_launch()/poll() periodically
    sup.fold_back("data/fleet.json")                 # winners persist

See ``docs/architecture.md`` (Deployment layers) for where this sits in
the system, and ``benchmarks/bench_online.py`` for the gated end-to-end
story (stale database -> adaptation -> bit-identical tokens -> fold-back).
"""
from __future__ import annotations

import hashlib
import importlib
import math
import os
import queue
import tempfile
import threading
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from concurrent.futures.process import BrokenProcessPool
from dataclasses import dataclass, field
from multiprocessing import get_context
from pathlib import Path

import numpy as np

from .core import Daisy, Program, TuningDatabase, fingerprint, program_fingerprint
from .core.database import Entry
from .core.ir import Array, Computation, Loop, acc
from .core.recipes import Recipe
from .fault import FaultInjected, FaultPlan, RestartPolicy

SUITES = ("polybench", "cloudsc", "all")
BACKENDS = ("xla", "pallas_interpret", "pallas")


# ---------------------------------------------------------------------------
# program registry coordinates (shared by CLI tasks and spawn-mode online
# tasks: both rebuild programs from coordinates instead of pickling IR)
# ---------------------------------------------------------------------------

def program_specs(suite: str, names: list[str] | None = None) -> list[tuple[str, str]]:
    """(source, name) coordinates of every program the suite tunes."""
    specs: list[tuple[str, str]] = []
    if suite in ("polybench", "all"):
        from .polybench import BENCHMARKS

        sel = names or list(BENCHMARKS)
        unknown = [n for n in sel if n not in BENCHMARKS]
        if unknown:
            raise SystemExit(
                f"unknown benchmark(s) {', '.join(unknown)}; "
                f"valid: {', '.join(BENCHMARKS)}"
            )
        specs += [("polybench", n) for n in sel]
    if suite in ("cloudsc", "all"):
        specs += [("cloudsc", "erosion"), ("cloudsc", "scheme")]
    return specs


def build_program(source: str, name: str, size: str = "mini",
                  kwargs: dict | None = None) -> Program:
    """Rebuild a program from its registry coordinates (IR computations hold
    lambdas, which do not pickle — workers reconstruct instead of receiving).

    ``source='import'`` resolves ``name`` as ``"module:function"`` and calls
    it with ``kwargs`` — how deployment-defined programs (e.g. an engine's
    logit pipeline) become addressable from spawn workers.
    """
    if source == "import":
        mod, _, fn = name.partition(":")
        if not mod or not fn:
            raise ValueError(
                f"source='import' needs name='module:function', got {name!r}")
        return getattr(importlib.import_module(mod), fn)(**(kwargs or {}))
    if source == "polybench":
        from .polybench import BENCHMARKS

        return BENCHMARKS[name].make("a", size)
    from .cloudsc import erosion_program, mini_cloudsc_program

    nproma, klev = (128, 137) if size == "bench" else (8, 5)
    if name == "erosion":
        return erosion_program(nproma=nproma, klev=4 if size == "mini" else klev)
    return mini_cloudsc_program(nproma=nproma, klev=klev)


def task_key(fp: str) -> str:
    """Filesystem-safe id for a nest fingerprint (started-marker filename)."""
    return hashlib.md5(fp.encode()).hexdigest()


def _task_program(task: dict) -> Program:
    """The task's program: carried directly (in-process modes) or rebuilt
    from registry coordinates (spawn workers)."""
    prog = task.get("program")
    if prog is not None:
        return prog
    return build_program(task["source"], task["name"], task.get("size", "mini"),
                         kwargs=task.get("builder_kwargs"))


def _worker_preamble(task: dict) -> None:
    """Started marker + injected-fault execution, shared by both workers."""
    scratch = task.get("scratch")
    if scratch:
        # started marker: if this worker dies, the supervisor can tell the
        # tasks that were actually running from the ones the pool never got
        # to (only the former are charged a retry attempt)
        (Path(scratch) / task_key(task["fingerprint"])).touch()
    fault = task.get("fault")  # injected by the parent's FaultPlan
    if fault == "crash":
        os._exit(3)  # hard kill, like a segfaulting kernel build
    if fault == "hang":
        time.sleep(float(task.get("hang_s", 3600.0)))
    if fault == "error":
        raise FaultInjected(
            f"injected worker error for {task['name']} nest {task['nest_index']}")


def tune_nest_task(task: dict) -> dict:
    """Pool worker: epoch-1 search for one canonical nest (offline CLI).

    Rebuilds and re-normalizes the program — the pass pipeline is
    deterministic, so ``nest_index`` addresses the same canonical nest the
    parent enumerated (the fingerprint check below enforces it).
    """
    _worker_preamble(task)
    prog = _task_program(task)
    d = Daisy(backend=task["backend"])
    p = d._normalized(prog)
    nest = p.body[task["nest_index"]]
    # fail fast, before the search burns its compile+measure budget
    if fingerprint(nest) != task["fingerprint"]:
        raise RuntimeError(
            f"normalization diverged between parent and worker for "
            f"{task['name']} nest {task['nest_index']}"
        )
    fp, emb, recipe, t, prov = d.seed_nest(
        p, nest, search=task["search"], search_iterations=task["iterations"],
        population=task["population"], repeats=task["repeats"],
        deadline_s=task.get("deadline_s"),
    )
    return {"fingerprint": fp, "embedding": np.asarray(emb).tolist(),
            "recipe": recipe.to_json(), "measured_us": t, "provenance": prov}


def online_search_task(task: dict) -> dict:
    """Pool worker for one *online* search: measure the incumbent recipe,
    then run the deadline-bounded epoch-1 search — both under the lowering
    the deployment backend executes — and report candidate vs incumbent.

    The same supervision (started markers, injected faults, retries,
    quarantine) applies as to :func:`tune_nest_task`; the extra fields in
    the result (``incumbent_us``, ``incumbent``, ``program_key``) feed the
    :class:`SwapPolicy` decision in the parent.
    """
    _worker_preamble(task)
    prog = _task_program(task)
    d = Daisy(backend=task["backend"])
    p = d._normalized(prog)
    nest = p.body[task["nest_index"]]
    if fingerprint(nest) != task["fingerprint"]:
        raise RuntimeError(
            f"normalization diverged between parent and worker for "
            f"{task['name']} nest {task['nest_index']}"
        )
    item = d._prepare_nest(p, nest, source=f"online:{task['name']}")
    inc = (Recipe.from_json(task["incumbent"]) if task.get("incumbent")
           else item.seed_recipe)
    repeats = int(task.get("repeats", 3))
    incumbent_us = d._measure_item(item, inc, repeats)
    recipe, t, prov = d._epoch1_item(
        item, True, int(task.get("iterations", 2)),
        int(task.get("population", 4)), repeats,
        deadline_s=task.get("deadline_s"))
    return {"fingerprint": item.fingerprint,
            "embedding": np.asarray(item.embedding).tolist(),
            "recipe": recipe.to_json(), "measured_us": t, "provenance": prov,
            "incumbent": inc.to_json(), "incumbent_us": incumbent_us,
            "name": task["name"], "nest_index": task["nest_index"],
            "program_key": task.get("program_key", "")}


class PoolStall(RuntimeError):
    """No task completed within the progress timeout — workers presumed hung."""


def run_supervised(
    tasks: list[dict],
    jobs: int,
    verbose: bool,
    on_result=None,
    task_timeout_s: float | None = None,
    max_task_retries: int = 1,
    retry_backoff_s: float = 0.0,
    fault_plan: FaultPlan | None = None,
    worker=tune_nest_task,
) -> tuple[list[dict], dict[str, str]]:
    """Run per-nest searches under supervision (the PR-7 pool).

    Returns ``(results, quarantined)`` where ``quarantined`` maps nest
    fingerprints that exhausted their retries to a reason string.
    ``on_result(task, result)`` fires as each nest lands (checkpoint hook).
    ``worker`` is the task function (:func:`tune_nest_task` offline,
    :func:`online_search_task` for deployment searches) — it must be a
    module-level callable so the spawn pool can pickle it.
    """
    results: list[dict] = []
    quarantined: dict[str, str] = {}
    policies: dict[str, RestartPolicy] = {}

    def policy(fp: str) -> RestartPolicy:
        return policies.setdefault(fp, RestartPolicy(
            max_restarts=max_task_retries, backoff_s=retry_backoff_s))

    def emit(t: dict, r: dict) -> None:
        results.append(r)
        if on_result is not None:
            on_result(t, r)
        if verbose:
            print(f"  [{len(results)}/{len(tasks)}] {t['name']} "
                  f"nest {t['nest_index']} -> {r['recipe']['kind']} "
                  f"({r['measured_us']:.0f}us)", flush=True)

    def charge(t: dict, exc: BaseException) -> bool:
        """One failed attempt: True -> retry, False -> quarantined."""
        fp = t["fingerprint"]
        if policy(fp).should_restart(exc):
            if verbose:
                print(f"  retry {t['name']} nest {t['nest_index']} "
                      f"(attempt {policies[fp].restarts + 1}): {exc}", flush=True)
            return True
        quarantined[fp] = (f"{t['name']} nest {t['nest_index']}: {exc} "
                           f"(after {policies[fp].restarts} attempt(s))")
        if verbose:
            print(f"  QUARANTINED {t['name']} nest {t['nest_index']}: {exc}",
                  flush=True)
        return False

    def consult(t: dict) -> dict:
        """Parent-side fault-plan consult: embed a picklable fault kind
        (dropping any stale kind from a previous attempt — a consumed fault
        must not replay on the retry)."""
        t = {k: v for k, v in t.items() if k != "fault"}
        if fault_plan is None:
            return t
        f = fault_plan.fire("tune.worker", key=t["fingerprint"])
        if f is not None:
            t["fault"] = f.kind
        return t

    if jobs <= 1 or len(tasks) <= 1:
        # in-process path: worker-kill faults cannot be executed literally
        # (they would kill the run itself) — every injected kind raises and
        # goes through the same retry/quarantine accounting
        todo = deque(tasks)
        while todo:
            t = consult(todo.popleft())
            try:
                if t.get("fault"):
                    raise FaultInjected(
                        f"injected {t['fault']} for {t['name']} "
                        f"nest {t['nest_index']}")
                r = worker(t)
            except Exception as e:  # noqa: BLE001 — supervised retry
                if charge(t, e):
                    todo.append(t)
                continue
            emit(t, r)
        return results, quarantined

    # spawn, not fork: workers must initialize their own JAX runtime rather
    # than inherit the parent's (forked XLA thread pools deadlock)
    ctx = get_context("spawn")
    remaining = list(tasks)
    # a pool-wide breakage cannot name its culprit: every started task in
    # the round is a suspect.  Suspects re-run SOLO (one per round) so the
    # next crash charges exactly the poison nest and co-started innocents
    # succeed instead of being quarantined by association.
    suspects: deque[dict] = deque()
    with tempfile.TemporaryDirectory(prefix="repro-tune-") as scratch:
        while remaining or suspects:
            if suspects:
                src = [suspects.popleft()]
            else:
                src, remaining = remaining, []
            round_tasks = []
            for t in src:
                t = consult(dict(t, scratch=scratch))
                (Path(scratch) / task_key(t["fingerprint"])).unlink(missing_ok=True)
                round_tasks.append(t)
            lost: list[dict] = []
            broken: BaseException | None = None
            ex = ProcessPoolExecutor(max_workers=min(jobs, len(round_tasks)),
                                     mp_context=ctx)
            futs = {ex.submit(worker, t): t for t in round_tasks}
            pending = set(futs)
            try:
                while pending:
                    done, pending = wait(pending, timeout=task_timeout_s,
                                         return_when=FIRST_COMPLETED)
                    if not done:
                        raise PoolStall(
                            f"no task completed within {task_timeout_s}s — "
                            f"killing {len(pending)} in-flight worker(s)")
                    for f in done:
                        t = futs[f]
                        try:
                            r = f.result()
                        except BrokenProcessPool as e:
                            broken = e
                            lost.append(t)
                            continue
                        except Exception as e:  # noqa: BLE001 — worker raised
                            if charge(t, e):
                                remaining.append(t)
                            continue
                        emit(t, r)
                    if broken is not None:
                        raise broken
            except (BrokenProcessPool, PoolStall) as e:
                broken = e
                lost.extend(futs[f] for f in pending)
                # hung/orphaned workers never exit on their own — kill them
                # so shutdown does not block behind a sleeping process
                for p in list(getattr(ex, "_processes", {}).values()):
                    try:
                        p.terminate()
                    except Exception:  # noqa: BLE001
                        pass
                ex.shutdown(wait=False, cancel_futures=True)
            else:
                ex.shutdown()
            if broken is not None:
                started = [t for t in lost
                           if (Path(scratch) / task_key(t["fingerprint"])).exists()]
                never_started = [t for t in lost if t not in started]
                if not started:
                    # nothing even began before the pool died: the pool
                    # itself is the problem, not a poison task — charge
                    # everyone so a permanently-broken pool still terminates
                    started, never_started = never_started, []
                for t in started:
                    if charge(t, broken):
                        suspects.append(t)
                remaining.extend(never_started)
                if verbose:
                    print(f"  pool lost ({broken}); salvaged {len(results)} "
                          f"result(s), {len(suspects)} suspect(s) to isolate, "
                          f"{len(remaining)} task(s) requeued", flush=True)
    return results, quarantined


# ---------------------------------------------------------------------------
# live telemetry
# ---------------------------------------------------------------------------

@dataclass
class NestStat:
    ema_s: float = 0.0
    count: int = 0
    total_s: float = 0.0
    last_s: float = 0.0


class NestTelemetry:
    """Per-key EMA wall times from real deployment steps.

    Keys are program fingerprints (``ServingEngine`` observes its logit
    pipeline's) or free-form labels (``Trainer`` step timings).  A disabled
    instance returns from ``observe`` before touching any state — the
    telemetry hook in a tuner-less engine/trainer costs one predicate per
    step.  All methods run on the observing (serving) thread; the
    supervisor reads from the same thread at its poll points, so no lock
    is needed.
    """

    def __init__(self, alpha: float = 0.25, enabled: bool = True):
        self.alpha = float(alpha)
        self.enabled = bool(enabled)
        self._stats: dict[str, NestStat] = {}

    def observe(self, key: str, seconds: float) -> None:
        if not self.enabled:
            return
        s = self._stats.get(key)
        if s is None:
            s = self._stats[key] = NestStat(ema_s=float(seconds))
        else:
            s.ema_s += self.alpha * (float(seconds) - s.ema_s)
        s.count += 1
        s.total_s += float(seconds)
        s.last_s = float(seconds)

    def ema(self, key: str) -> float | None:
        s = self._stats.get(key)
        return s.ema_s if s is not None else None

    def count(self, key: str) -> int:
        s = self._stats.get(key)
        return s.count if s is not None else 0

    def hottest(self, n: int = 1) -> list[tuple[str, float]]:
        """Keys ranked by accumulated wall time (the search-priority order:
        total time, not per-step time, is what adaptation can win back)."""
        ranked = sorted(self._stats.items(), key=lambda kv: -kv[1].total_s)
        return [(k, s.total_s) for k, s in ranked[: max(0, n)]]

    def reset(self, key: str) -> None:
        """Drop a key's stats (armed after a swap so the rollback watch
        compares post-swap observations only)."""
        self._stats.pop(key, None)

    def snapshot(self) -> dict[str, dict]:
        return {k: {"ema_s": s.ema_s, "count": s.count, "total_s": s.total_s,
                    "last_s": s.last_s}
                for k, s in self._stats.items()}


# ---------------------------------------------------------------------------
# a deployment-shaped tunable program (used by serving tests + bench_online;
# addressable from spawn workers as import:repro.autotune:logit_pipeline_program)
# ---------------------------------------------------------------------------

def logit_pipeline_program(vocab: int = 512, slots: int = 4,
                           name: str = "logit_pipeline") -> Program:
    """A canonical per-decode-step logit post-processing nest.

    Six elementwise stages over vocab-major ``(V, N)`` logits (per-token
    bias/scale/floor/bias/gain/cap against per-vocab vectors — the shape of
    real serving logit processors: penalties, temperature-like scaling,
    clamping).  Two properties make it the online-tuning demo nest:

    * **recipe-sensitive**: vocab-major layout puts the size-``V`` loop
      outermost, so the ``sequential`` recipe lowers to a ``fori_loop``
      over the whole vocabulary while ``vectorize`` fuses the chain into a
      handful of vector ops — an order-of-magnitude gap at serving shapes;
    * **bit-stable**: no multiply feeds an add anywhere in the chain (the
      stages alternate add / multiply / max / min), so XLA's FMA
      contraction cannot fire on the vectorized path and every legal
      lowering produces bit-identical outputs — hot-swapping recipes never
      changes a served token.

    Engine convention: the logits enter through input ``X`` of shape
    ``(vocab, batch_slots)`` and the processed logits leave through output
    ``Y`` of the same shape; every other input array is a deployment
    operand (``ServingEngine`` zero-fills the ones not given).
    """
    v, n = int(vocab), int(slots)

    def _xp(t):
        import jax.numpy as jnp

        return np if isinstance(t, (float, np.floating, np.ndarray)) else jnp

    c1 = Computation("bias", acc("T1", "v", "n"),
                     (acc("X", "v", "n"), acc("B", "v")), lambda x, b: x + b)
    c2 = Computation("scale", acc("T2", "v", "n"),
                     (acc("T1", "v", "n"), acc("S", "v")), lambda t, s: t * s)
    c3 = Computation("floor", acc("T3", "v", "n"),
                     (acc("T2", "v", "n"), acc("F", "v")),
                     lambda t, f: _xp(t).maximum(t, f))
    c4 = Computation("shift", acc("T4", "v", "n"),
                     (acc("T3", "v", "n"), acc("C", "v")), lambda t, c: t + c)
    c5 = Computation("gain", acc("T5", "v", "n"),
                     (acc("T4", "v", "n"), acc("G", "v")), lambda t, g: t * g)
    c6 = Computation("cap", acc("Y", "v", "n"),
                     (acc("T5", "v", "n"), acc("K", "v")),
                     lambda t, k: _xp(t).minimum(t, k))
    body = (Loop("v", v, body=(Loop("n", n, body=(c1, c2, c3, c4, c5, c6)),)),)
    arrays = (
        Array("X", (v, n)), Array("B", (v,)), Array("S", (v,)),
        Array("F", (v,)), Array("C", (v,)), Array("G", (v,)),
        Array("K", (v,)),
        Array("T1", (v, n)), Array("T2", (v, n)), Array("T3", (v, n)),
        Array("T4", (v, n)), Array("T5", (v, n)), Array("Y", (v, n)),
    )
    return Program(name, arrays, body,
                   temps=("T1", "T2", "T3", "T4", "T5"))


# ---------------------------------------------------------------------------
# swap policy + supervisor
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SwapPolicy:
    """When an online candidate may replace the incumbent recipe.

    ``margin``: the candidate's measured time must beat the incumbent's by
    this fraction (``cand * (1 + margin) < incumbent``) — hot-swapping for
    measurement noise would thrash the jit caches.  ``validate`` runs the
    candidate through ``fault.compile_with_degradation`` (compile AND
    execute once per backend rung) against a staged copy of the database
    before anything touches the live one.  ``rollback_ratio`` /
    ``rollback_window``: after a swap, if the telemetry EMA over the next
    ``rollback_window`` observations exceeds ``rollback_ratio`` x the
    pre-swap EMA, the swap is rolled back and the nest quarantined.
    ``min_observations`` keeps cold programs from being searched on no
    evidence.
    """

    margin: float = 0.1
    validate: bool = True
    validate_backends: tuple[str, ...] | None = None
    rollback_ratio: float = 1.5
    rollback_window: int = 8
    min_observations: int = 4

    def accepts(self, candidate_us: float, incumbent_us: float) -> bool:
        if not math.isfinite(candidate_us):
            return False
        if not math.isfinite(incumbent_us):
            return True  # incumbent unmeasurable: any validated candidate wins
        return candidate_us * (1.0 + self.margin) < incumbent_us

    def chain_for(self, backend: str) -> tuple[str, ...]:
        """Validation backend rungs: the deployment backend, degrading to
        ``xla`` (the rung order ``compile_with_degradation`` walks)."""
        if self.validate_backends is not None:
            return self.validate_backends
        return (backend,) if backend == "xla" else (backend, "xla")


@dataclass
class SwapRecord:
    """One committed hot-swap (kept on ``SearchSupervisor.swaps``)."""

    program: str
    fingerprint: str
    old_recipe: Recipe | None
    new_recipe: Recipe
    candidate_us: float
    incumbent_us: float
    generation: int
    degraded_to: str | None = None
    rolled_back: bool = False


@dataclass
class _RegisteredProgram:
    key: str              # program fingerprint == telemetry key
    program: Program
    name: str
    tasks: list[dict] = field(default_factory=list)


class SearchSupervisor:
    """Online adaptive tuning: telemetry -> search -> validate -> swap ->
    fold back.

    Owns the deployment's live :class:`TuningDatabase` and a
    :class:`NestTelemetry`; engines/trainers attach by passing the
    supervisor as ``tuner=`` (``ServingEngine`` registers its logit
    pipeline, observes step timings into ``tuner.telemetry``, and calls
    ``maybe_launch()`` / ``poll()`` every ``check_every`` steps).

    ``mode``: ``'thread'`` (default) supervises searches on a daemon
    thread so serving never blocks; ``'sync'`` runs them inline at the
    poll point (deterministic — tests, benchmarks); ``'spawn'`` fans them
    across the supervised process pool (requires ``builder`` coordinates
    at ``register`` time, since IR lambdas do not pickle).  All three run
    the same :func:`run_supervised` machinery, so crashes / hangs /
    repeated failures retry then quarantine instead of surfacing.
    """

    def __init__(
        self,
        db: TuningDatabase,
        backend: str = "xla",
        policy: SwapPolicy | None = None,
        telemetry: NestTelemetry | None = None,
        mode: str = "thread",
        jobs: int = 2,
        iterations: int = 2,
        population: int = 4,
        repeats: int = 3,
        deadline_s: float | None = 30.0,
        check_every: int = 16,
        task_timeout_s: float | None = None,
        max_task_retries: int = 1,
        fault_plan: FaultPlan | None = None,
        verbose: bool = False,
    ):
        if mode not in ("sync", "thread", "spawn"):
            raise ValueError(f"mode must be sync|thread|spawn, got {mode!r}")
        self.db = db
        self.backend = backend
        self.policy = policy or SwapPolicy()
        self.telemetry = telemetry or NestTelemetry()
        self.mode = mode
        self.jobs = jobs
        self.iterations = iterations
        self.population = population
        self.repeats = repeats
        self.deadline_s = deadline_s
        self.check_every = max(1, int(check_every))
        self.task_timeout_s = task_timeout_s
        self.max_task_retries = max_task_retries
        self.fault_plan = fault_plan
        self.verbose = verbose
        self.swaps: list[SwapRecord] = []
        self.rejected: list[dict] = []
        self.quarantined: dict[str, str] = {}
        self.degradations: list[tuple[str, str, str]] = []
        self._scout = Daisy(backend=backend)
        self._registered: dict[str, _RegisteredProgram] = {}
        self._results: queue.Queue = queue.Queue()
        self._quarantines: deque[dict[str, str]] = deque()
        self._thread: threading.Thread | None = None
        self._inflight: set[str] = set()
        self._searched: set[str] = set()
        self._watch: dict[str, dict] = {}

    # -- registration ------------------------------------------------------
    def register(self, program: Program, builder: dict | None = None) -> str:
        """Make a deployment program tunable; returns its telemetry key
        (the program fingerprint — what the attached engine observes under).

        ``builder`` gives registry coordinates for spawn workers, e.g.
        ``{"source": "import", "name": "repro.autotune:logit_pipeline_program",
        "builder_kwargs": {"vocab": 512, "slots": 4}}``; without it the
        program object itself rides in the task (in-process modes only).
        """
        key = program_fingerprint(program)
        if key in self._registered:
            return key
        if self.mode == "spawn" and builder is None:
            raise ValueError(
                "spawn mode needs builder coordinates (IR lambdas do not "
                "pickle): register(program, builder={'source': ..., 'name': ...})")
        name = getattr(program, "name", "program")
        p = self._scout._normalized(program)
        tasks: list[dict] = []
        for i, nest in enumerate(p.body):
            fp = fingerprint(nest)
            inc = self.db.lookup_exact(fp)
            t: dict = {
                "name": name, "nest_index": i, "backend": self.backend,
                "fingerprint": fp, "iterations": self.iterations,
                "population": self.population, "repeats": self.repeats,
                "deadline_s": self.deadline_s, "program_key": key,
                "incumbent": inc.to_json() if inc is not None else None,
            }
            if builder is not None:
                t.update(builder)
            if self.mode != "spawn":
                t["program"] = program
            tasks.append(t)
        self._registered[key] = _RegisteredProgram(key, program, name, tasks)
        return key

    # -- search lifecycle --------------------------------------------------
    def maybe_launch(self) -> int:
        """Launch searches for the hottest registered program with unsearched
        nests (at most one search round in flight); returns tasks launched."""
        if self._thread is not None and self._thread.is_alive():
            return 0
        self._thread = None
        for key, _heat in self.telemetry.hottest(max(1, len(self._registered))):
            reg = self._registered.get(key)
            if reg is None:
                continue
            if self.telemetry.count(key) < self.policy.min_observations:
                continue
            tasks = [t for t in reg.tasks
                     if t["fingerprint"] not in self._searched
                     and t["fingerprint"] not in self._inflight
                     and t["fingerprint"] not in self.quarantined]
            if tasks:
                return self._launch(tasks)
        return 0

    def _launch(self, tasks: list[dict]) -> int:
        for t in tasks:
            self._inflight.add(t["fingerprint"])
        # refresh incumbents at launch (a previous swap may have changed them)
        staged = []
        for t in tasks:
            inc = self.db.lookup_exact(t["fingerprint"])
            staged.append(dict(t, incumbent=inc.to_json() if inc else None))

        def work() -> None:
            try:
                _, quarantined = run_supervised(
                    staged, jobs=(self.jobs if self.mode == "spawn" else 1),
                    verbose=self.verbose,
                    on_result=lambda _t, r: self._results.put(r),
                    task_timeout_s=self.task_timeout_s,
                    max_task_retries=self.max_task_retries,
                    fault_plan=self.fault_plan, worker=online_search_task)
            except Exception as e:  # noqa: BLE001 — supervisor must survive
                quarantined = {t["fingerprint"]: f"search round died: {e}"
                               for t in staged}
            if quarantined:
                self._quarantines.append(quarantined)

        if self.mode == "sync":
            work()
        else:
            self._thread = threading.Thread(
                target=work, daemon=True, name="repro-autotune")
            self._thread.start()
        return len(staged)

    @property
    def busy(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def poll(self, engine=None) -> list[SwapRecord]:
        """Drain finished searches, apply the swap policy, check rollback
        watches; returns the swaps committed this call.  ``engine`` (when
        given) receives validation degradations on ``engine.degradations``.
        """
        while self._quarantines:
            for fp, reason in self._quarantines.popleft().items():
                self.quarantined[fp] = reason
                self._inflight.discard(fp)
        applied: list[SwapRecord] = []
        while True:
            try:
                r = self._results.get_nowait()
            except queue.Empty:
                break
            rec = self._consider(r, engine)
            if rec is not None:
                applied.append(rec)
        self._check_rollbacks()
        return applied

    # -- swap decision -----------------------------------------------------
    def _consider(self, r: dict, engine=None) -> SwapRecord | None:
        fp = r["fingerprint"]
        self._inflight.discard(fp)
        self._searched.add(fp)
        cand = Recipe.from_json(r["recipe"])
        inc = Recipe.from_json(r["incumbent"]) if r.get("incumbent") else None
        cand_us = float(r["measured_us"])
        inc_us = float(r.get("incumbent_us", float("inf")))
        pname = r.get("name", "?")

        def reject(reason: str) -> None:
            self.rejected.append({
                "fingerprint": fp, "program": pname, "reason": reason,
                "candidate_us": cand_us, "incumbent_us": inc_us,
                "candidate": cand.to_json()})

        if cand == inc:
            reject("no-win: search returned the incumbent")
            return None
        if not self.policy.accepts(cand_us, inc_us):
            reject(f"margin: {cand_us:.0f}us does not beat "
                   f"{inc_us:.0f}us by {self.policy.margin:.0%}")
            return None
        degraded_to = None
        reg = self._registered.get(r.get("program_key", ""))
        if self.policy.validate and reg is not None:
            ok, degraded_to, err = self._validate(reg.program, fp, cand, r)
            if not ok:
                reject(f"validation: {err}")
                return None
            if degraded_to is not None:
                sink = engine.degradations if engine is not None \
                    else self.degradations
                sink.append((pname, self.backend, degraded_to))
        prev = self._commit(fp, cand, cand_us, r)
        rec = SwapRecord(pname, fp, inc, cand, cand_us, inc_us,
                         generation=self.db.generation,
                         degraded_to=degraded_to)
        self.swaps.append(rec)
        self._arm_watch(fp, r.get("program_key", ""), prev, rec)
        return rec

    def _validate(self, program: Program, fp: str, cand: Recipe,
                  r: dict) -> tuple[bool, str | None, str | None]:
        """Compile + execute-once the program with the candidate staged in a
        scratch database — the live one is untouched until commit."""
        from .fault import compile_with_degradation

        emb = np.asarray(r.get("embedding", []), dtype=np.float64)
        val_db = TuningDatabase(radius=self.db.radius)
        replaced = False
        for e in self.db.entries:
            if e.fingerprint == fp:
                val_db.entries.append(Entry(fp, emb, cand, "online-candidate"))
                replaced = True
            else:
                val_db.entries.append(e)
        if not replaced:
            val_db.entries.append(Entry(fp, emb, cand, "online-candidate"))
        val_db._reindex()
        try:
            res = compile_with_degradation(
                program, backends=self.policy.chain_for(self.backend),
                db=val_db, fault_plan=self.fault_plan)
        except Exception as e:  # noqa: BLE001 — every rung failed
            return False, None, str(e)
        return True, (res.backend if res.degraded else None), None

    def _commit(self, fp: str, cand: Recipe, cand_us: float, r: dict):
        """Write the validated winner into the live database (generation
        bump = the hot swap: deployment jit-cache keys carry the
        generation, so the next step resolves the new recipe).  Returns the
        previous entry contents for rollback, or None for a fresh entry."""
        prov = r.get("provenance", "online")
        if self.db.lookup_exact(fp) is None:
            emb = np.asarray(r.get("embedding", []), dtype=np.float64)
            self.db.add(fp, emb, cand, provenance=prov, measured_us=cand_us)
            return None
        # replace_entry, not add: the incumbent may carry a stale *smaller*
        # measurement from the machine it was tuned on — live-validated
        # measurements taken here outrank it unconditionally
        return self.db.replace_entry(fp, cand, measured_us=cand_us,
                                     provenance=prov)

    # -- rollback ----------------------------------------------------------
    def _arm_watch(self, fp: str, key: str, prev, rec: SwapRecord) -> None:
        pre = self.telemetry.ema(key)
        self.telemetry.reset(key)  # the watch compares post-swap steps only
        self._watch[fp] = {"key": key, "pre_ema_s": pre, "prev": prev,
                           "record": rec}

    def _check_rollbacks(self) -> None:
        for fp, w in list(self._watch.items()):
            if self.telemetry.count(w["key"]) < self.policy.rollback_window:
                continue
            post, pre = self.telemetry.ema(w["key"]), w["pre_ema_s"]
            del self._watch[fp]
            if pre is not None and post is not None \
                    and post > self.policy.rollback_ratio * pre:
                self._rollback(fp, w, post, pre)

    def _rollback(self, fp: str, w: dict, post: float, pre: float) -> None:
        """The candidate won its isolated measurement but regressed live:
        restore the incumbent verbatim (generation bump un-swaps the jitted
        fns) and quarantine the nest against re-searching."""
        prev = w["prev"]
        if prev is not None:
            self.db.replace_entry(fp, prev[0], measured_us=prev[1],
                                  provenance=prev[2])
        else:
            self.db.entries[:] = [e for e in self.db.entries
                                  if e.fingerprint != fp]
            self.db.reindex()
        w["record"].rolled_back = True
        self.quarantined[fp] = (
            f"rolled back: post-swap EMA {post * 1e6:.0f}us > "
            f"{self.policy.rollback_ratio:.2f}x pre-swap {pre * 1e6:.0f}us")
        if self.verbose:
            print(f"  ROLLBACK {fp[:50]}: {self.quarantined[fp]}", flush=True)

    # -- fleet fold-back ---------------------------------------------------
    def fold_back(self, path: str | Path) -> dict[str, int]:
        """Merge this deployment's database (online winners included) into
        the fleet database file at ``path`` — atomic checksummed
        ``merge()`` + ``save()``, so concurrent fold-backs from several
        deployments compose and a reader never sees a torn file.  Returns
        the merge report ``{'added': n, 'improved': n, 'kept': n}``.
        """
        path = Path(path)
        disk = TuningDatabase.load(path) if path.exists() else TuningDatabase()
        report = disk.merge(self.db)
        n_swaps = sum(1 for s in self.swaps if not s.rolled_back)
        if n_swaps:
            disk.meta["online_swaps"] = int(
                disk.meta.get("online_swaps", 0)) + n_swaps
        path.parent.mkdir(parents=True, exist_ok=True)
        disk.save(path)
        return report
