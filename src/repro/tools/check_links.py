"""Check intra-repo markdown links (paths + heading anchors).

Usage:
    PYTHONPATH=src python -m repro.tools.check_links README.md docs

Each argument is a markdown file or a directory (scanned for ``*.md``).
Every inline link or image target is resolved relative to the file that
contains it: external schemes (http/https/mailto) are skipped, relative
paths must exist inside the repository, and ``#fragment`` anchors must
match a heading of the target file under GitHub's slugification rules
(lowercase, punctuation stripped, spaces to hyphens).  Exits nonzero with
one line per broken link — the docs CI job runs this over ``docs/`` and
the README so cross-references cannot rot silently.
"""
from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

# inline links/images: [text](target) / ![alt](target); ignores ```code``` via
# a fence-stripping pre-pass rather than regex heroics
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def _strip_fences(text: str) -> str:
    """Drop fenced code blocks so example snippets don't register links."""
    out, fenced = [], False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            fenced = not fenced
            continue
        if not fenced:
            out.append(line)
    return "\n".join(out)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug of one heading line."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return re.sub(r" ", "-", h)


def heading_slugs(path: Path) -> set[str]:
    """All anchor slugs a markdown file exposes (with -1/-2 dedup suffixes)."""
    slugs: set[str] = set()
    counts: dict[str, int] = {}
    for m in _HEADING_RE.finditer(_strip_fences(path.read_text())):
        base = slugify(m.group(1))
        n = counts.get(base, 0)
        counts[base] = n + 1
        slugs.add(base if n == 0 else f"{base}-{n}")
    return slugs


def check_file(path: Path, repo_root: Path) -> list[str]:
    """Broken-link descriptions for one markdown file (empty when clean)."""
    errors: list[str] = []
    text = _strip_fences(path.read_text())
    for m in _LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(_EXTERNAL) or target.startswith("<"):
            continue
        frag = ""
        if "#" in target:
            target, frag = target.split("#", 1)
        dest = path if not target else (path.parent / target).resolve()
        if not dest.exists():
            errors.append(f"{path}: broken link -> {m.group(1)}")
            continue
        try:  # links may not escape the repository
            dest.relative_to(repo_root)
        except ValueError:
            errors.append(f"{path}: link escapes repo -> {m.group(1)}")
            continue
        if frag:
            if dest.is_dir() or dest.suffix.lower() not in (".md", ".markdown"):
                errors.append(f"{path}: anchor on non-markdown -> {m.group(1)}")
            elif frag.lower() not in heading_slugs(dest):
                errors.append(f"{path}: missing anchor -> {m.group(1)}")
    return errors


def collect(args: list[str]) -> list[Path]:
    """Expand file/directory arguments into the markdown files to check."""
    files: list[Path] = []
    for a in args:
        p = Path(a)
        if p.is_dir():
            files.extend(sorted(p.glob("*.md")))
        elif p.exists():
            files.append(p)
        else:
            raise SystemExit(f"check_links: no such file or directory: {a}")
    return files


def main(argv: list[str] | None = None) -> int:
    """CLI entry point; returns the number of broken links."""
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("paths", nargs="+",
                    help="markdown files or directories of *.md")
    args = ap.parse_args(argv)
    repo_root = Path.cwd().resolve()
    errors: list[str] = []
    files = collect(args.paths)
    for f in files:
        errors.extend(check_file(f, repo_root))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_links: {len(files)} file(s), {len(errors)} broken link(s)")
    return len(errors)


if __name__ == "__main__":
    raise SystemExit(min(main(), 1))
