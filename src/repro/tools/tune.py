"""Offline transfer tuning: seed the recipe database from the A variants.

Usage:
    PYTHONPATH=src python -m repro.tools.tune --suite polybench --size mini
    PYTHONPATH=src python -m repro.tools.tune --suite all --size bench \
        --backend xla --jobs 2 --out data/pretuned_xla.json

Runs ``Daisy.seed``'s evolutionary search (paper §4, "Seeding a Scheduling
Database") over the selected suite — the PolyBench A variants and/or the two
CLOUDSC programs — fanning the per-nest epoch-1 searches across a process
pool, then runs the cross-nest transfer epoch (the paper's epochs 2-3) in
the parent and persists the database.

Re-running against an existing ``--out`` composes: the file is loaded
first, already-tuned fingerprints are skipped, and new results merge in
(per fingerprint the better-measured recipe wins).  The written file is
what ``Daisy.pretuned(backend=...)`` loads at deployment time.
"""
from __future__ import annotations

import argparse
import os
import time
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from multiprocessing import get_context
from pathlib import Path

import numpy as np

from ..core import Daisy, Program, TuningDatabase, fingerprint
from ..core.database import pretuned_dir
from ..core.recipes import Recipe

SUITES = ("polybench", "cloudsc", "all")
BACKENDS = ("xla", "pallas_interpret", "pallas")


def program_specs(suite: str, names: list[str] | None = None) -> list[tuple[str, str]]:
    """(source, name) coordinates of every program the suite tunes."""
    specs: list[tuple[str, str]] = []
    if suite in ("polybench", "all"):
        from ..polybench import BENCHMARKS

        sel = names or list(BENCHMARKS)
        unknown = [n for n in sel if n not in BENCHMARKS]
        if unknown:
            raise SystemExit(
                f"unknown benchmark(s) {', '.join(unknown)}; "
                f"valid: {', '.join(BENCHMARKS)}"
            )
        specs += [("polybench", n) for n in sel]
    if suite in ("cloudsc", "all"):
        specs += [("cloudsc", "erosion"), ("cloudsc", "scheme")]
    return specs


def build_program(source: str, name: str, size: str) -> Program:
    """Rebuild a program from its registry coordinates (IR computations hold
    lambdas, which do not pickle — workers reconstruct instead of receiving)."""
    if source == "polybench":
        from ..polybench import BENCHMARKS

        return BENCHMARKS[name].make("a", size)
    from ..cloudsc import erosion_program, mini_cloudsc_program

    nproma, klev = (128, 137) if size == "bench" else (8, 5)
    if name == "erosion":
        return erosion_program(nproma=nproma, klev=4 if size == "mini" else klev)
    return mini_cloudsc_program(nproma=nproma, klev=klev)


def _tune_nest(task: dict) -> dict:
    """Process-pool worker: epoch-1 search for one canonical nest.

    Rebuilds and re-normalizes the program — the pass pipeline is
    deterministic, so ``nest_index`` addresses the same canonical nest the
    parent enumerated (the fingerprint check below enforces it).
    """
    prog = build_program(task["source"], task["name"], task["size"])
    d = Daisy(backend=task["backend"])
    p = d._normalized(prog)
    nest = p.body[task["nest_index"]]
    # fail fast, before the search burns its compile+measure budget
    if fingerprint(nest) != task["fingerprint"]:
        raise RuntimeError(
            f"normalization diverged between parent and worker for "
            f"{task['name']} nest {task['nest_index']}"
        )
    fp, emb, recipe, t, prov = d.seed_nest(
        p, nest, search=task["search"], search_iterations=task["iterations"],
        population=task["population"], repeats=task["repeats"],
    )
    return {"fingerprint": fp, "embedding": np.asarray(emb).tolist(),
            "recipe": recipe.to_json(), "measured_us": t, "provenance": prov}


def _run_tasks(tasks: list[dict], jobs: int, verbose: bool) -> list[dict]:
    if jobs <= 1 or len(tasks) <= 1:
        out = []
        for i, t in enumerate(tasks):
            r = _tune_nest(t)
            if verbose:
                print(f"  [{i + 1}/{len(tasks)}] {t['name']} nest {t['nest_index']}"
                      f" -> {r['recipe']['kind']} ({r['measured_us']:.0f}us)")
            out.append(r)
        return out
    # spawn, not fork: workers must initialize their own JAX runtime rather
    # than inherit the parent's (forked XLA thread pools deadlock)
    ctx = get_context("spawn")
    results: list[dict] = []
    with ProcessPoolExecutor(max_workers=jobs, mp_context=ctx) as ex:
        futs = {ex.submit(_tune_nest, t): t for t in tasks}
        pending = set(futs)
        while pending:
            done, pending = wait(pending, return_when=FIRST_COMPLETED)
            for f in done:
                t = futs[f]
                r = f.result()
                if verbose:
                    print(f"  [{len(results) + 1}/{len(tasks)}] {t['name']} "
                          f"nest {t['nest_index']} -> {r['recipe']['kind']} "
                          f"({r['measured_us']:.0f}us)", flush=True)
                results.append(r)
    return results


def tune(
    suite: str = "all",
    size: str = "mini",
    backend: str = "xla",
    out: str | Path | None = None,
    names: list[str] | None = None,
    jobs: int = 1,
    iterations: int = 2,
    population: int = 4,
    repeats: int = 3,
    search: bool = True,
    transfer: bool = True,
    verbose: bool = True,
) -> tuple[TuningDatabase, Path]:
    """Tune the suite and persist/merge the database at ``out``."""
    out = Path(out) if out is not None else pretuned_dir() / f"pretuned_{backend}.json"
    db = TuningDatabase.load(out) if out.exists() else TuningDatabase()
    before = len(db.entries)

    # enumerate distinct canonical nests (normalization is pure IR work —
    # no JAX computation runs in the parent before the pool spins up)
    scout = Daisy(backend=backend)
    specs = program_specs(suite, names)
    progs: list[Program] = []
    tasks: list[dict] = []
    seen: set[str] = set()
    for source, name in specs:
        prog = build_program(source, name, size)
        progs.append(prog)
        p = scout._normalized(prog)
        for i, nest in enumerate(p.body):
            fp = fingerprint(nest)
            if fp in seen or db.lookup_exact(fp) is not None:
                continue
            seen.add(fp)
            tasks.append({
                "source": source, "name": name, "size": size, "nest_index": i,
                "backend": backend, "search": search, "iterations": iterations,
                "population": population, "repeats": repeats, "fingerprint": fp,
            })
    if verbose:
        print(f"tuning {len(tasks)} nests ({len(specs)} programs, suite={suite}, "
              f"size={size}, backend={backend}, jobs={jobs}, "
              f"{before} entries already tuned)")

    # epoch 1, fanned across the pool
    t0 = time.perf_counter()
    for r in _run_tasks(tasks, jobs, verbose):
        if not np.isfinite(r["measured_us"]):
            # every candidate lowering failed for this nest: ship no entry
            # (plan() falls back to the default recipe) rather than an
            # unvalidated recipe with an inf measurement
            print(f"  WARNING: no measurable lowering for {r['provenance']} "
                  f"({r['fingerprint'][:50]}); skipped")
            continue
        db.add(r["fingerprint"], np.asarray(r["embedding"]),
               Recipe.from_json(r["recipe"]),
               provenance=r["provenance"], measured_us=r["measured_us"])

    # epochs 2-3 (cross-nest transfer) need the merged database: run in the
    # parent, restricted to this run's nests so incremental runs compose
    if transfer and search and tasks:
        d = Daisy(db=db, backend=backend)
        n = d.transfer_epoch(progs, fingerprints=seen, repeats=repeats)
        if verbose:
            print(f"transfer epoch re-seeded {n} nests")

    # last run's coordinates at the top level, full history under "runs"
    # (incremental runs compose — a single suite/size would misdescribe
    # a database tuned across several)
    run_rec = {
        "suite": suite, "size": size, "backend": backend,
        "tuned_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "search_iterations": iterations, "population": population,
        "nests_tuned": len(tasks),
    }
    db.meta.update(run_rec)
    db.meta.setdefault("runs", []).append(run_rec)
    out.parent.mkdir(parents=True, exist_ok=True)
    db.save(out)
    if verbose:
        s = db.summary()
        print(f"wrote {out} in {time.perf_counter() - t0:.0f}s: "
              f"{s['entries']} entries (+{s['entries'] - before}), "
              f"{s['measured']} measured")
        print(f"  kinds: {s['kinds']}")
        print(f"  provenance: {s['provenance']}")
    return db, out


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--suite", default="all", choices=SUITES)
    ap.add_argument("--size", default="mini", choices=["mini", "bench"])
    ap.add_argument("--backend", default="xla", choices=BACKENDS,
                    help="measure under the lowering this backend executes")
    ap.add_argument("--out", default=None,
                    help="database path (default: data/pretuned_<backend>.json; "
                         "an existing file is merged into, not overwritten)")
    ap.add_argument("--names", default=None,
                    help="comma-separated polybench subset (e.g. gemm,bicg)")
    ap.add_argument("--jobs", type=int, default=None,
                    help="process-pool width for the per-nest searches "
                         "(default: min(4, cpu count); <=1 runs in-process)")
    ap.add_argument("--iterations", type=int, default=2,
                    help="evolutionary search iterations per nest (epoch 1)")
    ap.add_argument("--population", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timing repeats per candidate measurement")
    ap.add_argument("--no-search", dest="search", action="store_false",
                    help="analytic seeding only (idiom default recipes, measured)")
    ap.add_argument("--no-transfer", dest="transfer", action="store_false",
                    help="skip the cross-nest transfer epoch")
    args = ap.parse_args(argv)
    jobs = args.jobs if args.jobs is not None else min(4, os.cpu_count() or 1)
    tune(
        suite=args.suite, size=args.size, backend=args.backend, out=args.out,
        names=args.names.split(",") if args.names else None, jobs=jobs,
        iterations=args.iterations, population=args.population,
        repeats=args.repeats, search=args.search, transfer=args.transfer,
    )


if __name__ == "__main__":
    main()
