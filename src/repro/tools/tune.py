"""Offline transfer tuning: seed the recipe database from the A variants.

Usage:
    PYTHONPATH=src python -m repro.tools.tune --suite polybench --size mini
    PYTHONPATH=src python -m repro.tools.tune --suite all --size bench \
        --backend xla --jobs 2 --out data/pretuned_xla.json

Runs ``Daisy.seed``'s evolutionary search (paper §4, "Seeding a Scheduling
Database") over the selected suite — the PolyBench A variants and/or the two
CLOUDSC programs — fanning the per-nest epoch-1 searches across a process
pool, then runs the cross-nest transfer epoch (the paper's epochs 2-3) in
the parent and persists the database.

Re-running against an existing ``--out`` composes: the file is loaded
first, already-tuned fingerprints are skipped, and new results merge in
(per fingerprint the better-measured recipe wins).  The written file is
what ``Daisy.pretuned(backend=...)`` loads at deployment time.

The pool is supervised (a long tuning run must survive its own workers):

  * every completed nest is **checkpointed** into ``--out`` as it lands, so
    a crashed run loses nothing already measured and a re-run resumes from
    the crash point (the normal skip-tuned-fingerprints resume path);
  * a worker death (``BrokenProcessPool``) or a stall (no completion within
    ``--task-timeout``) kills the pool, salvages the finished results, and
    retries the started-but-unfinished tasks with bounded backoff
    (``RestartPolicy``); tasks the dead pool never started are requeued
    free of charge (started-marker files in a scratch dir tell them apart);
  * a nest that keeps killing workers is **quarantined** by fingerprint —
    recorded under ``meta["quarantined"]`` in the database and skipped by
    future runs until ``--retry-quarantined``.

Deterministic fault injection: a ``fault.FaultPlan`` with site
``tune.worker`` (key = nest fingerprint) makes the matching worker crash
(``os._exit``), hang, or raise — how the supervision above is tested.
"""
from __future__ import annotations

import argparse
import hashlib
import os
import tempfile
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from concurrent.futures.process import BrokenProcessPool
from multiprocessing import get_context
from pathlib import Path

import numpy as np

from ..core import Daisy, Program, TuningDatabase, fingerprint
from ..core.database import pretuned_dir
from ..core.recipes import Recipe
from ..fault import FaultInjected, FaultPlan, RestartPolicy

SUITES = ("polybench", "cloudsc", "all")
BACKENDS = ("xla", "pallas_interpret", "pallas")


def program_specs(suite: str, names: list[str] | None = None) -> list[tuple[str, str]]:
    """(source, name) coordinates of every program the suite tunes."""
    specs: list[tuple[str, str]] = []
    if suite in ("polybench", "all"):
        from ..polybench import BENCHMARKS

        sel = names or list(BENCHMARKS)
        unknown = [n for n in sel if n not in BENCHMARKS]
        if unknown:
            raise SystemExit(
                f"unknown benchmark(s) {', '.join(unknown)}; "
                f"valid: {', '.join(BENCHMARKS)}"
            )
        specs += [("polybench", n) for n in sel]
    if suite in ("cloudsc", "all"):
        specs += [("cloudsc", "erosion"), ("cloudsc", "scheme")]
    return specs


def build_program(source: str, name: str, size: str) -> Program:
    """Rebuild a program from its registry coordinates (IR computations hold
    lambdas, which do not pickle — workers reconstruct instead of receiving)."""
    if source == "polybench":
        from ..polybench import BENCHMARKS

        return BENCHMARKS[name].make("a", size)
    from ..cloudsc import erosion_program, mini_cloudsc_program

    nproma, klev = (128, 137) if size == "bench" else (8, 5)
    if name == "erosion":
        return erosion_program(nproma=nproma, klev=4 if size == "mini" else klev)
    return mini_cloudsc_program(nproma=nproma, klev=klev)


def _task_key(fp: str) -> str:
    """Filesystem-safe id for a nest fingerprint (started-marker filename)."""
    return hashlib.md5(fp.encode()).hexdigest()


def _tune_nest(task: dict) -> dict:
    """Process-pool worker: epoch-1 search for one canonical nest.

    Rebuilds and re-normalizes the program — the pass pipeline is
    deterministic, so ``nest_index`` addresses the same canonical nest the
    parent enumerated (the fingerprint check below enforces it).
    """
    scratch = task.get("scratch")
    if scratch:
        # started marker: if this worker dies, the supervisor can tell the
        # tasks that were actually running from the ones the pool never got
        # to (only the former are charged a retry attempt)
        (Path(scratch) / _task_key(task["fingerprint"])).touch()
    fault = task.get("fault")  # injected by the parent's FaultPlan
    if fault == "crash":
        os._exit(3)  # hard kill, like a segfaulting kernel build
    if fault == "hang":
        time.sleep(float(task.get("hang_s", 3600.0)))
    if fault == "error":
        raise FaultInjected(
            f"injected worker error for {task['name']} nest {task['nest_index']}")
    prog = build_program(task["source"], task["name"], task["size"])
    d = Daisy(backend=task["backend"])
    p = d._normalized(prog)
    nest = p.body[task["nest_index"]]
    # fail fast, before the search burns its compile+measure budget
    if fingerprint(nest) != task["fingerprint"]:
        raise RuntimeError(
            f"normalization diverged between parent and worker for "
            f"{task['name']} nest {task['nest_index']}"
        )
    fp, emb, recipe, t, prov = d.seed_nest(
        p, nest, search=task["search"], search_iterations=task["iterations"],
        population=task["population"], repeats=task["repeats"],
    )
    return {"fingerprint": fp, "embedding": np.asarray(emb).tolist(),
            "recipe": recipe.to_json(), "measured_us": t, "provenance": prov}


class _PoolStall(RuntimeError):
    """No task completed within the progress timeout — workers presumed hung."""


def _run_tasks(
    tasks: list[dict],
    jobs: int,
    verbose: bool,
    on_result=None,
    task_timeout_s: float | None = None,
    max_task_retries: int = 1,
    retry_backoff_s: float = 0.0,
    fault_plan: FaultPlan | None = None,
) -> tuple[list[dict], dict[str, str]]:
    """Run the epoch-1 searches under supervision.

    Returns ``(results, quarantined)`` where ``quarantined`` maps nest
    fingerprints that exhausted their retries to a reason string.
    ``on_result(task, result)`` fires as each nest lands (checkpoint hook).
    """
    results: list[dict] = []
    quarantined: dict[str, str] = {}
    policies: dict[str, RestartPolicy] = {}

    def policy(fp: str) -> RestartPolicy:
        return policies.setdefault(fp, RestartPolicy(
            max_restarts=max_task_retries, backoff_s=retry_backoff_s))

    def emit(t: dict, r: dict) -> None:
        results.append(r)
        if on_result is not None:
            on_result(t, r)
        if verbose:
            print(f"  [{len(results)}/{len(tasks)}] {t['name']} "
                  f"nest {t['nest_index']} -> {r['recipe']['kind']} "
                  f"({r['measured_us']:.0f}us)", flush=True)

    def charge(t: dict, exc: BaseException) -> bool:
        """One failed attempt: True -> retry, False -> quarantined."""
        fp = t["fingerprint"]
        if policy(fp).should_restart(exc):
            if verbose:
                print(f"  retry {t['name']} nest {t['nest_index']} "
                      f"(attempt {policies[fp].restarts + 1}): {exc}", flush=True)
            return True
        quarantined[fp] = (f"{t['name']} nest {t['nest_index']}: {exc} "
                           f"(after {policies[fp].restarts} attempt(s))")
        if verbose:
            print(f"  QUARANTINED {t['name']} nest {t['nest_index']}: {exc}",
                  flush=True)
        return False

    def consult(t: dict) -> dict:
        """Parent-side fault-plan consult: embed a picklable fault kind
        (dropping any stale kind from a previous attempt — a consumed fault
        must not replay on the retry)."""
        t = {k: v for k, v in t.items() if k != "fault"}
        if fault_plan is None:
            return t
        f = fault_plan.fire("tune.worker", key=t["fingerprint"])
        if f is not None:
            t["fault"] = f.kind
        return t

    if jobs <= 1 or len(tasks) <= 1:
        # in-process path: worker-kill faults cannot be executed literally
        # (they would kill the run itself) — every injected kind raises and
        # goes through the same retry/quarantine accounting
        queue = deque(tasks)
        while queue:
            t = consult(queue.popleft())
            try:
                if t.get("fault"):
                    raise FaultInjected(
                        f"injected {t['fault']} for {t['name']} "
                        f"nest {t['nest_index']}")
                r = _tune_nest(t)
            except Exception as e:  # noqa: BLE001 — supervised retry
                if charge(t, e):
                    queue.append(t)
                continue
            emit(t, r)
        return results, quarantined

    # spawn, not fork: workers must initialize their own JAX runtime rather
    # than inherit the parent's (forked XLA thread pools deadlock)
    ctx = get_context("spawn")
    remaining = list(tasks)
    # a pool-wide breakage cannot name its culprit: every started task in
    # the round is a suspect.  Suspects re-run SOLO (one per round) so the
    # next crash charges exactly the poison nest and co-started innocents
    # succeed instead of being quarantined by association.
    suspects: deque[dict] = deque()
    with tempfile.TemporaryDirectory(prefix="repro-tune-") as scratch:
        while remaining or suspects:
            if suspects:
                src = [suspects.popleft()]
            else:
                src, remaining = remaining, []
            round_tasks = []
            for t in src:
                t = consult(dict(t, scratch=scratch))
                (Path(scratch) / _task_key(t["fingerprint"])).unlink(missing_ok=True)
                round_tasks.append(t)
            lost: list[dict] = []
            broken: BaseException | None = None
            ex = ProcessPoolExecutor(max_workers=min(jobs, len(round_tasks)),
                                     mp_context=ctx)
            futs = {ex.submit(_tune_nest, t): t for t in round_tasks}
            pending = set(futs)
            try:
                while pending:
                    done, pending = wait(pending, timeout=task_timeout_s,
                                         return_when=FIRST_COMPLETED)
                    if not done:
                        raise _PoolStall(
                            f"no task completed within {task_timeout_s}s — "
                            f"killing {len(pending)} in-flight worker(s)")
                    for f in done:
                        t = futs[f]
                        try:
                            r = f.result()
                        except BrokenProcessPool as e:
                            broken = e
                            lost.append(t)
                            continue
                        except Exception as e:  # noqa: BLE001 — worker raised
                            if charge(t, e):
                                remaining.append(t)
                            continue
                        emit(t, r)
                    if broken is not None:
                        raise broken
            except (BrokenProcessPool, _PoolStall) as e:
                broken = e
                lost.extend(futs[f] for f in pending)
                # hung/orphaned workers never exit on their own — kill them
                # so shutdown does not block behind a sleeping process
                for p in list(getattr(ex, "_processes", {}).values()):
                    try:
                        p.terminate()
                    except Exception:  # noqa: BLE001
                        pass
                ex.shutdown(wait=False, cancel_futures=True)
            else:
                ex.shutdown()
            if broken is not None:
                started = [t for t in lost
                           if (Path(scratch) / _task_key(t["fingerprint"])).exists()]
                never_started = [t for t in lost if t not in started]
                if not started:
                    # nothing even began before the pool died: the pool
                    # itself is the problem, not a poison task — charge
                    # everyone so a permanently-broken pool still terminates
                    started, never_started = never_started, []
                for t in started:
                    if charge(t, broken):
                        suspects.append(t)
                remaining.extend(never_started)
                if verbose:
                    print(f"  pool lost ({broken}); salvaged {len(results)} "
                          f"result(s), {len(suspects)} suspect(s) to isolate, "
                          f"{len(remaining)} task(s) requeued", flush=True)
    return results, quarantined


def tune(
    suite: str = "all",
    size: str = "mini",
    backend: str = "xla",
    out: str | Path | None = None,
    names: list[str] | None = None,
    jobs: int = 1,
    iterations: int = 2,
    population: int = 4,
    repeats: int = 3,
    search: bool = True,
    transfer: bool = True,
    verbose: bool = True,
    task_timeout_s: float | None = None,
    max_task_retries: int = 1,
    retry_quarantined: bool = False,
    checkpoint: bool = True,
    fault_plan: FaultPlan | None = None,
) -> tuple[TuningDatabase, Path]:
    """Tune the suite and persist/merge the database at ``out``."""
    out = Path(out) if out is not None else pretuned_dir() / f"pretuned_{backend}.json"
    db = TuningDatabase.load(out) if out.exists() else TuningDatabase()
    before = len(db.entries)
    if retry_quarantined:
        db.meta.pop("quarantined", None)
    quarantine_meta: dict = db.meta.get("quarantined", {})

    # enumerate distinct canonical nests (normalization is pure IR work —
    # no JAX computation runs in the parent before the pool spins up)
    scout = Daisy(backend=backend)
    specs = program_specs(suite, names)
    progs: list[Program] = []
    tasks: list[dict] = []
    seen: set[str] = set()
    skipped_quarantined = 0
    for source, name in specs:
        prog = build_program(source, name, size)
        progs.append(prog)
        p = scout._normalized(prog)
        for i, nest in enumerate(p.body):
            fp = fingerprint(nest)
            if fp in seen or db.lookup_exact(fp) is not None:
                continue
            if fp in quarantine_meta:
                skipped_quarantined += 1
                continue
            seen.add(fp)
            tasks.append({
                "source": source, "name": name, "size": size, "nest_index": i,
                "backend": backend, "search": search, "iterations": iterations,
                "population": population, "repeats": repeats, "fingerprint": fp,
            })
    if verbose:
        quar = (f", {skipped_quarantined} quarantined"
                if skipped_quarantined else "")
        print(f"tuning {len(tasks)} nests ({len(specs)} programs, suite={suite}, "
              f"size={size}, backend={backend}, jobs={jobs}, "
              f"{before} entries already tuned{quar})")

    out.parent.mkdir(parents=True, exist_ok=True)

    def accept(r: dict) -> bool:
        if not np.isfinite(r["measured_us"]):
            # every candidate lowering failed for this nest: ship no entry
            # (plan() falls back to the default recipe) rather than an
            # unvalidated recipe with an inf measurement
            print(f"  WARNING: no measurable lowering for {r['provenance']} "
                  f"({r['fingerprint'][:50]}); skipped")
            return False
        db.add(r["fingerprint"], np.asarray(r["embedding"]),
               Recipe.from_json(r["recipe"]),
               provenance=r["provenance"], measured_us=r["measured_us"])
        return True

    def on_result(t: dict, r: dict) -> None:
        if accept(r) and checkpoint:
            # in-run checkpoint: a completed nest survives any later pool
            # loss, and a re-run against --out resumes past it
            db.save(out)

    # epoch 1, fanned across the pool under supervision
    t0 = time.perf_counter()
    _, quarantined = _run_tasks(
        tasks, jobs, verbose, on_result=on_result,
        task_timeout_s=task_timeout_s, max_task_retries=max_task_retries,
        fault_plan=fault_plan,
    )
    if quarantined:
        q = db.meta.setdefault("quarantined", {})
        for fp, reason in quarantined.items():
            q[fp] = {"reason": reason,
                     "at": time.strftime("%Y-%m-%dT%H:%M:%S")}

    # epochs 2-3 (cross-nest transfer) need the merged database: run in the
    # parent, restricted to this run's nests so incremental runs compose
    # (quarantined nests excluded — a recipe that kills workers must not be
    # re-run in the parent process)
    if transfer and search and tasks:
        d = Daisy(db=db, backend=backend)
        n = d.transfer_epoch(progs, fingerprints=seen - set(quarantined),
                             repeats=repeats)
        if verbose:
            print(f"transfer epoch re-seeded {n} nests")

    # last run's coordinates at the top level, full history under "runs"
    # (incremental runs compose — a single suite/size would misdescribe
    # a database tuned across several)
    run_rec = {
        "suite": suite, "size": size, "backend": backend,
        "tuned_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "search_iterations": iterations, "population": population,
        "nests_tuned": len(tasks),
    }
    if quarantined:
        run_rec["nests_quarantined"] = len(quarantined)
    db.meta.update(run_rec)
    db.meta.setdefault("runs", []).append(run_rec)
    db.save(out)
    if verbose:
        s = db.summary()
        print(f"wrote {out} in {time.perf_counter() - t0:.0f}s: "
              f"{s['entries']} entries (+{s['entries'] - before}), "
              f"{s['measured']} measured")
        print(f"  kinds: {s['kinds']}")
        print(f"  provenance: {s['provenance']}")
    return db, out


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--suite", default="all", choices=SUITES)
    ap.add_argument("--size", default="mini", choices=["mini", "bench"])
    ap.add_argument("--backend", default="xla", choices=BACKENDS,
                    help="measure under the lowering this backend executes")
    ap.add_argument("--out", default=None,
                    help="database path (default: data/pretuned_<backend>.json; "
                         "an existing file is merged into, not overwritten)")
    ap.add_argument("--names", default=None,
                    help="comma-separated polybench subset (e.g. gemm,bicg)")
    ap.add_argument("--jobs", type=int, default=None,
                    help="process-pool width for the per-nest searches "
                         "(default: min(4, cpu count); <=1 runs in-process)")
    ap.add_argument("--iterations", type=int, default=2,
                    help="evolutionary search iterations per nest (epoch 1)")
    ap.add_argument("--population", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timing repeats per candidate measurement")
    ap.add_argument("--no-search", dest="search", action="store_false",
                    help="analytic seeding only (idiom default recipes, measured)")
    ap.add_argument("--no-transfer", dest="transfer", action="store_false",
                    help="skip the cross-nest transfer epoch")
    ap.add_argument("--task-timeout", type=float, default=None,
                    help="progress timeout in seconds: if no nest completes "
                         "within it, in-flight workers are presumed hung, "
                         "killed and their tasks retried")
    ap.add_argument("--max-task-retries", type=int, default=1,
                    help="failed attempts per nest before it is quarantined")
    ap.add_argument("--retry-quarantined", action="store_true",
                    help="give nests recorded in meta['quarantined'] "
                         "another chance instead of skipping them")
    args = ap.parse_args(argv)
    jobs = args.jobs if args.jobs is not None else min(4, os.cpu_count() or 1)
    tune(
        suite=args.suite, size=args.size, backend=args.backend, out=args.out,
        names=args.names.split(",") if args.names else None, jobs=jobs,
        iterations=args.iterations, population=args.population,
        repeats=args.repeats, search=args.search, transfer=args.transfer,
        task_timeout_s=args.task_timeout,
        max_task_retries=args.max_task_retries,
        retry_quarantined=args.retry_quarantined,
    )


if __name__ == "__main__":
    main()
