"""Offline transfer tuning: seed the recipe database from the A variants.

Usage:
    PYTHONPATH=src python -m repro.tools.tune --suite polybench --size mini
    PYTHONPATH=src python -m repro.tools.tune --suite all --size bench \
        --backend xla --jobs 2 --out data/pretuned_xla.json

Runs ``Daisy.seed``'s evolutionary search (paper §4, "Seeding a Scheduling
Database") over the selected suite — the PolyBench A variants and/or the two
CLOUDSC programs — fanning the per-nest epoch-1 searches across a process
pool, then runs the cross-nest transfer epoch (the paper's epochs 2-3) in
the parent and persists the database.

Re-running against an existing ``--out`` composes: the file is loaded
first, already-tuned fingerprints are skipped, and new results merge in
(per fingerprint the better-measured recipe wins).  The written file is
what ``Daisy.pretuned(backend=...)`` loads at deployment time.

The pool is supervised (a long tuning run must survive its own workers):

  * every completed nest is **checkpointed** into ``--out`` as it lands, so
    a crashed run loses nothing already measured and a re-run resumes from
    the crash point (the normal skip-tuned-fingerprints resume path);
  * a worker death (``BrokenProcessPool``) or a stall (no completion within
    ``--task-timeout``) kills the pool, salvages the finished results, and
    retries the started-but-unfinished tasks with bounded backoff
    (``RestartPolicy``); tasks the dead pool never started are requeued
    free of charge (started-marker files in a scratch dir tell them apart);
  * a nest that keeps killing workers is **quarantined** by fingerprint —
    recorded under ``meta["quarantined"]`` in the database and skipped by
    future runs until ``--retry-quarantined``.

Deterministic fault injection: a ``fault.FaultPlan`` with site
``tune.worker`` (key = nest fingerprint) makes the matching worker crash
(``os._exit``), hang, or raise — how the supervision above is tested.

The search/measurement/supervision machinery itself lives in
``repro.autotune`` (shared with the online deployment tuner); this module
is the CLI orchestration plus compatibility aliases for the old names.
"""
from __future__ import annotations

import argparse
import os
import time
from pathlib import Path

import numpy as np

from ..autotune import (
    BACKENDS,
    SUITES,
    PoolStall,
    build_program,
    program_specs,
    run_supervised,
    task_key,
    tune_nest_task,
)
from ..core import Daisy, Program, TuningDatabase, fingerprint
from ..core.database import pretuned_dir
from ..core.recipes import Recipe
from ..fault import FaultPlan

# Pre-refactor names (tests and older callers import these from here).
_task_key = task_key
_tune_nest = tune_nest_task
_run_tasks = run_supervised
_PoolStall = PoolStall

__all__ = [
    "BACKENDS", "SUITES", "PoolStall", "build_program", "program_specs",
    "run_supervised", "task_key", "tune_nest_task", "tune", "main",
]


def tune(
    suite: str = "all",
    size: str = "mini",
    backend: str = "xla",
    out: str | Path | None = None,
    names: list[str] | None = None,
    jobs: int = 1,
    iterations: int = 2,
    population: int = 4,
    repeats: int = 3,
    search: bool = True,
    transfer: bool = True,
    verbose: bool = True,
    task_timeout_s: float | None = None,
    max_task_retries: int = 1,
    retry_quarantined: bool = False,
    checkpoint: bool = True,
    fault_plan: FaultPlan | None = None,
) -> tuple[TuningDatabase, Path]:
    """Tune the suite and persist/merge the database at ``out``."""
    out = Path(out) if out is not None else pretuned_dir() / f"pretuned_{backend}.json"
    db = TuningDatabase.load(out) if out.exists() else TuningDatabase()
    before = len(db.entries)
    if retry_quarantined:
        db.meta.pop("quarantined", None)
    quarantine_meta: dict = db.meta.get("quarantined", {})

    # enumerate distinct canonical nests (normalization is pure IR work —
    # no JAX computation runs in the parent before the pool spins up)
    scout = Daisy(backend=backend)
    specs = program_specs(suite, names)
    progs: list[Program] = []
    tasks: list[dict] = []
    seen: set[str] = set()
    skipped_quarantined = 0
    for source, name in specs:
        prog = build_program(source, name, size)
        progs.append(prog)
        p = scout._normalized(prog)
        for i, nest in enumerate(p.body):
            fp = fingerprint(nest)
            if fp in seen or db.lookup_exact(fp) is not None:
                continue
            if fp in quarantine_meta:
                skipped_quarantined += 1
                continue
            seen.add(fp)
            tasks.append({
                "source": source, "name": name, "size": size, "nest_index": i,
                "backend": backend, "search": search, "iterations": iterations,
                "population": population, "repeats": repeats, "fingerprint": fp,
            })
    if verbose:
        quar = (f", {skipped_quarantined} quarantined"
                if skipped_quarantined else "")
        print(f"tuning {len(tasks)} nests ({len(specs)} programs, suite={suite}, "
              f"size={size}, backend={backend}, jobs={jobs}, "
              f"{before} entries already tuned{quar})")

    out.parent.mkdir(parents=True, exist_ok=True)

    def accept(r: dict) -> bool:
        if not np.isfinite(r["measured_us"]):
            # every candidate lowering failed for this nest: ship no entry
            # (plan() falls back to the default recipe) rather than an
            # unvalidated recipe with an inf measurement
            print(f"  WARNING: no measurable lowering for {r['provenance']} "
                  f"({r['fingerprint'][:50]}); skipped")
            return False
        db.add(r["fingerprint"], np.asarray(r["embedding"]),
               Recipe.from_json(r["recipe"]),
               provenance=r["provenance"], measured_us=r["measured_us"])
        return True

    def on_result(t: dict, r: dict) -> None:
        if accept(r) and checkpoint:
            # in-run checkpoint: a completed nest survives any later pool
            # loss, and a re-run against --out resumes past it
            db.save(out)

    # epoch 1, fanned across the pool under supervision
    t0 = time.perf_counter()
    _, quarantined = run_supervised(
        tasks, jobs, verbose, on_result=on_result,
        task_timeout_s=task_timeout_s, max_task_retries=max_task_retries,
        fault_plan=fault_plan,
    )
    if quarantined:
        q = db.meta.setdefault("quarantined", {})
        for fp, reason in quarantined.items():
            q[fp] = {"reason": reason,
                     "at": time.strftime("%Y-%m-%dT%H:%M:%S")}

    # epochs 2-3 (cross-nest transfer) need the merged database: run in the
    # parent, restricted to this run's nests so incremental runs compose
    # (quarantined nests excluded — a recipe that kills workers must not be
    # re-run in the parent process)
    if transfer and search and tasks:
        d = Daisy(db=db, backend=backend)
        n = d.transfer_epoch(progs, fingerprints=seen - set(quarantined),
                             repeats=repeats)
        if verbose:
            print(f"transfer epoch re-seeded {n} nests")

    # last run's coordinates at the top level, full history under "runs"
    # (incremental runs compose — a single suite/size would misdescribe
    # a database tuned across several)
    run_rec = {
        "suite": suite, "size": size, "backend": backend,
        "tuned_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "search_iterations": iterations, "population": population,
        "nests_tuned": len(tasks),
    }
    if quarantined:
        run_rec["nests_quarantined"] = len(quarantined)
    db.meta.update(run_rec)
    db.meta.setdefault("runs", []).append(run_rec)
    db.save(out)
    if verbose:
        s = db.summary()
        print(f"wrote {out} in {time.perf_counter() - t0:.0f}s: "
              f"{s['entries']} entries (+{s['entries'] - before}), "
              f"{s['measured']} measured")
        print(f"  kinds: {s['kinds']}")
        print(f"  provenance: {s['provenance']}")
    return db, out


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--suite", default="all", choices=SUITES)
    ap.add_argument("--size", default="mini", choices=["mini", "bench"])
    ap.add_argument("--backend", default="xla", choices=BACKENDS,
                    help="measure under the lowering this backend executes")
    ap.add_argument("--out", default=None,
                    help="database path (default: data/pretuned_<backend>.json; "
                         "an existing file is merged into, not overwritten)")
    ap.add_argument("--names", default=None,
                    help="comma-separated polybench subset (e.g. gemm,bicg)")
    ap.add_argument("--jobs", type=int, default=None,
                    help="process-pool width for the per-nest searches "
                         "(default: min(4, cpu count); <=1 runs in-process)")
    ap.add_argument("--iterations", type=int, default=2,
                    help="evolutionary search iterations per nest (epoch 1)")
    ap.add_argument("--population", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timing repeats per candidate measurement")
    ap.add_argument("--no-search", dest="search", action="store_false",
                    help="analytic seeding only (idiom default recipes, measured)")
    ap.add_argument("--no-transfer", dest="transfer", action="store_false",
                    help="skip the cross-nest transfer epoch")
    ap.add_argument("--task-timeout", type=float, default=None,
                    help="progress timeout in seconds: if no nest completes "
                         "within it, in-flight workers are presumed hung, "
                         "killed and their tasks retried")
    ap.add_argument("--max-task-retries", type=int, default=1,
                    help="failed attempts per nest before it is quarantined")
    ap.add_argument("--retry-quarantined", action="store_true",
                    help="give nests recorded in meta['quarantined'] "
                         "another chance instead of skipping them")
    args = ap.parse_args(argv)
    jobs = args.jobs if args.jobs is not None else min(4, os.cpu_count() or 1)
    tune(
        suite=args.suite, size=args.size, backend=args.backend, out=args.out,
        names=args.names.split(",") if args.names else None, jobs=jobs,
        iterations=args.iterations, population=args.population,
        repeats=args.repeats, search=args.search, transfer=args.transfer,
        task_timeout_s=args.task_timeout,
        max_task_retries=args.max_task_retries,
        retry_quarantined=args.retry_quarantined,
    )


if __name__ == "__main__":
    main()
