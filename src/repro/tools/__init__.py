"""Developer tools: pass-pipeline introspection CLIs."""
