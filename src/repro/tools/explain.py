"""Explain what the compiler pass pipeline does to a program.

Usage:
    PYTHONPATH=src python -m repro.tools.explain gemm
    PYTHONPATH=src python -m repro.tools.explain cloudsc_erosion --no-fuse
    PYTHONPATH=src python -m repro.tools.explain saturation_chain --no-rewrite
    PYTHONPATH=src python -m repro.tools.explain 2mm --variant np --size bench --ir

Prints the per-pass report (wall time, nest/computation deltas, and every
custom stat a pass attached — fusion merge counts, LICM hoists and flop
deltas, expansion/CSE counts — rendered verbatim, never filtered to a
known-key list) followed by the canonical nests with their idiom
classification and the recipe the daisy scheduler would resolve for each.
"""
from __future__ import annotations

import argparse

from ..cloudsc import erosion_program, mini_cloudsc_program, saturation_chain_program
from ..core import Daisy
from ..core.ir import Loop, Program, loop_iterators, nest_computations
from ..polybench import BENCHMARKS

EXTRA = {
    "cloudsc_erosion": lambda size: erosion_program(
        nproma=128 if size == "bench" else 8, klev=137 if size == "bench" else 4
    ),
    "cloudsc_scheme": lambda size: mini_cloudsc_program(
        nproma=128 if size == "bench" else 8, klev=137 if size == "bench" else 5
    ),
    "saturation_chain": lambda size: saturation_chain_program(
        nproma=128 if size == "bench" else 8, klev=137 if size == "bench" else 5
    ),
}


def _describe_nest(nest, plan) -> str:
    if isinstance(nest, Loop):
        its = loop_iterators(nest)
        shape = "x".join(str(t) for t in _trips(nest, its))
        head = f"loops=({','.join(its)}) [{shape}]"
    else:
        head = "computation"
    comps = nest_computations(nest)
    return (
        f"{head} comps={len(comps)} idiom={plan.idiom} "
        f"recipe={plan.recipe.kind} source={plan.source}"
    )


def _trips(nest, its):
    trips = {}

    def rec(n):
        if isinstance(n, Loop):
            trips[n.iterator] = n.trip_count
            for b in n.body:
                rec(b)

    rec(nest)
    return [trips[i] for i in its]


def explain(program: Program, fuse: bool = True, rewrite: bool = True,
            show_ir: bool = False) -> str:
    """Render the per-pass report and canonical-nest plan for ``program``."""
    daisy = Daisy(fuse=fuse, rewrite=rewrite)
    ctx = daisy.explain(program, snapshots=show_ir)
    plan = daisy.plan(program)
    lines = [
        f"program {program.name}: {len(program.body)} authored nest(s) -> "
        f"{len(plan.program.body)} canonical kernel(s)",
        "",
        ctx.report(),
        "",
        "canonical nests:",
    ]
    for nest, np_ in zip(plan.program.body, plan.nests):
        lines.append("  " + _describe_nest(nest, np_))
    if show_ir:
        from ..core.ir import fingerprint

        lines += ["", "canonical IR fingerprints:"]
        for nest in plan.program.body:
            lines.append("  " + fingerprint(nest)[:120])
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("program",
                    help=f"polybench name ({', '.join(BENCHMARKS)}) or {', '.join(EXTRA)}")
    ap.add_argument("--variant", default="a", help="polybench variant: a | b | np")
    ap.add_argument("--size", default="mini", choices=["mini", "bench"])
    ap.add_argument("--no-fuse", dest="fuse", action="store_false",
                    help="stop after a priori normalization (no re-fusion)")
    ap.add_argument("--no-rewrite", dest="rewrite", action="store_false",
                    help="skip the expression rewrite passes (licm, "
                         "expand_factor, cse)")
    ap.add_argument("--ir", action="store_true", help="also print IR fingerprints")
    args = ap.parse_args()

    if args.program in EXTRA:
        prog = EXTRA[args.program](args.size)
    elif args.program in BENCHMARKS:
        prog = BENCHMARKS[args.program].make(args.variant, args.size)
    else:
        raise SystemExit(f"unknown program {args.program!r}")
    print(explain(prog, fuse=args.fuse, rewrite=args.rewrite, show_ir=args.ir))


if __name__ == "__main__":
    main()
