from .pipeline import DataConfig, LMDataPipeline  # noqa: F401
