"""Deterministic, shardable LM data pipeline.

Sources:
  * ``synthetic`` — seeded Zipf-ish token stream (self-contained training);
  * ``memmap``    — packed uint32 token files (np.memmap), the production path.

Properties the trainer relies on:
  * **Deterministic resume**: batch content is a pure function of
    ``(seed, step)`` — restoring a checkpoint at step k replays exactly the
    same stream (tested bit-exact in tests/test_train.py).
  * **Shardable**: ``shard_index/shard_count`` slice the global batch for
    per-host feeding on a real multi-host pod (each host feeds its local
    devices; jax.make_array_from_process_local_data assembles the global
    array).  On this 1-process container shard_count=1.
  * **Prefetch**: a background thread keeps ``prefetch`` batches ready.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from pathlib import Path

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    source: str = "synthetic"  # 'synthetic' | 'memmap'
    path: str | None = None    # token file for memmap
    seed: int = 0
    shard_index: int = 0
    shard_count: int = 1
    prefetch: int = 2


class LMDataPipeline:
    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.shard_count == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.shard_count
        if cfg.source == "memmap":
            assert cfg.path, "memmap source needs a token file"
            self._tokens = np.memmap(cfg.path, dtype=np.uint32, mode="r")
            assert len(self._tokens) > cfg.seq_len + 1
        else:
            self._tokens = None
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self._next_step = 0

    # -- pure batch function --------------------------------------------------
    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.shard_index])
        )
        b, s = self.local_batch, cfg.seq_len
        if self._tokens is None:
            # Zipf-distributed tokens: realistic embedding-gather skew
            toks = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
            toks = np.minimum(toks - 1, cfg.vocab - 1).astype(np.int32)
        else:
            n = len(self._tokens) - (s + 1)
            starts = rng.integers(0, n, size=(b,))
            toks = np.stack(
                [np.asarray(self._tokens[st : st + s + 1]) for st in starts]
            ).astype(np.int32)
            toks = np.minimum(toks, cfg.vocab - 1)
        return {"tokens": toks[:, :s], "labels": toks[:, 1:]}

    # -- prefetching iterator --------------------------------------------------
    def _worker(self) -> None:
        step = self._next_step
        while not self._stop.is_set():
            batch = self.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def start(self, step: int = 0) -> None:
        self.stop()
        self._next_step = step
        self._stop.clear()
        self._q = queue.Queue(maxsize=self.cfg.prefetch)
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def next(self) -> tuple[int, dict[str, np.ndarray]]:
        assert self._thread is not None, "call start() first"
        return self._q.get()

    def stop(self) -> None:
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=2.0)
            self._thread = None
