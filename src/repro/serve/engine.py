"""Batched serving engine: continuous-batching prefill + decode.

Static-shape design (TPU-friendly — no recompiles at runtime):
  * one jitted ``prefill`` (B, S_prompt) and one jitted ``decode`` (B, 1);
  * a fixed batch of request *slots*; finished slots are refilled from the
    queue and their cache rows reset (continuous batching without dynamic
    shapes: per-slot ``len`` vector + right-padded prompts);
  * greedy or temperature sampling.

The per-slot cache-length vector means a freshly admitted request coexists
with half-finished ones — the decode step masks per slot via its own length.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.cache import fingerprint_obj, jit_cache
from ..core.database import TuningDatabase
from ..models import model as M


@dataclass
class ServeConfig:
    batch_slots: int = 4
    max_len: int = 512
    max_new_tokens: int = 32
    temperature: float = 0.0
    seed: int = 0
    eos_id: int = -1  # -1: never stops early


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,)
    output: list[int] = field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Single-host engine; under pjit the same step functions shard over the
    mesh (batch -> data axis, heads/experts -> model axis)."""

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 tuning_db: TuningDatabase | None = None, mesh=None):
        """``mesh`` (any mesh with a ``model`` axis, e.g. from
        ``launch.mesh.make_mesh``) places the parameters with the sharding
        planner's specs (``launch.sharding.param_specs``) before the first
        jit — the decode step then partitions across the mesh via the
        committed shardings instead of running single-device."""
        from ..models.lowering import deployment_database

        self.cfg, self.params, self.scfg = cfg, params, scfg
        self.mesh = mesh
        if mesh is not None:
            from ..launch.sharding import param_specs

            shapes = jax.eval_shape(lambda p: p, params)
            self.params = jax.device_put(
                params, param_specs(shapes, mesh, cfg=cfg))
        # Deployments start warm: recipe resolution for this engine's
        # contractions runs against the shipped pretuned transfer database
        # (plus the canonical-GEMM model seed) unless the caller stages its
        # own tuning data.
        self.tuning_db = tuning_db if tuning_db is not None else deployment_database()
        # One jitted decode step per config *content*: re-created engines
        # with an equal config share the function and its jax trace cache,
        # so slot refills and engine restarts never retrace.
        self._decode = jit_cache.get_or_build(
            ("serve.decode", fingerprint_obj(cfg)),
            lambda: jax.jit(partial(M.decode_step, cfg)),
        )
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}
        self.rng = np.random.default_rng(scfg.seed)

    def submit(self, rid: int, prompt: np.ndarray) -> None:
        self.queue.append(Request(rid, np.asarray(prompt, np.int32)))

    def explain_kernels(self) -> str:
        """Pass-pipeline + contraction-plan report for this engine's config
        at its serving shape (ops introspection; content-cached so repeated
        calls and re-created engines share one pipeline run)."""
        from ..models.lowering import kernel_report

        return jit_cache.get_or_build(
            ("serve.kernel_report",
             fingerprint_obj(self.cfg, self.scfg.max_len, self.scfg.batch_slots),
             self.tuning_db.uid, self.tuning_db.generation),
            lambda: kernel_report(
                self.cfg, seq=self.scfg.max_len, batch=self.scfg.batch_slots,
                db=self.tuning_db,
            ),
        )

    # -- internals -------------------------------------------------------------
    def _prefill_one(self, req: Request, state_b1) -> Any:
        """Prefill a single request's row into a fresh (1, ...) state."""
        toks = req.prompt[None, :]  # (1, S)
        if self.cfg.family == "audio":
            # stub frontend: encoder memory from pseudo frame embeddings
            emb = jnp.zeros((1, self.cfg.frontend_len, self.cfg.d_model),
                            M._dtype(self.cfg))
            state_b1["memory"] = M.encode(self.cfg, self.params, emb)
        logits, state_b1 = self._decode(self.params, state_b1, jnp.asarray(toks))
        return logits[:, -1], state_b1

    def _sample(self, logits: jax.Array) -> int:
        lf = np.asarray(logits, np.float32)[0]
        if self.scfg.temperature <= 0.0:
            return int(lf.argmax())
        p = np.exp((lf - lf.max()) / self.scfg.temperature)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    # -- main loop ---------------------------------------------------------------
    def run(self) -> dict[int, list[int]]:
        """Drain the queue; returns rid -> generated tokens."""
        cfg, scfg = self.cfg, self.scfg
        results: dict[int, list[int]] = {}
        # simple slot loop: admit -> prefill -> decode until done
        while self.queue or self.active:
            # admit up to batch_slots requests (per-request states kept
            # separate; production path batches them — shapes are static)
            while self.queue and len(self.active) < scfg.batch_slots:
                req = self.queue.pop(0)
                state = M.init_decode_state(cfg, 1, scfg.max_len, ring=False)
                last_logits, state = self._prefill_one(req, state)
                req._state = state  # type: ignore[attr-defined]
                req._last = last_logits  # type: ignore[attr-defined]
                self.active[req.rid] = req
            # one decode step for every active request
            for rid in list(self.active):
                req = self.active[rid]
                tok = self._sample(req._last)  # type: ignore[attr-defined]
                req.output.append(tok)
                if (
                    len(req.output) >= scfg.max_new_tokens
                    or tok == scfg.eos_id
                ):
                    req.done = True
                    results[rid] = req.output
                    del self.active[rid]
                    continue
                logits, st = self._decode(
                    self.params, req._state, jnp.full((1, 1), tok, jnp.int32)
                )
                req._state = st  # type: ignore[attr-defined]
                req._last = logits[:, -1]  # type: ignore[attr-defined]
        return results
