"""Continuous-batching serving engine: request handles, batched decode,
pipelined dispatch.

Static-shape design (TPU-friendly — no recompiles at runtime):

  * ``submit(prompt)`` returns a :class:`RequestHandle` (``.done``,
    ``.tokens``, ``.result()``, optional per-token streaming callback);
    ``step()`` advances the engine one scheduling iteration and ``drain()``
    runs to completion.  ``run()`` survives as a deprecated wrapper.
  * one jitted **batched decode** over all ``batch_slots`` at once
    (``models.model.decode_slots``): every slot carries its own cache-length
    scalar, so a freshly admitted request coexists with half-finished ones
    and a slot refill never retraces — the jit cache key is config content
    and the traced shapes depend only on ``(batch_slots, max_len)``.
  * **shape-bucketed prefill admission**: prompts are right-padded to a
    small set of power-of-two buckets, so arrivals hit a handful of cached
    prefill traces instead of one per distinct prompt length.  Padded cache
    rows are causally masked (the slot's ``len`` is reset to the true prompt
    length) and overwritten as decode proceeds, so bucketing is bit-exact.
    Families with token-recurrent state (hybrid / ssm) prefill at exact
    length — a padded token would pollute the carried SSM state.
  * **pipelined dispatch**: greedy sampling is fused into the jitted step
    (on-device argmax feeding the next step's tokens), so step N+1 is
    dispatched while step N's tokens are still in flight; the host blocks
    only at harvest points, ``pipeline_depth`` steps behind the dispatch
    frontier.  Temperature sampling needs the logits on the host each step
    and therefore harvests synchronously.
"""
from __future__ import annotations

import warnings
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.database import TuningDatabase
from ..models import model as M


@dataclass
class ServeConfig:
    batch_slots: int = 4
    max_len: int = 512
    max_new_tokens: int = 32
    temperature: float = 0.0
    seed: int = 0
    eos_id: int = -1  # -1: never stops early
    # dispatch-ahead distance for the greedy path: how many batched steps may
    # be in flight before the host blocks on the oldest one's tokens
    pipeline_depth: int = 2
    min_bucket: int = 16  # smallest prefill bucket (powers of two upward)


def prefill_buckets(max_len: int, min_bucket: int = 16) -> tuple[int, ...]:
    """The padded prompt lengths prefill admission rounds up to: powers of
    two from ``min_bucket`` to ``max_len`` (``max_len`` itself always
    included so any prompt the cache can hold has a bucket)."""
    out: list[int] = []
    b = min_bucket
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


@dataclass(eq=False)
class RequestHandle:
    """A submitted request's live view: ``tokens`` grows as the engine
    harvests decode steps, ``done`` flips when eos / ``max_new_tokens`` is
    reached, and ``result()`` drives the engine until completion.  An
    ``on_token`` callback (``fn(handle, token)``) streams tokens as they
    are harvested."""

    rid: int
    prompt: np.ndarray
    tokens: list[int] = field(default_factory=list)
    done: bool = False
    on_token: Callable[["RequestHandle", int], None] | None = None
    _engine: "ServingEngine | None" = field(default=None, repr=False)

    def result(self) -> list[int]:
        """Block until this request completes (drives the owning engine's
        ``step()`` loop) and return the generated tokens."""
        while not self.done:
            if self._engine is None or self._engine.step() == 0 and not self.done:
                raise RuntimeError(f"request {self.rid} cannot complete: "
                                   "engine is idle")
        return self.tokens

    # -- engine-side bookkeeping ------------------------------------------
    def _append(self, tok: int, scfg: ServeConfig) -> None:
        self.tokens.append(tok)
        if self.on_token is not None:
            self.on_token(self, tok)
        if len(self.tokens) >= scfg.max_new_tokens or tok == scfg.eos_id:
            self.done = True


class ServingEngine:
    """Single-host continuous-batching engine; under pjit the same step
    functions shard over the mesh (batch -> data axis, heads/experts ->
    model axis).

    Lifecycle::

        eng = ServingEngine(cfg, params, ServeConfig(...))
        h = eng.submit(prompt)          # -> RequestHandle, queued
        eng.step()                      # admit + one batched decode + harvest
        eng.drain()                     # run to completion, {rid: tokens}
        h.result()                      # or drive until this handle is done
    """

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 tuning_db: TuningDatabase | None = None, mesh=None):
        """``mesh`` (any mesh with a ``model`` axis, e.g. from
        ``launch.mesh.make_mesh``) places the parameters with the sharding
        planner's specs before the first jit — the decode steps then
        partition across the mesh via the committed shardings instead of
        running single-device."""
        from ..models.lowering import deployment_context

        self.cfg, self.scfg = cfg, scfg
        # Shared deployment boilerplate (mesh placement + warm pretuned
        # tuning DB + fingerprint-keyed jit lookups) — same helper the
        # Trainer constructor uses.
        self._ctx = deployment_context(cfg, params, mesh=mesh,
                                       tuning_db=tuning_db)
        self.mesh = mesh
        self.params = self._ctx.params
        self.tuning_db = self._ctx.tuning_db
        # prefill (s >= 1) and slot-batched decode steps; content-keyed so
        # re-created engines with an equal config share the functions and
        # their jax trace caches — slot refills and restarts never retrace
        self._decode = self._ctx.jitted(
            "serve.decode", lambda: jax.jit(partial(M.decode_step, cfg)))
        self._step_greedy = self._ctx.jitted(
            "serve.decode_slots_greedy",
            lambda: jax.jit(partial(M.decode_slots_greedy, cfg)))
        self._step_logits = self._ctx.jitted(
            "serve.decode_slots", lambda: jax.jit(partial(M.decode_slots, cfg)))

        n = scfg.batch_slots
        self._buckets = prefill_buckets(scfg.max_len, scfg.min_bucket)
        self._states = M.init_slot_states(cfg, n, scfg.max_len)
        self._tokens = jnp.zeros((n,), jnp.int32)  # last sampled, per slot
        self._slots: list[RequestHandle | None] = [None] * n
        self._queue: deque[RequestHandle] = deque()
        # in-flight dispatched steps: (device tokens (N,), {slot: handle})
        self._pending: deque[tuple[Any, dict[int, RequestHandle]]] = deque()
        self.results: dict[int, list[int]] = {}
        self._next_rid = 0
        self.rng = np.random.default_rng(scfg.seed)

    # -- public API ------------------------------------------------------------
    def submit(self, prompt, _legacy_prompt=None, *, rid: int | None = None,
               on_token: Callable[[RequestHandle, int], None] | None = None,
               ) -> RequestHandle:
        """Queue a prompt; returns its :class:`RequestHandle`.

        The legacy positional form ``submit(rid, prompt)`` still works but
        is deprecated — pass the prompt first (an explicit id via ``rid=``).
        """
        if _legacy_prompt is not None:
            warnings.warn(
                "ServingEngine.submit(rid, prompt) is deprecated; use "
                "submit(prompt, rid=...) -> RequestHandle",
                DeprecationWarning, stacklevel=2)
            rid, prompt = int(prompt), _legacy_prompt
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError(f"prompt must be a non-empty 1-D token array, "
                             f"got shape {prompt.shape}")
        if prompt.size > self._buckets[-1]:
            raise ValueError(
                f"prompt length {prompt.size} exceeds the largest prefill "
                f"bucket {self._buckets[-1]} (max_len={self.scfg.max_len})")
        if prompt.size + self.scfg.max_new_tokens > self.scfg.max_len:
            raise ValueError(
                f"prompt length {prompt.size} + max_new_tokens "
                f"{self.scfg.max_new_tokens} exceeds max_len "
                f"{self.scfg.max_len} (the decode cache would overflow)")
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        h = RequestHandle(rid=rid, prompt=prompt, on_token=on_token,
                          _engine=self)
        self._queue.append(h)
        return h

    def step(self) -> int:
        """One scheduling iteration: harvest the mature in-flight step,
        admit queued requests into free slots, dispatch one batched decode
        over the occupied slots.  Returns the number of occupied slots
        after dispatch (0 = idle: queue empty, nothing in flight)."""
        scfg = self.scfg
        sync = scfg.temperature > 0.0
        depth = 0 if sync else max(0, scfg.pipeline_depth)
        self._admit()
        live = {i: h for i, h in enumerate(self._slots) if h is not None}
        if not live:
            while self._pending:
                self._harvest_one()
            return 0
        if sync:
            logits, self._states = self._step_logits(
                self.params, self._states, self._tokens)
            self._pending.append((logits, live))
        else:
            # pipelined: the sampled tokens stay on device and feed the next
            # dispatch; the host looks at them `pipeline_depth` steps later
            next_tok, self._states = self._step_greedy(
                self.params, self._states, self._tokens)
            self._tokens = next_tok
            self._pending.append((next_tok, live))
        # block on overdue steps: at most `depth` stay in flight (0 = the
        # host sees every step's result before dispatching the next)
        while len(self._pending) > depth:
            self._harvest_one()
        return len(live)

    def drain(self) -> dict[int, list[int]]:
        """Run until the queue and every slot are empty; returns
        ``rid -> generated tokens`` for every request finished so far."""
        while self._queue or self._pending or any(
                h is not None for h in self._slots):
            self.step()
        return self.results

    def run(self) -> dict[int, list[int]]:
        """Deprecated: drain the queue; returns rid -> generated tokens.

        Migration: ``submit(prompt)`` now returns a :class:`RequestHandle`
        — poll ``handle.done`` / read ``handle.tokens`` while calling
        ``engine.step()``, call ``handle.result()`` to block for one
        request, or ``engine.drain()`` for the old run-to-completion
        behaviour (same return value as ``run()``).
        """
        warnings.warn(
            "ServingEngine.run() is deprecated; use submit()/step()/drain() "
            "or RequestHandle.result()", DeprecationWarning, stacklevel=2)
        return self.drain()

    def explain_kernels(self) -> str:
        """Pass-pipeline + contraction-plan report for this engine's config
        at its serving shape (ops introspection; content-cached so repeated
        calls and re-created engines share one pipeline run)."""
        from ..models.lowering import kernel_report

        return self._ctx.jitted(
            "serve.kernel_report",
            lambda: kernel_report(
                self.cfg, seq=self.scfg.max_len, batch=self.scfg.batch_slots,
                db=self.tuning_db,
            ),
            self.scfg.max_len, self.scfg.batch_slots,
            self.tuning_db.uid, self.tuning_db.generation,
        )

    # -- internals -------------------------------------------------------------
    def _bucket_for(self, n: int) -> int:
        # token-recurrent families can't mask a padded prompt token out of
        # the carried state, so they prefill at exact length (still one
        # cached trace per *distinct* length — the pre-bucketing behaviour)
        if self.cfg.family in ("hybrid", "ssm"):
            return n
        return next(b for b in self._buckets if b >= n)

    def _prefill(self, h: RequestHandle):
        """Bucket-padded prefill of one request into a fresh b=1 state;
        returns (last-valid-position logits (V,), state)."""
        cfg, scfg = self.cfg, self.scfg
        s = int(h.prompt.size)
        bucket = self._bucket_for(s)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :s] = h.prompt
        state = M.init_decode_state(cfg, 1, scfg.max_len, ring=False)
        if cfg.family == "audio":
            # stub frontend: encoder memory from pseudo frame embeddings
            emb = jnp.zeros((1, cfg.frontend_len, cfg.d_model), M._dtype(cfg))
            state["memory"] = M.encode(cfg, self.params, emb)
        logits, state = self._decode(self.params, state, jnp.asarray(toks))
        # reset to the true length: the padded cache rows beyond it are
        # causally masked and get overwritten as decode proceeds
        state["len"] = jnp.asarray(s, jnp.int32)
        return logits[0, s - 1], state

    def _sample_host(self, logits) -> int:
        lf = np.asarray(logits, np.float32)
        if self.scfg.temperature <= 0.0:
            return int(lf.argmax())
        p = np.exp((lf - lf.max()) / self.scfg.temperature)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def _finish(self, h: RequestHandle) -> None:
        self.results[h.rid] = h.tokens

    def _admit(self) -> None:
        """Fill free slots from the queue: bucketed prefill, sample the
        first token, write the slot state."""
        while self._queue and None in self._slots:
            h = self._queue.popleft()
            last_logits, state = self._prefill(h)
            t0 = self._sample_host(last_logits)
            h._append(t0, self.scfg)
            if h.done:  # eos / max_new_tokens == 1: never occupies a slot
                self._finish(h)
                continue
            i = self._slots.index(None)
            self._slots[i] = h
            self._states = M.write_slot(self._states, i, state)
            self._tokens = self._tokens.at[i].set(t0)

    def _harvest_one(self) -> None:
        """Materialize the oldest in-flight step's tokens and credit them to
        the handles that occupied each slot at dispatch time.  This is the
        only point the host blocks on the device."""
        out, live = self._pending.popleft()
        arr = np.asarray(out)  # blocks until this step's results are ready
        for i, h in live.items():
            if h.done:  # finished in a younger harvest; overshoot dropped
                continue
            if arr.ndim == 1:  # greedy path: sampled tokens (N,)
                tok = int(arr[i])
            else:  # sync path: logits (N, V), sample on host
                tok = self._sample_host(arr[i])
                self._tokens = self._tokens.at[i].set(tok)
            h._append(tok, self.scfg)
            if h.done:
                self._finish(h)
                if self._slots[i] is h:
                    self._slots[i] = None
