"""Continuous-batching serving engine: request handles, batched decode,
pipelined dispatch, request-scoped fault isolation.

Static-shape design (TPU-friendly — no recompiles at runtime):

  * ``submit(prompt)`` returns a :class:`RequestHandle` (``.state``,
    ``.tokens``, ``.result()``, ``.cancel()``, optional per-token streaming
    callback); ``step()`` advances the engine one scheduling iteration and
    ``drain()`` runs to completion.  ``run()`` survives as a deprecated
    wrapper.
  * one jitted **batched decode** over all ``batch_slots`` at once
    (``models.model.decode_slots``): every slot carries its own cache-length
    scalar, so a freshly admitted request coexists with half-finished ones
    and a slot refill never retraces — the jit cache key is config content
    and the traced shapes depend only on ``(batch_slots, max_len)``.
  * **shape-bucketed prefill admission**: prompts are right-padded to a
    small set of power-of-two buckets, so arrivals hit a handful of cached
    prefill traces instead of one per distinct prompt length.  Padded cache
    rows are causally masked (the slot's ``len`` is reset to the true prompt
    length) and overwritten as decode proceeds, so bucketing is bit-exact.
    Families with token-recurrent state (hybrid / ssm) prefill at exact
    length — a padded token would pollute the carried SSM state.
  * **pipelined dispatch**: greedy sampling is fused into the jitted step
    (on-device argmax feeding the next step's tokens), so step N+1 is
    dispatched while step N's tokens are still in flight; the host blocks
    only at harvest points, ``pipeline_depth`` steps behind the dispatch
    frontier.  Temperature sampling needs the logits on the host each step
    and therefore harvests synchronously.

Failure model (request-scoped — one bad request never kills the batch):

  * a request whose prefill or harvest raises, or whose logits go
    non-finite (checked at prefill for every family and per slot on the
    synchronous sampling path), transitions to ``FAILED`` with the captured
    error; its slot is recycled and the surviving slots keep decoding
    token-for-token as if the failed request had hit eos.
  * ``submit(..., timeout_s=)`` arms a per-request deadline: overdue
    requests transition to ``TIMED_OUT`` at the next harvest (or while
    still queued), freeing their slot.
  * ``handle.cancel()`` withdraws a queued request or recycles a running
    one (``CANCELLED``); in-flight overshoot tokens are dropped at harvest.
  * a failure of the whole batched step fails the requests that occupied
    slots at dispatch time, but the engine itself stays serviceable.
  * ``compile_resilient`` is the hot-swap guardrail for tuned kernels: a
    candidate program is compiled *and validated* under each backend in
    order (``pallas`` → ``xla`` by default, via ``Daisy``'s backend
    degradation), so a broken Pallas build degrades to the XLA lowering
    instead of surfacing mid-traffic; degradations are recorded on
    ``engine.degradations``.

Deterministic fault injection (tests + ``bench_resilience``): pass a seeded
``fault.FaultPlan``; sites ``serve.prefill`` / ``serve.decode`` /
``serve.logits`` / ``serve.step`` poison exactly the scheduled requests.

Minimal serving loop::

    from repro.serve import ServeConfig, ServingEngine

    eng = ServingEngine(cfg, params, ServeConfig(batch_slots=8, max_len=512))
    h = eng.submit(prompt_tokens, on_token=lambda h, t: print(h.rid, t))
    while not h.done:          # or h.result() to block for this request,
        eng.step()             # or eng.drain() to run everything
    print(h.tokens)

Request states: ``QUEUED -> RUNNING -> {DONE, FAILED, TIMED_OUT,
CANCELLED}``; terminal handles expose ``.error`` and re-raise it from
``.result()``.  See ``docs/architecture.md`` (Deployment layers) for the
surrounding system and ``repro.autotune`` for the tuner hook.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from concurrent.futures import CancelledError
from dataclasses import dataclass, field
from enum import Enum
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.database import TuningDatabase
from ..fault import FaultInjected, FaultPlan
from ..models import model as M


class NonFiniteLogits(RuntimeError):
    """A request's logits went NaN/inf — numeric poison isolated to the one
    request instead of propagating through the batch."""


class RequestState(Enum):
    QUEUED = "queued"
    RUNNING = "running"
    COMPLETED = "completed"
    FAILED = "failed"
    TIMED_OUT = "timed_out"
    CANCELLED = "cancelled"


TERMINAL_STATES = frozenset(
    {RequestState.COMPLETED, RequestState.FAILED, RequestState.TIMED_OUT,
     RequestState.CANCELLED}
)


@dataclass
class ServeConfig:
    batch_slots: int = 4
    max_len: int = 512
    max_new_tokens: int = 32
    temperature: float = 0.0
    seed: int = 0
    eos_id: int = -1  # -1: never stops early
    # dispatch-ahead distance for the greedy path: how many batched steps may
    # be in flight before the host blocks on the oldest one's tokens
    pipeline_depth: int = 2
    min_bucket: int = 16  # smallest prefill bucket (powers of two upward)


def prefill_buckets(max_len: int, min_bucket: int = 16) -> tuple[int, ...]:
    """The padded prompt lengths prefill admission rounds up to: powers of
    two from ``min_bucket`` to ``max_len`` (``max_len`` itself always
    included so any prompt the cache can hold has a bucket)."""
    out: list[int] = []
    b = min_bucket
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


@dataclass(eq=False)
class RequestHandle:
    """A submitted request's live view: ``tokens`` grows as the engine
    harvests decode steps, ``state`` walks QUEUED → RUNNING → one terminal
    state (COMPLETED / FAILED / TIMED_OUT / CANCELLED), and ``result()``
    drives the engine until completion.  An ``on_token`` callback
    (``fn(handle, token)``) streams tokens as they are harvested; ``error``
    holds the captured exception of a FAILED request."""

    rid: int
    prompt: np.ndarray
    tokens: list[int] = field(default_factory=list)
    state: RequestState = RequestState.QUEUED
    error: BaseException | None = None
    deadline: float | None = None  # absolute time.monotonic() cutoff
    on_token: Callable[["RequestHandle", int], None] | None = None
    _engine: "ServingEngine | None" = field(default=None, repr=False)

    @property
    def done(self) -> bool:
        """True once the request reached any terminal state."""
        return self.state in TERMINAL_STATES

    @property
    def failed(self) -> bool:
        return self.state is RequestState.FAILED

    def result(self) -> list[int]:
        """Block until this request completes (drives the owning engine's
        ``step()`` loop) and return the generated tokens.  Raises the
        captured error for a FAILED request, :class:`TimeoutError` for a
        TIMED_OUT one and :class:`CancelledError` after ``cancel()``."""
        while not self.done:
            if self._engine is None or self._engine.step() == 0 and not self.done:
                raise RuntimeError(f"request {self.rid} cannot complete: "
                                   "engine is idle")
        if self.state is RequestState.FAILED:
            raise self.error if self.error is not None else \
                RuntimeError(f"request {self.rid} failed")
        if self.state is RequestState.TIMED_OUT:
            raise TimeoutError(
                f"request {self.rid} exceeded its deadline after "
                f"{len(self.tokens)} token(s)")
        if self.state is RequestState.CANCELLED:
            raise CancelledError(f"request {self.rid} was cancelled")
        return self.tokens

    def cancel(self) -> bool:
        """Withdraw the request: True if it transitioned to CANCELLED,
        False if it had already reached a terminal state."""
        if self.done:
            return False
        if self._engine is not None:
            self._engine._cancel(self)
        else:
            self.state = RequestState.CANCELLED
        return True

    def _overdue(self, now: float) -> bool:
        return self.deadline is not None and now > self.deadline

    # -- engine-side bookkeeping ------------------------------------------
    def _append(self, tok: int, scfg: ServeConfig) -> None:
        self.tokens.append(tok)
        if self.on_token is not None:
            self.on_token(self, tok)
        if len(self.tokens) >= scfg.max_new_tokens or tok == scfg.eos_id:
            self.state = RequestState.COMPLETED


class ServingEngine:
    """Single-host continuous-batching engine; under pjit the same step
    functions shard over the mesh (batch -> data axis, heads/experts ->
    model axis).

    Lifecycle::

        eng = ServingEngine(cfg, params, ServeConfig(...))
        h = eng.submit(prompt, timeout_s=5.0)  # -> RequestHandle, queued
        eng.step()                      # admit + one batched decode + harvest
        eng.drain()                     # run to completion, {rid: tokens}
        h.result()                      # or drive until this handle is done
        h.cancel()                      # withdraw a queued/running request

    ``drain()`` (and ``shutdown()``) closes the engine: later ``submit``
    calls raise instead of silently corrupting slot bookkeeping.
    """

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 tuning_db: TuningDatabase | None = None, mesh=None,
                 fault_plan: FaultPlan | None = None,
                 logit_program=None, logit_inputs=None,
                 tuner=None, program_backend: str = "xla"):
        """``mesh`` (any mesh with a ``model`` axis, e.g. from
        ``launch.mesh.make_mesh``) places the parameters with the sharding
        planner's specs before the first jit — the decode steps then
        partition across the mesh via the committed shardings instead of
        running single-device.  ``fault_plan`` arms deterministic fault
        injection (tests / resilience benchmark).

        ``logit_program`` (a canonical loop-nest :class:`~repro.core.ir.
        Program`, e.g. ``repro.autotune.logit_pipeline_program``) fuses a
        tuned logit post-processing stage into the jitted decode step: the
        batched decode's ``(N, V)`` logits enter the program's ``X`` input
        vocab-major as ``(V, N)`` and sampling reads its ``Y`` output.  The
        program is lowered through ``Daisy`` under ``program_backend``
        against ``tuning_db``, and the composite's jit-cache key carries
        ``(db.uid, db.generation)`` — a database commit hot-swaps the step
        fn at the next ``step()`` with zero traffic interruption.
        ``logit_inputs`` supplies the program's deployment operand arrays
        (missing ones are zero-filled).  ``tuner`` attaches a
        ``repro.autotune.SearchSupervisor``: the engine observes per-step
        telemetry into ``tuner.telemetry``, registers ``logit_program``,
        and drives ``tuner.maybe_launch()`` / ``tuner.poll()`` every
        ``tuner.check_every`` steps — the full online-adaptation loop.
        """
        from ..models.lowering import deployment_context

        self.cfg, self.scfg = cfg, scfg
        if tuner is not None:
            if tuning_db is None:
                tuning_db = tuner.db
            elif tuning_db is not tuner.db:
                raise ValueError(
                    "tuner.db and tuning_db are different databases; the "
                    "supervisor must commit swaps into the database the "
                    "engine resolves recipes from")
        # Shared deployment boilerplate (mesh placement + warm pretuned
        # tuning DB + fingerprint-keyed jit lookups) — same helper the
        # Trainer constructor uses.
        self._ctx = deployment_context(
            cfg, params, mesh=mesh, tuning_db=tuning_db,
            telemetry=tuner.telemetry if tuner is not None else None)
        self.mesh = mesh
        self.params = self._ctx.params
        self.tuning_db = self._ctx.tuning_db
        self.telemetry = self._ctx.telemetry
        self.fault_plan = fault_plan
        self.tuner = tuner
        self._step_count = 0
        # prefill (s >= 1) and slot-batched decode steps; content-keyed so
        # re-created engines with an equal config share the functions and
        # their jax trace caches — slot refills and restarts never retrace
        self._decode = self._ctx.jitted(
            "serve.decode", lambda: jax.jit(partial(M.decode_step, cfg)))
        self._step_greedy = self._ctx.jitted(
            "serve.decode_slots_greedy",
            lambda: jax.jit(partial(M.decode_slots_greedy, cfg)))
        self._step_logits = self._ctx.jitted(
            "serve.decode_slots", lambda: jax.jit(partial(M.decode_slots, cfg)))
        self.logit_program = logit_program
        if logit_program is not None:
            from ..core import Daisy, program_fingerprint

            self._daisy = Daisy(db=self.tuning_db, backend=program_backend)
            self._prog_key = program_fingerprint(logit_program)
            self._prog_aux = self._build_aux(logit_inputs or {})
            self._telemetry_key = self._prog_key
            if tuner is not None:
                tuner.register(logit_program)
            self._prog_gen: int | None = None
            self._resolve_step_fns()
        else:
            from ..core.cache import fingerprint_obj

            self._telemetry_key = f"serve.step:{fingerprint_obj(cfg)[:12]}"
            self._dispatch_greedy = self._step_greedy
            self._dispatch_logits = self._step_logits

        n = scfg.batch_slots
        self._buckets = prefill_buckets(scfg.max_len, scfg.min_bucket)
        self._states = M.init_slot_states(cfg, n, scfg.max_len)
        self._tokens = jnp.zeros((n,), jnp.int32)  # last sampled, per slot
        self._slots: list[RequestHandle | None] = [None] * n
        self._queue: deque[RequestHandle] = deque()
        # in-flight dispatched steps: (device tokens (N,), {slot: handle})
        self._pending: deque[tuple[Any, dict[int, RequestHandle]]] = deque()
        self.results: dict[int, list[int]] = {}
        self.failed: dict[int, RequestHandle] = {}
        # (program-name, from-backend, to-backend) of every compile that
        # degraded down the backend chain (see compile_resilient)
        self.degradations: list[tuple[str, str, str]] = []
        self._inflight: dict[int, RequestHandle] = {}
        self._closed = False
        self._next_rid = 0
        self.rng = np.random.default_rng(scfg.seed)

    # -- public API ------------------------------------------------------------
    def submit(self, prompt, _legacy_prompt=None, *, rid: int | None = None,
               on_token: Callable[[RequestHandle, int], None] | None = None,
               timeout_s: float | None = None,
               ) -> RequestHandle:
        """Queue a prompt; returns its :class:`RequestHandle`.

        ``timeout_s`` arms a per-request deadline (measured from submission):
        an overdue request transitions to TIMED_OUT at the next harvest and
        frees its slot.  Duplicate in-flight ``rid``s and submissions after
        ``drain()``/``shutdown()`` are rejected.

        The legacy positional form ``submit(rid, prompt)`` still works but
        is deprecated — pass the prompt first (an explicit id via ``rid=``).
        """
        if _legacy_prompt is not None:
            warnings.warn(
                "ServingEngine.submit(rid, prompt) is deprecated; use "
                "submit(prompt, rid=...) -> RequestHandle",
                DeprecationWarning, stacklevel=2)
            rid, prompt = int(prompt), _legacy_prompt
        if self._closed:
            raise RuntimeError(
                "ServingEngine is shut down (drain()/shutdown() was called); "
                "create a new engine to serve more requests")
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError(f"prompt must be a non-empty 1-D token array, "
                             f"got shape {prompt.shape}")
        if prompt.size > self._buckets[-1]:
            raise ValueError(
                f"prompt length {prompt.size} exceeds the largest prefill "
                f"bucket {self._buckets[-1]} (max_len={self.scfg.max_len})")
        if prompt.size + self.scfg.max_new_tokens > self.scfg.max_len:
            raise ValueError(
                f"prompt length {prompt.size} + max_new_tokens "
                f"{self.scfg.max_new_tokens} exceeds max_len "
                f"{self.scfg.max_len} (the decode cache would overflow)")
        if rid is None:
            rid = self._next_rid
        elif rid in self._inflight:
            raise ValueError(
                f"rid {rid} is already in flight (state "
                f"{self._inflight[rid].state.value}); duplicate ids would "
                "corrupt slot bookkeeping — pass a fresh rid or omit it")
        self._next_rid = max(self._next_rid, rid) + 1
        deadline = time.monotonic() + timeout_s if timeout_s is not None else None
        h = RequestHandle(rid=rid, prompt=prompt, on_token=on_token,
                          deadline=deadline, _engine=self)
        self._inflight[rid] = h
        self._queue.append(h)
        return h

    def step(self) -> int:
        """One scheduling iteration: harvest the mature in-flight step,
        admit queued requests into free slots, dispatch one batched decode
        over the occupied slots.  Returns the number of occupied slots
        after dispatch (0 = idle: queue empty, nothing in flight).

        Instrumented for online tuning: busy steps are timed into the
        telemetry sink (a no-op predicate when disabled), and an attached
        tuner is driven every ``tuner.check_every`` steps — launches
        background searches on the hottest nests and applies/rolls back
        swaps at the poll point."""
        if self.logit_program is not None:
            self._resolve_step_fns()  # picks up database commits (hot swap)
        t0 = time.perf_counter()
        n = self._step_impl()
        if n:
            self.telemetry.observe(self._telemetry_key,
                                   time.perf_counter() - t0)
        self._step_count += 1
        if self.tuner is not None \
                and self._step_count % self.tuner.check_every == 0:
            self.tuner.maybe_launch()
            self.tuner.poll(engine=self)
        return n

    def _step_impl(self) -> int:
        scfg = self.scfg
        sync = scfg.temperature > 0.0
        depth = 0 if sync else max(0, scfg.pipeline_depth)
        self._expire_queued()
        self._admit()
        live = {i: h for i, h in enumerate(self._slots) if h is not None}
        if not live:
            while self._pending:
                self._harvest_one()
            return 0
        try:
            if self.fault_plan is not None:
                self.fault_plan.maybe_raise("serve.step")
            if sync:
                logits, self._states = self._dispatch_logits(
                    self.params, self._states, self._tokens)
                self._pending.append((logits, live))
            else:
                # pipelined: the sampled tokens stay on device and feed the
                # next dispatch; the host looks at them `pipeline_depth`
                # steps later
                next_tok, self._states = self._dispatch_greedy(
                    self.params, self._states, self._tokens)
                self._tokens = next_tok
                self._pending.append((next_tok, live))
        except Exception as e:  # noqa: BLE001 — batch-level dispatch failure
            # the whole dispatched step is lost: fail the requests that
            # occupied slots, recycle them, and keep the engine serviceable
            # for the queue and for future submissions
            for i, h in live.items():
                self._fail(h, e, slot=i)
            return self._step_impl() if self._queue or self._pending else 0
        # block on overdue steps: at most `depth` stay in flight (0 = the
        # host sees every step's result before dispatching the next)
        while len(self._pending) > depth:
            self._harvest_one()
        return len(live)

    def drain(self) -> dict[int, list[int]]:
        """Run until the queue and every slot are empty, then shut the
        engine down; returns ``rid -> generated tokens`` for every request
        that COMPLETED (failed / timed-out / cancelled requests carry their
        outcome on their handle)."""
        while self._queue or self._pending or any(
                h is not None for h in self._slots):
            self.step()
        self._closed = True
        return self.results

    def shutdown(self) -> None:
        """Close the engine without draining: queued requests are cancelled,
        running ones keep their partial tokens and transition to CANCELLED;
        later ``submit`` calls raise."""
        for h in list(self._queue) + [h for h in self._slots if h is not None]:
            if not h.done:
                self._cancel(h)
        while self._pending:  # sync the device so nothing dangles
            self._harvest_one()
        self._closed = True

    def run(self) -> dict[int, list[int]]:
        """Deprecated: drain the queue; returns rid -> generated tokens.

        Migration: ``submit(prompt)`` now returns a :class:`RequestHandle`
        — poll ``handle.done`` / read ``handle.tokens`` while calling
        ``engine.step()``, call ``handle.result()`` to block for one
        request, or ``engine.drain()`` for the old run-to-completion
        behaviour (same return value as ``run()``).
        """
        warnings.warn(
            "ServingEngine.run() is deprecated; use submit()/step()/drain() "
            "or RequestHandle.result()", DeprecationWarning, stacklevel=2)
        return self.drain()

    def compile_resilient(self, program,
                          backends: tuple[str, ...] = ("pallas", "xla")):
        """Hot-swap guardrail: compile (and validate) a tuned canonical
        program for this engine, degrading across ``backends`` in order.

        A background ``evolve_recipe`` winner must never be swapped into a
        live engine on the strength of a compile that hasn't run: each rung
        builds through ``Daisy`` (whose recipe degradation maps Pallas kinds
        onto XLA equivalents under ``'xla'``) and executes once on random
        inputs before being accepted.  Falls through to the next backend on
        any failure; degradations are recorded on ``self.degradations``.
        Returns a :class:`repro.fault.DegradedCompile`.
        """
        from ..fault import compile_with_degradation

        res = compile_with_degradation(
            program, backends=backends, db=self.tuning_db,
            fault_plan=self.fault_plan)
        for b, _err in res.errors:
            self.degradations.append(
                (getattr(program, "name", "?"), b, res.backend))
        return res

    def explain_kernels(self) -> str:
        """Pass-pipeline + contraction-plan report for this engine's config
        at its serving shape (ops introspection; content-cached so repeated
        calls and re-created engines share one pipeline run)."""
        from ..models.lowering import kernel_report

        return self._ctx.jitted(
            "serve.kernel_report",
            lambda: kernel_report(
                self.cfg, seq=self.scfg.max_len, batch=self.scfg.batch_slots,
                db=self.tuning_db,
            ),
            self.scfg.max_len, self.scfg.batch_slots,
            self.tuning_db.uid, self.tuning_db.generation,
        )

    # -- tuned logit-program composite -----------------------------------------
    def _build_aux(self, given: dict) -> dict:
        """Validate + stage the logit program's deployment operands.

        The engine owns ``X`` (the step's vocab-major logits) and reads
        ``Y``; every other input array of the *normalized* program is a
        deployment operand — taken from ``logit_inputs`` when given
        (shape-checked), zero-filled otherwise.  Unknown names are errors:
        a typo'd operand silently zero-filled would corrupt served tokens.
        """
        prog = self._daisy._normalized(self.logit_program)
        shapes = {a.name: tuple(a.shape) for a in prog.input_arrays}
        v, n = self.cfg.vocab, self.scfg.batch_slots
        for io in ("X", "Y"):
            if shapes.get(io) != (v, n):
                raise ValueError(
                    f"logit program must carry {io} of shape (vocab, "
                    f"batch_slots) = ({v}, {n}), got "
                    f"{shapes.get(io)} in {self.logit_program.name!r}")
        unknown = sorted(set(given) - set(shapes))
        if unknown:
            raise ValueError(
                f"logit_inputs name(s) {unknown} are not input arrays of "
                f"{self.logit_program.name!r} (has {sorted(shapes)})")
        aux: dict[str, jnp.ndarray] = {}
        for name, shape in shapes.items():
            if name == "X":
                continue
            if name in given:
                arr = jnp.asarray(given[name], jnp.float32)
                if tuple(arr.shape) != shape:
                    raise ValueError(
                        f"logit_inputs[{name!r}] has shape {tuple(arr.shape)}"
                        f", program expects {shape}")
            else:
                arr = jnp.zeros(shape, jnp.float32)
            aux[name] = arr
        return aux

    def _resolve_step_fns(self) -> None:
        """(Re)build the decode+logit-program composites when the tuning
        database has moved — this IS the hot swap: the jit-cache key carries
        ``(program fingerprint, db.uid, db.generation)``, so a supervisor
        commit (or rollback) resolves a fresh composite on the next step
        while older generations stay cached (rollback is a cache hit)."""
        gen = self.tuning_db.generation
        if gen == self._prog_gen:
            return
        self._prog_gen = gen
        cfg, daisy = self.cfg, self._daisy
        prog, aux = self.logit_program, self._prog_aux

        def composite(sample_greedy: bool):
            # raw (unjitted) program fn: composes under the outer jit, and
            # Daisy's compile cache (keyed on db state) does the recipe work
            pfn, _plan = daisy.compile(prog, jit=False)

            def stepfn(params, states, tokens):
                logits, states = M.decode_slots(cfg, params, states, tokens)
                env = dict(aux)
                env["X"] = logits.T  # (N, V) -> vocab-major (V, N)
                out = pfn(env)["Y"]
                if sample_greedy:
                    return jnp.argmax(out, axis=0).astype(jnp.int32), states
                return out.T, states  # back to (N, V) for host sampling

            return jax.jit(stepfn)

        self._dispatch_greedy = self._ctx.jitted(
            "serve.decode_slots_greedy+program", lambda: composite(True),
            self._prog_key, self.tuning_db.uid, gen)
        self._dispatch_logits = self._ctx.jitted(
            "serve.decode_slots+program", lambda: composite(False),
            self._prog_key, self.tuning_db.uid, gen)

    # -- internals -------------------------------------------------------------
    def _bucket_for(self, n: int) -> int:
        # token-recurrent families can't mask a padded prompt token out of
        # the carried state, so they prefill at exact length (still one
        # cached trace per *distinct* length — the pre-bucketing behaviour)
        if self.cfg.family in ("hybrid", "ssm"):
            return n
        return next(b for b in self._buckets if b >= n)

    def _prefill(self, h: RequestHandle):
        """Bucket-padded prefill of one request into a fresh b=1 state;
        returns (last-valid-position logits (V,), state)."""
        cfg, scfg = self.cfg, self.scfg
        s = int(h.prompt.size)
        bucket = self._bucket_for(s)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :s] = h.prompt
        state = M.init_decode_state(cfg, 1, scfg.max_len, ring=False)
        if cfg.family == "audio":
            # stub frontend: encoder memory from pseudo frame embeddings
            emb = jnp.zeros((1, cfg.frontend_len, cfg.d_model), M._dtype(cfg))
            state["memory"] = M.encode(cfg, self.params, emb)
        logits, state = self._decode(self.params, state, jnp.asarray(toks))
        # reset to the true length: the padded cache rows beyond it are
        # causally masked and get overwritten as decode proceeds
        state["len"] = jnp.asarray(s, jnp.int32)
        return logits[0, s - 1], state

    def _sample_from(self, lf: np.ndarray) -> int:
        if self.scfg.temperature <= 0.0:
            return int(lf.argmax())
        p = np.exp((lf - lf.max()) / self.scfg.temperature)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def _check_finite(self, lf: np.ndarray, h: RequestHandle) -> None:
        if not np.isfinite(lf).all():
            raise NonFiniteLogits(
                f"request {h.rid}: non-finite logits "
                f"(nan={int(np.isnan(lf).sum())}, inf={int(np.isinf(lf).sum())} "
                f"of {lf.size})")

    # -- terminal transitions -------------------------------------------------
    def _retire(self, h: RequestHandle, slot: int | None = None) -> None:
        self._inflight.pop(h.rid, None)
        if slot is not None and self._slots[slot] is h:
            self._slots[slot] = None

    def _finish(self, h: RequestHandle, slot: int | None = None) -> None:
        self.results[h.rid] = h.tokens
        self._retire(h, slot)

    def _fail(self, h: RequestHandle, err: BaseException,
              slot: int | None = None) -> None:
        h.state = RequestState.FAILED
        h.error = err
        self.failed[h.rid] = h
        self._retire(h, slot)

    def _timeout(self, h: RequestHandle, slot: int | None = None) -> None:
        h.state = RequestState.TIMED_OUT
        self._retire(h, slot)

    def _cancel(self, h: RequestHandle) -> None:
        h.state = RequestState.CANCELLED
        try:
            self._queue.remove(h)
        except ValueError:
            pass
        slot = next((i for i, s in enumerate(self._slots) if s is h), None)
        self._retire(h, slot)

    def _expire_queued(self) -> None:
        """TIMED_OUT sweep over requests still waiting for a slot (their
        deadline can pass while every slot is busy)."""
        now = time.monotonic()
        for h in [h for h in self._queue if h._overdue(now)]:
            self._queue.remove(h)
            self._timeout(h)

    def _admit(self) -> None:
        """Fill free slots from the queue: bucketed prefill, sample the
        first token, write the slot state.  A request whose prefill raises
        or whose prefill logits are non-finite fails alone — admission
        continues with the rest of the queue."""
        while self._queue and None in self._slots:
            h = self._queue.popleft()
            if h._overdue(time.monotonic()):
                self._timeout(h)
                continue
            try:
                fault = None if self.fault_plan is None else \
                    self.fault_plan.maybe_raise("serve.prefill", key=h.rid)
                last_logits, state = self._prefill(h)
                lf = np.asarray(last_logits, np.float32)
                if fault is not None and fault.kind == "nan":
                    lf = np.full_like(lf, np.nan)
                self._check_finite(lf, h)
                t0 = self._sample_from(lf)
                h.state = RequestState.RUNNING
                h._append(t0, self.scfg)
            except Exception as e:  # noqa: BLE001 — request-scoped isolation
                self._fail(h, e)
                continue
            if h.done:  # eos / max_new_tokens == 1: never occupies a slot
                self._finish(h)
                continue
            i = self._slots.index(None)
            self._slots[i] = h
            self._states = M.write_slot(self._states, i, state)
            self._tokens = self._tokens.at[i].set(t0)

    def _harvest_one(self) -> None:
        """Materialize the oldest in-flight step's tokens and credit them to
        the handles that occupied each slot at dispatch time.  This is the
        only point the host blocks on the device — and the point where
        per-request outcomes are decided: deadlines expire here, injected or
        raised per-request work fails here, and a failed/overdue request
        frees its slot while every other slot's tokens are credited
        untouched."""
        out, live = self._pending.popleft()
        arr = np.asarray(out)  # blocks until this step's results are ready
        now = time.monotonic()
        for i, h in live.items():
            if h.done:  # finished in a younger harvest; overshoot dropped
                continue
            if h._overdue(now):
                self._timeout(h, slot=i)
                continue
            try:
                fault = None if self.fault_plan is None else \
                    self.fault_plan.maybe_raise("serve.decode", key=h.rid)
                if arr.ndim == 1:  # greedy path: sampled tokens (N,)
                    tok = int(arr[i])
                else:  # sync path: logits (N, V), sample on host
                    lf = arr[i]
                    lfault = self.fault_plan.maybe_raise(
                        "serve.logits", key=h.rid) if self.fault_plan else None
                    if (fault is not None and fault.kind == "nan") or (
                            lfault is not None and lfault.kind == "nan"):
                        lf = np.full_like(lf, np.nan)
                    self._check_finite(lf, h)
                    tok = self._sample_from(lf)
                    self._tokens = self._tokens.at[i].set(tok)
                h._append(tok, self.scfg)
            except Exception as e:  # noqa: BLE001 — request-scoped isolation
                self._fail(h, e, slot=i)
                continue
            if h.done:
                self._finish(h, slot=i)
