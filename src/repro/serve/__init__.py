from .engine import (  # noqa: F401
    NonFiniteLogits,
    RequestHandle,
    RequestState,
    ServeConfig,
    ServingEngine,
    prefill_buckets,
)
