from .engine import (  # noqa: F401
    RequestHandle,
    ServeConfig,
    ServingEngine,
    prefill_buckets,
)
