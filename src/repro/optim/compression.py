"""Gradient compression for the cross-pod (DCN) all-reduce.

At 2-pod scale the inter-pod link is the thin pipe: compressing the gradient
all-reduce payload over the ``pod`` axis cuts the collective term of the
roofline.  Two codecs, both with error feedback so compression noise
accumulates into the next step instead of being lost:

  * bf16    — 2x, numerically safe default;
  * int8    — 4x, per-tensor absmax scaling + error feedback residual.

Usage in the train step (DP sync): compress -> psum over 'pod' -> decompress;
the intra-pod reduce stays full precision (ICI is cheap).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def compress_grads(grads: Pytree, residual: Pytree | None, codec: str = "bf16"):
    """Returns (compressed, scales, new_residual)."""
    if codec == "none":
        return grads, None, residual
    if codec == "bf16":
        comp = jax.tree_util.tree_map(lambda g: g.astype(jnp.bfloat16), grads)
        return comp, None, residual
    if codec == "int8":
        def one(g, r):
            gf = g.astype(jnp.float32) + (r if r is not None else 0.0)
            scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
            err = gf - q.astype(jnp.float32) * scale
            return q, scale, err

        leaves, treedef = jax.tree_util.tree_flatten(grads)
        res_leaves = (
            treedef.flatten_up_to(residual) if residual is not None else [None] * len(leaves)
        )
        out = [one(g, r) for g, r in zip(leaves, res_leaves)]
        comp = treedef.unflatten([o[0] for o in out])
        scales = treedef.unflatten([o[1] for o in out])
        new_res = treedef.unflatten([o[2] for o in out])
        return comp, scales, new_res
    raise ValueError(codec)


def decompress_grads(comp: Pytree, scales: Pytree | None, codec: str = "bf16") -> Pytree:
    if codec == "none":
        return comp
    if codec == "bf16":
        return jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), comp)
    if codec == "int8":
        return jax.tree_util.tree_map(
            lambda q, s: q.astype(jnp.float32) * s, comp, scales
        )
    raise ValueError(codec)
