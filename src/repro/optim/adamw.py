"""AdamW + LR schedules (cosine and MiniCPM's WSD), grad clip/accum.

Pure-functional (no optax): state is a pytree shaped like params, so the
sharding planner shards optimizer state exactly like the parameters (ZeRO-1
falls out of pjit: each m/v shard lives with its parameter shard).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # 'cosine' | 'wsd' | 'const'
    wsd_decay_frac: float = 0.1  # final fraction of steps in 1-sqrt decay
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(1, cfg.warmup_steps))
    if cfg.schedule == "const":
        sched = jnp.asarray(1.0)
    elif cfg.schedule == "wsd":
        # Warmup-Stable-Decay (MiniCPM): stable plateau, then sqrt-like decay
        decay_start = cfg.total_steps * (1.0 - cfg.wsd_decay_frac)
        frac = jnp.clip((s - decay_start) / max(1.0, cfg.total_steps - decay_start), 0.0, 1.0)
        sched = 1.0 - (1.0 - cfg.min_lr_frac) * jnp.sqrt(frac)
    else:  # cosine
        frac = jnp.clip(s / max(1, cfg.total_steps), 0.0, 1.0)
        sched = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(math.pi * frac))
    return cfg.lr * warm * sched


def adamw_init(params: Pytree) -> dict:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(grads: Pytree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(grads)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))


def adamw_update(
    cfg: AdamWConfig, params: Pytree, grads: Pytree, state: dict
) -> tuple[Pytree, dict, dict]:
    """Returns (new_params, new_state, metrics). NaN/inf grads skip the step
    (fault tolerance: a poisoned micro-batch must not corrupt the weights)."""
    step = state["step"]
    gnorm = global_norm(grads)
    finite = jnp.isfinite(gnorm)
    scale = jnp.where(
        finite & (gnorm > cfg.grad_clip), cfg.grad_clip / jnp.maximum(gnorm, 1e-12), 1.0
    )
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        g = jnp.where(finite, g, 0.0)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mh = m_new / bc1
        vh = v_new / bc2
        delta = lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32))
        p_new = p.astype(jnp.float32) - jnp.where(finite, delta, 0.0)
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    metrics = {"grad_norm": gnorm, "lr": lr, "skipped": ~finite}
    return new_p, new_state, metrics
