from .adamw import AdamWConfig, adamw_init, adamw_update, lr_at  # noqa: F401
from .compression import compress_grads, decompress_grads  # noqa: F401
