from .suite import BENCHMARKS, NAMES, Benchmark  # noqa: F401
