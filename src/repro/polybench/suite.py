"""PolyBench 4.2 — the paper's 15 parallelizable benchmarks, in the IR.

Each benchmark ships three semantically-equivalent implementations:
  * ``a``  — the original PolyBench C loop structure (the paper's A variant),
  * ``b``  — an alternative permutation/composition (the paper's B variant),
  * ``np`` — the composition a NumPy/DaCe-style frontend would emit
             (paper §4.3: range indexing yields different loop structures).

Triangular domains are boxes + affine guards (see ir.Computation.guards).
Sizes are scaled from PolyBench LARGE to stay measurable on a 1-core CPU
container; all variants of one benchmark share sizes, so the paper's A/B
runtime *ratios* — the actual claim — are preserved.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from ..core.ir import (
    Access,
    Affine,
    Array,
    Call,
    Computation,
    Const,
    Loop,
    Program,
    Read,
    acc,
    aff,
)

ALPHA, BETA = 1.5, 1.2
ZERO = Const(0.0)


def L(it: str, n: int, *body, start: int = 0) -> Loop:
    return Loop(it, n, start=start, body=tuple(body))


def C(name, write, reads, expr, accumulate=None, guards=()):
    return Computation(name, write, tuple(reads), expr, accumulate, tuple(guards))


@dataclass(frozen=True)
class Benchmark:
    name: str
    sizes: dict[str, dict[str, int]]
    variants: dict[str, Callable[[dict[str, int]], Program]]
    output: str  # array checked for correctness

    def make(self, variant: str, size: str = "mini") -> Program:
        return self.variants[variant](self.sizes[size])


_B: dict[str, Benchmark] = {}


def _register(name, sizes, output, **variants):
    _B[name] = Benchmark(name, sizes, variants, output)


# ---------------------------------------------------------------------------
# gemm: C = alpha*A@B + beta*C          (paper Fig. 1)
# ---------------------------------------------------------------------------
def _gemm_arrays(s):
    return (Array("A", (s["ni"], s["nk"])), Array("B", (s["nk"], s["nj"])),
            Array("C", (s["ni"], s["nj"])))


def _gemm_comps(i, j, k, j2):
    scale = C("scale", acc("C", i, j), [acc("C", i, j)], Read(0) * BETA)
    mac = C("mac", acc("C", i, j2), [acc("A", i, k), acc("B", k, j2)],
            ALPHA * Read(0) * Read(1), accumulate="+")
    return scale, mac


def gemm_a(s):  # polybench: for i { for j: scale; for k: for j: mac }  (fused in i)
    scale, mac = _gemm_comps("i", "j", "k", "j2")
    nest = L("i", s["ni"],
             L("j", s["nj"], scale),
             L("k", s["nk"], L("j2", s["nj"], mac)))
    return Program("gemm_a", _gemm_arrays(s), (nest,))


def gemm_b(s):  # paper Fig.1 gemm_2: separate nests, MAC in (i,j,k) order
    scale, mac = _gemm_comps("i", "j", "k", "j2")
    return Program("gemm_b", _gemm_arrays(s), (
        L("i", s["ni"], L("j", s["nj"], scale)),
        L("i2", s["ni"], L("j2", s["nj"], L("k", s["nk"],
          mac.rename({"i": "i2"})))),
    ))


def gemm_np(s):  # C *= beta (2D nest); C += alpha*(A@B) (jk-outer order)
    scale, mac = _gemm_comps("i", "j", "k", "j2")
    return Program("gemm_np", _gemm_arrays(s), (
        L("j", s["nj"], L("i", s["ni"], scale)),
        L("k", s["nk"], L("i2", s["ni"], L("j2", s["nj"], mac.rename({"i": "i2"})))),
    ))


_register("gemm",
          {"mini": dict(ni=20, nj=24, nk=28),
           "bench": dict(ni=320, nj=320, nk=320)},
          "C", a=gemm_a, b=gemm_b, np=gemm_np)


# ---------------------------------------------------------------------------
# 2mm: tmp = alpha*A@B; D = tmp@C2 + beta*D
# ---------------------------------------------------------------------------
def _2mm_arrays(s):
    return (Array("A", (s["ni"], s["nk"])), Array("B", (s["nk"], s["nj"])),
            Array("C2", (s["nj"], s["nl"])), Array("D", (s["ni"], s["nl"])),
            Array("tmp", (s["ni"], s["nj"])))


def _2mm_nests(order1, order2, order3, s):
    z = C("zero", acc("tmp", "i", "j"), [], ZERO)
    m1 = C("m1", acc("tmp", "i", "j"), [acc("A", "i", "k"), acc("B", "k", "j")],
           ALPHA * Read(0) * Read(1), accumulate="+")
    sc = C("sc", acc("D", "p", "q"), [acc("D", "p", "q")], Read(0) * BETA)
    m2 = C("m2", acc("D", "p", "q"), [acc("tmp", "p", "r"), acc("C2", "r", "q")],
           Read(0) * Read(1), accumulate="+")
    dims = dict(i=s["ni"], j=s["nj"], k=s["nk"], p=s["ni"], q=s["nl"], r=s["nj"])

    def nest(order, comps):
        inner: tuple = comps
        for it in reversed(order):
            inner = (Loop(it, dims[it], body=inner),)
        return inner[0]

    return z, m1, sc, m2, nest


def mm2_a(s):  # polybench: for i { for j { tmp=0; for k: acc } }; same for D
    z, m1, sc, m2, nest = _2mm_nests(None, None, None, s)
    n1 = L("i", s["ni"], L("j", s["nj"], z, L("k", s["nk"], m1)))
    n2 = L("p", s["ni"], L("q", s["nl"], sc, L("r", s["nj"], m2)))
    return Program("2mm_a", _2mm_arrays(s), (n1, n2))


def mm2_b(s):  # all stages fissioned, contractions in (k/r)-outer order
    z, m1, sc, m2, nest = _2mm_nests(None, None, None, s)
    return Program("2mm_b", _2mm_arrays(s), (
        nest(["j", "i"], (z,)),
        nest(["k", "j", "i"], (m1,)),
        nest(["q", "p"], (sc,)),
        nest(["r", "q", "p"], (m2,)),
    ))


def mm2_np(s):  # tmp = alpha*A@B (matmul composition); D = tmp@C2 + beta*D
    z, m1, sc, m2, nest = _2mm_nests(None, None, None, s)
    return Program("2mm_np", _2mm_arrays(s), (
        nest(["i", "j"], (z,)),
        nest(["i", "k", "j"], (m1,)),
        nest(["p", "q"], (sc,)),
        nest(["p", "r", "q"], (m2,)),
    ))


_register("2mm",
          {"mini": dict(ni=16, nj=18, nk=22, nl=24),
           "bench": dict(ni=256, nj=256, nk=256, nl=256)},
          "D", a=mm2_a, b=mm2_b, np=mm2_np)


# ---------------------------------------------------------------------------
# 3mm: E=A@B; F=C3@D3; G=E@F
# ---------------------------------------------------------------------------
def _3mm_arrays(s):
    return (Array("A", (s["ni"], s["nk"])), Array("B", (s["nk"], s["nj"])),
            Array("C3", (s["nj"], s["nm"])), Array("D3", (s["nm"], s["nl"])),
            Array("E", (s["ni"], s["nj"])), Array("F", (s["nj"], s["nl"])),
            Array("G", (s["ni"], s["nl"])))


def _3mm_stage(out, in1, in2, its, dims):
    i, j, k = its
    z = C(f"z{out}", acc(out, i, j), [], ZERO)
    m = C(f"m{out}", acc(out, i, j), [acc(in1, i, k), acc(in2, k, j)],
          Read(0) * Read(1), accumulate="+")
    return z, m


def mm3_a(s):
    stages = []
    for out, in1, in2, (di, dj, dk), pre in [
        ("E", "A", "B", (s["ni"], s["nj"], s["nk"]), "e"),
        ("F", "C3", "D3", (s["nj"], s["nl"], s["nm"]), "f"),
        ("G", "E", "F", (s["ni"], s["nl"], s["nj"]), "g"),
    ]:
        i, j, k = pre + "i", pre + "j", pre + "k"
        z, m = _3mm_stage(out, in1, in2, (i, j, k), None)
        stages.append(L(i, di, L(j, dj, z, L(k, dk, m))))
    return Program("3mm_a", _3mm_arrays(s), tuple(stages))


def mm3_b(s):  # contractions k-outer, zero nests transposed
    stages = []
    for out, in1, in2, (di, dj, dk), pre in [
        ("E", "A", "B", (s["ni"], s["nj"], s["nk"]), "e"),
        ("F", "C3", "D3", (s["nj"], s["nl"], s["nm"]), "f"),
        ("G", "E", "F", (s["ni"], s["nl"], s["nj"]), "g"),
    ]:
        i, j, k = pre + "i", pre + "j", pre + "k"
        z, m = _3mm_stage(out, in1, in2, (i, j, k), None)
        stages.append(L(j, dj, L(i, di, z)))
        stages.append(L(k, dk, L(i, di, L(j, dj, m))))
    return Program("3mm_b", _3mm_arrays(s), tuple(stages))


_register("3mm",
          {"mini": dict(ni=14, nj=16, nk=18, nl=20, nm=22),
           "bench": dict(ni=224, nj=224, nk=224, nl=224, nm=224)},
          "G", a=mm3_a, b=mm3_b, np=mm3_a)


# ---------------------------------------------------------------------------
# syrk: C (lower tri) = beta*C + alpha*A@A^T       (guarded triangle)
# ---------------------------------------------------------------------------
def _syrk_arrays(s):
    return (Array("A", (s["n"], s["m"])), Array("C", (s["n"], s["n"])))


def _syrk_comps():
    tri = aff("i", ("j", -1))  # i - j >= 0  <=>  j <= i
    sc = C("sc", acc("C", "i", "j"), [acc("C", "i", "j")], Read(0) * BETA,
           guards=[tri])
    mac = C("mac", acc("C", "i", "j"), [acc("A", "i", "k"), acc("A", "j", "k")],
            ALPHA * Read(0) * Read(1), accumulate="+", guards=[tri])
    return sc, mac


def syrk_a(s):  # polybench: for i { for j<=i: scale; for k { for j<=i: mac } }
    sc, mac = _syrk_comps()
    return Program("syrk_a", _syrk_arrays(s), (
        L("i", s["n"], L("j", s["n"], sc),
          L("k", s["m"], L("j2", s["n"], mac.rename({"j": "j2"})))),
    ))


def syrk_b(s):  # fissioned, mac in (j,k,i) order
    sc, mac = _syrk_comps()
    return Program("syrk_b", _syrk_arrays(s), (
        L("i", s["n"], L("j", s["n"], sc)),
        L("j2", s["n"], L("k", s["m"], L("i2", s["n"],
          mac.rename({"i": "i2", "j": "j2"})))),
    ))


def syrk_np(s):  # NPBench: for i { C[i,:i+1]*=beta; for k: C[i,:i+1]+=... }
    sc, mac = _syrk_comps()
    return Program("syrk_np", _syrk_arrays(s), (
        L("i", s["n"], L("j", s["n"], sc), L("k", s["m"], L("j2", s["n"],
          mac.rename({"j": "j2"})))),
    ))


_register("syrk",
          {"mini": dict(n=18, m=22), "bench": dict(n=256, m=256)},
          "C", a=syrk_a, b=syrk_b, np=syrk_np)


# ---------------------------------------------------------------------------
# syr2k: C (lower tri) = beta*C + alpha*(A@B^T + B@A^T)
# ---------------------------------------------------------------------------
def _syr2k_arrays(s):
    return (Array("A", (s["n"], s["m"])), Array("B", (s["n"], s["m"])),
            Array("C", (s["n"], s["n"])))


def _syr2k_comps():
    tri = aff("i", ("j", -1))
    sc = C("sc", acc("C", "i", "j"), [acc("C", "i", "j")], Read(0) * BETA,
           guards=[tri])
    mac1 = C("mac1", acc("C", "i", "j"), [acc("A", "j", "k"), acc("B", "i", "k")],
             ALPHA * Read(0) * Read(1), accumulate="+", guards=[tri])
    mac2 = C("mac2", acc("C", "i", "j"), [acc("B", "j", "k"), acc("A", "i", "k")],
             ALPHA * Read(0) * Read(1), accumulate="+", guards=[tri])
    return sc, mac1, mac2


def syr2k_a(s):
    sc, mac1, mac2 = _syr2k_comps()
    return Program("syr2k_a", _syr2k_arrays(s), (
        L("i", s["n"], L("j", s["n"], sc),
          L("k", s["m"], L("j2", s["n"], mac1.rename({"j": "j2"}),
                           mac2.rename({"j": "j2"})))),
    ))


def syr2k_b(s):
    sc, mac1, mac2 = _syr2k_comps()
    return Program("syr2k_b", _syr2k_arrays(s), (
        L("j", s["n"], L("i", s["n"], sc)),
        L("k", s["m"], L("i2", s["n"], L("j2", s["n"],
          mac1.rename({"i": "i2", "j": "j2"})))),
        L("k3", s["m"], L("j3", s["n"], L("i3", s["n"],
          mac2.rename({"i": "i3", "j": "j3", "k": "k3"})))),
    ))


_register("syr2k",
          {"mini": dict(n=16, m=20), "bench": dict(n=224, m=224)},
          "C", a=syr2k_a, b=syr2k_b, np=syr2k_a)


# ---------------------------------------------------------------------------
# atax: y = A^T (A x)
# ---------------------------------------------------------------------------
def _atax_arrays(s):
    return (Array("A", (s["m"], s["n"])), Array("x", (s["n"],)),
            Array("y", (s["n"],)), Array("tmp", (s["m"],)))


def _atax_comps():
    zy = C("zy", acc("y", "jz"), [], ZERO)
    zt = C("zt", acc("tmp", "i"), [], ZERO)
    t1 = C("t1", acc("tmp", "i"), [acc("A", "i", "j"), acc("x", "j")],
           Read(0) * Read(1), accumulate="+")
    t2 = C("t2", acc("y", "j2"), [acc("A", "i", "j2"), acc("tmp", "i")],
           Read(0) * Read(1), accumulate="+")
    return zy, zt, t1, t2


def atax_a(s):  # polybench: zero y; for i { tmp=0; for j: t1; for j: t2 }
    zy, zt, t1, t2 = _atax_comps()
    return Program("atax_a", _atax_arrays(s), (
        L("jz", s["n"], zy),
        L("i", s["m"], zt, L("j", s["n"], t1), L("j2", s["n"], t2)),
    ))


def atax_b(s):  # fully fissioned, second stage (j,i) order
    zy, zt, t1, t2 = _atax_comps()
    return Program("atax_b", _atax_arrays(s), (
        L("jz", s["n"], zy),
        L("i", s["m"], zt),
        L("i2", s["m"], L("j", s["n"], t1.rename({"i": "i2"}))),
        L("j2", s["n"], L("i3", s["m"], t2.rename({"i": "i3"}))),
    ))


_register("atax",
          {"mini": dict(m=20, n=24), "bench": dict(m=1200, n=1200)},
          "y", a=atax_a, b=atax_b, np=atax_b)


# ---------------------------------------------------------------------------
# bicg: s = A^T r ; q = A p    (classically fused in one (i,j) nest)
# ---------------------------------------------------------------------------
def _bicg_arrays(sz):
    return (Array("A", (sz["n"], sz["m"])), Array("r", (sz["n"],)),
            Array("p", (sz["m"],)), Array("s", (sz["m"],)),
            Array("q", (sz["n"],)))


def _bicg_comps():
    zs = C("zs", acc("s", "jz"), [], ZERO)
    zq = C("zq", acc("q", "iz"), [], ZERO)
    cs = C("cs", acc("s", "j"), [acc("r", "i"), acc("A", "i", "j")],
           Read(0) * Read(1), accumulate="+")
    cq = C("cq", acc("q", "i"), [acc("A", "i", "j"), acc("p", "j")],
           Read(0) * Read(1), accumulate="+")
    return zs, zq, cs, cq


def bicg_a(s):  # fused: for i { for j { s[j]+=..; q[i]+=.. } }
    zs, zq, cs, cq = _bicg_comps()
    return Program("bicg_a", _bicg_arrays(s), (
        L("jz", s["m"], zs), L("iz", s["n"], zq),
        L("i", s["n"], L("j", s["m"], cs, cq)),
    ))


def bicg_b(s):  # fissioned, s-stage in (j,i) order
    zs, zq, cs, cq = _bicg_comps()
    return Program("bicg_b", _bicg_arrays(s), (
        L("jz", s["m"], zs), L("iz", s["n"], zq),
        L("j", s["m"], L("i", s["n"], cs)),
        L("i2", s["n"], L("j2", s["m"], cq.rename({"i": "i2", "j": "j2"}))),
    ))


_register("bicg",
          {"mini": dict(n=20, m=24), "bench": dict(n=1200, m=1200)},
          "s", a=bicg_a, b=bicg_b, np=bicg_b)


# ---------------------------------------------------------------------------
# mvt / gemver / gesummv family
# ---------------------------------------------------------------------------
def _gemver_arrays(s):
    n = s["n"]
    return (Array("A", (n, n)), Array("u1", (n,)), Array("v1", (n,)),
            Array("u2", (n,)), Array("v2", (n,)), Array("w", (n,)),
            Array("x", (n,)), Array("y", (n,)), Array("z", (n,)))


def _gemver_comps():
    a_up = C("a_up", acc("A", "i", "j"),
             [acc("A", "i", "j"), acc("u1", "i"), acc("v1", "j"),
              acc("u2", "i"), acc("v2", "j")],
             Read(0) + Read(1) * Read(2) + Read(3) * Read(4))
    x_up = C("x_up", acc("x", "j2"), [acc("A", "i2", "j2"), acc("y", "i2")],
             BETA * Read(0) * Read(1), accumulate="+")
    x_z = C("x_z", acc("x", "j3"), [acc("x", "j3"), acc("z", "j3")],
            Read(0) + Read(1))
    w_up = C("w_up", acc("w", "i4"), [acc("A", "i4", "j4"), acc("x", "j4")],
             ALPHA * Read(0) * Read(1), accumulate="+")
    return a_up, x_up, x_z, w_up


def gemver_a(s):
    a_up, x_up, x_z, w_up = _gemver_comps()
    n = s["n"]
    return Program("gemver_a", _gemver_arrays(s), (
        L("i", n, L("j", n, a_up)),
        L("i2", n, L("j2", n, x_up)),
        L("j3", n, x_z),
        L("i4", n, L("j4", n, w_up)),
    ))


def gemver_b(s):  # x-stage in (j,i) order; w-stage (j,i) order
    a_up, x_up, x_z, w_up = _gemver_comps()
    n = s["n"]
    return Program("gemver_b", _gemver_arrays(s), (
        L("j", n, L("i", n, a_up)),
        L("j2", n, L("i2", n, x_up)),
        L("j3", n, x_z),
        L("j4", n, L("i4", n, w_up)),
    ))


_register("gemver",
          {"mini": dict(n=20), "bench": dict(n=1000)},
          "w", a=gemver_a, b=gemver_b, np=gemver_b)


def _gesummv_arrays(s):
    n = s["n"]
    return (Array("A", (n, n)), Array("B", (n, n)), Array("x", (n,)),
            Array("y", (n,)), Array("tmp", (n,)))


def _gesummv_comps():
    zt = C("zt", acc("tmp", "i"), [], ZERO)
    zy = C("zy", acc("y", "i"), [], ZERO)
    ct = C("ct", acc("tmp", "i"), [acc("A", "i", "j"), acc("x", "j")],
           Read(0) * Read(1), accumulate="+")
    cy = C("cy", acc("y", "i"), [acc("B", "i", "j"), acc("x", "j")],
           Read(0) * Read(1), accumulate="+")
    fin = C("fin", acc("y", "i"), [acc("tmp", "i"), acc("y", "i")],
            ALPHA * Read(0) + BETA * Read(1))
    return zt, zy, ct, cy, fin


def gesummv_a(s):  # polybench: one i loop: zero, j loop (both MACs), finalize
    zt, zy, ct, cy, fin = _gesummv_comps()
    n = s["n"]
    return Program("gesummv_a", _gesummv_arrays(s), (
        L("i", n, zt, zy, L("j", n, ct, cy), fin),
    ))


def gesummv_b(s):  # fissioned; MACs in (j,i) order
    zt, zy, ct, cy, fin = _gesummv_comps()
    n = s["n"]
    return Program("gesummv_b", _gesummv_arrays(s), (
        L("i", n, zt), L("i1", n, zy.rename({"i": "i1"})),
        L("j", n, L("i2", n, ct.rename({"i": "i2"}))),
        L("j2", n, L("i3", n, cy.rename({"i": "i3", "j": "j2"}))),
        L("i4", n, fin.rename({"i": "i4"})),
    ))


_register("gesummv",
          {"mini": dict(n=20), "bench": dict(n=1000)},
          "y", a=gesummv_a, b=gesummv_b, np=gesummv_b)


# ---------------------------------------------------------------------------
# doitgen: sum[r,q,p] = A[r,q,s]*C4[s,p];  A[r,q,p] = sum[r,q,p]
# ---------------------------------------------------------------------------
def _doitgen_arrays(s):
    return (Array("A", (s["nr"], s["nq"], s["np"])),
            Array("C4", (s["np"], s["np"])),
            Array("sum", (s["nr"], s["nq"], s["np"])))


def _doitgen_comps():
    z = C("z", acc("sum", "r", "q", "p"), [], ZERO)
    m = C("m", acc("sum", "r", "q", "p"), [acc("A", "r", "q", "s"), acc("C4", "s", "p")],
          Read(0) * Read(1), accumulate="+")
    cp = C("cp", acc("A", "r", "q", "p2"), [acc("sum", "r", "q", "p2")], Read(0))
    return z, m, cp


def doitgen_a(s):  # polybench: for r, q { for p {z; for s: m}; for p: copy }
    z, m, cp = _doitgen_comps()
    return Program("doitgen_a", _doitgen_arrays(s), (
        L("r", s["nr"], L("q", s["nq"],
          L("p", s["np"], z, L("s", s["np"], m)),
          L("p2", s["np"], cp))),
    ))


def doitgen_b(s):  # fissioned; contraction with s outer
    z, m, cp = _doitgen_comps()
    return Program("doitgen_b", _doitgen_arrays(s), (
        L("r", s["nr"], L("q", s["nq"], L("p", s["np"], z))),
        L("s", s["np"], L("r2", s["nr"], L("q2", s["nq"], L("p3", s["np"],
          m.rename({"r": "r2", "q": "q2", "p": "p3"})))),),
        L("r3", s["nr"], L("q3", s["nq"], L("p2", s["np"],
          cp.rename({"r": "r3", "q": "q3"})))),
    ))


_register("doitgen",
          {"mini": dict(nr=8, nq=10, np=12), "bench": dict(nr=64, nq=64, np=64)},
          "A", a=doitgen_a, b=doitgen_b, np=doitgen_b)


# ---------------------------------------------------------------------------
# jacobi-2d: T steps of 5-point smoothing A->Bt, Bt->A
# ---------------------------------------------------------------------------
def _jacobi_arrays(s):
    return (Array("A", (s["n"], s["n"])), Array("Bt", (s["n"], s["n"])))


def _stencil5(name, dst, src, i, j):
    return C(name, acc(dst, i, j),
             [acc(src, i, j),
              acc(src, i, aff(j, const=-1)), acc(src, i, aff(j, const=1)),
              acc(src, aff(i, const=1), j), acc(src, aff(i, const=-1), j)],
             0.2 * (Read(0) + Read(1) + Read(2) + Read(3) + Read(4)))


def jacobi2d_a(s):
    n = s["n"]
    s1 = _stencil5("s1", "Bt", "A", "i", "j")
    s2 = _stencil5("s2", "A", "Bt", "i2", "j2")
    return Program("jacobi2d_a", _jacobi_arrays(s), (
        Loop("t", s["t"], body=(
            Loop("i", n - 1, start=1, body=(Loop("j", n - 1, start=1, body=(s1,)),)),
            Loop("i2", n - 1, start=1, body=(Loop("j2", n - 1, start=1, body=(s2,)),)),
        )),
    ))


def jacobi2d_b(s):  # spatial loops transposed (j outer) — strided variant
    n = s["n"]
    s1 = _stencil5("s1", "Bt", "A", "i", "j")
    s2 = _stencil5("s2", "A", "Bt", "i2", "j2")
    return Program("jacobi2d_b", _jacobi_arrays(s), (
        Loop("t", s["t"], body=(
            Loop("j", n - 1, start=1, body=(Loop("i", n - 1, start=1, body=(s1,)),)),
            Loop("j2", n - 1, start=1, body=(Loop("i2", n - 1, start=1, body=(s2,)),)),
        )),
    ))


_register("jacobi-2d",
          {"mini": dict(n=14, t=4), "bench": dict(n=400, t=40)},
          "A", a=jacobi2d_a, b=jacobi2d_b, np=jacobi2d_a)


# ---------------------------------------------------------------------------
# heat-3d: T steps of 7-point 3D stencil, two-buffer
# ---------------------------------------------------------------------------
def _heat_arrays(s):
    n = s["n"]
    return (Array("A", (n, n, n)), Array("Bt", (n, n, n)))


def _stencil7(name, dst, src, i, j, k):
    return C(name, acc(dst, i, j, k),
             [acc(src, i, j, k),
              acc(src, aff(i, const=1), j, k), acc(src, aff(i, const=-1), j, k),
              acc(src, i, aff(j, const=1), k), acc(src, i, aff(j, const=-1), k),
              acc(src, i, j, aff(k, const=1)), acc(src, i, j, aff(k, const=-1))],
             Read(0) + 0.125 * (Read(1) - 2.0 * Read(0) + Read(2))
             + 0.125 * (Read(3) - 2.0 * Read(0) + Read(4))
             + 0.125 * (Read(5) - 2.0 * Read(0) + Read(6)))


def heat3d_a(s):
    n = s["n"]
    s1 = _stencil7("s1", "Bt", "A", "i", "j", "k")
    s2 = _stencil7("s2", "A", "Bt", "i2", "j2", "k2")
    return Program("heat3d_a", _heat_arrays(s), (
        Loop("t", s["t"], body=(
            Loop("i", n - 1, start=1, body=(Loop("j", n - 1, start=1, body=(
                Loop("k", n - 1, start=1, body=(s1,)),)),)),
            Loop("i2", n - 1, start=1, body=(Loop("j2", n - 1, start=1, body=(
                Loop("k2", n - 1, start=1, body=(s2,)),)),)),
        )),
    ))


def heat3d_b(s):  # (k,j,i) spatial order — fully strided
    n = s["n"]
    s1 = _stencil7("s1", "Bt", "A", "i", "j", "k")
    s2 = _stencil7("s2", "A", "Bt", "i2", "j2", "k2")
    return Program("heat3d_b", _heat_arrays(s), (
        Loop("t", s["t"], body=(
            Loop("k", n - 1, start=1, body=(Loop("j", n - 1, start=1, body=(
                Loop("i", n - 1, start=1, body=(s1,)),)),)),
            Loop("k2", n - 1, start=1, body=(Loop("j2", n - 1, start=1, body=(
                Loop("i2", n - 1, start=1, body=(s2,)),)),)),
        )),
    ))


_register("heat-3d",
          {"mini": dict(n=10, t=3), "bench": dict(n=80, t=20)},
          "A", a=heat3d_a, b=heat3d_b, np=heat3d_a)


# ---------------------------------------------------------------------------
# fdtd-2d: electromagnetic FDTD kernel, 4 statements under the time loop
# ---------------------------------------------------------------------------
def _fdtd_arrays(s):
    return (Array("ex", (s["nx"], s["ny"])), Array("ey", (s["nx"], s["ny"])),
            Array("hz", (s["nx"], s["ny"])), Array("fict", (s["t"],)))


def _fdtd_comps():
    s0 = C("s0", acc("ey", aff(const=0), "j0"), [acc("fict", "t")], Read(0))
    s1 = C("s1", acc("ey", "i1", "j1"),
           [acc("ey", "i1", "j1"), acc("hz", "i1", "j1"),
            acc("hz", aff("i1", const=-1), "j1")],
           Read(0) - 0.5 * (Read(1) - Read(2)))
    s2 = C("s2", acc("ex", "i2", "j2"),
           [acc("ex", "i2", "j2"), acc("hz", "i2", "j2"),
            acc("hz", "i2", aff("j2", const=-1))],
           Read(0) - 0.5 * (Read(1) - Read(2)))
    s3 = C("s3", acc("hz", "i3", "j3"),
           [acc("hz", "i3", "j3"), acc("ex", "i3", aff("j3", const=1)),
            acc("ex", "i3", "j3"), acc("ey", aff("i3", const=1), "j3"),
            acc("ey", "i3", "j3")],
           Read(0) - 0.7 * (Read(1) - Read(2) + Read(3) - Read(4)))
    return s0, s1, s2, s3


def fdtd2d_a(s):
    s0, s1, s2, s3 = _fdtd_comps()
    nx, ny = s["nx"], s["ny"]
    return Program("fdtd2d_a", _fdtd_arrays(s), (
        Loop("t", s["t"], body=(
            Loop("j0", ny, body=(s0,)),
            Loop("i1", nx, start=1, body=(Loop("j1", ny, body=(s1,)),)),
            Loop("i2", nx, body=(Loop("j2", ny, start=1, body=(s2,)),)),
            Loop("i3", nx - 1, body=(Loop("j3", ny - 1, body=(s3,)),)),
        )),
    ))


def fdtd2d_b(s):  # spatial loops transposed — the paper's pathological variant
    s0, s1, s2, s3 = _fdtd_comps()
    nx, ny = s["nx"], s["ny"]
    return Program("fdtd2d_b", _fdtd_arrays(s), (
        Loop("t", s["t"], body=(
            Loop("j0", ny, body=(s0,)),
            Loop("j1", ny, body=(Loop("i1", nx, start=1, body=(s1,)),)),
            Loop("j2", ny, start=1, body=(Loop("i2", nx, body=(s2,)),)),
            Loop("j3", ny - 1, body=(Loop("i3", nx - 1, body=(s3,)),)),
        )),
    ))


_register("fdtd-2d",
          {"mini": dict(nx=12, ny=14, t=4), "bench": dict(nx=400, ny=400, t=40)},
          "hz", a=fdtd2d_a, b=fdtd2d_b, np=fdtd2d_a)


# ---------------------------------------------------------------------------
# correlation / covariance
# ---------------------------------------------------------------------------
def _corr_arrays(s):
    m, n = s["m"], s["n"]
    return (Array("data", (n, m)), Array("mean", (m,)), Array("stddev", (m,)),
            Array("corr", (m, m)))


def _corr_comps(n_float):
    zm = C("zm", acc("mean", "j"), [], ZERO)
    sm = C("sm", acc("mean", "j"), [acc("data", "i", "j")], Read(0),
           accumulate="+")
    dm = C("dm", acc("mean", "j2"), [acc("mean", "j2")], Read(0) / n_float)
    zs = C("zs", acc("stddev", "j3"), [], ZERO)
    ss = C("ss", acc("stddev", "j3"), [acc("data", "i3", "j3"), acc("mean", "j3")],
           (Read(0) - Read(1)) * (Read(0) - Read(1)), accumulate="+")
    import numpy as _np

    def _finish_std(s_):
        import jax.numpy as jnp
        x = (s_ / n_float) ** 0.5
        # guard against ~0 stddev exactly like polybench (<=0.1 -> 1.0)
        mod = jnp if not isinstance(s_, (float, _np.floating, _np.ndarray)) else _np
        return mod.where(x <= 0.1, 1.0, x)

    ds = C("ds", acc("stddev", "j4"), [acc("stddev", "j4")],
           Call("finish_std", _finish_std, (Read(0),)))
    cn = C("cn", acc("data", "i5", "j5"),
           [acc("data", "i5", "j5"), acc("mean", "j5"), acc("stddev", "j5")],
           (Read(0) - Read(1)) / ((n_float ** 0.5) * Read(2)))
    zc = C("zc", acc("corr", "k1", "k2"), [], Const(1.0))
    cc = C("cc", acc("corr", "k3", "k4"),
           [acc("data", "i6", "k3"), acc("data", "i6", "k4")],
           Read(0) * Read(1), accumulate="+",
           guards=[aff("k4", ("k3", -1), const=-1)])  # k4 > k3
    sym = C("sym", acc("corr", "k6", "k5"), [acc("corr", "k5", "k6")],
            Read(0), guards=[aff("k6", ("k5", -1), const=-1)])
    return zm, sm, dm, zs, ss, ds, cn, zc, cc, sym


def correlation_a(s):
    m, n = s["m"], s["n"]
    zm, sm, dm, zs, ss, ds, cn, zc, cc, sym = _corr_comps(float(n))
    return Program("correlation_a", _corr_arrays(s), (
        L("j", m, zm, Loop("i", n, body=(sm,)), ),
        L("j2", m, dm),
        L("j3", m, zs, Loop("i3", n, body=(ss,))),
        L("j4", m, ds),
        L("i5", n, L("j5", m, cn)),
        L("k1", m, L("k2", m, zc)),
        L("k3", m, L("k4", m, L("i6", n, cc))),
        L("k5", m, L("k6", m, sym)),
    ))


def correlation_b(s):  # reductions in (i,j) order, corr in (i,k,k') order
    m, n = s["m"], s["n"]
    zm, sm, dm, zs, ss, ds, cn, zc, cc, sym = _corr_comps(float(n))
    return Program("correlation_b", _corr_arrays(s), (
        L("j", m, zm),
        L("i", n, L("jj", m, sm.rename({"j": "jj"}))),
        L("j2", m, dm),
        L("j3", m, zs),
        L("i3", n, L("jj3", m, ss.rename({"j3": "jj3"}))),
        L("j4", m, ds),
        L("j5", m, L("i5", n, cn)),
        L("k1", m, L("k2", m, zc)),
        L("i6", n, L("k3", m, L("k4", m, cc))),
        L("k5", m, L("k6", m, sym)),
    ))


_register("correlation",
          {"mini": dict(m=12, n=16), "bench": dict(m=240, n=260)},
          "corr", a=correlation_a, b=correlation_b, np=correlation_b)


def _cov_arrays(s):
    m, n = s["m"], s["n"]
    return (Array("data", (n, m)), Array("mean", (m,)), Array("cov", (m, m)))


def _cov_comps(n_float):
    zm = C("zm", acc("mean", "j"), [], ZERO)
    sm = C("sm", acc("mean", "j"), [acc("data", "i", "j")], Read(0),
           accumulate="+")
    dm = C("dm", acc("mean", "j2"), [acc("mean", "j2")], Read(0) / n_float)
    cn = C("cn", acc("data", "i5", "j5"), [acc("data", "i5", "j5"), acc("mean", "j5")],
           Read(0) - Read(1))
    zc = C("zc", acc("cov", "k1", "k2"), [], ZERO,
           guards=[aff("k2", ("k1", -1))])  # k2 >= k1
    cc = C("cc", acc("cov", "k3", "k4"),
           [acc("data", "i6", "k3"), acc("data", "i6", "k4")],
           Read(0) * Read(1) / (n_float - 1.0), accumulate="+",
           guards=[aff("k4", ("k3", -1))])
    sym = C("sym", acc("cov", "k6", "k5"), [acc("cov", "k5", "k6")],
            Read(0), guards=[aff("k6", ("k5", -1), const=-1)])
    return zm, sm, dm, cn, zc, cc, sym


def covariance_a(s):
    m, n = s["m"], s["n"]
    zm, sm, dm, cn, zc, cc, sym = _cov_comps(float(n))
    return Program("covariance_a", _cov_arrays(s), (
        L("j", m, zm, Loop("i", n, body=(sm,))),
        L("j2", m, dm),
        L("i5", n, L("j5", m, cn)),
        L("k1", m, L("k2", m, zc, Loop("i6", n, body=(cc.rename({"k3": "k1", "k4": "k2"}),)), )),
        L("k5", m, L("k6", m, sym)),
    ))


def covariance_b(s):
    m, n = s["m"], s["n"]
    zm, sm, dm, cn, zc, cc, sym = _cov_comps(float(n))
    return Program("covariance_b", _cov_arrays(s), (
        L("j", m, zm),
        L("i", n, L("jj", m, sm.rename({"j": "jj"}))),
        L("j2", m, dm),
        L("j5", m, L("i5", n, cn)),
        L("k1", m, L("k2", m, zc)),
        L("i6", n, L("k3", m, L("k4", m, cc))),
        L("k5", m, L("k6", m, sym)),
    ))


_register("covariance",
          {"mini": dict(m=12, n=16), "bench": dict(m=240, n=260)},
          "cov", a=covariance_a, b=covariance_b, np=covariance_b)


BENCHMARKS: dict[str, Benchmark] = dict(_B)
NAMES = tuple(BENCHMARKS)
assert len(NAMES) == 15, NAMES
