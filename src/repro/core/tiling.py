"""Grid-tiling planner for canonical nests (the Pallas lowering's front half).

Normalization collapses loop-nest variants onto canonical forms; this module
decides how a canonical nest's iteration space maps onto a Pallas grid:

* **parallel iterators** (no carried dependence, appear in every write) become
  grid dimensions, each partitioned into VPU-aligned tiles ``(…, sublane=8k,
  lane=128k)``;
* for reductions, the **innermost reduction iterator** becomes one extra
  'arbitrary' grid dimension accumulated through a VMEM scratch block (the
  GEMM pattern generalized to any associative accumulate), while outer
  reduction iterators stay whole inside the tile;
* **constant-offset reads** (stencils) and non-zero loop starts are handled by
  halo padding: the planner computes, per array dimension, how far accesses
  reach outside ``[0, extent)`` so the emitter can pad-and-shift each operand
  into a view whose blocks are exactly tile-aligned (one BlockSpec per affine
  access map — overlapping halo reads become *distinct operands*, which is
  how Pallas expresses them without giving up blocked pipelining).

Tile sizes come from the recipe (``Recipe.tile`` / ``Schedule.nest_tile``,
assigned to the innermost axes) or default to whole extents shrunk until the
estimated VMEM working set — the sum of all operand blocks plus the
accumulator — fits the budget.

The planner is deliberately strict: anything it cannot prove tileable
(carried dependences, multi-iterator or non-unit-coefficient subscripts,
scalar targets) raises ``TilingError`` and the caller falls back to the
generic vectorized lowering.  Everything it accepts is exactly the class the
paper's normalization produces for PolyBench and CLOUDSC.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Sequence

from .codegen import _ACC_INIT, Unsupported
from .dependence import EQ, nest_direction_vectors
from .ir import (
    Access,
    Affine,
    Computation,
    Loop,
    Node,
    Program,
    loop_iterators,
    nest_computations,
)

LANE = 128    # TPU lane width (last axis)
SUBLANE = 8   # fp32 sublane (second-to-last axis)

DEFAULT_VMEM_BUDGET = 1 << 23  # bytes (~8 MB of the ~16 MB/core VMEM)


class TilingError(Unsupported):
    """The nest is outside the tiled-Pallas class; fall back to vectorize."""


@dataclass(frozen=True)
class TiledIter:
    """One iterator of the nest mapped onto the grid (or kept in-tile)."""

    name: str
    start: int
    stop: int
    tile: int
    role: str  # 'parallel' | 'reduce_grid' | 'reduce_inner'

    @property
    def trip(self) -> int:
        """Iteration count of the underlying loop range."""
        return max(0, self.stop - self.start)

    @property
    def n_tiles(self) -> int:
        """Grid extent along this iterator (ceil-divided, at least 1)."""
        return max(1, -(-self.trip // self.tile))


@dataclass(frozen=True)
class DimMap:
    """How one array dimension of an access maps onto the plan.

    ``iterator`` is None for constant subscripts; ``const`` carries the
    affine constant (the stencil offset / loop-start shift folded into the
    operand view's origin by the emitter).
    """

    iterator: str | None
    const: int


@dataclass
class TilePlan:
    """Complete tiling decision for one nest: axis roles, grid, halos."""

    kind: str                         # 'parallel' | 'reduce'
    parallel: tuple[TiledIter, ...]   # loop order (outer -> inner)
    reduce_inner: tuple[TiledIter, ...]
    reduce_grid: TiledIter | None
    comps: tuple[Computation, ...]    # program order
    grid: tuple[int, ...]             # parallel tiles (+ reduction tiles last)
    vmem_bytes: int
    halo: dict[str, tuple[tuple[int, int], ...]]  # array -> per-dim (lo, hi) pad

    @property
    def axes(self) -> tuple[TiledIter, ...]:
        """Canonical slab axis order: parallel, inner reductions, grid reduction."""
        tail = (self.reduce_grid,) if self.reduce_grid is not None else ()
        return self.parallel + self.reduce_inner + tail

    @property
    def axis_of(self) -> dict[str, int]:
        """Iterator name -> position in the canonical slab axis order."""
        return {a.name: k for k, a in enumerate(self.axes)}

    @property
    def iter_of(self) -> dict[str, TiledIter]:
        """Iterator name -> its ``TiledIter``."""
        return {a.name: a for a in self.axes}

    def access_dims(self, a: Access) -> list[DimMap]:
        """Per-dimension ``DimMap`` of one access under this plan."""
        return [_dim_map(ix, self.iter_of) for ix in a.index]


def _dim_map(ix: Affine, iters: Mapping[str, TiledIter]) -> DimMap:
    its = ix.iterators()
    if not its:
        if ix.coeffs:  # non-affine marker
            raise TilingError("non-affine subscript")
        return DimMap(None, ix.const)
    if len(its) != 1 or ix.coeff(its[0]) != 1:
        raise TilingError(f"subscript {ix!r} is not a unit-coefficient iterator")
    if its[0] not in iters:
        raise TilingError(f"iterator {its[0]} not bound by the nest")
    return DimMap(its[0], ix.const)


def _loop_bounds(nest: Node) -> dict[str, tuple[int, int]]:
    out: dict[str, tuple[int, int]] = {}

    def rec(n: Node) -> None:
        if isinstance(n, Loop):
            if n.step != 1:
                raise TilingError(f"loop {n.iterator} has step {n.step}")
            out[n.iterator] = (n.start, n.stop)
            for b in n.body:
                rec(b)

    rec(nest)
    return out


def _align_floor(axis_pos: int, n_axes: int, trip: int) -> tuple[int, int]:
    """(alignment, floor) for auto-chosen tiles: lane axis multiples of 128,
    sublane axis multiples of 8, outer axes unconstrained."""
    if axis_pos == n_axes - 1:
        unit = LANE
    elif axis_pos == n_axes - 2:
        unit = SUBLANE
    else:
        unit = 1
    return unit, min(unit, max(1, trip))


def _shrink_to_budget(
    tiles: list[int],
    trips: list[int],
    block_bytes,
    budget: int,
) -> list[int]:
    """Halve the largest tile (keeping VPU alignment) until the estimated
    working set fits; stop at the alignment floors."""
    n = len(tiles)
    while block_bytes(tiles) > budget:
        best, best_gain = -1, 0
        for k in range(n):
            unit, floor = _align_floor(k, n, trips[k])
            if tiles[k] <= floor:
                continue
            new = max(floor, -(-(tiles[k] // 2) // unit) * unit)
            if new < tiles[k] and tiles[k] - new > best_gain:
                best, best_gain = k, tiles[k] - new
        if best < 0:
            break  # at the floors everywhere: accept best effort
        unit, floor = _align_floor(best, n, trips[best])
        tiles[best] = max(floor, -(-(tiles[best] // 2) // unit) * unit)
    return tiles


def plan_nest_tiling(
    program: Program,
    nest: Node,
    tile: Sequence[int] | None = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> TilePlan:
    """Partition a canonical nest's iterators into a Pallas grid.

    Raises ``TilingError`` for anything outside the tiled class (carried
    dependences, non-unit subscripts, scalar writes, mixed write/reduction
    roles) — callers fall back to the generic lowering.
    """
    if not isinstance(nest, Loop):
        raise TilingError("bare computation")
    bounds = _loop_bounds(nest)
    iterators = list(loop_iterators(nest))
    comps = nest_computations(nest)
    trips = {it: max(0, bounds[it][1] - bounds[it][0]) for it in iterators}
    if any(t <= 0 for t in trips.values()):
        raise TilingError("empty iteration domain")

    vectors = nest_direction_vectors(iterators, trips, comps)
    carried = [it for k, it in enumerate(iterators)
               if any(v.directions[k] != EQ for v in vectors)]
    if carried:
        raise TilingError(f"carried iterators {carried} (recurrence)")

    used = {it for c in comps for it in c.iterators()}
    if set(iterators) - used:
        raise TilingError("nest has loops no computation references")

    # Per-computation iterator roles.  Reduction axes = used but not written.
    red_its: list[str] = []
    for c in comps:
        w_its = {it for ix in c.write.index for it in ix.iterators()}
        extra = [it for it in iterators if it in set(c.iterators()) - w_its]
        if extra:
            if c.accumulate is None:
                raise TilingError(f"{c.name}: assignment under non-write axes")
            red_its.extend(it for it in extra if it not in red_its)
        if not c.write.index:
            raise TilingError(f"{c.name}: scalar write target")

    if red_its:
        if len(comps) != 1:
            raise TilingError("reduction nest with multiple computations")
        if comps[0].accumulate not in _ACC_INIT:
            raise TilingError(f"unsupported accumulate {comps[0].accumulate!r}")
        kind = "reduce"
    else:
        kind = "parallel"
    par_its = [it for it in iterators if it not in red_its]
    # An accumulate under the full parallel grid re-executes once per grid
    # step of any axis it does not use — only safe when it uses them all.
    for c in comps:
        if c.accumulate is not None and kind == "parallel":
            if set(par_its) - set(c.iterators()):
                raise TilingError(f"{c.name}: accumulate misses grid iterators")

    # ---- tile sizes -------------------------------------------------------
    red_order = [it for it in iterators if it in red_its]
    grid_red_it = red_order[-1] if red_order else None
    par_tiles = [trips[it] for it in par_its]
    red_tile = trips[grid_red_it] if grid_red_it else None
    if tile:
        want = [max(1, int(x)) for x in tile]
        if kind == "reduce" and len(want) > 1:
            red_tile = min(want.pop(), red_tile)
        want = want[-len(par_its):] if par_its else []
        for k, w in zip(range(len(par_its) - len(want), len(par_its)), want):
            par_tiles[k] = min(w, par_tiles[k])
    else:
        all_tiles = par_tiles + ([red_tile] if red_tile else [])
        all_trips = [trips[it] for it in par_its] + (
            [trips[grid_red_it]] if grid_red_it else [])

        def est(ts: list[int]) -> int:
            """VMEM estimate for a candidate tile assignment."""
            p = dict(zip(par_its + ([grid_red_it] if grid_red_it else []), ts))
            return _estimate_vmem(program, comps, p, trips, red_order)

        all_tiles = _shrink_to_budget(all_tiles, all_trips, est, vmem_budget)
        par_tiles = all_tiles[: len(par_its)]
        if grid_red_it:
            red_tile = all_tiles[-1]

    parallel = tuple(
        TiledIter(it, *bounds[it], tile=t, role="parallel")
        for it, t in zip(par_its, par_tiles)
    )
    reduce_inner = tuple(
        TiledIter(it, *bounds[it], tile=trips[it], role="reduce_inner")
        for it in red_order[:-1]
    )
    reduce_grid = (
        TiledIter(grid_red_it, *bounds[grid_red_it], tile=red_tile,
                  role="reduce_grid")
        if grid_red_it else None
    )
    grid = tuple(p.n_tiles for p in parallel)
    if reduce_grid is not None:
        grid = grid + (reduce_grid.n_tiles,)

    plan = TilePlan(
        kind=kind, parallel=parallel, reduce_inner=reduce_inner,
        reduce_grid=reduce_grid, comps=tuple(comps), grid=grid,
        vmem_bytes=0, halo={},
    )
    _validate_accesses(program, plan)
    plan.halo = _halo(program, plan)
    tile_map = {a.name: a.tile for a in plan.axes}
    plan.vmem_bytes = _estimate_vmem(program, comps, tile_map, trips, red_order)
    return plan


def _validate_accesses(program: Program, plan: TilePlan) -> None:
    writes: dict[str, tuple] = {}
    par = {a.name for a in plan.parallel}
    for c in plan.comps:
        for a in (c.write,) + c.reads:
            dims = plan.access_dims(a)  # raises on non-unit subscripts
            seen = [d.iterator for d in dims if d.iterator is not None]
            if len(seen) != len(set(seen)):
                raise TilingError(f"{a.array}: iterator used in two dims")
            if len(dims) != len(program.array(a.array).shape):
                raise TilingError(f"{a.array}: rank mismatch")
        wdims = plan.access_dims(c.write)
        if any(d.iterator is not None and d.iterator not in par for d in wdims):
            raise TilingError(f"{c.name}: write subscript uses reduction axis")
        prev = writes.get(c.write.array)
        if prev is not None and prev != c.write.index:
            raise TilingError(f"{c.write.array}: two write maps in one nest")
        writes[c.write.array] = c.write.index
        # reads of an array written earlier in the nest must match the write
        # map exactly (the emitter forwards the in-kernel slab)
        for r in c.reads:
            if r.array in writes and writes[r.array] != r.index:
                raise TilingError(f"{r.array}: read of stale in-kernel write")


def _halo(program: Program, plan: TilePlan) -> dict[str, tuple[tuple[int, int], ...]]:
    """Per array dimension, how far padded views reach outside [0, extent).

    A dimension subscripted ``it + c`` is materialized (by the emitter) as a
    view of length ``n_tiles * tile`` starting at ``start + c`` — the pad
    covers both the stencil offsets and the tile-rounding tail."""
    iters = plan.iter_of
    lo: dict[str, list[int]] = {}
    hi: dict[str, list[int]] = {}
    for c in plan.comps:
        for a in (c.write,) + c.reads:
            shape = program.array(a.array).shape
            l = lo.setdefault(a.array, [0] * len(shape))
            h = hi.setdefault(a.array, [0] * len(shape))
            for d, dm in enumerate(plan.access_dims(a)):
                if dm.iterator is None:
                    if not 0 <= dm.const < shape[d]:
                        raise TilingError(f"{a.array}: constant index OOB")
                    continue
                ti = iters[dm.iterator]
                origin = ti.start + dm.const
                span = ti.n_tiles * ti.tile
                l[d] = max(l[d], -origin)
                h[d] = max(h[d], origin + span - shape[d])
    return {k: tuple(zip(lo[k], hi[k])) for k in lo}


def _estimate_vmem(
    program: Program,
    comps: Sequence[Computation],
    tile_of: Mapping[str, int],
    trips: Mapping[str, int],
    red_order: Sequence[str],
) -> int:
    """Bytes resident per grid step: one block per distinct access map plus
    the old-content alias of each output and the reduction accumulator."""
    itemsize = 4
    inner = set(red_order[:-1])

    def block_elems(a: Access) -> int:
        n = 1
        for ix in a.index:
            its = ix.iterators()
            if not its:
                continue
            it = its[0]
            n *= trips[it] if it in inner else tile_of.get(it, trips[it])
        return n

    seen: set[tuple] = set()
    total = 0
    for c in comps:
        for a in (c.write,) + c.reads:
            key = (a.array, a.index)
            if key in seen:
                continue
            seen.add(key)
            total += block_elems(a) * itemsize
        # output block + accumulator scratch for reductions
        total += block_elems(c.write) * itemsize
        if c.accumulate is not None and red_order:
            total += block_elems(c.write) * itemsize
    return total
