"""Evolutionary recipe search (paper §4, "Seeding a Scheduling Database").

The paper seeds candidate optimizations per nest (originally from the
Tiramisu auto-scheduler — unavailable offline, replaced by an analytical
seed: the idiom-derived recipe plus perturbations), refines them over a few
iterations of mutation + selection with measured runtime as fitness, and
re-seeds from the recipes of the most similar nests (transfer).
"""
from __future__ import annotations

import random
from dataclasses import replace
from typing import Callable, Mapping

import jax
import numpy as np

from .codegen import Schedule, compile_jax
from .idioms import IdiomMatch
from .ir import Node, Program
from .recipes import GEMM_TILE_PRESETS, Recipe
from .util import time_fn


def default_recipe_for(idiom: IdiomMatch) -> Recipe:
    if idiom.kind in ("blas3",):
        return Recipe(kind="einsum", notes=f"idiom:{idiom.kind}")
    if idiom.kind in ("blas2", "dot"):
        return Recipe(kind="einsum", notes=f"idiom:{idiom.kind}")
    if idiom.kind == "recurrence":
        return Recipe(kind="vectorize", notes="recurrence: carried iterators stay sequential")
    return Recipe(kind="vectorize", notes=f"idiom:{idiom.kind}")


def schedule_from_recipe(recipe: Recipe, interpret: bool = True) -> Schedule:
    if recipe.kind == "einsum":
        return Schedule(mode="canonical", use_idioms=True, vec_budget=recipe.vec_budget,
                        pallas_gemm=False, interpret=interpret)
    if recipe.kind == "pallas_gemm":
        return Schedule(mode="canonical", use_idioms=True, vec_budget=recipe.vec_budget,
                        pallas_gemm=True, tile=recipe.tile, interpret=interpret)
    if recipe.kind == "sequential":
        return Schedule(mode="as_written", use_idioms=False, vec_budget=recipe.vec_budget,
                        interpret=interpret)
    return Schedule(mode="canonical", use_idioms=False, vec_budget=recipe.vec_budget,
                    interpret=interpret)


def _mutate(recipe: Recipe, rng: random.Random) -> Recipe:
    r = recipe
    roll = rng.random()
    if roll < 0.3:
        r = replace(r, vec_budget=max(1 << 16, min(1 << 24, int(r.vec_budget * rng.choice([0.25, 0.5, 2, 4])))))
    elif roll < 0.6 and r.kind in ("einsum", "vectorize"):
        r = replace(r, kind="vectorize" if r.kind == "einsum" else "einsum")
    elif roll < 0.8 and r.kind == "pallas_gemm":
        r = replace(r, tile=rng.choice(GEMM_TILE_PRESETS))
    else:
        r = replace(r, unroll=rng.choice([1, 2, 4]))
    return r


def measure_recipe(
    nest_program: Program,
    inputs: Mapping[str, np.ndarray],
    recipe: Recipe,
    repeats: int = 3,
) -> float:
    """Wall time (us) of one nest lowered under ``recipe``; inf on failure."""
    try:
        sched = schedule_from_recipe(recipe)
        fn = jax.jit(compile_jax(nest_program, sched))
        args = {k: np.asarray(v, dtype=np.float32) for k, v in inputs.items()}
        return time_fn(lambda: fn(args), repeats=repeats)
    except Exception:
        return float("inf")


def evolve_recipe(
    nest_program: Program,
    inputs: Mapping[str, np.ndarray],
    seed_recipe: Recipe,
    iterations: int = 3,
    population: int = 4,
    rng_seed: int = 0,
    reseed_pool: list[Recipe] | None = None,
) -> tuple[Recipe, float]:
    """Mutation+selection over recipes, runtime fitness (paper's epochs).

    ``reseed_pool`` models the paper's 2nd/3rd epochs: recipes of the most
    similar nests (by embedding distance) join the population.
    """
    rng = random.Random(rng_seed)
    pop = [seed_recipe] + [_mutate(seed_recipe, rng) for _ in range(population - 1)]
    if reseed_pool:
        pop.extend(reseed_pool[: population // 2])
    best, best_t = seed_recipe, measure_recipe(nest_program, inputs, seed_recipe)
    for _ in range(iterations):
        scored = [(measure_recipe(nest_program, inputs, r), r) for r in pop]
        scored.sort(key=lambda t: t[0])
        if scored[0][0] < best_t:
            best_t, best = scored[0]
        survivors = [r for _, r in scored[: max(2, population // 2)]]
        pop = survivors + [_mutate(rng.choice(survivors), rng) for _ in range(population - len(survivors))]
    return best, best_t
