"""Evolutionary recipe search (paper §4, "Seeding a Scheduling Database").

The paper seeds candidate optimizations per nest (originally from the
Tiramisu auto-scheduler — unavailable offline, replaced by an analytical
seed: the idiom-derived recipe plus perturbations), refines them over a few
iterations of mutation + selection with measured runtime as fitness, and
re-seeds from the recipes of the most similar nests (transfer).
"""
from __future__ import annotations

import math
import random
import time
import zlib
from dataclasses import replace
from typing import Callable, Mapping

import jax
import numpy as np

from .codegen import Schedule, compile_jax
from .idioms import IdiomMatch
from .ir import Node, Program
from .recipes import (
    GEMM_TILE_PRESETS,
    NEST_TILE_PRESETS,
    REDUCE_TILE_PRESETS,
    Recipe,
)
from .util import time_fn


def default_recipe_for(idiom: IdiomMatch) -> Recipe:
    """The idiom-keyed fallback recipe when the database has no entry."""
    if idiom.kind in ("blas3",):
        return Recipe(kind="einsum", notes=f"idiom:{idiom.kind}")
    if idiom.kind in ("blas2", "dot"):
        return Recipe(kind="einsum", notes=f"idiom:{idiom.kind}")
    if idiom.kind == "recurrence":
        return Recipe(kind="vectorize", notes="recurrence: carried iterators stay sequential")
    return Recipe(kind="vectorize", notes=f"idiom:{idiom.kind}")


def schedule_from_recipe(
    recipe: Recipe, interpret: bool = True, shard_axis: str | None = None
) -> Schedule:
    """Recipe -> Schedule.  ``shard_axis`` is the scheduler-level default
    mesh axis (``Daisy.shard_axis`` under a mesh); the recipe's own
    ``parallelize`` knob — the one the evolutionary search may flip — wins
    when set: an axis name pins the nest to that axis, the ``'none'``
    sentinel disables sharding for the nest (None defers to the default)."""
    axis = recipe.parallelize or shard_axis
    if axis == "none":
        axis = None
    if recipe.kind == "einsum":
        return Schedule(mode="canonical", use_idioms=True, vec_budget=recipe.vec_budget,
                        pallas_gemm=False, interpret=interpret, shard_axis=axis)
    if recipe.kind == "pallas_gemm":
        return Schedule(mode="canonical", use_idioms=True, vec_budget=recipe.vec_budget,
                        pallas_gemm=True, tile=recipe.tile, interpret=interpret,
                        shard_axis=axis)
    if recipe.kind == "pallas_nest":
        return Schedule(mode="canonical", use_idioms=False, vec_budget=recipe.vec_budget,
                        pallas_nest=True, nest_tile=recipe.tile,
                        unroll=recipe.unroll, interpret=interpret, shard_axis=axis)
    if recipe.kind == "pallas_reduce":
        return Schedule(mode="canonical", use_idioms=False, vec_budget=recipe.vec_budget,
                        pallas_reduce=True, nest_tile=recipe.tile,
                        unroll=recipe.unroll, interpret=interpret, shard_axis=axis)
    if recipe.kind == "sequential":
        return Schedule(mode="as_written", use_idioms=False, vec_budget=recipe.vec_budget,
                        interpret=interpret, shard_axis=axis)
    return Schedule(mode="canonical", use_idioms=False, vec_budget=recipe.vec_budget,
                    interpret=interpret, shard_axis=axis)


def _mutate(recipe: Recipe, rng: random.Random) -> Recipe:
    r = recipe
    roll = rng.random()
    if roll < 0.25:
        r = replace(r, vec_budget=max(1 << 16, min(1 << 24, int(r.vec_budget * rng.choice([0.25, 0.5, 2, 4])))))
    elif roll < 0.45 and r.kind in ("einsum", "vectorize"):
        r = replace(r, kind="vectorize" if r.kind == "einsum" else "einsum")
    elif roll < 0.6:
        # hop into / out of the grid-tiled Pallas class.  A pallas_* recipe
        # on a nest outside its class falls back to the generic lowering at
        # compile time, so mis-kinded mutants still measure (never crash) —
        # selection simply discards them when the fallback is slower.
        if r.kind == "vectorize":
            kind = rng.choice(["pallas_nest", "pallas_reduce"])
            presets = NEST_TILE_PRESETS if kind == "pallas_nest" else REDUCE_TILE_PRESETS
            r = replace(r, kind=kind, tile=rng.choice(presets))
        elif r.kind in ("pallas_nest", "pallas_reduce"):
            r = replace(r, kind="vectorize", tile=None)
        elif r.kind == "pallas_gemm":
            r = replace(r, tile=rng.choice(GEMM_TILE_PRESETS))
        elif r.kind == "einsum":
            # library-call reductions can try the tiled in-kernel reduction
            r = replace(r, kind="pallas_reduce", tile=rng.choice(REDUCE_TILE_PRESETS))
        else:  # 'sequential': the only remaining hop is back to vectorize
            r = replace(r, kind="vectorize", tile=None)
    elif roll < 0.85 and r.kind in ("pallas_nest", "pallas_reduce", "pallas_gemm"):
        presets = {"pallas_nest": NEST_TILE_PRESETS,
                   "pallas_reduce": REDUCE_TILE_PRESETS,
                   "pallas_gemm": GEMM_TILE_PRESETS}[r.kind]
        r = replace(r, tile=rng.choice(presets))
    elif roll < 0.95:
        r = replace(r, unroll=rng.choice([1, 2, 4]))
    else:
        # cycle the mesh-axis knob (None = scheduler default, 'none' =
        # sharding off for this nest, 'data' = pin): under a mesh,
        # ``Daisy.compile`` routes the nest through the partition planner
        # accordingly; single-device measurement is unaffected, so the knob
        # rides along neutrally until a mesh deployment reads it.
        cycle = {None: "data", "data": "none", "none": None}
        r = replace(r, parallelize=cycle.get(r.parallelize))
    return r


def nest_rng_seed(fingerprint: str, salt: str = "") -> int:
    """Deterministic per-nest RNG seed for the evolutionary search.

    Every nest gets its own mutation stream (a shared fixed seed would walk
    the identical mutation sequence for every nest in a batch), stable across
    runs and processes so tuning is reproducible.
    """
    return zlib.crc32(f"{salt}{fingerprint}".encode()) & 0x7FFFFFFF


def measure_recipe(
    nest_program: Program,
    inputs: Mapping[str, np.ndarray],
    recipe: Recipe,
    repeats: int = 3,
    interpret: bool = True,
) -> float:
    """Wall time (us) of one nest lowered under ``recipe``; inf on failure.

    ``interpret`` must match the lowering the deployment backend executes
    (``Daisy`` threads its own flag here): under ``backend='pallas'`` fitness
    taken from interpret-mode Pallas kernels does not rank like the compiled
    kernels ``Daisy.compile`` later runs.  Non-finite timings are rejected
    (reported as inf) so a broken measurement can never win selection.
    """
    try:
        sched = schedule_from_recipe(recipe, interpret=interpret)
        fn = jax.jit(compile_jax(nest_program, sched))
        args = {k: np.asarray(v, dtype=np.float32) for k, v in inputs.items()}
        t = time_fn(lambda: fn(args), repeats=repeats)
        return t if math.isfinite(t) else float("inf")
    except Exception:
        return float("inf")


def evolve_recipe(
    nest_program: Program,
    inputs: Mapping[str, np.ndarray],
    seed_recipe: Recipe,
    iterations: int = 3,
    population: int = 4,
    rng_seed: int = 0,
    reseed_pool: list[Recipe] | None = None,
    resolve: Callable[[Recipe], Recipe] | None = None,
    interpret: bool = True,
    repeats: int = 3,
    deadline_s: float | None = None,
) -> tuple[Recipe, float]:
    """Mutation+selection over recipes, runtime fitness (paper's epochs).

    ``reseed_pool`` models the paper's 2nd/3rd epochs: recipes of the most
    similar nests (by embedding distance) join the population.

    ``resolve`` (e.g. ``Daisy._backend_recipe``) maps each candidate onto
    the lowering the deployment backend will actually run before timing it,
    so fitness measures what ``compile()`` later executes — under the 'xla'
    backend Pallas-kind mutants are timed as their vectorize/einsum
    degradations and no Pallas kernel is ever built.  ``interpret`` is the
    other half of that contract: it selects interpret vs compiled Pallas,
    exactly as ``Daisy.compile`` does for the chosen backend.

    ``deadline_s`` is a wall-clock budget: when it expires mid-search the
    best recipe measured *so far* is returned (partial results) instead of
    the search overrunning its slot — how background deployment searches
    stay inside their scheduling window.  The budget changes only when
    measurement stops, never what is mutated: a run that finishes under
    its deadline walks the identical RNG sequence as an unbounded one.
    """
    rng = random.Random(rng_seed)
    deadline = (time.monotonic() + deadline_s) if deadline_s is not None else None

    def out_of_time() -> bool:
        """Whether the wall-clock deadline (if any) has expired."""
        return deadline is not None and time.monotonic() >= deadline

    pop = [seed_recipe] + [_mutate(seed_recipe, rng) for _ in range(population - 1)]
    if reseed_pool:
        pop.extend(reseed_pool[: population // 2])

    # Recipes are frozen (hashable) values: memoize each candidate's wall
    # time so survivors are timed once, not re-timed every iteration they
    # stay in the population (that re-timing dominated seed wall time).
    timed: dict[Recipe, float] = {}

    def fitness(r: Recipe) -> float:
        """Memoized wall time of one candidate recipe (lower is better)."""
        key = resolve(r) if resolve is not None else r
        if key not in timed:
            timed[key] = measure_recipe(
                nest_program, inputs, key, repeats=repeats, interpret=interpret
            )
        return timed[key]

    best, best_t = seed_recipe, fitness(seed_recipe)
    for _ in range(iterations):
        if out_of_time():
            break
        scored = []
        for r in pop:
            scored.append((fitness(r), r))
            if out_of_time():
                break
        scored.sort(key=lambda t: t[0])
        if scored and scored[0][0] < best_t:
            best_t, best = scored[0]
        if len(scored) < len(pop):
            break  # deadline cut this iteration short: keep the partial best
        survivors = [r for _, r in scored[: max(2, population // 2)]]
        pop = survivors + [_mutate(rng.choice(survivors), rng) for _ in range(population - len(survivors))]
    return best, best_t
