"""Optimization recipes — what the transfer-tuning database stores per nest.

A recipe is the downstream half of the paper's pipeline: after normalization
maps a nest to canonical form, the recipe says how to lower it.  Recipes are
deliberately small — that is the point of the paper: normalization collapses
the input space so a handful of recipes covers many programs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any


@dataclass(frozen=True)
class Recipe:
    """Lowering decisions for one canonical nest.

    kind:
      'einsum'        — BLAS-class idiom: dispatch to jnp.einsum (library call)
      'pallas_gemm'   — same idiom, routed to the Pallas MXU kernel (TPU path)
      'pallas_nest'   — grid-tiled Pallas kernel for fully-parallel nests
                        (elementwise/stencil groups; tiling planner partitions
                        the parallel iterators into a VPU-aligned grid)
      'pallas_reduce' — grid-tiled Pallas kernel for associative reductions
                        (innermost reduction iterator becomes an 'arbitrary'
                        grid dim accumulated through VMEM scratch; ``unroll``
                        splits the in-tile reduction into sequential chunks)
      'vectorize'     — generic vectorized lowering of all legal iterators
      'sequential'    — keep sequential loops (recurrences; the safe fallback)

    ``tile`` is the Pallas block-size tuple: ``(bm, bn, bk)`` for
    'pallas_gemm'; for 'pallas_nest'/'pallas_reduce' it is assigned to the
    *innermost* parallel axes (with the reduction tile last for
    'pallas_reduce') and the planner clamps it to the nest's extents.
    """

    kind: str = "vectorize"
    vec_budget: int = 1 << 22          # materialization budget (elements)
    tile: tuple[int, ...] | None = None  # Pallas block sizes (see docstring)
    # mesh axis for the outer parallel loop: an axis name pins the nest to
    # that axis, 'none' disables sharding, None defers to the scheduler's
    # default (Daisy.shard_axis under a mesh)
    parallelize: str | None = None
    unroll: int = 1                    # reduction unroll factor
    notes: str = ""

    def to_json(self) -> dict[str, Any]:
        """JSON-serializable dict form (tile tuple becomes a list)."""
        d = dataclasses.asdict(self)
        d["tile"] = list(self.tile) if self.tile else None
        return d

    @staticmethod
    def from_json(d: dict[str, Any]) -> "Recipe":
        """Rebuild a ``Recipe`` from its ``to_json`` form."""
        d = dict(d)
        if d.get("tile"):
            d["tile"] = tuple(d["tile"])
        return Recipe(**d)


DEFAULT_RECIPE = Recipe(kind="vectorize")

# MXU-aligned tile presets for the Pallas GEMM (multiples of (8,128)); the
# evolutionary search mutates within this set.
GEMM_TILE_PRESETS: tuple[tuple[int, int, int], ...] = (
    (128, 128, 128),
    (256, 128, 128),
    (128, 256, 128),
    (256, 256, 128),
    (512, 128, 128),
    (128, 128, 256),
    (512, 256, 128),
    (256, 256, 256),
)

# VPU-aligned tile presets for the grid-tiled nest kernel: (sublane, lane)
# pairs — multiples of (8, 128) for fp32 — plus lane-only presets for rank-1
# nests.  Assigned to the innermost parallel axes; the planner clamps each
# entry to the axis extent, so one preset set serves every canonical shape.
NEST_TILE_PRESETS: tuple[tuple[int, ...], ...] = (
    (8, 128),
    (16, 128),
    (32, 128),
    (8, 256),
    (16, 256),
    (64, 128),
    (8, 512),
    (128,),
    (512,),
    (1024,),
)

# For 'pallas_reduce' the last element is the reduction-axis tile (the
# 'arbitrary' grid dimension accumulated through VMEM scratch).
REDUCE_TILE_PRESETS: tuple[tuple[int, ...], ...] = (
    (8, 128, 128),
    (16, 128, 128),
    (8, 256, 128),
    (8, 128, 256),
    (32, 128, 128),
    (8, 128, 512),
    (128, 128),
    (256, 128),
)
