"""Optimization recipes — what the transfer-tuning database stores per nest.

A recipe is the downstream half of the paper's pipeline: after normalization
maps a nest to canonical form, the recipe says how to lower it.  Recipes are
deliberately small — that is the point of the paper: normalization collapses
the input space so a handful of recipes covers many programs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any


@dataclass(frozen=True)
class Recipe:
    """Lowering decisions for one canonical nest.

    kind:
      'einsum'       — BLAS-class idiom: dispatch to jnp.einsum (library call)
      'pallas_gemm'  — same idiom, routed to the Pallas MXU kernel (TPU path)
      'vectorize'    — generic vectorized lowering of all legal iterators
      'sequential'   — keep sequential loops (recurrences; the safe fallback)
    """

    kind: str = "vectorize"
    vec_budget: int = 1 << 22          # materialization budget (elements)
    tile: tuple[int, int, int] | None = None   # Pallas (bm, bn, bk)
    parallelize: str | None = None     # mesh axis for the outer parallel loop
    unroll: int = 1                    # reduction unroll factor
    notes: str = ""

    def to_json(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["tile"] = list(self.tile) if self.tile else None
        return d

    @staticmethod
    def from_json(d: dict[str, Any]) -> "Recipe":
        d = dict(d)
        if d.get("tile"):
            d["tile"] = tuple(d["tile"])
        return Recipe(**d)


DEFAULT_RECIPE = Recipe(kind="vectorize")

# MXU-aligned tile presets for the Pallas GEMM (multiples of (8,128)); the
# evolutionary search mutates within this set.
GEMM_TILE_PRESETS: tuple[tuple[int, int, int], ...] = (
    (128, 128, 128),
    (256, 128, 128),
    (128, 256, 128),
    (256, 256, 128),
    (512, 128, 128),
    (128, 128, 256),
    (512, 256, 128),
    (256, 256, 256),
)
