"""Canonical-form re-fusion — the first post-normalization optimization pass.

Maximal fission (paper §2.1) splits every loop body into the finest legal
pieces so each atomic nest can be scheduled independently.  That is ideal
for *analysis* but pessimal for *execution* of elementwise chains: a
CLOUDSC-style guarded update sequence or a softmax/rmsnorm pipeline becomes
N kernels making N full passes over memory, each materializing its
intermediate.

``FusionPass`` runs after normalization and greedily re-fuses *adjacent*
sibling nests (at every nesting level) when

  1. their iteration domains match — both are perfect nests whose loop
     chains agree in (start, stop, step) level by level,
  2. neither side matches a library-call idiom (blas3/blas2/dot stay
     standalone so einsum/Pallas dispatch keeps seeing a single
     contraction) nor a recurrence (carried nests stay untouched), and
  3. no fusion-preventing dependence exists: for every conflicting access
     pair between the two bodies (second nest's iterators mapped onto the
     first's by position), the solved direction vector must not be
     lexicographically positive — an instance of the earlier nest may never
     end up running *after* the later-nest instance that depends on it.
     Unknown ('*') directions conservatively block fusion.

Legality reuses the normalizer's dependence machinery
(``access_pairs`` / ``_solve_directions``), so the oracle that proves
fission legal is the same one that proves re-fusion legal.
"""
from __future__ import annotations

from dataclasses import replace

from .dependence import ANY, EQ, GT, access_pairs, _solve_directions
from .idioms import classify_nest
from .ir import Computation, Loop, Node, Program
from .normalize import normalization_pipeline
from .passes import PassContext, PassPipeline

# Idioms that must stay standalone: the scheduler lowers them as single
# library calls (jnp.einsum / Pallas MXU kernel); fusing an elementwise tail
# into them would break the dispatch back to a generic loop.
LIBRARY_IDIOMS = frozenset({"blas3", "blas2", "dot"})
_NO_FUSE = LIBRARY_IDIOMS | {"recurrence"}


def _perfect_chain(node: Node) -> list[Loop] | None:
    """The loop chain of a perfect nest (computations only at the innermost
    level), or None for computations / imperfect nests."""
    if isinstance(node, Computation):
        return None
    chain: list[Loop] = []
    cur: Node = node
    while isinstance(cur, Loop):
        chain.append(cur)
        kids = cur.body
        if all(isinstance(k, Computation) for k in kids):
            return chain
        if len(kids) != 1:
            return None  # multiple loop children: imperfect
        cur = kids[0]
    return None


def _chains_match(c1: list[Loop] | None, c2: list[Loop] | None) -> bool:
    if c1 is None or c2 is None or len(c1) != len(c2) or not c1:
        return False
    return all(
        (a.start, a.stop, a.step) == (b.start, b.stop, b.step)
        for a, b in zip(c1, c2)
    )


def domains_match(n1: Node, n2: Node) -> bool:
    """Both perfect nests with level-by-level equal (start, stop, step)."""
    return _chains_match(_perfect_chain(n1), _perfect_chain(n2))


def fusion_legal(n1: Node, n2: Node) -> bool:
    """No fusion-preventing dependence between adjacent nests n1, n2.

    Originally every instance of n1 executes before every instance of n2.
    After fusion both bodies run under n1's loops, n1's computations first
    within each iteration, iterations in lexicographic order.  A conflicting
    access pair a(I1) ~ b(I2) (a from n1, b from n2, iterators aligned by
    position) keeps its original order iff I1 <= I2; a dependence instance
    with I1 > I2 — direction vector lexicographically positive, leading
    ``'>'`` — would be reversed, so it prevents fusion.  ``'*'`` (unsolvable)
    may hide such an instance and blocks conservatively.  Enclosing shared
    loops need no check: fusing siblings never reorders across their
    iterations.
    """
    c1, c2 = _perfect_chain(n1), _perfect_chain(n2)
    return _chains_match(c1, c2) and _legal_chains(c1, c2)


def _legal_chains(c1: list[Loop], c2: list[Loop]) -> bool:
    mapping = {b.iterator: a.iterator for a, b in zip(c1, c2)}
    iterators = [l.iterator for l in c1]
    trip = {l.iterator: l.trip_count for l in c1}
    comps1 = list(c1[-1].body)
    comps2 = [c.rename(mapping) for c in c2[-1].body]

    for u in comps1:
        for v in comps2:
            # Two same-operator accumulations into one container commute —
            # ``access_pairs`` drops the pair, so the dependence test cannot
            # see it — but fusing them interleaves the accumulation order.
            # That is numerically legal yet reassociates floating point; we
            # promise fused programs stay bit-identical to the oracle, so
            # keep such nests (e.g. syr2k's two triangular MACs) apart.
            if (
                u.accumulate is not None
                and u.accumulate == v.accumulate
                and u.write.array == v.write.array
            ):
                return False
            for a, b in access_pairs(u, v):
                d = _solve_directions(a, b, iterators, trip)
                if d is None:
                    continue  # accesses can never coincide
                for it in iterators:
                    s = d[it]
                    if s == EQ:
                        continue
                    if s == GT or s == ANY:
                        return False  # (potentially) lex-positive: reversed
                    break  # '<' leads: strictly earlier, order preserved
    return True


def _fuse_chains(c1: list[Loop], c2: list[Loop]) -> Loop:
    mapping = {b.iterator: a.iterator for a, b in zip(c1, c2)}
    merged = tuple(c1[-1].body) + tuple(c.rename(mapping) for c in c2[-1].body)
    body: tuple[Node, ...] = merged
    for loop in reversed(c1):
        body = (replace(loop, body=body),)
    return body[0]


def fuse_pair(n1: Node, n2: Node) -> Node:
    """Merge n2's computations into n1's loop chain (callers prove legality)."""
    c1, c2 = _perfect_chain(n1), _perfect_chain(n2)
    assert c1 is not None and c2 is not None and len(c1) == len(c2)
    return _fuse_chains(c1, c2)


def fuse_siblings(
    siblings: tuple[Node, ...], stats: dict[str, int]
) -> tuple[Node, ...]:
    """Greedy adjacent re-fusion over one body, innermost-first."""
    # recurse first so already-fused inner groups are visible to idiom checks
    recursed: list[Node] = []
    for n in siblings:
        if isinstance(n, Loop):
            n = replace(n, body=fuse_siblings(n.body, stats))
        recursed.append(n)

    # idiom memo (classification probes exprs).  Values keep the classified
    # node alive, so a recycled id() can never alias a freed node's entry.
    kinds: dict[int, tuple[Node, str]] = {}

    def kind(n: Node) -> str:
        """Memoized idiom kind of a candidate nest."""
        hit = kinds.get(id(n))
        if hit is None or hit[0] is not n:
            hit = (n, classify_nest(n).kind)
            kinds[id(n)] = hit
        return hit[1]

    out: list[Node] = []
    for nxt in recursed:
        while out:
            cur = out[-1]
            if not (isinstance(cur, Loop) and isinstance(nxt, Loop)):
                break
            c_cur, c_nxt = _perfect_chain(cur), _perfect_chain(nxt)
            if not _chains_match(c_cur, c_nxt):
                stats["domain_mismatch"] += 1
                break
            if kind(cur) in _NO_FUSE or kind(nxt) in _NO_FUSE:
                stats["idiom_guarded"] += 1
                break
            if not _legal_chains(c_cur, c_nxt):
                stats["dependence_blocked"] += 1
                break
            out.pop()
            nxt = _fuse_chains(c_cur, c_nxt)
            stats["fused"] += 1
        out.append(nxt)
    return tuple(out)


def _new_stats() -> dict[str, int]:
    return {"fused": 0, "idiom_guarded": 0,
            "domain_mismatch": 0, "dependence_blocked": 0}


def fuse_program(program: Program) -> Program:
    """Functional entry point: re-fuse all fusable adjacent nests."""
    return replace(program, body=fuse_siblings(program.body, _new_stats()))


class FusionPass:
    """Pass-protocol wrapper recording fusion stats into the PassContext."""

    name = "fusion"

    def run(self, program: Program, ctx: PassContext | None = None) -> Program:
        """Fuse adjacent nests, attaching merge/guard counters to ``ctx``."""
        stats = _new_stats()
        out = replace(program, body=fuse_siblings(program.body, stats))
        if ctx is not None:
            for k, v in stats.items():
                ctx.add_stat(self.name, k, v)
        return out


def optimization_pipeline(fuse: bool = True, rewrite: bool = True) -> PassPipeline:
    """The full normalize-then-optimize pipeline the scheduler runs:
    COFFEE-style expression rewrites (LICM, expansion/factorization, CSE)
    run on the maximally-fissioned form, then re-fusion slots in between
    them and canonical renaming, so fingerprints stay stable however the
    rewrites and fusion reshaped the nest structure.  ``fuse=False``
    with ``rewrite=False`` degrades to exactly the paper's a priori
    normalization.
    """
    from .rewrite import rewrite_passes  # local import: rewrite -> passes -> ir

    pipeline = normalization_pipeline()
    licm, expand_factor, cse = rewrite_passes()
    if rewrite:
        pipeline = pipeline.with_pass(licm, before="canonical_rename")
        pipeline = pipeline.with_pass(expand_factor, before="canonical_rename")
        pipeline.name = "optimize"
    if fuse:
        pipeline = pipeline.with_pass(FusionPass(), before="canonical_rename")
        pipeline.name = "optimize"
    if rewrite:
        # CSE hunts duplicates *across* the computations sharing one nest
        # body, which only exist after re-fusion merges sibling nests — on
        # the maximally-fissioned form every nest holds a single computation.
        pipeline = pipeline.with_pass(cse, before="canonical_rename")
    return pipeline
