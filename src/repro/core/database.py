"""Transfer-tuning database (paper §4): canonical nest -> recipe.

Lookup order mirrors the paper exactly:
 1. exact fingerprint match ("if a B loop nest is reduced to an A loop nest")
 2. nearest neighbour by Euclidean distance on the performance embedding
    (within ``radius``); the recipe of the most similar nest transfers.
 3. miss -> the caller falls back to the default recipe.

The database is JSON-persistable so seeded schedules ship with the framework.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from .embedding import distance
from .recipes import Recipe


@dataclass
class Entry:
    fingerprint: str
    embedding: np.ndarray
    recipe: Recipe
    provenance: str = ""
    measured_us: float | None = None


@dataclass
class TuningDatabase:
    entries: list[Entry] = field(default_factory=list)
    radius: float = 6.0

    def add(self, fingerprint: str, embedding: np.ndarray, recipe: Recipe,
            provenance: str = "", measured_us: float | None = None) -> None:
        for e in self.entries:
            if e.fingerprint == fingerprint:
                # keep the better-measured recipe
                if measured_us is not None and (e.measured_us is None or measured_us < e.measured_us):
                    e.recipe, e.measured_us, e.provenance = recipe, measured_us, provenance
                return
        self.entries.append(Entry(fingerprint, np.asarray(embedding, dtype=np.float64),
                                  recipe, provenance, measured_us))

    def lookup_exact(self, fingerprint: str) -> Recipe | None:
        for e in self.entries:
            if e.fingerprint == fingerprint:
                return e.recipe
        return None

    def lookup_nearest(self, embedding: np.ndarray, k: int = 1) -> list[tuple[float, Entry]]:
        scored = sorted(
            ((distance(embedding, e.embedding), e) for e in self.entries),
            key=lambda t: t[0],
        )
        return [s for s in scored[:k] if s[0] <= self.radius]

    def lookup(self, fingerprint: str, embedding: np.ndarray) -> tuple[Recipe | None, str]:
        r = self.lookup_exact(fingerprint)
        if r is not None:
            return r, "exact"
        near = self.lookup_nearest(embedding)
        if near:
            return near[0][1].recipe, f"transfer(d={near[0][0]:.2f})"
        return None, "miss"

    # -- persistence ---------------------------------------------------------
    def save(self, path: str | Path) -> None:
        data = [
            {
                "fingerprint": e.fingerprint,
                "embedding": e.embedding.tolist(),
                "recipe": e.recipe.to_json(),
                "provenance": e.provenance,
                "measured_us": e.measured_us,
            }
            for e in self.entries
        ]
        Path(path).write_text(json.dumps({"radius": self.radius, "entries": data}, indent=1))

    @staticmethod
    def load(path: str | Path) -> "TuningDatabase":
        raw = json.loads(Path(path).read_text())
        db = TuningDatabase(radius=raw.get("radius", 6.0))
        for d in raw["entries"]:
            db.entries.append(
                Entry(d["fingerprint"], np.asarray(d["embedding"]),
                      Recipe.from_json(d["recipe"]), d.get("provenance", ""),
                      d.get("measured_us"))
            )
        return db
