"""Transfer-tuning database (paper §4): canonical nest -> recipe.

Lookup order mirrors the paper exactly:
 1. exact fingerprint match ("if a B loop nest is reduced to an A loop nest")
 2. nearest neighbour by Euclidean distance on the performance embedding
    (within ``radius``); the recipe of the most similar nest transfers.
 3. miss -> the caller falls back to the default recipe.

Both lookups are indexed (PR-1): exact matches go through a fingerprint
dict, and nearest-neighbour queries run one vectorized ``np.linalg.norm``
over a stacked embedding matrix instead of a Python loop per entry.  A
``generation`` counter bumps on every mutation so the compilation cache can
key plans by database state.

The database is JSON-persistable so seeded schedules ship with the
framework; the format is versioned (v2 adds the ``version`` field) and
``load`` accepts the unversioned v1 files written by the seed revision.
"""
from __future__ import annotations

import hashlib
import itertools
import json
import math
import os
import sys
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from .embedding import distance
from .recipes import Recipe

SCHEMA_VERSION = 2


class DatabaseCorruption(RuntimeError):
    """A database file (and its ``.bak``, if any) failed to parse or failed
    its content checksum."""

# Directory holding the shipped pretuned databases (``repro.tools.tune``
# output).  Overridable for deployments that stage their own tuning data.
PRETUNED_DIR_ENV = "REPRO_PRETUNED_DIR"


def pretuned_dir() -> Path:
    """Directory holding shipped pretuned databases (env-overridable)."""
    d = os.environ.get(PRETUNED_DIR_ENV)
    return Path(d) if d else Path(__file__).resolve().parents[3] / "data"


def default_pretuned_path(backend: str = "xla") -> Path:
    """Path of the shipped pretuned database for ``backend``.

    Looks for ``pretuned_<backend>.json`` then the generic ``pretuned.json``
    under ``pretuned_dir()``; raises FileNotFoundError (with the tune-CLI
    incantation) when neither exists.
    """
    root = pretuned_dir()
    cands = [root / f"pretuned_{backend}.json", root / "pretuned.json"]
    for c in cands:
        if c.exists():
            return c
    raise FileNotFoundError(
        f"no pretuned database for backend {backend!r} under {root} "
        f"(looked for {', '.join(c.name for c in cands)}); generate one with "
        f"`python -m repro.tools.tune --suite all --backend {backend} "
        f"--out {cands[0]}`"
    )


def try_load_pretuned(backend: str = "xla") -> "TuningDatabase | None":
    """The shipped pretuned database, or None when none is installed."""
    try:
        return TuningDatabase.load(default_pretuned_path(backend))
    except FileNotFoundError:
        return None


@dataclass
class Entry:
    """One tuned nest: canonical fingerprint, embedding, winning recipe."""

    fingerprint: str
    embedding: np.ndarray
    recipe: Recipe
    provenance: str = ""
    measured_us: float | None = None


@dataclass
class TuningDatabase:
    """Fingerprint-addressed recipe store with nearest-embedding transfer.

    Exact fingerprint hits return the tuned recipe; misses fall back to the
    nearest structural embedding within ``radius``.  Persistence is atomic
    and checksummed (see the persistence section below).
    """

    entries: list[Entry] = field(default_factory=list)
    radius: float = 6.0
    # Free-form tuning provenance (suite/size/backend/timestamp, written by
    # ``repro.tools.tune``); persisted alongside the entries.
    meta: dict = field(default_factory=dict)

    _uid_counter = itertools.count()

    def __post_init__(self) -> None:
        # Process-unique, never-reused instance token: cache keys derived
        # from a database must use this (plus ``generation``), not ``id()``
        # — a freed database's address can be reused by a new instance,
        # which would let a module-global cache serve stale results.
        self.uid = next(TuningDatabase._uid_counter)
        self._gen = 0
        self._by_fp: dict[str, int] = {}
        self._matrix: np.ndarray | None = None
        self._reindex()

    # -- index maintenance ---------------------------------------------------
    def _reindex(self) -> None:
        self._by_fp = {}
        for i, e in enumerate(self.entries):
            self._by_fp.setdefault(e.fingerprint, i)
        self._matrix = None

    def _sync(self) -> None:
        # Mutations should go through add(); the length check catches the
        # legacy direct-append pattern.  In-place *replacement* of an entry
        # keeps the length and is not detected — call reindex() after one.
        if len(self.entries) != len(self._by_fp):
            self._reindex()
            self._gen += 1

    def reindex(self) -> None:
        """Rebuild the lookup index after mutating ``entries`` in place."""
        self._reindex()
        self._gen += 1

    @property
    def generation(self) -> int:
        """Bumps on every mutation — cache keys derived from this database
        must include it so plans resolved against older contents expire."""
        self._sync()
        return self._gen

    def add(self, fingerprint: str, embedding: np.ndarray, recipe: Recipe,
            provenance: str = "", measured_us: float | None = None) -> str:
        """Insert or upgrade an entry; returns what happened:
        ``'added'`` | ``'replaced'`` (better-measured recipe won) | ``'kept'``."""
        self._sync()
        i = self._by_fp.get(fingerprint)
        if i is not None:
            e = self.entries[i]
            # keep the better-measured recipe
            if measured_us is not None and (e.measured_us is None or measured_us < e.measured_us):
                e.recipe, e.measured_us, e.provenance = recipe, measured_us, provenance
                self._gen += 1
                return "replaced"
            return "kept"
        self.entries.append(Entry(fingerprint, np.asarray(embedding, dtype=np.float64),
                                  recipe, provenance, measured_us))
        self._by_fp[fingerprint] = len(self.entries) - 1
        self._matrix = None
        self._gen += 1
        return "added"

    def replace_entry(
        self, fingerprint: str, recipe: Recipe,
        measured_us: float | None = None, provenance: str = "",
    ) -> tuple[Recipe, float | None, str]:
        """Unconditionally swap an entry's recipe; returns the previous
        ``(recipe, measured_us, provenance)`` so the caller can restore it.

        ``add`` keeps whichever recipe carries the *smaller* measurement —
        correct for offline seeding, wrong for a hot-swap or rollback where
        the incumbent's stored timing is stale (taken on different hardware
        or load) and the caller has just re-measured both sides live.  The
        embedding is untouched (same canonical nest), and the generation
        bumps so caches keyed on database state expire."""
        self._sync()
        i = self._by_fp.get(fingerprint)
        if i is None:
            raise KeyError(f"no entry for fingerprint {fingerprint!r}")
        e = self.entries[i]
        prev = (e.recipe, e.measured_us, e.provenance)
        e.recipe, e.measured_us, e.provenance = recipe, measured_us, provenance
        self._gen += 1
        return prev

    def lookup_exact(self, fingerprint: str) -> Recipe | None:
        """The recipe tuned for exactly this fingerprint, or None."""
        self._sync()
        i = self._by_fp.get(fingerprint)
        return self.entries[i].recipe if i is not None else None

    def lookup_nearest(self, embedding: np.ndarray, k: int = 1) -> list[tuple[float, Entry]]:
        """Up to ``k`` nearest entries within ``radius``, as (distance, entry)."""
        self._sync()
        if not self.entries:
            return []
        q = np.asarray(embedding, dtype=np.float64)
        if self._matrix is None or self._matrix.shape[0] != len(self.entries):
            try:
                self._matrix = np.stack(
                    [np.asarray(e.embedding, dtype=np.float64) for e in self.entries]
                )
            except ValueError:  # ragged embeddings: pairwise fallback
                self._matrix = None
        if self._matrix is not None and q.shape == self._matrix.shape[1:]:
            d = np.linalg.norm(self._matrix - q[None, :], axis=1)
        else:
            d = np.array([distance(q, e.embedding) for e in self.entries])
        order = np.argsort(d, kind="stable")[:k]
        return [(float(d[i]), self.entries[i]) for i in order if d[i] <= self.radius]

    def merge(self, other: "TuningDatabase") -> dict[str, int]:
        """Fold ``other``'s entries into this database.

        Incremental tuning runs compose: per fingerprint the better-measured
        recipe wins (the same rule ``add`` applies), unknown fingerprints are
        appended, tuning-run histories (``meta['runs']``) concatenate, and
        ``other``'s remaining meta fills in missing keys.  Databases tuned
        for different backends refuse to merge — their measurements were
        taken under different lowerings and do not rank against each other.
        Returns a report ``{'added': n, 'improved': n, 'kept': n}``.
        """
        mine = self.meta.get("backend")
        theirs = other.meta.get("backend")
        if mine and theirs and mine != theirs:
            raise ValueError(
                f"refusing to merge databases tuned for different backends "
                f"({mine!r} vs {theirs!r}): their measurements do not rank "
                "against each other"
            )
        report = {"added": 0, "improved": 0, "kept": 0}
        label = {"added": "added", "replaced": "improved", "kept": "kept"}
        for e in other.entries:
            action = self.add(e.fingerprint, e.embedding, e.recipe,
                              provenance=e.provenance, measured_us=e.measured_us)
            report[label[action]] += 1
        runs = list(self.meta.get("runs", []))
        runs += [r for r in other.meta.get("runs", []) if r not in runs]
        for k, v in other.meta.items():
            self.meta.setdefault(k, v)
        if runs:
            self.meta["runs"] = runs
        return report

    def summary(self) -> dict:
        """Size/provenance report: entry count, recipe-kind and provenance
        histograms, how many entries carry a measurement, and the meta."""
        kinds: dict[str, int] = {}
        prov: dict[str, int] = {}
        for e in self.entries:
            kinds[e.recipe.kind] = kinds.get(e.recipe.kind, 0) + 1
            key = e.provenance.rsplit(":", 1)[-1] if e.provenance else "unknown"
            prov[key] = prov.get(key, 0) + 1
        return {
            "entries": len(self.entries),
            "measured": sum(1 for e in self.entries if e.measured_us is not None),
            "kinds": dict(sorted(kinds.items())),
            "provenance": dict(sorted(prov.items())),
            "meta": dict(self.meta),
        }

    def lookup(self, fingerprint: str, embedding: np.ndarray) -> tuple[Recipe | None, str]:
        """Exact-then-nearest lookup; returns (recipe-or-None, provenance)."""
        r = self.lookup_exact(fingerprint)
        if r is not None:
            return r, "exact"
        near = self.lookup_nearest(embedding)
        if near:
            return near[0][1].recipe, f"transfer(d={near[0][0]:.2f})"
        return None, "miss"

    # -- persistence ---------------------------------------------------------
    #
    # Durability contract: ``save`` is atomic (tmp + fsync + ``os.replace``)
    # so a reader never sees a half-written file, the document carries a
    # content checksum so bit rot / manual edits / torn copies are *detected*
    # rather than silently deserialized, and each successful save refreshes a
    # ``.bak`` sibling that ``load`` falls back to when the primary is
    # corrupt.  The tuning pool checkpoints through ``save`` after every
    # completed nest, so this path must survive being interrupted at any
    # instruction.

    @staticmethod
    def _checksum(doc: dict) -> str:
        """Content checksum over the canonical (sorted-key, compact) JSON of
        everything except the checksum field itself."""
        body = {k: v for k, v in doc.items() if k != "checksum"}
        blob = json.dumps(body, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()

    @staticmethod
    def _write_atomic(path: Path, text: str) -> None:
        tmp = path.with_name(f".{path.name}.{os.getpid()}.tmp")
        with open(tmp, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    def save(self, path: str | Path) -> None:
        """Atomically persist to JSON (checksum + ``.bak`` refresh on success)."""
        data = [
            {
                "fingerprint": e.fingerprint,
                "embedding": e.embedding.tolist(),
                "recipe": e.recipe.to_json(),
                "provenance": e.provenance,
                # inf/nan would serialize as the non-JSON token 'Infinity'
                "measured_us": e.measured_us
                if e.measured_us is not None and math.isfinite(e.measured_us)
                else None,
            }
            for e in self.entries
        ]
        doc = {"version": SCHEMA_VERSION, "radius": self.radius, "entries": data}
        if self.meta:
            doc["meta"] = self.meta
        doc["checksum"] = self._checksum(doc)
        path = Path(path)
        text = json.dumps(doc, indent=1)
        self._write_atomic(path, text)
        # second copy only after the primary landed: the .bak always holds a
        # complete, checksummed document from some successful save
        self._write_atomic(path.with_suffix(path.suffix + ".bak"), text)

    @staticmethod
    def _parse(path: Path) -> dict:
        raw = json.loads(path.read_text())
        if not isinstance(raw, dict) or "entries" not in raw:
            raise DatabaseCorruption(f"{path}: not a tuning-database document")
        stored = raw.get("checksum")
        if stored is not None and stored != TuningDatabase._checksum(raw):
            raise DatabaseCorruption(f"{path}: content checksum mismatch")
        return raw

    @staticmethod
    def load(path: str | Path) -> "TuningDatabase":
        """Load a database, recovering from corruption via the ``.bak``.

        A primary that fails to parse or fails its checksum is reported on
        stderr and the ``.bak`` sibling (written on every successful save)
        is tried; :class:`DatabaseCorruption` is raised only when both are
        unreadable.  A version newer than this code supports is *not*
        corruption and raises ``ValueError`` immediately.
        """
        path = Path(path)
        bak = path.with_suffix(path.suffix + ".bak")
        try:
            raw = TuningDatabase._parse(path)
        except (json.JSONDecodeError, DatabaseCorruption, KeyError) as primary_err:
            if not bak.exists():
                raise DatabaseCorruption(
                    f"{path}: unreadable ({primary_err}) and no .bak exists"
                ) from primary_err
            print(f"WARNING: {path} is corrupt ({primary_err}); "
                  f"recovering from {bak.name}", file=sys.stderr)
            try:
                raw = TuningDatabase._parse(bak)
            except (json.JSONDecodeError, DatabaseCorruption, KeyError) as bak_err:
                raise DatabaseCorruption(
                    f"{path}: both primary ({primary_err}) and backup "
                    f"({bak_err}) are unreadable"
                ) from primary_err
        version = raw.get("version", 1)  # v1 files carry no version field
        if version > SCHEMA_VERSION:
            raise ValueError(
                f"{path}: database version {version} is newer than supported "
                f"({SCHEMA_VERSION})"
            )
        db = TuningDatabase(radius=raw.get("radius", 6.0), meta=raw.get("meta", {}))
        for d in raw["entries"]:
            db.entries.append(
                Entry(d["fingerprint"], np.asarray(d["embedding"]),
                      Recipe.from_json(d["recipe"]), d.get("provenance", ""),
                      d.get("measured_us"))
            )
        db._reindex()
        return db
