"""Dependence analysis for the loop-nest IR.

Provides the two legality oracles the normalization passes need (paper §2):

* ``body_dependence_graph``  — edges between the children of a loop, used by
  maximal loop fission (classic loop-distribution legality: SCC condensation
  of the dependence graph, emitted in topological order).
* ``nest_direction_vectors`` — direction vectors over a nest's iterators, used
  by stride minimization (a permutation is legal iff every dependence's
  permuted direction vector stays lexicographically non-negative).

Directions are represented per iterator as one of ``'=' '<' '>' '*'`` where
``'<'`` means the dependence flows from an earlier to a later iteration
(positive distance).  Anything we cannot solve exactly becomes ``'*'``
(conservative: blocks the transformation).  Reduction self-dependences of
computations flagged ``accumulate`` are treated as reorderable (associative
rewrites are permitted, as in the paper's GEMM interchange).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Iterable, Sequence

from .ir import (
    NONAFFINE,
    Access,
    Computation,
    Loop,
    Node,
    Program,
    walk,
)

EQ, LT, GT, ANY = "=", "<", ">", "*"


def _conflict(a: Access, b: Access, a_writes: bool, b_writes: bool) -> bool:
    return a.array == b.array and (a_writes or b_writes)


_COMMUTATIVE = ("+", "*", "max", "min")


def access_pairs(
    c1: Computation, c2: Computation
) -> Iterable[tuple[Access, Access]]:
    """All conflicting (at least one write) access pairs between c1 and c2.

    Write-write pairs where BOTH computations accumulate with the same
    commutative-associative operator are skipped: two ``+=`` updates to the
    same container commute regardless of interleaving, so they impose no
    ordering (this is what lets e.g. syr2k's two MACs fission apart)."""
    both_acc = (
        c1.accumulate is not None
        and c1.accumulate == c2.accumulate
        and c1.accumulate in _COMMUTATIVE
    )
    for a in c1.accesses():
        a_w = a is c1.write
        for b in c2.accesses():
            b_w = b is c2.write
            if a_w and b_w and both_acc:
                continue
            if _conflict(a, b, a_w, b_w):
                yield a, b


def _solve_directions(
    a: Access,
    b: Access,
    shared: Sequence[str],
    trip: dict[str, int],
) -> dict[str, str] | None:
    """Possible per-iterator directions for dependence instances a(I) ~ b(I').

    Returns None if no dependence can exist (e.g. constant offsets can never
    coincide), else a dict iterator -> direction describing delta = I - I'
    (``'<'`` ⇒ a's instance at a strictly earlier iteration than b's).

    Exact solving is restricted to the common case where both accesses use
    equal coefficients on the shared iterators per dimension; anything else
    (transposed accesses, non-affine terms, private iterators in a dimension)
    degrades to ``'*'`` for the involved iterators.
    """
    if len(a.index) != len(b.index):
        return {it: ANY for it in shared}

    # delta[it] = it_value_in_a - it_value_in_b, None = unconstrained so far
    delta: dict[str, int | None] = {it: None for it in shared}
    wild: set[str] = set()

    for ia, ib in zip(a.index, b.index):
        its = set(ia.iterators()) | set(ib.iterators())
        if ia.coeff(NONAFFINE) or ib.coeff(NONAFFINE):
            wild |= its & set(shared)
            continue
        priv = its - set(shared)
        sh = [it for it in shared if it in its]
        if priv:
            # a private iterator can absorb any difference
            wild |= set(sh)
            continue
        if not sh:
            if ia.const != ib.const:
                return None  # constant dims differ -> elements never overlap
            continue
        coeffs_equal = all(ia.coeff(it) == ib.coeff(it) for it in sh)
        if coeffs_equal and len(sh) == 1:
            it = sh[0]
            c = ia.coeff(it)
            rhs = ib.const - ia.const
            if c == 0:
                if rhs != 0:
                    return None
                continue
            if rhs % c != 0:
                return None
            d = rhs // c
            if abs(d) >= trip.get(it, 1 << 30):
                return None
            if delta[it] is None:
                delta[it] = d
            elif delta[it] != d:
                return None
        else:
            wild |= set(sh)

    out: dict[str, str] = {}
    for it in shared:
        if it in wild:
            out[it] = ANY
        elif delta[it] is None:
            out[it] = ANY  # unconstrained by any dimension
        elif delta[it] == 0:
            out[it] = EQ
        elif delta[it] < 0:
            # delta = I_a - I_b < 0: a's instance runs at an *earlier*
            # iteration than b's -> dependence flows a -> b.
            out[it] = LT
        else:
            out[it] = GT
    return out


def _is_reduction_self_dep(c1: Computation, c2: Computation, a: Access, b: Access) -> bool:
    """Self flow/output dep of an accumulating computation on its own target.

    Only the *same-index* self dependence (``C[i,j] (+)= f(..., C[i,j])``) is
    the associative-reduction dependence that permutation may reorder.  A read
    of the written array at a shifted index (``C[i,j] += C[i,j-1]``) is a real
    recurrence and must NOT be skipped.
    """
    return (
        c1 is c2
        and c1.accumulate is not None
        and a.array == c1.write.array
        and b.array == c1.write.array
        and a.index == b.index
    )


# ---------------------------------------------------------------------------
# Fission legality: dependence graph over a loop body's children
# ---------------------------------------------------------------------------
def _subtree_computations(n: Node) -> list[Computation]:
    if isinstance(n, Computation):
        return [n]
    return [c for _, c in walk(n)]


def body_dependence_graph(
    loop_iter: str, trip: dict[str, int], children: Sequence[Node]
) -> list[set[int]]:
    """adj[i] = set of j such that distributing child i after child j is unsafe
    unless i, j share a nest — i.e. there is a dependence edge i -> j.

    Edge semantics (execution order within one iteration of the loops outside
    ``loop_iter``): edge u -> v  ⇔  some instance of u must execute before some
    instance of v.  Children in the same SCC must remain fused; SCCs are
    emitted in topological order.
    """
    n = len(children)
    adj: list[set[int]] = [set() for _ in range(n)]
    comps = [_subtree_computations(ch) for ch in children]

    for i, j in itertools.combinations(range(n), 2):
        fwd = bwd = False  # i -> j, j -> i
        for c1 in comps[i]:
            for c2 in comps[j]:
                for a, b in access_pairs(c1, c2):
                    d = _solve_directions(a, b, [loop_iter], trip)
                    if d is None:
                        continue
                    s = d[loop_iter]
                    if s == EQ:
                        fwd = True  # same iteration: textual order i before j
                    elif s == LT:
                        fwd = True  # c1 instance earlier -> source i
                    elif s == GT:
                        bwd = True
                    else:  # ANY
                        fwd = bwd = True
                if fwd and bwd:
                    break
            if fwd and bwd:
                break
        if fwd:
            adj[i].add(j)
        if bwd:
            adj[j].add(i)
    return adj


def condense_sccs(adj: list[set[int]]) -> list[list[int]]:
    """Tarjan SCC condensation returning SCCs in topological order.

    Ties are broken so that the result is stable w.r.t. original child order.
    """
    n = len(adj)
    index = [-1] * n
    low = [0] * n
    on = [False] * n
    stack: list[int] = []
    sccs: list[list[int]] = []
    counter = [0]

    def strongconnect(v: int) -> None:
        """Iterative Tarjan visit from ``v`` (explicit stack, no recursion)."""
        work = [(v, iter(sorted(adj[v])))]
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on[v] = True
        while work:
            node, it = work[-1]
            advanced = False
            for w in it:
                if index[w] == -1:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on[w] = True
                    work.append((w, iter(sorted(adj[w]))))
                    advanced = True
                    break
                elif on[w]:
                    low[node] = min(low[node], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                low[work[-1][0]] = min(low[work[-1][0]], low[node])
            if low[node] == index[node]:
                scc = []
                while True:
                    w = stack.pop()
                    on[w] = False
                    scc.append(w)
                    if w == node:
                        break
                sccs.append(sorted(scc))

    for v in range(n):
        if index[v] == -1:
            strongconnect(v)

    # Tarjan emits SCCs in reverse topological order.
    sccs.reverse()
    # Stable topological sort honoring textual order among independent SCCs.
    scc_of = {}
    for k, scc in enumerate(sccs):
        for v in scc:
            scc_of[v] = k
    edges = [set() for _ in sccs]
    indeg = [0] * len(sccs)
    for u in range(n):
        for v in adj[u]:
            a, b = scc_of[u], scc_of[v]
            if a != b and b not in edges[a]:
                edges[a].add(b)
                indeg[b] += 1
    import heapq

    ready = [(min(sccs[k]), k) for k in range(len(sccs)) if indeg[k] == 0]
    heapq.heapify(ready)
    order: list[list[int]] = []
    while ready:
        _, k = heapq.heappop(ready)
        order.append(sccs[k])
        for b in edges[k]:
            indeg[b] -= 1
            if indeg[b] == 0:
                heapq.heappush(ready, (min(sccs[b]), b))
    return order


# ---------------------------------------------------------------------------
# Permutation legality: direction vectors over a nest's iterators
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class DepVector:
    """One dependence direction vector, aligned with the nest's iterators."""

    directions: tuple[str, ...]

    def permuted(self, perm: Sequence[int]) -> tuple[str, ...]:
        """The directions reordered under a loop permutation."""
        return tuple(self.directions[p] for p in perm)


def nest_direction_vectors(
    iterators: Sequence[str],
    trip: dict[str, int],
    computations: Sequence[Computation],
) -> list[DepVector]:
    """All dependence direction vectors among computations of one atomic nest."""
    vectors: set[tuple[str, ...]] = set()
    for c1 in computations:
        for c2 in computations:
            for a, b in access_pairs(c1, c2):
                if _is_reduction_self_dep(c1, c2, a, b):
                    # associative accumulation: reorderable by construction
                    continue
                d = _solve_directions(a, b, list(iterators), trip)
                if d is None:
                    continue
                vec = tuple(d[it] for it in iterators)
                if all(s == EQ for s in vec):
                    continue  # loop-independent: any permutation preserves it
                # Each dependence shows up in both (c1,c2) and (c2,c1) order;
                # keep only the positive orientation (first non-'=' is not '>')
                # — the mirrored, lexicographically-negative copy is redundant.
                lead = next(s for s in vec if s != EQ)
                if lead == GT:
                    continue
                vectors.add(vec)
    return [DepVector(v) for v in sorted(vectors)]


def permutation_legal(vectors: Iterable[DepVector], perm: Sequence[int]) -> bool:
    """Legal iff each permuted direction vector is lexicographically positive.

    Scan: '<' before any '>'/'*' makes the vector positive; '=' continues;
    '>' or '*' encountered first makes it (potentially) negative -> illegal.
    """
    for v in vectors:
        for s in v.permuted(perm):
            if s == LT:
                break
            if s == EQ:
                continue
            return False  # GT or ANY first
    return True
