"""COFFEE-style expression rewrites on the canonical form.

The normalization pipeline (paper §2) reshapes *loops*; these passes reshape
the *scalar math inside* them, in the spirit of COFFEE's rewrite engine:
flop-reducing, oracle-checked transformations that run after maximal fission
and before re-fusion, so the scheduler's recipes see the cheapest equivalent
computation.  All passes are identity on computations whose ``expr`` is an
opaque callable — only symbolic :class:`repro.core.ir.Expr` trees are
inspected — and identity whenever a cost guard or legality check fails, so
slotting them into the pipeline can never regress an unmigrated front-end.

* ``LICMPass`` — loop-invariant code motion.  A subexpression whose reads
  use only a proper subset of the enclosing loop iterators is hoisted into a
  scratch array filled by a new sibling nest placed just before the current
  one; the computation then reads the scratch value instead of recomputing
  the subexpression on every iteration of the invariant loops.  Equal
  subexpressions over never-written inputs share one scratch array across
  *all* top-level nests.  Note XLA's while-loop invariant code motion
  already subsumes the easy case (a chain over closure-captured constants
  inside one ``lax.scan`` body is hoisted to the entry computation), so that
  shape shows no end-to-end win.  What XLA cannot do — and LICM can, because
  the IR knows the iteration space — is hoist work that reads the per-step
  slices of a scanned field (syntactically step-dependent in HLO, invariant
  along the *inner* band/species axis in the IR), or share one evaluation
  across several separate scans.  That is exactly the
  ``saturation_chain_program`` shape ``bench_rewrite`` gates on, and the
  transformation is bit-exact (the same float ops run, just once).
* ``ExpandFactorPass`` — expansion ``(a+b)*c -> ac+bc`` and factorization
  ``ab+ac -> a(b+c)`` as a cost-guarded fixpoint pair.  Expansion splits a
  sum-of-products accumulation into one accumulation per product term (each
  its own sibling nest), which is what unlocks BLAS idiom dispatch — a
  ``(A+B)@C``-style MAC is not multiplicative as written, but each expanded
  term is.  Factorization merges terms sharing a non-constant factor when
  that strictly reduces the op count.  Both reassociate floating point, so
  they are gated by ``allclose`` (not bit-identity) in the benchmark.
* ``CSEPass`` — common subexpression elimination across the computations of
  one nest: a duplicated subtree whose support covers the full iterator set
  is materialized once into a scratch array written by a new leading
  computation, and every user reads it back.  Within a single expression,
  duplicates already cost nothing (``Expr.to_callable`` deduplicates the
  DAG), so only cross-computation duplicates are considered.

Legality is deliberately conservative: a subexpression is only hoisted or
shared when none of the arrays it reads are written anywhere in the nest,
which makes the scratch value trivially iteration-invariant (hoisting) or
order-independent (CSE).  Guards never block a rewrite — scratch values are
computed over the full rectangular domain (overcompute is harmless; the
guarded points simply never read them).
"""
from __future__ import annotations

import itertools
from dataclasses import replace
from typing import Iterable

from .ir import (
    Access,
    Array,
    BinOp,
    Call,
    Computation,
    Const,
    Expr,
    Loop,
    Neg,
    Node,
    Program,
    Read,
    aff,
    expr_map_reads,
    expr_nodes,
    expr_ops,
    expr_reads,
    rename_nest,
    walk,
)
from .passes import PassContext

MIN_HOIST_OPS = 2  # don't trade a memory round-trip for a single flop
MAX_EXPAND_TERMS = 4


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------
def is_symbolic(comp: Computation) -> bool:
    """True when the computation's expr is an inspectable ``Expr`` tree."""
    return isinstance(comp.expr, Expr)


def _written_arrays(node: Node) -> set[str]:
    if isinstance(node, Computation):
        return {node.write.array}
    return {c.write.array for _, c in walk(node)}


def resolved_signature(
    e: Expr,
    reads: tuple[Access, ...],
    rename: dict[str, str] | None = None,
) -> str:
    """Structural signature with ``Read(i)`` resolved to its access.

    Two subtrees in different computations of the same nest get equal keys
    iff they compute the same value at every iteration point (same ops over
    the same array elements).  Iterator names are compared literally unless
    ``rename`` maps them to a canonical spelling — LICM passes a positional
    one so fission-suffixed siblings (``JL_f1`` vs ``JL_f2``) still share a
    hoisted temp.
    """
    if isinstance(e, Read):
        a = reads[e.i]
        if rename:
            a = a.rename(rename)
        return f"{a.array}[{','.join(repr(ix) for ix in a.index)}]"
    if isinstance(e, Const):
        return repr(e.value)
    kids = " ".join(resolved_signature(c, reads, rename) for c in e.children())
    if isinstance(e, BinOp):
        return f"({e.op} {kids})"
    if isinstance(e, Neg):
        return f"(neg {kids})"
    return f"(call {e.fn_name} {kids})"  # Call


def _expr_read_accesses(e: Expr, comp: Computation) -> list[Access]:
    """The accesses referenced by ``e``, in first-use order, deduplicated."""
    out: list[Access] = []
    for i in expr_reads(e):
        a = comp.reads[i]
        if a not in out:
            out.append(a)
    return out


def _subexpr_support(e: Expr, comp: Computation) -> set[str]:
    """Iterators the subexpression's value actually varies over."""
    sup: set[str] = set()
    for i in expr_reads(e):
        sup.update(comp.reads[i].iterators())
    return sup


def _contains_call(e: Expr) -> bool:
    return any(isinstance(n, Call) for n in expr_nodes(e))


def program_flops(p: Program) -> int:
    """Weighted flop count of all symbolic computations, trip-weighted.

    Opaque exprs contribute nothing (they cannot be inspected); guards are
    ignored (a rectangular overestimate).  The rewrite passes report this
    before/after so ``PassContext.report()`` shows the work they removed.
    """
    total = 0
    for nest in p.body:
        for loops, comp in walk(nest):
            if not is_symbolic(comp):
                continue
            trip = 1
            for l in loops:
                trip *= max(1, l.trip_count)
            total += expr_ops(comp.expr) * trip
    return total


def _map_comps(node: Node, fn, prefix: tuple[Loop, ...] = ()) -> Node:
    """Rebuild a nest, mapping every computation through ``fn(loops, comp)``."""
    if isinstance(node, Computation):
        return fn(prefix, node)
    return replace(
        node,
        body=tuple(_map_comps(b, fn, prefix + (node,)) for b in node.body),
    )


def _fresh_name(base: str, taken: set[str]) -> str:
    if base not in taken:
        return base
    for k in itertools.count(1):  # pragma: no cover - collision fallback
        if f"{base}_{k}" not in taken:
            return f"{base}_{k}"
    raise AssertionError  # pragma: no cover


# ---------------------------------------------------------------------------
# loop-invariant code motion
# ---------------------------------------------------------------------------
class LICMPass:
    """Hoist invariant subexpressions into temps filled by sibling nests."""

    name = "licm"

    def run(self, program: Program, ctx: PassContext | None = None) -> Program:
        """Apply LICM to every top-level nest; record hoist/flop stats."""
        flops_before = program_flops(program)
        taken = set(program.array_names)
        counter = itertools.count()
        suffix = itertools.count()
        arrays = list(program.arrays)
        temps = list(program.temps)
        body: list[Node] = []
        hoisted = 0
        reused = 0

        # Arrays written by *any* nest: a hoisted temp may only be shared
        # across top-level nests when its sources are program inputs (never
        # written), otherwise a later nest could observe stale values.
        global_written: set[str] = set()
        for nest in program.body:
            global_written |= _written_arrays(nest)
        shared_cache: dict[str, tuple[str, tuple[int, ...]]] = {}

        for nest in program.body:
            if not isinstance(nest, Loop):
                body.append(nest)
                continue
            written = _written_arrays(nest)
            cache: dict[str, tuple[str, tuple[int, ...]]] = {}
            pre: list[Node] = []

            def visit(loops: tuple[Loop, ...], comp: Computation) -> Computation:
                """Hoist qualifying subexpressions out of one computation."""
                nonlocal hoisted
                if not is_symbolic(comp) or not loops:
                    return comp
                its = [l.iterator for l in loops]
                trips = {l.iterator: max(1, l.trip_count) for l in loops}
                # positional spelling, so fission-suffixed sibling chains
                # (JL_f1 vs JL_f2, same bounds) share one hoisted temp
                canon = {
                    l.iterator: f"@{pos}:{l.start}:{l.stop}:{l.step}"
                    for pos, l in enumerate(loops)
                }
                new_reads = list(comp.reads)

                def qualifies(e: Expr) -> tuple[int, ...] | None:
                    """Loop positions a hoistable subexpression varies over,
                    or None when hoisting is illegal or not profitable."""
                    accs = [comp.reads[i] for i in expr_reads(e)]
                    if any(not a.is_affine for a in accs):
                        return None
                    if any(a.array in written for a in accs):
                        return None
                    sup = _subexpr_support(e, comp)
                    if not sup.issubset(its) or sup == set(its):
                        return None
                    dropped = 1
                    for it in its:
                        if it not in sup:
                            dropped *= trips[it]
                    if dropped < 2:
                        return None
                    if expr_ops(e) < MIN_HOIST_OPS and not _contains_call(e):
                        return None
                    return tuple(p for p, it in enumerate(its) if it in sup)

                def hoist(e: Expr, sup: tuple[int, ...]) -> Expr:
                    """Materialize ``e`` into a (possibly shared) temp and
                    return the ``Read`` that replaces it."""
                    nonlocal hoisted, reused
                    key = resolved_signature(e, comp.reads, canon)
                    shareable = all(
                        comp.reads[i].array not in global_written
                        for i in expr_reads(e))
                    hit = cache.get(key)
                    if hit is None and shareable:
                        hit = shared_cache.get(key)
                        if hit is not None:
                            reused += 1
                            cache[key] = hit
                    if hit is None:
                        tname = _fresh_name(f"_licm{next(counter)}", taken)
                        taken.add(tname)
                        sup_loops = [loops[p] for p in sup]
                        shape = tuple(l.stop for l in sup_loops)
                        accs = _expr_read_accesses(e, comp)
                        remap = {
                            i: accs.index(comp.reads[i]) for i in expr_reads(e)
                        }
                        hcomp = Computation(
                            f"licm_{comp.name}",
                            Access(tname,
                                   tuple(aff(l.iterator) for l in sup_loops)),
                            tuple(accs),
                            expr_map_reads(e, remap),
                        )
                        hnest: Node = hcomp
                        for l in reversed(sup_loops):
                            hnest = Loop(l.iterator, l.stop, l.start, l.step,
                                         (hnest,))
                        if sup_loops:
                            hnest = rename_nest(hnest, f"_h{next(suffix)}")
                        pre.append(hnest)
                        arrays.append(Array(tname, shape))
                        temps.append(tname)
                        cache[key] = (tname, sup)
                        if shareable:
                            shared_cache[key] = cache[key]
                        hit = cache[key]
                        hoisted += 1
                    tname, sup = hit
                    acc_t = Access(tname, tuple(aff(its[p]) for p in sup))
                    if acc_t in new_reads:
                        idx = new_reads.index(acc_t)
                    else:
                        idx = len(new_reads)
                        new_reads.append(acc_t)
                    return Read(idx)

                def rw(e: Expr) -> Expr:
                    """Rewrite the tree top-down, hoisting maximal subtrees."""
                    if isinstance(e, (Read, Const)):
                        return e
                    sup = qualifies(e)
                    if sup is not None:
                        return hoist(e, sup)
                    kids = e.children()
                    return e.rebuild(tuple(rw(c) for c in kids)) if kids else e

                new_expr = rw(comp.expr)
                if new_expr is comp.expr and len(new_reads) == len(comp.reads):
                    return comp
                return replace(comp, reads=tuple(new_reads), expr=new_expr)

            new_nest = _map_comps(nest, visit)
            body.extend(pre)
            body.append(new_nest)

        if ctx is not None:
            ctx.add_stat(self.name, "hoisted", hoisted)
            if reused:
                ctx.add_stat(self.name, "reused", reused)
            if hoisted:
                out = replace(program, arrays=tuple(arrays),
                              body=tuple(body), temps=tuple(temps))
                ctx.add_stat(self.name, "flops_before", flops_before)
                ctx.add_stat(self.name, "flops_after", program_flops(out))
                return out
        if not hoisted:
            return program
        return replace(program, arrays=tuple(arrays), body=tuple(body),
                       temps=tuple(temps))


# ---------------------------------------------------------------------------
# expansion + factorization (cost-guarded fixpoint pair)
# ---------------------------------------------------------------------------
def _flat_add(e: Expr) -> list[Expr]:
    if isinstance(e, BinOp) and e.op == "add":
        return _flat_add(e.lhs) + _flat_add(e.rhs)
    return [e]


def _flat_mul(e: Expr) -> list[Expr]:
    if isinstance(e, BinOp) and e.op == "mul":
        return _flat_mul(e.lhs) + _flat_mul(e.rhs)
    return [e]


def _build_chain(op: str, terms: list[Expr]) -> Expr:
    out = terms[0]
    for t in terms[1:]:
        out = BinOp(op, out, t)
    return out


def _is_product(e: Expr) -> bool:
    """A pure product term: Mul/Neg over reads and constants only."""
    for n in expr_nodes(e):
        if isinstance(n, (Read, Const, Neg)):
            continue
        if isinstance(n, BinOp) and n.op == "mul":
            continue
        return False
    return True


def _distribute(e: Expr) -> list[Expr]:
    """Top-level add terms after distributing products over sums."""
    if isinstance(e, BinOp) and e.op == "add":
        return _distribute(e.lhs) + _distribute(e.rhs)
    if isinstance(e, BinOp) and e.op == "mul":
        lt, rt = _distribute(e.lhs), _distribute(e.rhs)
        if len(lt) * len(rt) == 1:
            return [e]
        return [BinOp("mul", a, b) for a in lt for b in rt]
    return [e]


def _factor_once(e: Expr) -> Expr:
    """One bottom-up factorization sweep: ``ab+ac -> a(b+c)`` when cheaper."""
    kids = e.children()
    if kids:
        e = e.rebuild(tuple(_factor_once(c) for c in kids))
    if not (isinstance(e, BinOp) and e.op == "add"):
        return e
    terms = _flat_add(e)
    factors = [ [f for f in _flat_mul(t)] for t in terms]
    # first non-constant factor (by appearance) present in >= 2 terms
    shared: Expr | None = None
    for fs in factors:
        for f in fs:
            if isinstance(f, Const):
                continue
            hits = sum(
                1 for other in factors
                if any(g.signature() == f.signature() for g in other)
            )
            if hits >= 2:
                shared = f
                break
        if shared is not None:
            break
    if shared is None:
        return e
    sig = shared.signature()
    residuals, others, first_pos = [], [], None
    for pos, (t, fs) in enumerate(zip(terms, factors)):
        idx = next((k for k, g in enumerate(fs) if g.signature() == sig), None)
        if idx is None:
            others.append((pos, t))
            continue
        rest = fs[:idx] + fs[idx + 1:]
        residuals.append(_build_chain("mul", rest) if rest else Const(1.0))
        if first_pos is None:
            first_pos = pos
    merged = BinOp("mul", shared, _build_chain("add", residuals))
    new_terms = [t for _, t in others]
    new_terms.insert(
        sum(1 for pos, _ in others if pos < (first_pos or 0)), merged)
    new = _build_chain("add", new_terms)
    return new if expr_ops(new) < expr_ops(e) else e


def _perfect_single(nest: Node) -> tuple[list[Loop], Computation] | None:
    """(loop chain, the single computation) for a perfect 1-comp nest."""
    if not isinstance(nest, Loop):
        return None
    chain: list[Loop] = []
    cur: Node = nest
    while isinstance(cur, Loop):
        chain.append(cur)
        if len(cur.body) != 1:
            return None
        cur = cur.body[0]
    return chain, cur


class ExpandFactorPass:
    """Expansion and factorization to a cost-guarded fixpoint."""

    name = "expand_factor"
    max_iter = 8

    def run(self, program: Program, ctx: PassContext | None = None) -> Program:
        """Iterate expansion (nest splits) + factorization until stable."""
        flops_before = program_flops(program)
        expanded = factored = 0
        cur = program
        for _ in range(self.max_iter):
            nxt, ne = self._expand(cur)
            nxt, nf = self._factor(nxt)
            expanded += ne
            factored += nf
            if nxt.body == cur.body:
                break
            cur = nxt
        if ctx is not None:
            ctx.add_stat(self.name, "expanded", expanded)
            ctx.add_stat(self.name, "factored", factored)
            if expanded or factored:
                ctx.add_stat(self.name, "flops_before", flops_before)
                ctx.add_stat(self.name, "flops_after", program_flops(cur))
        return cur

    def _expand(self, program: Program) -> tuple[Program, int]:
        body: list[Node] = []
        count = 0
        for nest in program.body:
            ps = _perfect_single(nest)
            if ps is None:
                body.append(nest)
                continue
            chain, comp = ps
            w_its = {it for ix in comp.write.index for it in ix.iterators()}
            reduction = any(l.iterator not in w_its for l in chain)
            if (not is_symbolic(comp) or comp.accumulate != "+"
                    or not reduction):
                body.append(nest)
                continue
            terms = _distribute(comp.expr)
            if (len(terms) < 2 or len(terms) > MAX_EXPAND_TERMS
                    or not all(_is_product(t) for t in terms)):
                body.append(nest)
                continue
            for k, t in enumerate(terms):
                used = expr_reads(t)
                remap = {i: k2 for k2, i in enumerate(used)}
                piece = replace(
                    comp,
                    name=f"{comp.name}_e{k}",
                    reads=tuple(comp.reads[i] for i in used),
                    expr=expr_map_reads(t, remap),
                )
                pnest: Node = piece
                for l in reversed(chain):
                    pnest = Loop(l.iterator, l.stop, l.start, l.step, (pnest,))
                if k:
                    pnest = rename_nest(pnest, f"_e{k}")
                body.append(pnest)
            count += len(terms) - 1
        if not count:
            return program, 0
        return replace(program, body=tuple(body)), count

    def _factor(self, program: Program) -> tuple[Program, int]:
        count = 0

        def visit(loops: tuple[Loop, ...], comp: Computation) -> Computation:
            nonlocal count
            if not is_symbolic(comp):
                return comp
            new = _factor_once(comp.expr)
            if new.signature() == comp.expr.signature():
                return comp
            count += 1
            return replace(comp, expr=new)

        body = tuple(_map_comps(n, visit) for n in program.body)
        if not count:
            return program, 0
        return replace(program, body=body), count


# ---------------------------------------------------------------------------
# cross-computation CSE
# ---------------------------------------------------------------------------
def _perfect_multi(nest: Node) -> tuple[list[Loop], list[Computation]] | None:
    """(loop chain, innermost computations) for a perfect nest with >= 2."""
    if not isinstance(nest, Loop):
        return None
    chain: list[Loop] = []
    cur: Node = nest
    while isinstance(cur, Loop):
        chain.append(cur)
        if all(isinstance(k, Computation) for k in cur.body):
            comps = list(cur.body)
            return (chain, comps) if len(comps) >= 2 else None
        if len(cur.body) != 1:
            return None
        cur = cur.body[0]
    return None


class CSEPass:
    """Materialize subtrees duplicated across a nest's computations."""

    name = "cse"

    def run(self, program: Program, ctx: PassContext | None = None) -> Program:
        """Share full-support duplicated subexpressions through scratch."""
        flops_before = program_flops(program)
        taken = set(program.array_names)
        counter = itertools.count()
        arrays = list(program.arrays)
        temps = list(program.temps)
        body: list[Node] = []
        eliminated = 0

        for nest in program.body:
            pm = _perfect_multi(nest)
            if pm is None:
                body.append(nest)
                continue
            chain, comps = pm
            its = tuple(l.iterator for l in chain)
            written = _written_arrays(nest)
            for _ in range(16):
                target = self._best_duplicate(comps, its, written)
                if target is None:
                    break
                eliminated += 1
                comps = self._materialize(
                    target, comps, its, chain, arrays, temps, taken, counter)
            new: Node = replace(chain[-1], body=tuple(comps))
            for l in reversed(chain[:-1]):
                new = replace(l, body=(new,))
            body.append(new)

        if not eliminated:
            return program
        out = replace(program, arrays=tuple(arrays), body=tuple(body),
                      temps=tuple(temps))
        if ctx is not None:
            ctx.add_stat(self.name, "flops_before", flops_before)
            ctx.add_stat(self.name, "flops_after", program_flops(out))
        if ctx is not None:
            ctx.add_stat(self.name, "eliminated", eliminated)
        return out

    def _candidates(self, comp: Computation, its: tuple[str, ...],
                    written: set[str]) -> Iterable[tuple[str, Expr]]:
        if not is_symbolic(comp):
            return
        for e in expr_nodes(comp.expr):
            if isinstance(e, (Read, Const)):
                continue
            if expr_ops(e) < MIN_HOIST_OPS and not _contains_call(e):
                continue
            accs = [comp.reads[i] for i in expr_reads(e)]
            if any(a.array in written or not a.is_affine for a in accs):
                continue
            if _subexpr_support(e, comp) != set(its):
                continue
            yield resolved_signature(e, comp.reads), e

    def _best_duplicate(self, comps, its, written):
        seen: dict[str, list[tuple[int, Expr]]] = {}
        order: list[str] = []
        for ci, comp in enumerate(comps):
            per_comp: set[str] = set()
            for key, e in self._candidates(comp, its, written):
                if key in per_comp:
                    continue
                per_comp.add(key)
                if key not in seen:
                    order.append(key)
                seen.setdefault(key, []).append((ci, e))
        dups = [k for k in order if len(seen[k]) >= 2]
        if not dups:
            return None
        best = max(dups, key=lambda k: (expr_ops(seen[k][0][1]),
                                        -order.index(k)))
        return best, seen[best]

    def _materialize(self, target, comps, its, chain, arrays, temps, taken,
                     counter):
        key, users = target
        first = users[0][1]
        src = comps[users[0][0]]
        tname = _fresh_name(f"_cse{next(counter)}", taken)
        taken.add(tname)
        accs = _expr_read_accesses(first, src)
        remap = {i: accs.index(src.reads[i]) for i in expr_reads(first)}
        tcomp = Computation(
            f"cse_{src.name}",
            Access(tname, tuple(aff(it) for it in its)),
            tuple(accs),
            expr_map_reads(first, remap),
        )
        arrays.append(Array(tname, tuple(l.stop for l in chain)))
        temps.append(tname)
        user_ids = {ci for ci, _ in users}
        out = [tcomp]
        for ci, comp in enumerate(comps):
            if ci not in user_ids:
                out.append(comp)
                continue
            new_reads = list(comp.reads)
            acc_t = Access(tname, tuple(aff(it) for it in its))
            if acc_t in new_reads:
                idx = new_reads.index(acc_t)
            else:
                idx = len(new_reads)
                new_reads.append(acc_t)

            def rw(e: Expr) -> Expr:
                if resolved_signature(e, comp.reads) == key:
                    return Read(idx)
                kids = e.children()
                return e.rebuild(tuple(rw(c) for c in kids)) if kids else e

            out.append(replace(comp, reads=tuple(new_reads),
                               expr=rw(comp.expr)))
        return out


def rewrite_passes() -> tuple[LICMPass, ExpandFactorPass, CSEPass]:
    """The three rewrite passes in pipeline order (LICM first: hoisting a
    partial-support duplicate beats materializing it at full rank)."""
    return (LICMPass(), ExpandFactorPass(), CSEPass())
