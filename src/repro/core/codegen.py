"""Code generation: loop-nest IR -> executable code.

Three lowerings:

* ``execute_numpy``  — the semantic oracle: literal nested Python loops over
  numpy arrays.  Slow; used by tests to validate every other path.
* ``compile_jax(mode='as_written')`` — the *baseline compiler* analogue: the
  nest is lowered in its authored loop order; only each computation's
  innermost legal loop is vectorized (what ``clang -O3``'s auto-vectorizer
  sees), everything else becomes sequential ``lax.fori_loop``s.  No idioms.
* ``compile_jax(mode='canonical')`` — the scheduled path: every legal
  iterator is vectorized (subject to a materialization budget), reductions
  become vector reductions, and BLAS-class computations are dispatched to
  ``jnp.einsum`` / Pallas (idiom detection), mirroring the paper's recipe DB.

On top of the canonical path, two recipe-selected lowerings:

* ``Schedule.pallas_nest`` / ``Schedule.pallas_reduce`` route whole canonical
  nests through the grid-tiled Pallas kernel (``repro.core.tiling`` plans the
  grid, ``repro.kernels.nest_kernel`` emits the ``pallas_call``); nests
  outside the tiled class fall back to the generic path silently.
* ``Schedule.scan`` lowers carried (recurrence) loops to ``lax.scan`` with
  leading-axis operands sliced per step instead of whole arrays carried
  through a ``fori_loop`` and re-gathered every iteration.

Legality is decided with the same dependence machinery the normalizer uses:
an iterator may be materialized as an array axis iff no dependence of the
nest is carried by it (reduction self-deps of flagged accumulations exempt).
"""
from __future__ import annotations

import itertools
import math
import weakref
from dataclasses import dataclass, field
from dataclasses import replace as dc_replace
from typing import Any, Callable, Mapping, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .dependence import EQ, nest_direction_vectors
from .ir import (
    Access,
    Affine,
    Array,
    Computation,
    Loop,
    Node,
    Program,
    loop_iterators,
    nest_computations,
    walk,
)

# Shared accumulate-op semantics: neutral elements and reducers.  The Pallas
# nest kernel (repro.kernels.nest_kernel) imports these (plus ``_combine``)
# so both lowerings stay in sync when an accumulate op is added.
_ACC_INIT = {"+": 0.0, "*": 1.0, "max": -np.inf, "min": np.inf}
_ACC_REDUCE = {"+": jnp.sum, "*": jnp.prod, "max": jnp.max, "min": jnp.min}


# ---------------------------------------------------------------------------
# Oracle: literal numpy interpreter
# ---------------------------------------------------------------------------
def execute_numpy(program: Program, inputs: Mapping[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Interpret ``program`` literally in float64 numpy (the semantics oracle).

    Loops run point-by-point in authored order, so any transformed program
    whose outputs ``np.array_equal`` this one is bit-identical, not merely
    close.  Returns the full array environment (inputs copied, temps zeroed).
    """
    env = {
        a.name: (
            np.zeros(a.shape, dtype=np.float64)
            if a.name in program.temps
            else np.array(inputs[a.name], dtype=np.float64, copy=True)
        )
        for a in program.arrays
    }

    def eval_aff(a: Affine, it_env: dict[str, int]) -> int:
        """Evaluate an affine index expression under the iterator bindings."""
        return a.const + sum(c * it_env[k] for k, c in a.coeffs)

    def run(node: Node, it_env: dict[str, int]) -> None:
        """Execute one loop/computation node under the iterator bindings."""
        if isinstance(node, Computation):
            if any(eval_aff(g, it_env) < 0 for g in node.guards):
                return
            vals = []
            for r in node.reads:
                ix = tuple(eval_aff(e, it_env) for e in r.index)
                vals.append(env[r.array][ix] if ix else env[r.array][()])
            out = node.expr(*vals)
            wix = tuple(eval_aff(e, it_env) for e in node.write.index)
            tgt = env[node.write.array]
            if node.accumulate is None:
                tgt[wix] = out
            elif node.accumulate == "+":
                tgt[wix] += out
            elif node.accumulate == "*":
                tgt[wix] *= out
            elif node.accumulate == "max":
                tgt[wix] = max(tgt[wix], out)
            elif node.accumulate == "min":
                tgt[wix] = min(tgt[wix], out)
            else:
                raise ValueError(node.accumulate)
        else:
            for v in range(node.start, node.stop, node.step):
                it_env[node.iterator] = v
                for child in node.body:
                    run(child, it_env)
            it_env.pop(node.iterator, None)

    for n in program.body:
        run(n, {})
    return env


# ---------------------------------------------------------------------------
# JAX backend
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Schedule:
    """Scheduling decisions for ``compile_jax`` (one per top-level nest).

    The Pallas knobs select the grid-tiled lowering of canonical nests
    (``repro.core.tiling`` + ``repro.kernels.nest_kernel``): ``pallas_nest``
    routes fully-parallel nests (elementwise/stencil groups), ``pallas_reduce``
    routes associative reductions through a grid-accumulated scratch block.
    Nests outside the tiled class silently fall back to the generic
    vectorized path, so both flags are safe to set unconditionally.

    ``scan`` replaces the whole-array-carry ``lax.fori_loop`` lowering of
    carried (recurrence) loops with a ``lax.scan`` that slices leading-axis
    operands into per-step rows and stacks the written rows (canonical mode
    only; 'as_written' keeps the baseline-compiler fori behavior).

    ``shard_axis`` opts the nest into the mesh partitioner
    (``repro.core.partition``): when ``compile_sharded`` runs over a mesh
    axis of that name, the planner may shard the nest's outermost parallel
    iterator across it (None keeps the nest single-device/replicated).  The
    flag is inert under plain ``compile_jax``.
    """

    mode: str = "canonical"  # 'as_written' | 'canonical'
    use_idioms: bool = True  # BLAS-class dispatch (einsum / Pallas)
    vec_budget: int = 1 << 22  # max materialized elements per computation
    pallas_gemm: bool = False  # route GEMM idiom to the Pallas MXU kernel
    tile: tuple[int, int, int] | None = None  # Pallas GEMM block sizes
    interpret: bool = True  # Pallas interpret mode (CPU container)
    pallas_nest: bool = False  # grid-tiled Pallas for parallel nests
    pallas_reduce: bool = False  # grid-tiled Pallas for reduction nests
    nest_tile: tuple[int, ...] | None = None  # trailing-axis tiles (+red last)
    unroll: int = 1  # in-kernel reduction unroll factor
    scan: bool = True  # lax.scan recurrences (canonical mode)
    vmem_budget: int = 1 << 23  # tiling planner working-set budget (bytes)
    shard_axis: str | None = None  # mesh axis for the partition planner


# Trace-time lowering counters (tests assert which path actually fired).
LOWERING_STATS = {"scan": 0, "fori": 0}


@dataclass
class _VecAxis:
    iterator: str
    start: int
    stop: int
    step: int

    @property
    def trip(self) -> int:
        return max(0, (self.stop - self.start + self.step - 1) // self.step)


class Unsupported(Exception):
    """A nest shape the structured JAX lowering cannot express (caller falls
    back to the scan-based general path)."""


def _written_arrays(node: Node) -> list[str]:
    if isinstance(node, Computation):
        return [node.write.array]
    out: list[str] = []
    for _, c in walk(node):
        if c.write.array not in out:
            out.append(c.write.array)
    return out


def _is_multiplicative(expr: Callable, n_reads: int) -> float | None:
    """Probe: does ``expr(*xs) == c * prod(xs)``? Return c, else None.

    Memoized per ``expr`` object (weakly, so cached programs don't leak):
    the 4-numpy-probe answer is a pure function of the callable, and idiom
    detection re-asks it for every computation on every trace."""
    try:
        per_expr = _MULT_MEMO.setdefault(expr, {})
    except TypeError:  # not weakref-able (e.g. some builtins/partials)
        return _is_multiplicative_probe(expr, n_reads)
    if n_reads not in per_expr:
        per_expr[n_reads] = _is_multiplicative_probe(expr, n_reads)
    return per_expr[n_reads]


_MULT_MEMO: "weakref.WeakKeyDictionary[Callable, dict[int, float | None]]" = (
    weakref.WeakKeyDictionary()
)


def _is_multiplicative_probe(expr: Callable, n_reads: int) -> float | None:
    if n_reads == 0:
        return None
    rng = np.random.default_rng(0)
    try:
        c = float(expr(*([np.float64(1.0)] * n_reads)))
    except Exception:
        return None
    if not np.isfinite(c) or c == 0.0:
        return None
    for _ in range(3):
        xs = rng.uniform(0.5, 2.0, size=n_reads)
        try:
            got = float(expr(*[np.float64(x) for x in xs]))
        except Exception:
            return None
        want = c * float(np.prod(xs))
        if not np.isclose(got, want, rtol=1e-10, atol=1e-12):
            return None
    return c


def _single_iter_dims(a: Access) -> list[str] | None:
    """If every dim of ``a`` is exactly one iterator (coeff 1, const 0), return
    the iterator per dim; else None."""
    out = []
    for ix in a.index:
        if ix.const != 0 or len(ix.coeffs) != 1 or ix.coeffs[0][1] != 1:
            return None
        out.append(ix.coeffs[0][0])
    return out


def _offset_iter_dims(a: Access) -> list[tuple[str, int]] | None:
    """Like ``_single_iter_dims`` but tolerating constant offsets: per dim,
    ``(iterator, const)`` when the subscript is ``iterator + const`` (coeff 1);
    None when any dim is not of that shape."""
    out = []
    for ix in a.index:
        if len(ix.coeffs) != 1 or ix.coeffs[0][1] != 1:
            return None
        out.append((ix.coeffs[0][0], ix.const))
    return out


class _NestEmitter:
    """Emits one top-level nest into JAX, structure-driven."""

    def __init__(self, program: Program, schedule: Schedule):
        self.p = program
        self.s = schedule

    # -- planning -----------------------------------------------------------
    def plan(self, nest: Node) -> dict[str, bool]:
        """iterator -> vectorizable? (plus budget-driven demotion).

        Legality is *per loop over its own subtree*: a loop may be
        materialized as an array axis iff no dependence among the
        computations it encloses is carried by its iterator.  Dependences
        between sibling nests are enforced by their sequential emission
        order and do not constrain vectorization.
        """
        if isinstance(nest, Computation):
            return {}
        iterators = list(loop_iterators(nest))
        legal: dict[str, bool] = {}

        def visit(n: Node) -> None:
            if isinstance(n, Computation):
                return
            comps = nest_computations(n)
            vecs = nest_direction_vectors([n.iterator], {n.iterator: n.trip_count}, comps)
            legal[n.iterator] = all(v.directions[0] == EQ for v in vecs)
            for b in n.body:
                visit(b)

        visit(nest)
        if self.s.mode == "as_written":
            # only each computation's innermost enclosing loop is vectorized
            inner: set[str] = set()
            for loops, _ in walk(nest):
                if loops:
                    inner.add(loops[-1].iterator)
            return {it: (legal[it] and it in inner) for it in iterators}
        # canonical: vectorize all legal iterators within the budget,
        # demoting from the *outermost* side (keeps inner/fast axes wide).
        vec = {it: legal[it] for it in iterators}
        for loops, comp in walk(nest):
            used = [l for l in loops if vec.get(l.iterator)]
            prod = math.prod(max(1, l.trip_count) for l in used)
            for l in used:  # outermost first
                if prod <= self.s.vec_budget:
                    break
                vec[l.iterator] = False
                prod //= max(1, l.trip_count)
        return vec

    def _trips(self, nest: Node) -> dict[str, int]:
        out: dict[str, int] = {}

        def rec(n: Node) -> None:
            if isinstance(n, Loop):
                out[n.iterator] = n.trip_count
                for b in n.body:
                    rec(b)

        rec(nest)
        return out

    # -- emission -----------------------------------------------------------
    def emit(self, nest: Node, env: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
        if self.s.pallas_nest or self.s.pallas_reduce:
            try:
                from ..kernels.nest_kernel import emit_nest

                return emit_nest(self.p, nest, env, self.s)
            except Unsupported:
                pass  # outside the tiled class: generic lowering below
        self.vec_plan = self.plan(nest)
        return self._emit(nest, env, {}, [])

    def _emit(
        self,
        node: Node,
        env: dict[str, jnp.ndarray],
        seq_env: dict[str, Any],
        vec_axes: list[_VecAxis],
    ) -> dict[str, jnp.ndarray]:
        if isinstance(node, Computation):
            return self._emit_comp(node, env, seq_env, vec_axes)
        if self.vec_plan.get(node.iterator, False):
            vec2 = vec_axes + [_VecAxis(node.iterator, node.start, node.stop, node.step)]
            for child in node.body:
                env = self._emit(child, env, seq_env, vec2)
            return env
        if node.trip_count <= 0:
            return env
        # sequential loop: prefer the lax.scan lowering (leading-axis operands
        # become per-step slices; written rows are stacked instead of
        # scattered into a whole-array carry each iteration)
        if self.s.mode == "canonical" and self.s.scan:
            out = self._try_scan_loop(node, env, seq_env, vec_axes)
            if out is not None:
                LOWERING_STATS["scan"] += 1
                return out
        # fallback: lax.fori_loop carrying the written arrays whole
        carried = _written_arrays(node)
        LOWERING_STATS["fori"] += 1

        def body(k, carry):
            e = dict(env)
            e.update(dict(zip(carried, carry)))
            s2 = dict(seq_env)
            s2[node.iterator] = node.start + k * node.step
            for child in node.body:
                e = self._emit(child, e, s2, vec_axes)
            return tuple(e[a] for a in carried)

        out = lax.fori_loop(0, node.trip_count, body, tuple(env[a] for a in carried))
        env = dict(env)
        env.update(dict(zip(carried, out)))
        return env

    # -- scan lowering of carried loops --------------------------------------
    def _scan_sliceable(self, node: Loop) -> tuple[dict[str, int], set[str]] | None:
        """Classify the arrays of a sequential loop's subtree.

        Returns ``(written_lookback, readonly)`` where ``written_lookback``
        maps each *written* array whose every access subscripts the leading
        axis with exactly ``t + const`` (write const 0, read consts <= 0) to
        its maximum lookback depth, and ``readonly`` holds read-only arrays
        accessed only at ``t`` itself.  None when no written array qualifies
        (scanning would buy nothing over fori)."""
        t = node.iterator
        status: dict[str, dict] = {}
        for _, c in walk(node):
            for a, is_w in [(c.write, True)] + [(r, False) for r in c.reads]:
                rec = status.setdefault(a.array, {"w": [], "r": [], "bad": False})
                ix0 = a.index[0] if a.index else None
                uses_t = any(ix.coeff(t) != 0 for ix in a.index)
                if ix0 is not None and ix0.coeffs == ((t, 1),) and not any(
                    ix.coeff(t) != 0 for ix in a.index[1:]
                ):
                    (rec["w"] if is_w else rec["r"]).append(ix0.const)
                elif uses_t:
                    rec["bad"] = True
                else:
                    rec.setdefault("plain", True)  # t-independent access
        written_lb: dict[str, int] = {}
        readonly: set[str] = set()
        for name, rec in status.items():
            if rec["bad"] or rec.get("plain"):
                continue
            if rec["w"]:
                if all(c == 0 for c in rec["w"]) and all(c <= 0 for c in rec["r"]):
                    written_lb[name] = max([0] + [-c for c in rec["r"]])
            elif rec["r"] and all(c == 0 for c in rec["r"]):
                readonly.add(name)
        if not written_lb:
            return None
        return written_lb, readonly

    def _try_scan_loop(self, node: Loop, env, seq_env, vec_axes):
        if node.step != 1:
            return None
        cls = self._scan_sliceable(node)
        if cls is None:
            return None
        written_lb, readonly = cls
        t, start, n = node.iterator, node.start, node.trip_count
        for name in list(written_lb) + sorted(readonly):
            arr = env[name]
            if arr.ndim == 0 or node.start + n > arr.shape[0]:
                return None  # leading axis does not cover the loop range
        sliceable = set(written_lb) | readonly

        def lag_name(a: str, d: int) -> str:
            return f"{a}@lag{d}"

        def rw_access(a: Access) -> Access:
            if a.array not in sliceable:
                return a
            c = a.index[0].const
            nm = a.array if c == 0 else lag_name(a.array, -c)
            return Access(nm, a.index[1:])

        def rw(nd: Node) -> Node:
            if isinstance(nd, Computation):
                return dc_replace(
                    nd,
                    write=rw_access(nd.write),
                    reads=tuple(rw_access(r) for r in nd.reads),
                )
            return dc_replace(nd, body=tuple(rw(b) for b in nd.body))

        children = tuple(rw(ch) for ch in node.body)
        whole_written = [a for a in _written_arrays(node) if a not in written_lb]

        xs = {}
        for a in sliceable:
            arr = env[a]
            xs[a] = arr if (start == 0 and n == arr.shape[0]) else lax.slice(
                arr, [start] + [0] * (arr.ndim - 1),
                [start + n] + list(arr.shape[1:]))
        vks = start + jnp.arange(n, dtype=jnp.int32)
        lags0 = {
            lag_name(a, d): env[a][(start - d) % env[a].shape[0]]
            for a, lb in written_lb.items() for d in range(1, lb + 1)
        }
        whole0 = {a: env[a] for a in whole_written}

        def body(carry, x):
            lags, whole = carry
            vk, slabs = x
            e = dict(env)
            e.update(whole)
            e.update(slabs)
            e.update(lags)
            s2 = dict(seq_env)
            s2[t] = vk
            for ch in children:
                e = self._emit(ch, e, s2, vec_axes)
            new_lags = {}
            for a, lb in written_lb.items():
                if lb >= 1:
                    new_lags[lag_name(a, 1)] = e[a]
                for d in range(2, lb + 1):
                    new_lags[lag_name(a, d)] = lags[lag_name(a, d - 1)]
            return (new_lags, {a: e[a] for a in whole}), {
                a: e[a] for a in written_lb}

        (_, whole_f), ys = lax.scan(body, (lags0, whole0), (vks, xs))
        env = dict(env)
        for a in written_lb:
            arr = env[a]
            rows = ys[a].astype(arr.dtype)
            env[a] = rows if (start == 0 and n == arr.shape[0]) else (
                lax.dynamic_update_slice(
                    arr, rows, [start] + [0] * (arr.ndim - 1)))
        env.update(whole_f)
        return env

    # -- computation emission -----------------------------------------------
    def _axes_for(self, comp: Computation, vec_axes: list[_VecAxis]) -> list[_VecAxis]:
        used = set(comp.iterators())
        return [a for a in vec_axes if a.iterator in used]

    def _iter_value(self, it: str, axes: list[_VecAxis], seq_env: dict[str, Any]):
        for pos, a in enumerate(axes):
            if a.iterator == it:
                r = a.start + a.step * jnp.arange(a.trip, dtype=jnp.int32)
                shape = [1] * len(axes)
                shape[pos] = a.trip
                return r.reshape(shape)
        if it in seq_env:
            return seq_env[it]
        raise Unsupported(f"iterator {it} not bound")

    def _eval_affine(self, e: Affine, axes: list[_VecAxis], seq_env: dict[str, Any]):
        val = e.const
        for it, c in e.coeffs:
            val = val + c * self._iter_value(it, axes, seq_env)
        return val

    def _fast_read(self, a: Access, arr, axes: list[_VecAxis]):
        """Direct (possibly sliced/transposed) array view when every dim of
        ``a`` is a distinct vectorized axis up to a constant offset — avoids
        materializing iota index grids and a gather per access, which XLA
        fuses far worse than the plain slice+transpose+reshape this emits
        (dominant for re-fused elementwise chains and constant-offset
        stencil reads like ``A[i-1, j]``)."""
        its_c = _offset_iter_dims(a)
        if its_c is None or len(its_c) != arr.ndim:
            return None
        its = [it for it, _ in its_c]
        if len(set(its)) != len(its):
            return None
        axis_of = {ax.iterator: k for k, ax in enumerate(axes)}
        if not all(it in axis_of for it in its):
            return None
        lo = []
        for d, (it, c) in enumerate(its_c):
            ax = axes[axis_of[it]]
            start = ax.start + c
            if ax.step != 1 or start < 0 or start + ax.trip > arr.shape[d]:
                return None
            lo.append(start)
        if any(lo) or any(axes[axis_of[it]].trip != arr.shape[d]
                          for d, it in enumerate(its)):
            arr = lax.slice(
                arr, lo, [s + axes[axis_of[it]].trip
                          for s, it in zip(lo, its)])
        order = sorted(range(arr.ndim), key=lambda d: axis_of[its[d]])
        out = jnp.transpose(arr, order) if order != list(range(arr.ndim)) else arr
        shape = [1] * len(axes)
        for d, it in enumerate(its):
            shape[axis_of[it]] = arr.shape[d]
        return out.reshape(shape)

    def _gather(self, a: Access, env, axes, seq_env):
        arr = env[a.array]
        if not a.index:
            return arr
        fast = self._fast_read(a, arr, axes)
        if fast is not None:
            return fast
        idx = tuple(self._eval_affine(ix, axes, seq_env) for ix in a.index)
        if all(np.isscalar(i) or (hasattr(i, "ndim") and i.ndim == 0) for i in idx):
            return arr[idx]
        # broadcast scalar dims to arrays for advanced indexing
        shape = jnp.broadcast_shapes(*[jnp.shape(i) for i in idx if hasattr(i, "shape")] or [()])
        idx = tuple(jnp.broadcast_to(jnp.asarray(i, jnp.int32), shape) for i in idx)
        return arr[idx]

    def _emit_comp(self, comp, env, seq_env, vec_axes):
        axes = self._axes_for(comp, vec_axes)
        if self.s.use_idioms:
            out = self._try_einsum(comp, env, seq_env, axes)
            if out is not None:
                env = dict(env)
                env[comp.write.array] = out
                return env
        vals = comp.expr(*[self._gather(r, env, axes, seq_env) for r in comp.reads])
        full_shape = tuple(a.trip for a in axes)
        vals = jnp.broadcast_to(vals, jnp.broadcast_shapes(jnp.shape(vals), full_shape))

        mask = None
        for g in comp.guards:
            gv = self._eval_affine(g, axes, seq_env)
            m = jnp.broadcast_to(jnp.asarray(gv) >= 0, full_shape)
            mask = m if mask is None else (mask & m)

        # split axes into write (kept) vs reduction (folded)
        w_its = set(it for ix in comp.write.index for it in ix.iterators())
        keep = [k for k, a in enumerate(axes) if a.iterator in w_its]
        red = [k for k, a in enumerate(axes) if a.iterator not in w_its]
        acc = comp.accumulate
        if red and acc is None:
            raise Unsupported(f"{comp.name}: assignment under reduction axes")
        if mask is not None and acc is not None:
            fill = _ACC_INIT[acc]
            vals = jnp.where(mask, vals, fill)
        if red:
            vals = _ACC_REDUCE[acc](vals, axis=tuple(red))
        kept_axes = [axes[k] for k in keep]

        arr = env[comp.write.array]
        env = dict(env)
        if not comp.write.index:  # scalar (0-d) target
            if acc is None:
                new = jnp.where(mask, vals, arr) if mask is not None else vals
            else:
                new = _combine(acc, arr, vals)
            env[comp.write.array] = new.astype(arr.dtype)
            return env

        # fast path: write map is a permutation of kept axes addressing a
        # contiguous region of the array (identity scatter / interior slice)
        # (for accumulates, any mask was already folded into neutral fills)
        fast = self._fast_write(comp, kept_axes, arr)
        if fast is not None:
            perm, los, full = fast
            vt = jnp.transpose(vals, perm) if perm != tuple(range(vals.ndim)) else vals
            old = arr if full else lax.slice(
                arr, los, [lo + kept_axes[p].trip for lo, p in zip(los, perm)])
            if acc is None:
                if mask is not None:
                    mt = jnp.transpose(mask, perm) if perm != tuple(range(mask.ndim)) else mask
                    # mask covers only kept axes here (no reduction with set)
                    vt = jnp.where(mt, vt, old)
                new = vt.astype(arr.dtype)
            else:
                new = _combine(acc, old, vt).astype(arr.dtype)
            env[comp.write.array] = (
                new if full else lax.dynamic_update_slice(arr, new, los))
            return env

        widx = tuple(
            jnp.broadcast_to(
                jnp.asarray(self._eval_affine(ix, kept_axes, seq_env), jnp.int32),
                tuple(a.trip for a in kept_axes),
            )
            for ix in comp.write.index
        )
        if acc is None:
            if mask is not None:
                # set-writes have no reduction axes, so mask is over kept axes
                cur = arr[widx]
                vals = jnp.where(mask, vals, cur)
            env[comp.write.array] = arr.at[widx].set(vals.astype(arr.dtype))
        else:
            upd = getattr(arr.at[widx], {"+": "add", "*": "multiply", "max": "max", "min": "min"}[acc])
            env[comp.write.array] = upd(vals.astype(arr.dtype))
        return env

    def _fast_write(self, comp, kept_axes, arr):
        """Return ``(perm, origins, full_cover)`` when the write map is a
        permutation of the kept vectorized axes addressing a contiguous
        in-bounds region (constant offsets and non-zero loop starts allowed:
        stencil interiors update via slice instead of an index-grid scatter).
        """
        its_c = _offset_iter_dims(comp.write)
        if its_c is None or len(its_c) != arr.ndim:
            return None
        its = [it for it, _ in its_c]
        axis_of = {a.iterator: k for k, a in enumerate(kept_axes)}
        if set(its) != set(axis_of) or len(set(its)) != len(its):
            return None
        los, full = [], True
        for d, (it, c) in enumerate(its_c):
            a = kept_axes[axis_of[it]]
            lo = a.start + c
            if a.step != 1 or lo < 0 or lo + a.trip > arr.shape[d]:
                return None
            los.append(lo)
            full = full and lo == 0 and a.trip == arr.shape[d]
        return tuple(axis_of[it] for it in its), tuple(los), full

    # -- BLAS idiom: einsum / Pallas GEMM ------------------------------------
    def _try_einsum(self, comp, env, seq_env, axes):
        if comp.accumulate != "+" or comp.guards or len(comp.reads) < 1:
            return None
        c = _is_multiplicative(comp.expr, len(comp.reads))
        if c is None:
            return None
        ax_of = {a.iterator: a for a in axes}
        # every iterator of the computation must be a vectorized full-range axis
        for it in comp.iterators():
            a = ax_of.get(it)
            if a is None or a.start != 0 or a.step != 1:
                return None
        # accesses: dims are single iterators (full range) or seq-env scalars
        def classify(a: Access):
            letters, slicers = [], []
            arr = env[a.array]
            for d, ix in enumerate(a.index):
                its = ix.iterators()
                if len(its) == 1 and ix.const == 0 and ix.coeff(its[0]) == 1 and its[0] in ax_of:
                    if ax_of[its[0]].trip != arr.shape[d]:
                        return None
                    letters.append(its[0])
                    slicers.append(None)
                elif not its or all(it in seq_env for it in its):
                    slicers.append(self._eval_affine(ix, [], seq_env))
                    letters.append(None)
                else:
                    return None
            return letters, slicers

        w = classify(comp.write)
        if w is None or any(l is None for l in w[0]):
            return None
        rs = [classify(r) for r in comp.reads]
        if any(r is None for r in rs):
            return None

        sym: dict[str, str] = {}

        def letter(it: str) -> str:
            if it not in sym:
                sym[it] = "abcdefghijklmnopqrstuvwxyz"[len(sym)]
            return sym[it]

        operands, subs = [], []
        for (letters, slicers), acc_r in zip(rs, comp.reads):
            arr = env[acc_r.array]
            sub = ""
            for d in range(len(letters) - 1, -1, -1):
                if letters[d] is None:
                    arr = jnp.take(arr, jnp.asarray(slicers[d], jnp.int32), axis=d)
            for d, l in enumerate(letters):
                if l is not None:
                    sub += letter(l)
            operands.append(arr)
            subs.append(sub)
        out_sub = "".join(letter(l) for l in w[0])
        for l in out_sub:
            if not any(l in s for s in subs):
                return None  # output iterator never read: einsum can't broadcast it
        arr = env[comp.write.array]
        if tuple(ax_of[l].trip for l in w[0]) != arr.shape:
            return None  # partial-cover writes take the generic path
        contrib = None
        if self.s.pallas_gemm and len(operands) == 2:
            # canonical 2-operand contraction -> Pallas MXU kernel
            try:
                from ..kernels import ops as kops

                contrib = kops.einsum2(
                    subs[0], subs[1], out_sub, operands[0], operands[1],
                    tile=self.s.tile, interpret=self.s.interpret,
                )
            except Exception:
                contrib = None
        if contrib is None:
            spec = ",".join(subs) + "->" + out_sub
            contrib = jnp.einsum(spec, *operands)
        if c != 1.0:
            contrib = contrib * c
        return arr + contrib.astype(arr.dtype)


def _combine(acc: str, a, b):
    return {"+": lambda: a + b, "*": lambda: a * b,
            "max": lambda: jnp.maximum(a, b), "min": lambda: jnp.minimum(a, b)}[acc]()


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def compile_jax(
    program: Program,
    per_nest: Schedule | Sequence[Schedule] = Schedule(),
) -> Callable[[Mapping[str, Any]], dict[str, Any]]:
    """Build a jit-able fn: {array: value} -> {array: value} (updated).

    ``per_nest`` is one ``Schedule`` per top-level nest (the daisy scheduler
    resolves one recipe per canonical nest); a single ``Schedule`` is
    broadcast to every nest.
    """
    if isinstance(per_nest, Schedule):
        schedules: Sequence[Schedule] = (per_nest,) * len(program.body)
    else:
        schedules = tuple(per_nest)
        if len(schedules) != len(program.body):
            raise ValueError(
                f"{program.name}: got {len(schedules)} schedules for "
                f"{len(program.body)} top-level nests"
            )

    def fn(inputs: Mapping[str, Any]) -> dict[str, Any]:
        """Run every nest under its schedule; returns the array environment."""
        env = {
            a.name: (
                jnp.zeros(a.shape, dtype=jnp.float32)
                if a.name in program.temps
                else jnp.asarray(inputs[a.name])
            )
            for a in program.arrays
        }
        for nest, sched in zip(program.body, schedules):
            em = _NestEmitter(program, sched)
            env = em.emit(nest, env)
        return env

    return fn


def run_jax(
    program: Program,
    inputs: Mapping[str, Any],
    per_nest: Schedule | Sequence[Schedule] | None = None,
):
    """Compile ``program`` with ``compile_jax``, jit it, and run it once."""
    sched = per_nest if per_nest is not None else Schedule()
    return jax.jit(compile_jax(program, sched))(dict(inputs))
