"""Sharded execution of canonical programs (data-parallel mesh partitioning).

The paper's flagship application is embarrassingly parallel over horizontal
grid columns (CLOUDSC's NPROMA blocking, §5.2); after a priori normalization
the minimal-stride permutation has already surfaced that parallel iterator in
every canonical nest.  This module picks it up and maps it onto a mesh axis:

* ``plan_program_partition`` — the planner.  Per canonical nest it walks the
  iterators outermost-first and selects the first *parallel* iterator (no
  dependence carried by it, per the same direction-vector oracle the
  normalizer uses) whose accesses are **shard-aligned**: the iterator appears
  in exactly one dimension of every access that uses it, with coefficient 1
  and offset 0, covering the full array extent.  Everything else vetoes:

    - carried / scan iterators (recurrences)        -> try the next iterator
    - constant-offset or strided use (``A[p-1]``)   -> cross-shard flow, veto
    - guards referencing the iterator               -> shard-position
      dependent control flow, veto
    - accumulations over the sharded iterator whose extent does not divide
      the mesh (padding would feed garbage into the all-reduce), veto

  A nest with no shardable iterator falls back to replication, and every
  array it touches is pinned replicated program-wide (the plan restarts until
  the array assignment is globally consistent — one ``PartitionSpec`` per
  array for the whole program).

* ``compile_sharded`` — the executor.  Builds the shard-local program (loop
  extents and array dims divided by the mesh axis, padded up when the extent
  does not divide), emits each nest through the existing per-nest lowering
  (``_NestEmitter``: einsum idioms, Pallas kernels, scan recurrences — all
  unchanged inside the shard), inserts the all-reduce (``psum``/``pmax``/
  ``pmin``) after nests that accumulate over their sharded iterator, and
  wraps the whole body in ``shard_map`` with one ``PartitionSpec`` per array.
  When nothing shards (or the mesh axis is 1) it returns the plain
  single-device lowering — sharding is always a sound no-op to request.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec

from .codegen import Schedule, _NestEmitter, compile_jax
from .dependence import EQ, nest_direction_vectors
from .ir import (
    Array,
    Computation,
    Loop,
    Node,
    Program,
    loop_iterators,
    nest_computations,
    walk,
)

# accumulate ops with a mesh collective (no pprod exists; '*' stays vetoed)
_SHARD_REDUCE = {"+", "max", "min"}


@dataclass(frozen=True)
class NestPartition:
    """Sharding decision for one top-level nest."""

    iterator: str | None                       # None -> replicated fallback
    reduces: tuple[tuple[str, str], ...] = ()  # (array, op) all-reduced after
    reason: str = "sharded"                    # veto reason when iterator=None


@dataclass
class ProgramPartition:
    """Whole-program sharding plan: one spec per array, one choice per nest."""

    axis: str
    n_shards: int
    array_dims: dict[str, int | None]  # array -> sharded dim (None: replicated)
    nests: list[NestPartition] = field(default_factory=list)

    @property
    def sharded(self) -> bool:
        """True when at least one nest actually shards an iterator."""
        return any(n.iterator is not None for n in self.nests)

    def padded_extent(self, extent: int) -> int:
        """``extent`` rounded up to a multiple of the shard count."""
        return -(-extent // self.n_shards) * self.n_shards

    def spec(self, shape: tuple[int, ...], name: str) -> PartitionSpec:
        """The ``PartitionSpec`` for array ``name`` under this plan."""
        d = self.array_dims.get(name)
        return PartitionSpec(*[self.axis if i == d else None
                               for i in range(len(shape))])

    def describe(self) -> str:
        """Human-readable rendering of the per-nest/per-array decisions."""
        lines = [f"partition over axis '{self.axis}' x{self.n_shards}:"]
        for k, np_ in enumerate(self.nests):
            if np_.iterator is None:
                lines.append(f"  nest {k}: replicated ({np_.reason})")
            else:
                red = "".join(f" all-reduce({a},{op})" for a, op in np_.reduces)
                lines.append(f"  nest {k}: shard {np_.iterator}{red}")
        reps = sorted(a for a, d in self.array_dims.items() if d is None)
        shs = {a: d for a, d in self.array_dims.items() if d is not None}
        lines.append("  arrays: " + ", ".join(
            [f"{a}@dim{d}" for a, d in sorted(shs.items())] + reps))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# per-nest candidate analysis
# ---------------------------------------------------------------------------
def _loops_of(nest: Node) -> dict[str, Loop]:
    out: dict[str, Loop] = {}

    def rec(n: Node) -> None:
        if isinstance(n, Loop):
            out[n.iterator] = n
            for b in n.body:
                rec(b)

    rec(nest)
    return out


def _nest_arrays(nest: Node) -> set[str]:
    return {a.array for c in nest_computations(nest) for a in c.accesses()}


def _candidate(
    program: Program, nest: Loop, p: str, n_shards: int
) -> tuple[dict[str, tuple], dict[str, str]] | str:
    """Try sharding ``nest`` over iterator ``p``.

    Returns ``(requirements, reduces)`` — ``requirements`` maps each touched
    array to ``('dim', d)`` (shard on dim d) or ``('rep',)`` (replicate),
    ``reduces`` maps accumulated arrays to their all-reduce op — or a veto
    reason string.
    """
    loop = _loops_of(nest)[p]
    if loop.start != 0 or loop.step != 1:
        return f"{p}: non-canonical bounds [{loop.start}::{loop.step}]"
    if loop.trip_count < n_shards:
        return f"{p}: extent {loop.trip_count} < {n_shards} shards"

    # parallel? no dependence among the loop's own computations carried by p
    comps_p = nest_computations(loop)
    vecs = nest_direction_vectors([p], {p: loop.trip_count}, comps_p)
    if not all(v.directions[0] == EQ for v in vecs):
        return f"{p}: carried dependence (recurrence stays per-shard-serial)"

    req: dict[str, tuple] = {}
    reduces: dict[str, str] = {}

    def merge(arr: str, want: tuple) -> str | None:
        have = req.get(arr)
        if have is None or have == want:
            req[arr] = want
            return None
        return f"{arr}: conflicting shard requirements {have} vs {want}"

    for _, comp in walk(nest):
        uses_p = p in comp.iterators()
        if any(g.coeff(p) != 0 for g in comp.guards):
            return f"{p}: guard of '{comp.name}' references the shard iterator"
        for a, is_write in [(comp.write, True)] + [(r, False) for r in comp.reads]:
            dims_p = [d for d, ix in enumerate(a.index) if ix.coeff(p) != 0]
            if not dims_p:
                if is_write and uses_p:
                    # value varies with p, write target does not: a reduction
                    # over the sharded iterator -> all-reduce after the nest
                    if comp.accumulate not in _SHARD_REDUCE:
                        return (f"{p}: '{comp.name}' writes {a.array} without "
                                f"an all-reducible accumulate")
                    if loop.trip_count % n_shards != 0:
                        return (f"{p}: reduction over a padded extent "
                                f"({loop.trip_count} % {n_shards} != 0)")
                    prev = reduces.setdefault(a.array, comp.accumulate)
                    if prev != comp.accumulate:
                        return f"{a.array}: mixed reduce ops {prev}/{comp.accumulate}"
                    err = merge(a.array, ("rep",))
                else:
                    # access never sees p -> this nest needs the array whole
                    err = merge(a.array, ("rep",))
                if err:
                    return err
                continue
            if len(dims_p) != 1:
                return f"{p}: {a.array} uses the shard iterator in two dims"
            d = dims_p[0]
            ix = a.index[d]
            if ix.coeffs != ((p, 1),) or ix.const != 0:
                return (f"{p}: {a.array}[..{ix!r}..] is offset/strided — "
                        "cross-shard flow")
            arr = program.array(a.array)
            if loop.stop != arr.shape[d]:
                return (f"{p}: loop [0:{loop.stop}] covers {a.array} dim {d} "
                        f"({arr.shape[d]}) partially")
            err = merge(a.array, ("dim", d))
            if err:
                return err
    # the all-reduce runs only after the whole nest: any read of a reduce
    # target inside the nest (e.g. a sibling computation outside the
    # candidate loop, or an explicit self-read) would observe per-shard
    # partial sums -> veto
    for arr in reduces:
        for c in nest_computations(nest):
            if any(r.array == arr for r in c.reads):
                return (f"{arr}: reduce target read inside the nest "
                        "(partial sums would be visible)")
    return req, reduces


# ---------------------------------------------------------------------------
# program-level planning
# ---------------------------------------------------------------------------
def plan_program_partition(
    program: Program,
    n_shards: int,
    axis: str = "data",
    enabled: Sequence[bool] | None = None,
) -> ProgramPartition:
    """One consistent sharding plan for the whole (normalized) program.

    Greedy over nests in program order, outermost iterator first; arrays get
    exactly one spec program-wide.  When a replicated nest touches an array
    an earlier nest sharded, that array is pinned replicated and planning
    restarts (bounded by the array count), so the result is always globally
    consistent — nests that cannot agree simply stay replicated.
    """
    if enabled is None:
        enabled = [True] * len(program.body)
    forced_rep: set[str] = set()
    for _ in range(len(program.arrays) + 1):
        assigned: dict[str, int | None] = {}
        nests: list[NestPartition] = []
        restart = False
        for nest, en in zip(program.body, enabled):
            chosen: NestPartition | None = None
            chosen_req: dict[str, tuple] = {}
            reason = "sharding disabled for this nest"
            # arrays whose *replication* would admit this nest's best
            # candidate (it needs them whole — e.g. as all-reduce targets —
            # while an earlier nest sharded them).  Replicating an array is
            # always sound, so prefer unlocking this nest over keeping a
            # possibly-trivial earlier sharding.
            unlockable: set[str] | None = None
            if en and isinstance(nest, Loop):
                for p in loop_iterators(nest):
                    cand = _candidate(program, nest, p, n_shards)
                    if isinstance(cand, str):
                        if reason == "sharding disabled for this nest":
                            reason = cand  # outermost veto, for diagnostics
                        continue
                    req, reduces = cand
                    clashes: set[str] = set()
                    fixable = True
                    for arr, want in req.items():
                        d = want[1] if want[0] == "dim" else None
                        if (d is not None and arr in forced_rep) or (
                            arr in assigned and assigned[arr] != d
                        ):
                            clashes.add(arr)
                            # only a want-replicated / have-sharded clash is
                            # curable by forcing replication
                            if d is not None:
                                fixable = False
                    if not clashes:
                        chosen = NestPartition(p, tuple(sorted(reduces.items())))
                        chosen_req = req
                        break
                    if reason == "sharding disabled for this nest":
                        reason = (f"{p}: array spec conflict on "
                                  f"{'/'.join(sorted(clashes))} (replicated "
                                  "for whole-program consistency)")
                    if unlockable is None and fixable:
                        unlockable = clashes
            if chosen is None:
                if unlockable:
                    forced_rep |= unlockable
                    restart = True
                    break
                touched = _nest_arrays(nest)
                conflict = {a for a in touched if assigned.get(a) is not None}
                if conflict:
                    forced_rep |= conflict
                    restart = True
                    break
                for a in touched:
                    assigned.setdefault(a, None)
                nests.append(NestPartition(None, reason=reason))
            else:
                for arr, want in chosen_req.items():
                    assigned[arr] = want[1] if want[0] == "dim" else None
                nests.append(chosen)
        if not restart:
            for a in program.arrays:  # untouched arrays stay replicated
                assigned.setdefault(a.name, None)
            return ProgramPartition(axis, n_shards, assigned, nests)
    raise AssertionError("partition planning failed to converge")  # pragma: no cover


# ---------------------------------------------------------------------------
# shard-local program + executor
# ---------------------------------------------------------------------------
def _rewrite_extent(node: Node, iterator: str, stop: int) -> Node:
    if isinstance(node, Computation):
        return node
    body = tuple(_rewrite_extent(b, iterator, stop) for b in node.body)
    if node.iterator == iterator:
        return replace(node, stop=stop, body=body)
    return replace(node, body=body)


def local_program(program: Program, plan: ProgramPartition) -> Program:
    """The per-shard program: sharded dims and loop extents divided (padded
    up to the mesh first when the extent does not divide)."""
    n = plan.n_shards
    arrays = []
    for a in program.arrays:
        d = plan.array_dims.get(a.name)
        if d is None:
            arrays.append(a)
        else:
            shape = list(a.shape)
            shape[d] = plan.padded_extent(shape[d]) // n
            arrays.append(Array(a.name, tuple(shape), a.dtype))
    body = []
    for nest, np_ in zip(program.body, plan.nests):
        if np_.iterator is None:
            body.append(nest)
        else:
            ext = plan.padded_extent(_loops_of(nest)[np_.iterator].stop) // n
            body.append(_rewrite_extent(nest, np_.iterator, ext))
    return Program(program.name, tuple(arrays), tuple(body), program.temps)


def _all_reduce(op: str, old, new, axis: str):
    if op == "+":
        # accumulate folds into the (replicated) prior contents: sum only
        # the per-shard contributions, then add the base back once
        return old + lax.psum(new - old, axis)
    if op == "max":
        return lax.pmax(new, axis)
    return lax.pmin(new, axis)


def compile_sharded(
    program: Program,
    per_nest: Schedule | Sequence[Schedule] = Schedule(),
    mesh: Any = None,
    axis: str = "data",
) -> tuple[Callable[[Mapping[str, Any]], dict[str, Any]], ProgramPartition]:
    """Like ``compile_jax`` but executed across ``mesh``'s ``axis``.

    Nests whose ``Schedule.shard_axis`` names ``axis`` are considered for
    sharding (a broadcast single Schedule enables every nest); the planner
    still vetoes per nest.  Returns ``(fn, plan)`` — when nothing shards the
    fn IS the single-device lowering and the plan records every veto reason.
    """
    if isinstance(per_nest, Schedule):
        schedules: Sequence[Schedule] = (per_nest,) * len(program.body)
    else:
        schedules = tuple(per_nest)
        if len(schedules) != len(program.body):
            raise ValueError(
                f"{program.name}: got {len(schedules)} schedules for "
                f"{len(program.body)} top-level nests")
    n = int(mesh.shape[axis]) if mesh is not None else 1
    if n <= 1:  # degenerate mesh: report an honest all-replicated plan
        enabled: Sequence[bool] = [False] * len(program.body)
    else:
        enabled = [s.shard_axis == axis for s in schedules]
    plan = plan_program_partition(program, max(n, 1), axis, enabled)
    if mesh is None or n <= 1 or not plan.sharded:
        return compile_jax(program, schedules), plan

    local = local_program(program, plan)
    in_names = [a.name for a in program.input_arrays]
    all_names = [a.name for a in program.arrays]
    shapes = {a.name: a.shape for a in program.arrays}
    from ..kernels.compat import shard_map_compat

    def local_fn(*vals):
        """Per-shard body: run every nest locally, all-reducing as planned."""
        env: dict[str, jnp.ndarray] = {}
        lvals = dict(zip(in_names, vals))
        for a in local.arrays:
            env[a.name] = (jnp.zeros(a.shape, jnp.float32)
                           if a.name in local.temps else lvals[a.name])
        for nest, sched, np_ in zip(local.body, schedules, plan.nests):
            old = {arr: env[arr] for arr, _ in np_.reduces}
            env = _NestEmitter(local, sched).emit(nest, env)
            for arr, op in np_.reduces:
                env[arr] = _all_reduce(op, old[arr], env[arr], axis)
        return tuple(env[k] for k in all_names)

    sm = shard_map_compat(
        local_fn, mesh,
        in_specs=tuple(plan.spec(shapes[k], k) for k in in_names),
        out_specs=tuple(plan.spec(shapes[k], k) for k in all_names),
    )

    def fn(inputs: Mapping[str, Any]) -> dict[str, Any]:
        """Pad inputs to shard multiples, run the shard map, unpad outputs."""
        vals = []
        for k in in_names:
            v = jnp.asarray(inputs[k])
            d = plan.array_dims.get(k)
            if d is not None:
                pad = plan.padded_extent(v.shape[d]) - v.shape[d]
                if pad:
                    widths = [(0, pad if i == d else 0) for i in range(v.ndim)]
                    v = jnp.pad(v, widths)
            vals.append(v)
        outs = dict(zip(all_names, sm(*vals)))
        for k, v in outs.items():
            d = plan.array_dims.get(k)
            if d is not None and v.shape[d] != shapes[k][d]:
                outs[k] = lax.slice(
                    v, [0] * v.ndim,
                    [shapes[k][i] if i == d else s
                     for i, s in enumerate(v.shape)])
        return outs

    return fn, plan


def run_sharded(
    program: Program,
    inputs: Mapping[str, Any],
    mesh: Any,
    per_nest: Schedule | Sequence[Schedule] | None = None,
    axis: str = "data",
):
    """One-shot jitted sharded execution (mirrors ``run_jax``)."""
    sched = per_nest if per_nest is not None else Schedule(shard_axis=axis)
    fn, _ = compile_sharded(program, sched, mesh=mesh, axis=axis)
    return jax.jit(fn)(dict(inputs))
