"""Idiom detection on canonical nests (paper §4: "idiom detection, i.e.,
replacing the loop nest with the matching BLAS library call").

On TPU the "library call" is the Pallas MXU kernel (or XLA's native dot via
``jnp.einsum``).  Detection requires the canonical form: after fission each
nest holds one computation class, and after stride minimization operand
layouts are predictable — this is why detection fails without normalization
(reproduced in benchmarks/fig9: idiom hit-rate with vs without).
"""
from __future__ import annotations

from dataclasses import dataclass

from .codegen import _is_multiplicative, _single_iter_dims
from .dependence import EQ, nest_direction_vectors
from .ir import Computation, Loop, Node, loop_iterators, nest_computations


@dataclass(frozen=True)
class IdiomMatch:
    """Result of idiom classification for one nest."""

    kind: str  # 'blas3' | 'blas2' | 'dot' | 'stencil' | 'elementwise' | 'reduction' | 'recurrence'
    detail: str = ""


def _trips(nest: Node) -> dict[str, int]:
    out: dict[str, int] = {}

    def rec(n: Node) -> None:
        if isinstance(n, Loop):
            out[n.iterator] = n.trip_count
            for b in n.body:
                rec(b)

    rec(nest)
    return out


def classify_nest(nest: Node) -> IdiomMatch:
    """Classify a canonical nest into the recipe-selection idiom taxonomy.

    Carried dependences win (recurrence); otherwise single-computation
    multiplicative reductions map to blas3/blas2/dot by output rank, and the
    rest split into reduction, stencil and elementwise.
    """
    comps = nest_computations(nest)
    iterators = list(loop_iterators(nest)) if isinstance(nest, Loop) else []
    vectors = nest_direction_vectors(iterators, _trips(nest), comps)
    carried = [
        it for k, it in enumerate(iterators)
        if any(v.directions[k] != EQ for v in vectors)
    ]
    if carried:
        return IdiomMatch("recurrence", detail=",".join(carried))

    if len(comps) == 1:
        c = comps[0]
        w_its = {it for ix in c.write.index for it in ix.iterators()}
        red = [it for it in iterators if it in set(c.iterators()) - w_its]
        mult = _is_multiplicative(c.expr, len(c.reads))
        matrix_reads = sum(
            1
            for r in c.reads
            if _single_iter_dims(r) is not None and len(r.index) >= 1
        )
        if c.accumulate == "+" and red and mult is not None and not c.guards:
            out_rank = len(c.write.index)
            if out_rank >= 2 and matrix_reads >= 2:
                return IdiomMatch("blas3", detail=f"red={red}")
            if out_rank == 1:
                return IdiomMatch("blas2", detail=f"red={red}")
            if out_rank == 0:
                return IdiomMatch("dot", detail=f"red={red}")
        if c.accumulate is not None and red:
            return IdiomMatch("reduction")
        # constant-offset reads over the write iterators -> stencil
        offsets = False
        for r in c.reads:
            for ix in r.index:
                if ix.const != 0 and ix.iterators():
                    offsets = True
        if offsets:
            return IdiomMatch("stencil")
        return IdiomMatch("elementwise")
    # multiple computations (fused SCC without carried deps at this level)
    return IdiomMatch("elementwise", detail=f"group={len(comps)}")
