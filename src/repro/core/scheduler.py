"""daisy — the normalized auto-scheduler (paper §4).

Pipeline per program:
  1. the compiler pass pipeline (``repro.core.passes``): a priori
     normalization (scalar expansion, maximal fission, stride
     minimization) followed by canonical-form re-fusion
     (``repro.core.fusion``) and canonical renaming — each stage
     individually timed and content-addressed in the compilation cache,
  2. per canonical nest: idiom detection,
  3. recipe resolution against the transfer-tuning database
     (exact fingerprint -> embedding nearest-neighbour -> idiom default),
  4. lowering via the scheduled JAX codegen (einsum/Pallas idioms,
     vectorization, sequential recurrences).

Seeding (`Daisy.seed`) mirrors the paper: normalize the A variants, give
BLAS-3 nests the library-call recipe directly, run the evolutionary search
for the rest, store recipes keyed by fingerprint + embedding.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Mapping, Sequence

import jax
import numpy as np

from .cache import CacheStats, CompilationCache
from .codegen import compile_jax
from .database import TuningDatabase
from .embedding import embed_nest
from .fusion import optimization_pipeline
from .idioms import classify_nest
from .ir import (
    Array,
    Node,
    Program,
    fingerprint,
    loop_iterators,
    nest_computations,
    program_fingerprint,
    walk,
)
from .passes import PassContext
from .recipes import Recipe
from .search import default_recipe_for, evolve_recipe, measure_recipe, schedule_from_recipe


@dataclass
class NestPlan:
    fingerprint: str
    idiom: str
    recipe: Recipe
    source: str  # 'exact' | 'transfer(d=..)' | 'default(..)'


@dataclass
class ProgramPlan:
    program: Program  # normalized
    nests: list[NestPlan]

    @property
    def normalized(self) -> bool:
        return True


def nest_program(program: Program, nest: Node) -> Program:
    """A standalone single-nest program (used for per-nest measurement)."""
    arrays = {a.array for _, a in _nest_accesses(nest)}
    return Program(
        name=f"{program.name}:nest",
        arrays=tuple(a for a in program.arrays if a.name in arrays),
        body=(nest,),
        temps=tuple(t for t in program.temps if t in arrays),
    )


def _nest_accesses(nest: Node):
    from .ir import Computation

    if isinstance(nest, Computation):
        for a in nest.accesses():
            yield nest, a
    else:
        for _, c in walk(nest):
            for a in c.accesses():
                yield c, a


def random_inputs(program: Program, seed: int = 0, dtype=np.float32) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    return {
        a.name: rng.uniform(0.1, 1.0, size=a.shape).astype(dtype)
        for a in program.input_arrays
    }


class Daisy:
    def __init__(
        self,
        db: TuningDatabase | None = None,
        interpret: bool = True,
        cache: CompilationCache | None = None,
        fuse: bool = True,
        backend: str | None = None,
    ):
        """``backend`` selects how Pallas-kind recipes are executed:

        * ``'xla'``             — rewrite pallas recipes onto their XLA
                                  equivalents (einsum / vectorize); no Pallas
                                  kernels are built at all,
        * ``'pallas_interpret'``— Pallas kernels in interpret mode (CPU
                                  correctness container; the default),
        * ``'pallas'``          — compiled Pallas (the TPU deploy target).

        ``interpret`` is kept for backward compatibility; passing ``backend``
        overrides it.
        """
        if backend is not None:
            if backend not in ("xla", "pallas_interpret", "pallas"):
                raise ValueError(f"unknown backend {backend!r}")
            interpret = backend != "pallas"
        self.backend = backend or ("pallas_interpret" if interpret else "pallas")
        self.db = db if db is not None else TuningDatabase()
        self.interpret = interpret
        self.fuse = fuse
        # The compiler pass pipeline: a priori normalization + canonical-form
        # re-fusion.  Shared by plan/compile/seed so database fingerprints
        # always refer to the same canonical form.
        self.pipeline = optimization_pipeline(fuse=fuse)
        # Content-addressed memo for the pipeline -> plan -> compile chain.
        # Keys include the database generation, so seeding new recipes
        # expires stale plans while normalized programs stay cached.
        self.cache = cache if cache is not None else CompilationCache()

    @property
    def cache_stats(self) -> CacheStats:
        return self.cache.stats

    # -- caching --------------------------------------------------------------
    def _normalized(self, program: Program, fp: str | None = None) -> Program:
        # Whole-pipeline memo first (one lookup on the hot path); on a miss
        # the pipeline run itself memoizes per stage, so programs converging
        # onto the same intermediate form share all downstream stage work.
        key = ("pipeline", self.pipeline.name, fp or program_fingerprint(program))
        return self.cache.get_or_build(
            key, lambda: self.pipeline.run(program, cache=self.cache)
        )

    def explain(self, program: Program, snapshots: bool = False) -> PassContext:
        """Run the pass pipeline uncached, returning the per-pass context
        (wall time, nest/computation deltas, fusion stats, IR snapshots)."""
        ctx = PassContext(snapshots=snapshots)
        self.pipeline.run(program, ctx=ctx)
        return ctx

    def _plan_key(self, fp: str, normalize_first: bool) -> tuple:
        # id(db) scopes entries to the database instance (self.db keeps it
        # alive), so Daisy objects sharing one CompilationCache but holding
        # different databases never exchange plans; generation expires plans
        # resolved against older contents of the *same* database.
        return (fp, normalize_first, self.fuse, self.interpret, self.backend,
                id(self.db), self.db.generation)

    def _backend_recipe(self, recipe: Recipe) -> Recipe:
        """Map a recipe onto the selected backend: under 'xla' the Pallas
        kinds degrade to their XLA equivalents (same schedule semantics,
        library/vector lowering instead of kernels)."""
        if self.backend == "xla" and recipe.kind.startswith("pallas"):
            kind = "einsum" if recipe.kind == "pallas_gemm" else "vectorize"
            return replace(recipe, kind=kind, tile=None)
        return recipe

    # -- planning -------------------------------------------------------------
    def plan(
        self, program: Program, normalize_first: bool = True, _fp: str | None = None
    ) -> ProgramPlan:
        fp = _fp or program_fingerprint(program)
        key = ("plan",) + self._plan_key(fp, normalize_first)
        cached = self.cache.get(key)
        if cached is not None:
            return cached
        p = self._normalized(program, fp) if normalize_first else program
        plans: list[NestPlan] = []
        for nest in p.body:
            nest_fp = fingerprint(nest)
            emb = embed_nest(p, nest)
            idiom = classify_nest(nest)
            recipe, source = self.db.lookup(nest_fp, emb)
            if recipe is None:
                recipe = default_recipe_for(idiom)
                source = f"default({idiom.kind})"
            plans.append(NestPlan(nest_fp, idiom.kind, recipe, source))
        result = ProgramPlan(p, plans)
        self.cache.put(key, result)
        return result

    # -- compilation ----------------------------------------------------------
    def compile(
        self, program: Program, normalize_first: bool = True, jit: bool = True
    ) -> tuple[Callable[[Mapping[str, np.ndarray]], dict], ProgramPlan]:
        fp = program_fingerprint(program)
        key = ("compile", jit) + self._plan_key(fp, normalize_first)
        cached = self.cache.get(key)
        if cached is not None:
            return cached
        plan = self.plan(program, normalize_first=normalize_first, _fp=fp)
        per_nest = [
            schedule_from_recipe(self._backend_recipe(np_.recipe), self.interpret)
            for np_ in plan.nests
        ]
        fn = compile_jax(plan.program, per_nest)
        result = ((jax.jit(fn) if jit else fn), plan)
        self.cache.put(key, result)
        return result

    # -- seeding (paper: A variants define the database) -----------------------
    def seed(
        self,
        programs: Sequence[Program],
        search: bool = True,
        search_iterations: int = 2,
        verbose: bool = False,
    ) -> None:
        pending: list[tuple[str, np.ndarray, Program, dict[str, np.ndarray], Recipe]] = []
        for prog in programs:
            p = self._normalized(prog)
            for nest in p.body:
                fp = fingerprint(nest)
                if self.db.lookup_exact(fp) is not None:
                    continue
                emb = embed_nest(p, nest)
                idiom = classify_nest(nest)
                seed_recipe = default_recipe_for(idiom)
                # one standalone program + one input set per nest, reused by
                # every measurement epoch below
                nprog = nest_program(p, nest)
                inputs = random_inputs(nprog)
                if idiom.kind in ("blas3",):
                    # BLAS-3: straight to the library-call recipe (paper §4)
                    t = measure_recipe(nprog, inputs, self._backend_recipe(seed_recipe))
                    self.db.add(fp, emb, seed_recipe, provenance=f"{prog.name}:idiom", measured_us=t)
                    continue
                pending.append((fp, emb, nprog, inputs, seed_recipe))

        # epoch 1: evolutionary search per nest
        results: list[tuple[str, np.ndarray, Recipe, float]] = []
        for fp, emb, nprog, inputs, seed_recipe in pending:
            if search:
                # candidates are timed as the backend will actually lower
                # them (under 'xla' no Pallas kernel is built or measured)
                best, t = evolve_recipe(nprog, inputs, seed_recipe,
                                        iterations=search_iterations,
                                        resolve=self._backend_recipe)
            else:
                best, t = seed_recipe, measure_recipe(
                    nprog, inputs, self._backend_recipe(seed_recipe))
            results.append((fp, emb, best, t))
            if verbose:
                print(f"  seeded {fp[:60]} -> {best.kind} ({t:.0f}us)")

        # epochs 2-3: re-seed each nest from its most similar nests' recipes
        for fp, emb, best, t in results:
            self.db.add(fp, emb, best, provenance="search", measured_us=t)
        if search:
            for fp, emb, nprog, inputs, _ in pending:
                near = self.db.lookup_nearest(emb, k=10)
                pool = [e.recipe for _, e in near]
                cur = self.db.lookup_exact(fp)
                best, t = evolve_recipe(nprog, inputs, cur,
                                        iterations=1, reseed_pool=pool,
                                        resolve=self._backend_recipe)
                self.db.add(fp, emb, best, provenance="search+transfer", measured_us=t)
