"""daisy — the normalized auto-scheduler (paper §4).

Pipeline per program:
  1. the compiler pass pipeline (``repro.core.passes``): a priori
     normalization (scalar expansion, maximal fission, stride
     minimization) followed by canonical-form re-fusion
     (``repro.core.fusion``) and canonical renaming — each stage
     individually timed and content-addressed in the compilation cache,
  2. per canonical nest: idiom detection,
  3. recipe resolution against the transfer-tuning database
     (exact fingerprint -> embedding nearest-neighbour -> idiom default),
  4. lowering via the scheduled JAX codegen (einsum/Pallas idioms,
     vectorization, sequential recurrences).

Seeding (`Daisy.seed`) mirrors the paper: normalize the A variants, give
BLAS-3 nests the library-call recipe directly, run the evolutionary search
for the rest, store recipes keyed by fingerprint + embedding.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from pathlib import Path
from typing import Any, Callable, Mapping, Sequence

import jax
import numpy as np

from .cache import CacheStats, CompilationCache
from .codegen import compile_jax
from .database import TuningDatabase, default_pretuned_path
from .embedding import embed_nest
from .fusion import optimization_pipeline
from .idioms import classify_nest
from .ir import (
    Array,
    Node,
    Program,
    fingerprint,
    loop_iterators,
    nest_computations,
    program_fingerprint,
    walk,
)
from .passes import PassContext
from .recipes import Recipe
from .search import (
    default_recipe_for,
    evolve_recipe,
    measure_recipe,
    nest_rng_seed,
    schedule_from_recipe,
)


@dataclass
class NestPlan:
    """Scheduling decision for one canonical nest (recipe + its provenance)."""

    fingerprint: str
    idiom: str
    recipe: Recipe
    source: str  # 'exact' | 'transfer(d=..)' | 'default(..)'


@dataclass
class ProgramPlan:
    """The normalized program plus one ``NestPlan`` per canonical nest."""

    program: Program  # normalized
    nests: list[NestPlan]
    # filled by ``Daisy.compile`` under a mesh: the partition planner's
    # whole-program sharding decision (None before compilation / no mesh)
    partition: "Any | None" = None

    @property
    def normalized(self) -> bool:
        """Plans are always built from the normalized program."""
        return True


def nest_program(program: Program, nest: Node) -> Program:
    """A standalone single-nest program (used for per-nest measurement).

    Temps the nest *consumes* — reads before it has written them, i.e.
    values produced by earlier nests of the full program — are demoted to
    inputs of the standalone program.  ``random_inputs`` fills inputs only,
    so keeping them as temps would measure every downstream nest on
    zero-filled operands: degenerate data the deployed program never sees.
    """
    arrays = {a.array for _, a in _nest_accesses(nest)}
    temps = set(program.temps) & arrays
    written: set[str] = set()
    consumed: set[str] = set()
    for c in nest_computations(nest):
        for a in c.reads:
            if a.array in temps and a.array not in written:
                consumed.add(a.array)
        # an accumulate write folds into the array's current value — the
        # initial contents are consumed unless this nest wrote them first
        if c.accumulate is not None and c.write.array in temps \
                and c.write.array not in written:
            consumed.add(c.write.array)
        written.add(c.write.array)
    return Program(
        name=f"{program.name}:nest",
        arrays=tuple(a for a in program.arrays if a.name in arrays),
        body=(nest,),
        temps=tuple(t for t in program.temps if t in temps - consumed),
    )


def _nest_accesses(nest: Node):
    from .ir import Computation

    if isinstance(nest, Computation):
        for a in nest.accesses():
            yield nest, a
    else:
        for _, c in walk(nest):
            for a in c.accesses():
                yield c, a


def random_inputs(program: Program, seed: int = 0, dtype=np.float32) -> dict[str, np.ndarray]:
    """Uniform(0.1, 1) arrays for every input (non-temp) array."""
    rng = np.random.default_rng(seed)
    return {
        a.name: rng.uniform(0.1, 1.0, size=a.shape).astype(dtype)
        for a in program.input_arrays
    }


class Daisy:
    """The daisy scheduler: normalize, plan recipes per nest, compile.

    Runs the full optimization pipeline (a priori normalization +
    COFFEE-style rewrites + re-fusion), resolves one ``Recipe`` per
    canonical nest from the tuning database (exact, transfer, or idiom
    default), and lowers through the JAX/Pallas backends — memoizing every
    stage in a content-addressed cache.
    """

    def __init__(
        self,
        db: TuningDatabase | None = None,
        interpret: bool = True,
        cache: CompilationCache | None = None,
        fuse: bool = True,
        rewrite: bool = True,
        backend: str | None = None,
        mesh: Any = None,
        shard_axis: str = "data",
    ):
        """``backend`` selects how Pallas-kind recipes are executed:

        * ``'xla'``             — rewrite pallas recipes onto their XLA
                                  equivalents (einsum / vectorize); no Pallas
                                  kernels are built at all,
        * ``'pallas_interpret'``— Pallas kernels in interpret mode (CPU
                                  correctness container; the default),
        * ``'pallas'``          — compiled Pallas (the TPU deploy target).

        ``interpret`` is kept for backward compatibility; passing ``backend``
        overrides it.

        ``mesh`` turns on the sharded execution path: ``compile`` routes the
        normalized program through the partition planner
        (``repro.core.partition``), which shards each canonical nest's
        outermost parallel iterator across ``mesh``'s ``shard_axis`` and
        falls back to replication wherever the dependence oracle vetoes.  A
        recipe's ``parallelize`` knob overrides the default axis per nest.
        """
        if backend is not None:
            if backend not in ("xla", "pallas_interpret", "pallas"):
                raise ValueError(f"unknown backend {backend!r}")
            interpret = backend != "pallas"
        self.backend = backend or ("pallas_interpret" if interpret else "pallas")
        self.db = db if db is not None else TuningDatabase()
        self.interpret = interpret
        self.fuse = fuse
        self.rewrite = rewrite
        self.mesh = mesh
        self.shard_axis = shard_axis
        # The compiler pass pipeline: a priori normalization + COFFEE-style
        # expression rewrites + canonical-form re-fusion.  Shared by
        # plan/compile/seed so database fingerprints always refer to the
        # same canonical form.
        self.pipeline = optimization_pipeline(fuse=fuse, rewrite=rewrite)
        # Content-addressed memo for the pipeline -> plan -> compile chain.
        # Keys include the database generation, so seeding new recipes
        # expires stale plans while normalized programs stay cached.
        self.cache = cache if cache is not None else CompilationCache()

    @property
    def cache_stats(self) -> CacheStats:
        """Hit/miss counters of the underlying compilation cache."""
        return self.cache.stats

    # -- caching --------------------------------------------------------------
    def _normalized(self, program: Program, fp: str | None = None) -> Program:
        # Whole-pipeline memo first (one lookup on the hot path); on a miss
        # the pipeline run itself memoizes per stage, so programs converging
        # onto the same intermediate form share all downstream stage work.
        key = ("pipeline", self.pipeline.name, fp or program_fingerprint(program))
        return self.cache.get_or_build(
            key, lambda: self.pipeline.run(program, cache=self.cache)
        )

    def explain(self, program: Program, snapshots: bool = False) -> PassContext:
        """Run the pass pipeline uncached, returning the per-pass context
        (wall time, nest/computation deltas, fusion stats, IR snapshots)."""
        ctx = PassContext(snapshots=snapshots)
        self.pipeline.run(program, ctx=ctx)
        return ctx

    def _plan_key(self, fp: str, normalize_first: bool) -> tuple:
        # db.uid scopes entries to the database instance (a process-unique
        # token, unlike id(), which a later instance can reuse after GC), so
        # Daisy objects sharing one CompilationCache but holding different
        # databases never exchange plans; generation expires plans resolved
        # against older contents of the *same* database.
        # the mesh enters by value (axis names + sizes + device ids), not
        # identity: two equal meshes over the same devices address the same
        # compiled fn, while equal-shaped meshes over *different* devices —
        # whose shard_maps place outputs differently — stay distinct
        mesh_sig = (tuple(sorted(self.mesh.shape.items())),
                    tuple(d.id for d in self.mesh.devices.flat),
                    self.shard_axis) if self.mesh is not None else None
        return (fp, normalize_first, self.fuse, self.interpret, self.backend,
                mesh_sig, self.db.uid, self.db.generation)

    def _backend_recipe(self, recipe: Recipe) -> Recipe:
        """Map a recipe onto the selected backend: under 'xla' the Pallas
        kinds degrade to their XLA equivalents (same schedule semantics,
        library/vector lowering instead of kernels)."""
        if self.backend == "xla" and recipe.kind.startswith("pallas"):
            kind = "einsum" if recipe.kind == "pallas_gemm" else "vectorize"
            return replace(recipe, kind=kind, tile=None)
        return recipe

    # -- planning -------------------------------------------------------------
    def plan(
        self, program: Program, normalize_first: bool = True, _fp: str | None = None
    ) -> ProgramPlan:
        """Normalize (unless told not to) and resolve a recipe per nest."""
        fp = _fp or program_fingerprint(program)
        key = ("plan",) + self._plan_key(fp, normalize_first)
        cached = self.cache.get(key)
        if cached is not None:
            return cached
        p = self._normalized(program, fp) if normalize_first else program
        plans: list[NestPlan] = []
        for nest in p.body:
            nest_fp = fingerprint(nest)
            emb = embed_nest(p, nest)
            idiom = classify_nest(nest)
            recipe, source = self.db.lookup(nest_fp, emb)
            if recipe is None:
                recipe = default_recipe_for(idiom)
                source = f"default({idiom.kind})"
            plans.append(NestPlan(nest_fp, idiom.kind, recipe, source))
        result = ProgramPlan(p, plans)
        self.cache.put(key, result)
        return result

    # -- compilation ----------------------------------------------------------
    def compile(
        self, program: Program, normalize_first: bool = True, jit: bool = True
    ) -> tuple[Callable[[Mapping[str, np.ndarray]], dict], ProgramPlan]:
        """Plan and lower ``program``; returns (callable, plan), memoized."""
        fp = program_fingerprint(program)
        key = ("compile", jit) + self._plan_key(fp, normalize_first)
        cached = self.cache.get(key)
        if cached is not None:
            return cached
        plan = self.plan(program, normalize_first=normalize_first, _fp=fp)
        per_nest = [
            schedule_from_recipe(
                self._backend_recipe(np_.recipe), self.interpret,
                shard_axis=self.shard_axis if self.mesh is not None else None)
            for np_ in plan.nests
        ]
        if self.mesh is not None:
            from .partition import compile_sharded

            fn, plan.partition = compile_sharded(
                plan.program, per_nest, mesh=self.mesh, axis=self.shard_axis)
        else:
            fn = compile_jax(plan.program, per_nest)
        result = ((jax.jit(fn) if jit else fn), plan)
        self.cache.put(key, result)
        return result

    # -- seeding (paper: A variants define the database) -----------------------
    def _prepare_nest(self, p: Program, nest: Node, source: str) -> "_SeedItem":
        # one standalone program + one input set per nest, reused by every
        # measurement epoch
        idiom = classify_nest(nest)
        nprog = nest_program(p, nest)
        return _SeedItem(fingerprint(nest), embed_nest(p, nest), idiom.kind,
                         nprog, random_inputs(nprog),
                         default_recipe_for(idiom), source)

    def _measure_item(self, item: "_SeedItem", recipe: Recipe, repeats: int) -> float:
        return measure_recipe(item.nprog, item.inputs,
                              self._backend_recipe(recipe),
                              repeats=repeats, interpret=self.interpret)

    def _epoch1_item(
        self, item: "_SeedItem", search: bool, iterations: int,
        population: int, repeats: int, deadline_s: float | None = None,
    ) -> tuple[Recipe, float, str]:
        """Epoch-1 recipe for one nest: BLAS-3 takes the library-call recipe
        directly (paper §4), everything else runs the evolutionary search.
        ``deadline_s`` bounds the search's wall clock (the single BLAS-3
        measurement is not worth budgeting)."""
        if item.idiom == "blas3":
            t = self._measure_item(item, item.seed_recipe, repeats)
            return item.seed_recipe, t, f"{item.source}:idiom"
        return self._search_item(item, search, iterations, population, repeats,
                                 deadline_s=deadline_s)

    def _add_measured(self, item: "_SeedItem", recipe: Recipe,
                      provenance: str, t: float) -> None:
        # a nest whose every candidate lowering failed (t = inf) ships no
        # entry: plan() falls back to the default recipe at runtime, and the
        # persisted JSON stays free of unvalidated recipes
        if math.isfinite(t):
            self.db.add(item.fingerprint, item.embedding, recipe,
                        provenance=provenance, measured_us=t)

    def _search_item(
        self, item: "_SeedItem", search: bool, iterations: int,
        population: int, repeats: int, deadline_s: float | None = None,
    ) -> tuple[Recipe, float, str]:
        if not search:
            t = self._measure_item(item, item.seed_recipe, repeats)
            return item.seed_recipe, t, f"{item.source}:analytic"
        # candidates are timed as the backend will actually lower them
        # (under 'xla' no Pallas kernel is built or measured; under
        # 'pallas' the measurement compiles, never interprets)
        best, t = evolve_recipe(
            item.nprog, item.inputs, item.seed_recipe,
            iterations=iterations, population=population,
            rng_seed=nest_rng_seed(item.fingerprint),
            resolve=self._backend_recipe,
            interpret=self.interpret, repeats=repeats,
            deadline_s=deadline_s)
        # store what was actually measured: under 'xla' a pallas-kind winner
        # was timed (and will compile) as its degradation — persisting the
        # raw kind would mislabel the database entry
        return self._backend_recipe(best), t, f"{item.source}:search"

    def _reseed_pool(self, fp: str, emb: np.ndarray, k: int = 10) -> list[Recipe]:
        """Recipes of the most similar *other* nests for the transfer epoch.

        The nest's own database entry (same fingerprint, distance 0) is
        excluded — re-seeding a nest with its own recipe is a no-op that
        would crowd genuinely foreign recipes out of the pool.
        """
        near = self.db.lookup_nearest(emb, k=k + 1)
        return [e.recipe for _, e in near if e.fingerprint != fp][:k]

    def _transfer_item(self, item: "_SeedItem", repeats: int = 3,
                       iterations: int = 1) -> None:
        fp = item.fingerprint
        pool = self._reseed_pool(fp, item.embedding)
        cur = self.db.lookup_exact(fp) or item.seed_recipe
        best, t = evolve_recipe(
            item.nprog, item.inputs, cur, iterations=iterations,
            reseed_pool=pool,
            rng_seed=nest_rng_seed(fp, salt="transfer:"),
            resolve=self._backend_recipe,
            interpret=self.interpret, repeats=repeats)
        self._add_measured(item, self._backend_recipe(best),
                           f"{item.source}:search+transfer", t)

    def seed_nest(
        self,
        p: Program,
        nest: Node,
        search: bool = True,
        search_iterations: int = 2,
        population: int = 4,
        repeats: int = 3,
        source: str = "",
        deadline_s: float | None = None,
    ) -> tuple[str, np.ndarray, Recipe, float, str]:
        """Epoch-1 seeding of one canonical nest of a *normalized* program.

        BLAS-3 nests take the library-call recipe directly (paper §4); the
        rest run the evolutionary search.  All timings are taken under the
        same lowering ``compile`` executes for this Daisy's backend.  Does
        not touch the database — returns ``(fingerprint, embedding, recipe,
        measured_us, provenance)`` so callers (``seed``, the tune CLI's
        process-pool workers) add or merge the result themselves.
        ``deadline_s`` caps the search's wall clock (partial results win).
        """
        item = self._prepare_nest(p, nest, source or p.name)
        recipe, t, prov = self._epoch1_item(
            item, search, search_iterations, population, repeats,
            deadline_s=deadline_s)
        return item.fingerprint, item.embedding, recipe, t, prov

    def seed(
        self,
        programs: Sequence[Program],
        search: bool = True,
        search_iterations: int = 2,
        population: int = 4,
        repeats: int = 3,
        verbose: bool = False,
    ) -> None:
        """Tune the database from seed programs (paper: the A variants).

        Canonical nests are deduped across programs, epoch 1 resolves a
        recipe per unique nest (library call for BLAS-3, evolutionary search
        otherwise), and the winners are written back to ``self.db``.
        """
        pending: list[_SeedItem] = []
        seen: set[str] = set()
        for prog in programs:
            p = self._normalized(prog)
            for nest in p.body:
                fp = fingerprint(nest)
                # dedupe against the database AND within this batch:
                # identical canonical nests arising from different variants
                # (the paper's central case) are searched once, not once per
                # source program
                if fp in seen or self.db.lookup_exact(fp) is not None:
                    continue
                seen.add(fp)
                pending.append(self._prepare_nest(p, nest, prog.name))

        # epoch 1: library-call recipe for BLAS-3, evolutionary search else
        for item in pending:
            recipe, t, prov = self._epoch1_item(
                item, search, search_iterations, population, repeats)
            self._add_measured(item, recipe, prov, t)
            if verbose:
                print(f"  seeded {item.fingerprint[:60]} -> {recipe.kind} ({t:.0f}us)")

        # epochs 2-3: re-seed each nest from its most similar nests' recipes
        if search:
            for item in pending:
                if item.idiom == "blas3":
                    continue  # library-call recipes don't join the search
                self._transfer_item(item, repeats=repeats)

    def transfer_epoch(
        self,
        programs: Sequence[Program],
        fingerprints: set[str] | None = None,
        repeats: int = 3,
        iterations: int = 1,
    ) -> int:
        """The paper's 2nd/3rd seeding epochs as a standalone pass: re-seed
        each already-seeded nest of ``programs`` from the recipes of its most
        similar database neighbours (own entry excluded) and keep the
        better-measured winner.  ``fingerprints`` restricts the pass (the
        tune CLI limits it to nests tuned in the current run so incremental
        runs don't re-measure the whole database).  Returns the number of
        nests re-seeded.
        """
        done = 0
        seen: set[str] = set()
        for prog in programs:
            p = self._normalized(prog)
            for nest in p.body:
                fp = fingerprint(nest)
                if fp in seen or self.db.lookup_exact(fp) is None:
                    continue
                if fingerprints is not None and fp not in fingerprints:
                    continue
                seen.add(fp)
                item = self._prepare_nest(p, nest, prog.name)
                if item.idiom == "blas3":
                    continue  # library-call recipes don't join the search
                self._transfer_item(item, repeats=repeats, iterations=iterations)
                done += 1
        return done

    # -- pretuned deployments ---------------------------------------------------
    @classmethod
    def pretuned(
        cls,
        backend: str | None = "xla",
        path: str | Path | None = None,
        **kwargs,
    ) -> "Daisy":
        """A Daisy warmed with the shipped pretuned transfer-tuning database.

        Loads ``data/pretuned_<backend>.json`` (written offline by
        ``python -m repro.tools.tune``; directory overridable via
        ``REPRO_PRETUNED_DIR``) so deployments resolve recipes from measured
        tuning data instead of idiom defaults.  ``path`` overrides the
        lookup entirely.  ``backend=None`` resolves to ``'xla'`` for both
        the database *and* the execution backend — the Daisy must run the
        lowering its recipes were measured under.
        """
        backend = backend or "xla"
        p = Path(path) if path is not None else default_pretuned_path(backend)
        return cls(db=TuningDatabase.load(p), backend=backend, **kwargs)


@dataclass
class _SeedItem:
    """Per-nest state shared by every seeding epoch (built once per nest)."""

    fingerprint: str
    embedding: np.ndarray
    idiom: str
    nprog: Program
    inputs: dict[str, np.ndarray]
    seed_recipe: Recipe
    source: str
