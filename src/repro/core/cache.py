"""Content-addressed compilation cache (PR-1 tentpole).

The paper's premise is that a priori normalization collapses many loop-nest
variants onto one canonical form, so a small recipe database covers them.
This module exploits the same property at the *compilation* layer: a stable
whole-program fingerprint (``repro.core.ir.program_fingerprint``) addresses
the memoized result of the ``normalize -> plan -> compile_jax`` chain, so a
repeated or structurally-identical program returns the cached jitted
callable (with its jax trace cache intact) instead of re-running fission,
stride minimization and recipe resolution.

Three pieces:

* ``CacheStats``      — hit/miss/eviction counters (surfaced on ``Daisy``).
* ``CompilationCache``— a bounded LRU from content-derived keys to compiled
                        artifacts; shared by the scheduler, the serving
                        engine and the trainer.
* ``fingerprint_obj`` — a stable content fingerprint for configuration
                        objects (nested dataclasses / primitives), used to
                        key jitted model functions so re-created engines or
                        trainers with equal configs reuse one jitted fn.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable, Hashable

import numpy as np


@dataclass
class CacheStats:
    """Hit/miss/eviction counters for one ``CompilationCache``."""

    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def lookups(self) -> int:
        """Total lookups (hits + misses)."""
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        """Fraction of lookups served from the cache (0.0 when unused)."""
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> dict[str, float]:
        """The counters as a plain dict (telemetry/JSON artifacts)."""
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": round(self.hit_rate, 4),
        }

    def __repr__(self) -> str:
        return (
            f"CacheStats(hits={self.hits}, misses={self.misses}, "
            f"evictions={self.evictions}, hit_rate={self.hit_rate:.2%})"
        )


_MISSING = object()


class CompilationCache:
    """Bounded LRU cache from content-derived keys to compiled artifacts.

    Keys must be hashable tuples built from content fingerprints (never
    object identity), so two structurally-identical inputs share a slot.
    Values are arbitrary compiled artifacts: jitted callables, ``ProgramPlan``
    objects, normalized programs.
    """

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.stats = CacheStats()
        self._entries: OrderedDict[Hashable, Any] = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:  # does not touch stats/LRU
        return key in self._entries

    def get(self, key: Hashable, default: Any = None) -> Any:
        """Look ``key`` up, counting the hit/miss and refreshing LRU order."""
        val = self._entries.get(key, _MISSING)
        if val is _MISSING:
            self.stats.misses += 1
            return default
        self.stats.hits += 1
        self._entries.move_to_end(key)
        return val

    def put(self, key: Hashable, value: Any) -> None:
        """Insert (or refresh) ``key``, evicting LRU entries past capacity."""
        self._entries[key] = value
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.stats.evictions += 1

    def get_or_build(self, key: Hashable, build: Callable[[], Any]) -> Any:
        """Return the cached value for ``key``, building + caching on miss."""
        val = self.get(key, _MISSING)
        if val is _MISSING:
            val = build()
            self.put(key, val)
        return val

    def invalidate(self, key: Hashable | None = None) -> None:
        """Drop one entry (or everything, if ``key`` is None). Stats survive."""
        if key is None:
            self._entries.clear()
        else:
            self._entries.pop(key, None)


def _canon(obj: Any) -> str:
    """Canonical text form of a configuration value, for fingerprinting."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        inner = ",".join(
            f"{f.name}={_canon(getattr(obj, f.name))}"
            for f in dataclasses.fields(obj)
        )
        return f"{type(obj).__name__}({inner})"
    if isinstance(obj, dict):
        inner = ",".join(f"{_canon(k)}:{_canon(v)}" for k, v in sorted(obj.items(), key=repr))
        return f"{{{inner}}}"
    if isinstance(obj, (list, tuple)):
        return f"[{','.join(_canon(x) for x in obj)}]"
    if isinstance(obj, np.ndarray):
        return f"nd{obj.shape}{obj.dtype}:{hashlib.sha256(np.ascontiguousarray(obj).tobytes()).hexdigest()[:16]}"
    if isinstance(obj, float):
        return f"{obj:.17g}"
    if callable(obj):
        return f"fn:{getattr(obj, '__qualname__', repr(obj))}"
    return repr(obj)


def fingerprint_obj(*objs: Any) -> str:
    """Stable content fingerprint of configuration objects.

    Recurses through dataclasses, dicts, sequences and numpy arrays; two
    equal-content configs fingerprint identically across processes (modulo
    opaque callables, which hash by qualified name).
    """
    return hashlib.sha256("|".join(_canon(o) for o in objs).encode()).hexdigest()


# A process-wide cache for jitted model-level functions (serving decode
# steps, train steps).  Keyed by config fingerprints so re-created engines
# and trainers reuse one jitted function — and with it jax's trace cache.
jit_cache = CompilationCache(capacity=64)
