"""Affine loop-nest IR — the substrate for a priori loop nest normalization.

The paper (Trümper et al., CGO'25) defines:
  * Computation — unit of work with exactly one write of a scalar to a container.
  * Loop — iterator, bounds, step, and a body of computations/loops.
  * Loop nest — tree of loops and computations (Fig. 2).

This module is a faithful, symbolic encoding of those definitions.  Index
expressions are affine maps over the enclosing iterators, which is what the
paper's Polly-based lifting produces for the benchmarks it handles; non-affine
accesses are representable (coefficient on the special iterator ``"*"``) and
deliberately block normalization, modeling the paper's lifting failures
(correlation/covariance in §4.1).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Iterable, Mapping, Sequence

import numpy as np

NONAFFINE = "*"  # marker iterator for non-affine index terms


# ---------------------------------------------------------------------------
# Affine expressions
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Affine:
    """``sum(coeffs[it] * it) + const`` over iterator names."""

    coeffs: tuple[tuple[str, int], ...] = ()
    const: int = 0

    @staticmethod
    def of(*terms: tuple[str, int] | str, const: int = 0) -> "Affine":
        cs: dict[str, int] = {}
        for t in terms:
            name, c = (t, 1) if isinstance(t, str) else t
            cs[name] = cs.get(name, 0) + c
        return Affine(tuple(sorted((k, v) for k, v in cs.items() if v != 0)), const)

    def coeff(self, it: str) -> int:
        for k, v in self.coeffs:
            if k == it:
                return v
        return 0

    @property
    def is_affine(self) -> bool:
        return self.coeff(NONAFFINE) == 0

    def iterators(self) -> tuple[str, ...]:
        return tuple(k for k, _ in self.coeffs if k != NONAFFINE)

    def rename(self, mapping: Mapping[str, str]) -> "Affine":
        return Affine(
            tuple(sorted((mapping.get(k, k), v) for k, v in self.coeffs)), self.const
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        parts = [f"{v}*{k}" if v != 1 else k for k, v in self.coeffs]
        if self.const or not parts:
            parts.append(str(self.const))
        return "+".join(parts)


def aff(*terms, const: int = 0) -> Affine:
    """Shorthand: aff('i'), aff(('i',2),'j',const=1)."""
    return Affine.of(*terms, const=const)


# ---------------------------------------------------------------------------
# Data containers and accesses
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Array:
    """A data container with a row-major layout (strides derived from shape)."""

    name: str
    shape: tuple[int, ...]
    dtype: str = "float32"

    @property
    def strides(self) -> tuple[int, ...]:
        s = [1] * len(self.shape)
        for d in range(len(self.shape) - 2, -1, -1):
            s[d] = s[d + 1] * self.shape[d + 1]
        return tuple(s)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


@dataclass(frozen=True)
class Access:
    """An affine access ``array[index_0, ..., index_{r-1}]``."""

    array: str
    index: tuple[Affine, ...]

    @property
    def is_affine(self) -> bool:
        return all(ix.is_affine for ix in self.index)

    def iterators(self) -> tuple[str, ...]:
        seen: list[str] = []
        for ix in self.index:
            for it in ix.iterators():
                if it not in seen:
                    seen.append(it)
        return tuple(seen)

    def rename(self, mapping: Mapping[str, str]) -> "Access":
        return Access(self.array, tuple(ix.rename(mapping) for ix in self.index))


def acc(array: str, *index) -> Access:
    """Shorthand: acc('A','i','k'), acc('C','i',aff('j',const=1))."""
    ix = tuple(x if isinstance(x, Affine) else aff(x) for x in index)
    return Access(array, ix)


# ---------------------------------------------------------------------------
# Computations and loops
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Computation:
    """One statement: ``write op= expr(*reads)``.

    ``expr`` is an opaque scalar function (jnp-traceable) of the read values —
    the IR reasons only about the access structure, exactly like the paper's
    symbolic representation. ``accumulate`` marks reduction writes
    (``'+'``, ``'max'``, ``'min'``, ``'*'``) vs plain assignment (None).

    ``guards`` are affine inequalities ``g(iters) >= 0`` restricting the
    iteration domain — triangular PolyBench domains are represented as a
    rectangular box plus guards (the isl-domain flattened), which keeps loop
    bounds static while preserving semantics.
    """

    name: str
    write: Access
    reads: tuple[Access, ...]
    expr: Callable[..., Any]
    accumulate: str | None = None
    guards: tuple[Affine, ...] = ()

    def accesses(self) -> tuple[Access, ...]:
        return (self.write,) + self.reads

    def iterators(self) -> tuple[str, ...]:
        seen: list[str] = []
        for a in self.accesses():
            for it in a.iterators():
                if it not in seen:
                    seen.append(it)
        for g in self.guards:
            for it in g.iterators():
                if it not in seen:
                    seen.append(it)
        return tuple(seen)

    def rename(self, mapping: Mapping[str, str]) -> "Computation":
        return replace(
            self,
            write=self.write.rename(mapping),
            reads=tuple(r.rename(mapping) for r in self.reads),
            guards=tuple(g.rename(mapping) for g in self.guards),
        )


@dataclass(frozen=True)
class Loop:
    """``for it in range(start, stop, step): body``  (bounds are static ints)."""

    iterator: str
    stop: int
    start: int = 0
    step: int = 1
    body: tuple["Node", ...] = ()

    @property
    def trip_count(self) -> int:
        return max(0, (self.stop - self.start + self.step - 1) // self.step)

    def rename(self, mapping: Mapping[str, str]) -> "Loop":
        return replace(
            self,
            iterator=mapping.get(self.iterator, self.iterator),
            body=tuple(b.rename(mapping) for b in self.body),
        )


Node = Loop | Computation


@dataclass(frozen=True)
class Program:
    """An ordered sequence of loops/computations plus array declarations.

    ``temps`` names scratch containers: they are zero-initialized by the
    runtime rather than supplied as inputs, and normalization (e.g. scalar
    expansion) may freely change their shapes.
    """

    name: str
    arrays: tuple[Array, ...]
    body: tuple[Node, ...]
    temps: tuple[str, ...] = ()

    def array(self, name: str) -> Array:
        for a in self.arrays:
            if a.name == name:
                return a
        raise KeyError(name)

    @property
    def array_names(self) -> tuple[str, ...]:
        return tuple(a.name for a in self.arrays)

    @property
    def input_arrays(self) -> tuple[Array, ...]:
        return tuple(a for a in self.arrays if a.name not in self.temps)


# ---------------------------------------------------------------------------
# Tree utilities
# ---------------------------------------------------------------------------
def walk(node: Node, prefix: tuple[Loop, ...] = ()) -> Iterable[tuple[tuple[Loop, ...], Computation]]:
    """Yield (enclosing loops, computation) for every computation under node."""
    if isinstance(node, Computation):
        yield prefix, node
    else:
        for child in node.body:
            yield from walk(child, prefix + (node,))


def program_computations(p: Program) -> list[tuple[tuple[Loop, ...], Computation]]:
    out: list[tuple[tuple[Loop, ...], Computation]] = []
    for n in p.body:
        out.extend(walk(n))
    return out


def loop_iterators(node: Node) -> tuple[str, ...]:
    """In-order iterator names of a nest (paper's loop -> (i_1..i_n) notation)."""
    if isinstance(node, Computation):
        return ()
    its = (node.iterator,)
    for child in node.body:
        for it in loop_iterators(child):
            if it not in its:
                its = its + (it,)
    return its


def is_perfect_nest(node: Node) -> bool:
    """True if node is a chain of single-child loops ending in computations."""
    while isinstance(node, Loop):
        kids = node.body
        if all(isinstance(k, Computation) for k in kids):
            return True
        if len(kids) != 1:
            return False
        node = kids[0]
    return True


def nest_computations(node: Node) -> list[Computation]:
    return [c for _, c in walk(node)] if isinstance(node, Loop) else [node]


def nest_loops(node: Node) -> list[Loop]:
    """The chain of loops from the root of a (quasi-)perfect nest."""
    out: list[Loop] = []
    while isinstance(node, Loop):
        out.append(node)
        loops = [k for k in node.body if isinstance(k, Loop)]
        if len(loops) == 1 and len(node.body) == 1:
            node = loops[0]
        else:
            break
    return out


def rename_nest(node: Node, suffix: str) -> Node:
    """Clone a nest with fresh iterator names (paper §2.1: i'_1 = i_1, ...)."""
    its = loop_iterators(node) if isinstance(node, Loop) else ()
    mapping = {it: f"{it}{suffix}" for it in its}
    return node.rename(mapping)


def fingerprint(node: Node) -> str:
    """Structural fingerprint of a nest, invariant to iterator names.

    Canonical iterator names are assigned by in-order traversal position so two
    nests that differ only in naming hash identically — this is the key the
    transfer-tuning database ultimately relies on.
    """
    its = loop_iterators(node) if isinstance(node, Loop) else ()
    mapping = {it: f"t{k}" for k, it in enumerate(its)}

    def fmt_aff(a: Affine) -> str:
        return repr(a.rename(mapping))

    def fmt_acc(a: Access) -> str:
        return f"{a.array}[{','.join(fmt_aff(ix) for ix in a.index)}]"

    def fmt(n: Node) -> str:
        if isinstance(n, Computation):
            rd = ";".join(fmt_acc(r) for r in n.reads)
            gd = ";".join(fmt_aff(g) for g in n.guards)
            return f"C({fmt_acc(n.write)}{n.accumulate or '='}{rd}|{gd})"
        inner = ",".join(fmt(b) for b in n.body)
        return f"L[{mapping.get(n.iterator, n.iterator)}:{n.start}:{n.stop}:{n.step}]({inner})"

    return fmt(node)


def _expr_signature(comp: Computation) -> str:
    """Content signature of a computation's opaque scalar ``expr``.

    The structural fingerprint deliberately ignores ``expr`` (the IR reasons
    about access structure only), but a *compilation* cache must not conflate
    two programs whose nests match structurally while computing different
    scalar functions.  Two complementary captures:

    * for plain Python functions, a hash of the code object (bytecode,
      consts, names) plus closure cell values and defaults — exact for the
      lambdas the front-ends build, including rebuilt-from-source copies;
    * evaluation at fixed probe points spanning sign changes and magnitudes
      past common thresholds, for callables without ``__code__`` (ufuncs,
      partials) and to distinguish equal-bytecode closures whose cell
      values repr identically.

    If probing fails (e.g. the expr only accepts traced values) the
    signature falls back to identity, which can only cause cache misses,
    never wrong hits — cached programs keep their exprs alive, so a live
    entry's id cannot be reused by a different function.
    """
    parts = []
    f = comp.expr
    code = getattr(f, "__code__", None)
    if code is not None:
        try:
            def cell_text(v: Any) -> str:
                # repr truncates large arrays ('...'), which would conflate
                # closures over arrays equal only at the printed corners
                if isinstance(v, np.ndarray):
                    digest = hashlib.sha256(np.ascontiguousarray(v).tobytes())
                    return f"nd{v.shape}{v.dtype}:{digest.hexdigest()[:16]}"
                return repr(v)

            cells = tuple(
                cell_text(c.cell_contents)
                for c in (getattr(f, "__closure__", None) or ())
            )
            src = (code.co_code.hex() + repr(code.co_consts) + repr(code.co_names)
                   + repr(cells) + repr(getattr(f, "__defaults__", None)))
            parts.append("c:" + hashlib.sha256(src.encode()).hexdigest()[:16])
        except Exception:
            pass
    n = len(comp.reads)
    probes = (
        [1.0] * n,
        [0.5 + 0.375 * k for k in range(n)],
        [-1.25 + 0.5 * k for k in range(n)],
        [3.75 - 0.625 * k for k in range(n)],
        [-4.5 + 1.125 * k for k in range(n)],
    )
    vals = []
    for p in probes:
        try:
            v = float(f(*[np.float64(x) for x in p]))
        except Exception:
            if parts:  # bytecode hash alone still identifies the function
                return parts[0]
            return f"opaque@{id(f):x}"
        vals.append(f"{v:.12g}" if np.isfinite(v) else repr(v))
    parts.append(",".join(vals))
    return "|".join(parts)


def program_fingerprint(program: Program, content: bool = True) -> str:
    """Stable whole-program fingerprint: arrays, temps, body, expr content.

    Invariant to iterator renaming (via the per-nest ``fingerprint``) and to
    the program's display name, so structurally-identical programs — the
    paper's A/B variants after normalization, or a re-built config — address
    the same cache slot.  With ``content=True`` (the default used by the
    compilation cache) each computation's scalar expression is probed so that
    structure-equal programs computing different math stay distinct.
    """
    arrays = ";".join(
        f"{a.name}:{'x'.join(map(str, a.shape))}:{a.dtype}" for a in program.arrays
    )
    temps = ",".join(sorted(program.temps))
    body = "|".join(fingerprint(n) for n in program.body)
    text = f"arrays({arrays})temps({temps})body({body})"
    if content:
        exprs = "|".join(_expr_signature(c) for _, c in program_computations(program))
        text += f"exprs({exprs})"
    return hashlib.sha256(text.encode()).hexdigest()
