"""Affine loop-nest IR — the substrate for a priori loop nest normalization.

The paper (Trümper et al., CGO'25) defines:
  * Computation — unit of work with exactly one write of a scalar to a container.
  * Loop — iterator, bounds, step, and a body of computations/loops.
  * Loop nest — tree of loops and computations (Fig. 2).

This module is a faithful, symbolic encoding of those definitions.  Index
expressions are affine maps over the enclosing iterators, which is what the
paper's Polly-based lifting produces for the benchmarks it handles; non-affine
accesses are representable (coefficient on the special iterator ``"*"``) and
deliberately block normalization, modeling the paper's lifting failures
(correlation/covariance in §4.1).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Iterable, Mapping, Sequence

import numpy as np

NONAFFINE = "*"  # marker iterator for non-affine index terms


# ---------------------------------------------------------------------------
# Affine expressions
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Affine:
    """``sum(coeffs[it] * it) + const`` over iterator names."""

    coeffs: tuple[tuple[str, int], ...] = ()
    const: int = 0

    @staticmethod
    def of(*terms: tuple[str, int] | str, const: int = 0) -> "Affine":
        """Build from ``('i', 2)`` pairs or bare iterator names (coeff 1)."""
        cs: dict[str, int] = {}
        for t in terms:
            name, c = (t, 1) if isinstance(t, str) else t
            cs[name] = cs.get(name, 0) + c
        return Affine(tuple(sorted((k, v) for k, v in cs.items() if v != 0)), const)

    def coeff(self, it: str) -> int:
        """The coefficient of iterator ``it`` (0 when absent)."""
        for k, v in self.coeffs:
            if k == it:
                return v
        return 0

    @property
    def is_affine(self) -> bool:
        """True unless the expression carries the non-affine marker term."""
        return self.coeff(NONAFFINE) == 0

    def iterators(self) -> tuple[str, ...]:
        """The iterator names with nonzero coefficients."""
        return tuple(k for k, _ in self.coeffs if k != NONAFFINE)

    def rename(self, mapping: Mapping[str, str]) -> "Affine":
        """A copy with iterator names substituted via ``mapping``."""
        return Affine(
            tuple(sorted((mapping.get(k, k), v) for k, v in self.coeffs)), self.const
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        parts = [f"{v}*{k}" if v != 1 else k for k, v in self.coeffs]
        if self.const or not parts:
            parts.append(str(self.const))
        return "+".join(parts)


def aff(*terms, const: int = 0) -> Affine:
    """Shorthand: aff('i'), aff(('i',2),'j',const=1)."""
    return Affine.of(*terms, const=const)


# ---------------------------------------------------------------------------
# Data containers and accesses
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Array:
    """A data container with a row-major layout (strides derived from shape)."""

    name: str
    shape: tuple[int, ...]
    dtype: str = "float32"

    @property
    def strides(self) -> tuple[int, ...]:
        """Row-major element strides derived from the shape."""
        s = [1] * len(self.shape)
        for d in range(len(self.shape) - 2, -1, -1):
            s[d] = s[d + 1] * self.shape[d + 1]
        return tuple(s)

    @property
    def size(self) -> int:
        """Total element count (1 for scalars)."""
        return int(np.prod(self.shape)) if self.shape else 1


@dataclass(frozen=True)
class Access:
    """An affine access ``array[index_0, ..., index_{r-1}]``."""

    array: str
    index: tuple[Affine, ...]

    @property
    def is_affine(self) -> bool:
        """True when every index expression is affine."""
        return all(ix.is_affine for ix in self.index)

    def iterators(self) -> tuple[str, ...]:
        """Iterators appearing in any index, in first-appearance order."""
        seen: list[str] = []
        for ix in self.index:
            for it in ix.iterators():
                if it not in seen:
                    seen.append(it)
        return tuple(seen)

    def rename(self, mapping: Mapping[str, str]) -> "Access":
        """A copy with iterator names substituted in every index."""
        return Access(self.array, tuple(ix.rename(mapping) for ix in self.index))


def acc(array: str, *index) -> Access:
    """Shorthand: acc('A','i','k'), acc('C','i',aff('j',const=1))."""
    ix = tuple(x if isinstance(x, Affine) else aff(x) for x in index)
    return Access(array, ix)


# ---------------------------------------------------------------------------
# Symbolic scalar expressions
# ---------------------------------------------------------------------------
class Expr:
    """A symbolic scalar expression over a computation's reads tuple.

    Historically ``Computation.expr`` was an opaque Python callable, which the
    pass pipeline could execute but never inspect — every hoistable
    subexpression was recomputed on every iteration because no pass could see
    inside it.  ``Expr`` trees make the scalar math first-class IR:

    * ``Read(i)``  — the value of ``reads[i]`` at the current iteration point,
    * ``Const(v)`` — a compile-time float constant,
    * ``BinOp(op, lhs, rhs)`` — ``add | sub | mul | div | max | min``,
    * ``Neg(arg)`` — unary negation,
    * ``Call(name, fn, args)`` — an opaque scalar function (e.g. the IFS
      thermodynamic functions) applied to sub-expressions; rewrites treat it
      as an atomic, expensive leaf operation.

    Instances are frozen and compare/hash *structurally*, so rewrite passes
    (``repro.core.rewrite``) can detect duplicated subtrees, and the content
    fingerprint is a pure function of the tree (stable across processes).

    Every ``Expr`` is itself callable: ``__call__`` lazily compiles the tree
    via :meth:`to_callable` and evaluates it, so every existing consumer —
    ``execute_numpy``, the JAX lowerings, ``nest_kernel``, the idiom probes —
    keeps treating ``comp.expr`` as a plain scalar function.  Arithmetic
    operators build trees (``Read(0) * 1.5 + Read(1)``), mirroring how the
    front-end builders previously wrote lambdas.
    """

    def __add__(self, other: "Expr | float") -> "Expr":
        """Build ``self + other`` (numbers are wrapped into ``Const``)."""
        return BinOp("add", self, as_expr(other))

    def __radd__(self, other: "Expr | float") -> "Expr":
        """Build ``other + self``."""
        return BinOp("add", as_expr(other), self)

    def __sub__(self, other: "Expr | float") -> "Expr":
        """Build ``self - other``."""
        return BinOp("sub", self, as_expr(other))

    def __rsub__(self, other: "Expr | float") -> "Expr":
        """Build ``other - self``."""
        return BinOp("sub", as_expr(other), self)

    def __mul__(self, other: "Expr | float") -> "Expr":
        """Build ``self * other``."""
        return BinOp("mul", self, as_expr(other))

    def __rmul__(self, other: "Expr | float") -> "Expr":
        """Build ``other * self``."""
        return BinOp("mul", as_expr(other), self)

    def __truediv__(self, other: "Expr | float") -> "Expr":
        """Build ``self / other``."""
        return BinOp("div", self, as_expr(other))

    def __rtruediv__(self, other: "Expr | float") -> "Expr":
        """Build ``other / self``."""
        return BinOp("div", as_expr(other), self)

    def __neg__(self) -> "Expr":
        """Build ``-self``."""
        return Neg(self)

    def __call__(self, *vals: Any) -> Any:
        """Evaluate at concrete read values (compiles once, then caches)."""
        fn = getattr(self, "_fn", None)
        if fn is None:
            fn = self.to_callable()
            object.__setattr__(self, "_fn", fn)
        return fn(*vals)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        """Render the structural signature."""
        return self.signature()

    def signature(self) -> str:
        """Deterministic structural key (used for CSE, dedup, fingerprints)."""
        sig = getattr(self, "_sig", None)
        if sig is None:
            sig = self._signature()
            object.__setattr__(self, "_sig", sig)
        return sig

    def _signature(self) -> str:
        raise NotImplementedError  # pragma: no cover - abstract

    def children(self) -> tuple["Expr", ...]:
        """Direct sub-expressions (empty for ``Read``/``Const`` leaves)."""
        return ()

    def rebuild(self, children: tuple["Expr", ...]) -> "Expr":
        """A structurally-identical node with ``children`` substituted."""
        return self

    def to_callable(self) -> Callable[..., Any]:
        """Synthesize the jnp-traceable scalar function this tree denotes.

        The tree is compiled (once) to a flat sequence of Python statements
        with duplicated subtrees evaluated a single time, so evaluation speed
        matches the hand-written lambdas the front-ends used to build, and
        within-expression common subexpressions are already deduplicated.
        ``max``/``min`` dispatch to numpy for numpy/scalar operands and to
        ``jax.numpy`` for traced values, like the CLOUDSC helpers.
        """
        lines: list[str] = []
        names: dict[str, str] = {}  # signature -> local name
        env: dict[str, Any] = {"_emax": _eval_max, "_emin": _eval_min}

        def emit(e: "Expr") -> str:
            """Emit one node, reusing the local bound to any repeated subtree."""
            if isinstance(e, Read):
                return f"_v[{e.i}]"
            if isinstance(e, Const):
                return repr(e.value)
            key = e.signature()
            hit = names.get(key)
            if hit is not None:
                return hit
            if isinstance(e, BinOp):
                a, b = emit(e.lhs), emit(e.rhs)
                sym = {"add": "+", "sub": "-", "mul": "*", "div": "/"}.get(e.op)
                rhs = f"{a} {sym} {b}" if sym else (
                    f"_emax({a}, {b})" if e.op == "max" else f"_emin({a}, {b})")
            elif isinstance(e, Neg):
                rhs = f"-{emit(e.arg)}"
            elif isinstance(e, Call):
                fname = f"_f{len(env)}"
                env[fname] = e.fn
                rhs = f"{fname}({', '.join(emit(a) for a in e.args)})"
            else:  # pragma: no cover - defensive
                raise TypeError(type(e))
            name = f"_t{len(names)}"
            names[key] = name
            lines.append(f"    {name} = {rhs}")
            return name

        out = emit(self)
        src = "def _expr(*_v):\n" + "\n".join(lines + [f"    return {out}"])
        exec(compile(src, "<repro.Expr>", "exec"), env)
        return env["_expr"]


def as_expr(v: "Expr | float | int") -> "Expr":
    """Coerce a Python number to ``Const``; pass ``Expr`` through."""
    if isinstance(v, Expr):
        return v
    if isinstance(v, (int, float, np.integer, np.floating)):
        return Const(float(v))
    raise TypeError(f"cannot build Expr from {type(v).__name__}")


@dataclass(frozen=True, repr=False)
class Read(Expr):
    """The value of ``reads[i]`` at the current iteration point."""

    i: int

    def _signature(self) -> str:
        return f"r{self.i}"


@dataclass(frozen=True, repr=False)
class Const(Expr):
    """A compile-time float constant."""

    value: float

    def _signature(self) -> str:
        return repr(self.value)


@dataclass(frozen=True, repr=False)
class BinOp(Expr):
    """A binary operation: ``add | sub | mul | div | max | min``."""

    op: str
    lhs: Expr
    rhs: Expr

    def _signature(self) -> str:
        return f"({self.op} {self.lhs.signature()} {self.rhs.signature()})"

    def children(self) -> tuple[Expr, ...]:
        """The two operands."""
        return (self.lhs, self.rhs)

    def rebuild(self, children: tuple[Expr, ...]) -> Expr:
        """Same op over new operands."""
        return BinOp(self.op, children[0], children[1])


@dataclass(frozen=True, repr=False)
class Neg(Expr):
    """Unary negation."""

    arg: Expr

    def _signature(self) -> str:
        return f"(neg {self.arg.signature()})"

    def children(self) -> tuple[Expr, ...]:
        """The single operand."""
        return (self.arg,)

    def rebuild(self, children: tuple[Expr, ...]) -> Expr:
        """Negation of the new operand."""
        return Neg(children[0])


@dataclass(frozen=True, repr=False)
class Call(Expr):
    """An opaque scalar function applied to sub-expressions.

    Compared/hashed by ``fn_name`` (+ args), so two programs built from the
    same module-level helper (e.g. ``foeewm``) fingerprint identically while
    the callable itself stays out of the structural identity.  Rewrites treat
    a ``Call`` as an expensive atomic operation — prime hoisting material.
    """

    fn_name: str
    fn: Callable[..., Any] = field(compare=False)
    args: tuple[Expr, ...] = ()

    def _signature(self) -> str:
        return f"(call {self.fn_name} {' '.join(a.signature() for a in self.args)})"

    def __hash__(self) -> int:
        """Hash by name + args (``fn`` is identity-excluded, like ``__eq__``)."""
        return hash((self.fn_name, self.args))

    def children(self) -> tuple[Expr, ...]:
        """The argument expressions."""
        return self.args

    def rebuild(self, children: tuple[Expr, ...]) -> Expr:
        """Same function over new arguments."""
        return Call(self.fn_name, self.fn, tuple(children))


def emax(a: "Expr | float", b: "Expr | float") -> Expr:
    """Symbolic elementwise maximum."""
    return BinOp("max", as_expr(a), as_expr(b))


def emin(a: "Expr | float", b: "Expr | float") -> Expr:
    """Symbolic elementwise minimum."""
    return BinOp("min", as_expr(a), as_expr(b))


def _np_like(v: Any) -> bool:
    return isinstance(v, (int, float, np.generic, np.ndarray))


def _eval_max(a: Any, b: Any) -> Any:
    if _np_like(a) and _np_like(b):
        return np.maximum(a, b)
    import jax.numpy as jnp

    return jnp.maximum(a, b)


def _eval_min(a: Any, b: Any) -> Any:
    if _np_like(a) and _np_like(b):
        return np.minimum(a, b)
    import jax.numpy as jnp

    return jnp.minimum(a, b)


def expr_nodes(e: Expr) -> list[Expr]:
    """Unique sub-expressions of ``e`` in post-order (children first).

    Structural duplicates appear once — matching what :meth:`Expr.to_callable`
    actually evaluates — so op counts over this list reflect real work.
    """
    seen: set[str] = set()
    out: list[Expr] = []

    def rec(n: Expr) -> None:
        """Post-order walk, visiting each distinct subtree once."""
        key = n.signature()
        if key in seen:
            return
        seen.add(key)
        for c in n.children():
            rec(c)
        out.append(n)

    rec(e)
    return out


def expr_reads(e: Expr) -> tuple[int, ...]:
    """Sorted unique ``Read`` indices referenced by ``e``."""
    return tuple(sorted({n.i for n in expr_nodes(e) if isinstance(n, Read)}))


def expr_map_reads(e: Expr, mapping: Mapping[int, int]) -> Expr:
    """Rewrite every ``Read(i)`` to ``Read(mapping[i])`` (identity if absent)."""
    if isinstance(e, Read):
        return Read(mapping.get(e.i, e.i))
    kids = e.children()
    if not kids:
        return e
    return e.rebuild(tuple(expr_map_reads(c, mapping) for c in kids))


CALL_COST = 8  # flop surrogate for an opaque Call (transcendental chains)


def expr_ops(e: Expr) -> int:
    """Weighted operation count of the deduplicated expression DAG.

    ``BinOp``/``Neg`` count 1; a ``Call`` counts :data:`CALL_COST` (the IFS
    thermodynamic functions expand to ~10-20 flops including ``exp``).  Used
    by the rewrite passes' cost guards and the flops-before/after stats.
    """
    total = 0
    for n in expr_nodes(e):
        if isinstance(n, (BinOp, Neg)):
            total += 1
        elif isinstance(n, Call):
            total += CALL_COST
    return total


# ---------------------------------------------------------------------------
# Computations and loops
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Computation:
    """One statement: ``write op= expr(*reads)``.

    ``expr`` is a scalar function (jnp-traceable) of the read values — either
    an opaque Python callable, or a symbolic :class:`Expr` tree (itself
    callable) that the rewrite passes can inspect and transform; the IR
    otherwise reasons only about the access structure, exactly like the
    paper's symbolic representation. ``accumulate`` marks reduction writes
    (``'+'``, ``'max'``, ``'min'``, ``'*'``) vs plain assignment (None).

    ``guards`` are affine inequalities ``g(iters) >= 0`` restricting the
    iteration domain — triangular PolyBench domains are represented as a
    rectangular box plus guards (the isl-domain flattened), which keeps loop
    bounds static while preserving semantics.
    """

    name: str
    write: Access
    reads: tuple[Access, ...]
    expr: Callable[..., Any]
    accumulate: str | None = None
    guards: tuple[Affine, ...] = ()

    def accesses(self) -> tuple[Access, ...]:
        """All accesses: the write first, then the reads."""
        return (self.write,) + self.reads

    def iterators(self) -> tuple[str, ...]:
        """Iterators referenced by any access or guard, in appearance order."""
        seen: list[str] = []
        for a in self.accesses():
            for it in a.iterators():
                if it not in seen:
                    seen.append(it)
        for g in self.guards:
            for it in g.iterators():
                if it not in seen:
                    seen.append(it)
        return tuple(seen)

    def rename(self, mapping: Mapping[str, str]) -> "Computation":
        """A copy with iterators substituted in accesses and guards."""
        return replace(
            self,
            write=self.write.rename(mapping),
            reads=tuple(r.rename(mapping) for r in self.reads),
            guards=tuple(g.rename(mapping) for g in self.guards),
        )


@dataclass(frozen=True)
class Loop:
    """``for it in range(start, stop, step): body``  (bounds are static ints)."""

    iterator: str
    stop: int
    start: int = 0
    step: int = 1
    body: tuple["Node", ...] = ()

    @property
    def trip_count(self) -> int:
        """Number of iterations (0 when the range is empty)."""
        return max(0, (self.stop - self.start + self.step - 1) // self.step)

    def rename(self, mapping: Mapping[str, str]) -> "Loop":
        """A copy with the iterator (and body iterators) substituted."""
        return replace(
            self,
            iterator=mapping.get(self.iterator, self.iterator),
            body=tuple(b.rename(mapping) for b in self.body),
        )


Node = Loop | Computation


@dataclass(frozen=True)
class Program:
    """An ordered sequence of loops/computations plus array declarations.

    ``temps`` names scratch containers: they are zero-initialized by the
    runtime rather than supplied as inputs, and normalization (e.g. scalar
    expansion) may freely change their shapes.
    """

    name: str
    arrays: tuple[Array, ...]
    body: tuple[Node, ...]
    temps: tuple[str, ...] = ()

    def array(self, name: str) -> Array:
        """The declared ``Array`` named ``name`` (KeyError when absent)."""
        for a in self.arrays:
            if a.name == name:
                return a
        raise KeyError(name)

    @property
    def array_names(self) -> tuple[str, ...]:
        """All declared array names, in declaration order."""
        return tuple(a.name for a in self.arrays)

    @property
    def input_arrays(self) -> tuple[Array, ...]:
        """The non-temp arrays callers must supply as inputs."""
        return tuple(a for a in self.arrays if a.name not in self.temps)


# ---------------------------------------------------------------------------
# Tree utilities
# ---------------------------------------------------------------------------
def walk(node: Node, prefix: tuple[Loop, ...] = ()) -> Iterable[tuple[tuple[Loop, ...], Computation]]:
    """Yield (enclosing loops, computation) for every computation under node."""
    if isinstance(node, Computation):
        yield prefix, node
    else:
        for child in node.body:
            yield from walk(child, prefix + (node,))


def program_computations(p: Program) -> list[tuple[tuple[Loop, ...], Computation]]:
    """Every (enclosing loops, computation) pair across the whole program."""
    out: list[tuple[tuple[Loop, ...], Computation]] = []
    for n in p.body:
        out.extend(walk(n))
    return out


def loop_iterators(node: Node) -> tuple[str, ...]:
    """In-order iterator names of a nest (paper's loop -> (i_1..i_n) notation)."""
    if isinstance(node, Computation):
        return ()
    its = (node.iterator,)
    for child in node.body:
        for it in loop_iterators(child):
            if it not in its:
                its = its + (it,)
    return its


def is_perfect_nest(node: Node) -> bool:
    """True if node is a chain of single-child loops ending in computations."""
    while isinstance(node, Loop):
        kids = node.body
        if all(isinstance(k, Computation) for k in kids):
            return True
        if len(kids) != 1:
            return False
        node = kids[0]
    return True


def nest_computations(node: Node) -> list[Computation]:
    """All computations under one nest (or the node itself, when bare)."""
    return [c for _, c in walk(node)] if isinstance(node, Loop) else [node]


def nest_loops(node: Node) -> list[Loop]:
    """The chain of loops from the root of a (quasi-)perfect nest."""
    out: list[Loop] = []
    while isinstance(node, Loop):
        out.append(node)
        loops = [k for k in node.body if isinstance(k, Loop)]
        if len(loops) == 1 and len(node.body) == 1:
            node = loops[0]
        else:
            break
    return out


def rename_nest(node: Node, suffix: str) -> Node:
    """Clone a nest with fresh iterator names (paper §2.1: i'_1 = i_1, ...)."""
    its = loop_iterators(node) if isinstance(node, Loop) else ()
    mapping = {it: f"{it}{suffix}" for it in its}
    return node.rename(mapping)


def fingerprint(node: Node) -> str:
    """Structural fingerprint of a nest, invariant to iterator names.

    Canonical iterator names are assigned by in-order traversal position so two
    nests that differ only in naming hash identically — this is the key the
    transfer-tuning database ultimately relies on.
    """
    its = loop_iterators(node) if isinstance(node, Loop) else ()
    mapping = {it: f"t{k}" for k, it in enumerate(its)}

    def fmt_aff(a: Affine) -> str:
        """Render an affine index under canonical iterator names."""
        return repr(a.rename(mapping))

    def fmt_acc(a: Access) -> str:
        """Render one access as ``array[idx,...]``."""
        return f"{a.array}[{','.join(fmt_aff(ix) for ix in a.index)}]"

    def fmt(n: Node) -> str:
        """Render a node (and its subtree) into the fingerprint string."""
        if isinstance(n, Computation):
            rd = ";".join(fmt_acc(r) for r in n.reads)
            gd = ";".join(fmt_aff(g) for g in n.guards)
            return f"C({fmt_acc(n.write)}{n.accumulate or '='}{rd}|{gd})"
        inner = ",".join(fmt(b) for b in n.body)
        return f"L[{mapping.get(n.iterator, n.iterator)}:{n.start}:{n.stop}:{n.step}]({inner})"

    return fmt(node)


def _expr_signature(comp: Computation) -> str:
    """Content signature of a computation's opaque scalar ``expr``.

    The structural fingerprint deliberately ignores ``expr`` (the IR reasons
    about access structure only), but a *compilation* cache must not conflate
    two programs whose nests match structurally while computing different
    scalar functions.  Two complementary captures:

    * for plain Python functions, a hash of the code object (bytecode,
      consts, names) plus closure cell values and defaults — exact for the
      lambdas the front-ends build, including rebuilt-from-source copies;
    * evaluation at fixed probe points spanning sign changes and magnitudes
      past common thresholds, for callables without ``__code__`` (ufuncs,
      partials) and to distinguish equal-bytecode closures whose cell
      values repr identically.

    If probing fails (e.g. the expr only accepts traced values) the
    signature falls back to identity, which can only cause cache misses,
    never wrong hits — cached programs keep their exprs alive, so a live
    entry's id cannot be reused by a different function.

    Symbolic :class:`Expr` trees short-circuit both captures: their
    structural signature is already an exact, process-stable content key
    (``Call`` nodes contribute their ``fn_name``), so rewritten programs
    fingerprint deterministically without any probing.
    """
    parts = []
    f = comp.expr
    if isinstance(f, Expr):
        return "e:" + hashlib.sha256(f.signature().encode()).hexdigest()[:16]
    code = getattr(f, "__code__", None)
    if code is not None:
        try:
            def cell_text(v: Any) -> str:
                # repr truncates large arrays ('...'), which would conflate
                # closures over arrays equal only at the printed corners
                if isinstance(v, np.ndarray):
                    digest = hashlib.sha256(np.ascontiguousarray(v).tobytes())
                    return f"nd{v.shape}{v.dtype}:{digest.hexdigest()[:16]}"
                return repr(v)

            cells = tuple(
                cell_text(c.cell_contents)
                for c in (getattr(f, "__closure__", None) or ())
            )
            src = (code.co_code.hex() + repr(code.co_consts) + repr(code.co_names)
                   + repr(cells) + repr(getattr(f, "__defaults__", None)))
            parts.append("c:" + hashlib.sha256(src.encode()).hexdigest()[:16])
        except Exception:
            pass
    n = len(comp.reads)
    probes = (
        [1.0] * n,
        [0.5 + 0.375 * k for k in range(n)],
        [-1.25 + 0.5 * k for k in range(n)],
        [3.75 - 0.625 * k for k in range(n)],
        [-4.5 + 1.125 * k for k in range(n)],
    )
    vals = []
    for p in probes:
        try:
            v = float(f(*[np.float64(x) for x in p]))
        except Exception:
            if parts:  # bytecode hash alone still identifies the function
                return parts[0]
            return f"opaque@{id(f):x}"
        vals.append(f"{v:.12g}" if np.isfinite(v) else repr(v))
    parts.append(",".join(vals))
    return "|".join(parts)


def program_fingerprint(program: Program, content: bool = True) -> str:
    """Stable whole-program fingerprint: arrays, temps, body, expr content.

    Invariant to iterator renaming (via the per-nest ``fingerprint``) and to
    the program's display name, so structurally-identical programs — the
    paper's A/B variants after normalization, or a re-built config — address
    the same cache slot.  With ``content=True`` (the default used by the
    compilation cache) each computation's scalar expression is probed so that
    structure-equal programs computing different math stay distinct.
    """
    arrays = ";".join(
        f"{a.name}:{'x'.join(map(str, a.shape))}:{a.dtype}" for a in program.arrays
    )
    temps = ",".join(sorted(program.temps))
    body = "|".join(fingerprint(n) for n in program.body)
    text = f"arrays({arrays})temps({temps})body({body})"
    if content:
        exprs = "|".join(_expr_signature(c) for _, c in program_computations(program))
        text += f"exprs({exprs})"
    return hashlib.sha256(text.encode()).hexdigest()
