"""A priori loop nest normalization (paper §2): the two criteria.

Pass 1 — **maximal loop fission** (§2.1): split every loop body into the
finest legal pieces.  Children of a loop that have no mutual dependence are
divided into separate nests with cloned iterators; children in a dependence
cycle (an SCC) stay fused — the result is a sequence of *atomic* loop nests.
Applied as a fixed point over the tree (fissioning only ever shrinks bodies).

Pass 2 — **stride minimization** (§2.2): for every atomic nest, find the
legal loop permutation minimizing the stride criterion — the sum over all
computations and accesses of the address distance between two subsequent
(innermost-iteration) accesses, using row-major linearization.  ≤ MAX_ENUM
iterators are permuted exhaustively; deeper nests fall back to the paper's
group-sort approximation (order iterators by descending stride weight).

``normalize`` = fission → stride-minimization → canonical iterator renaming,
run as the canonical ``PassPipeline`` built by ``normalization_pipeline()``
(the scheduler extends the same pipeline with post-normalization
optimization passes such as re-fusion — see ``repro.core.fusion``).
"""
from __future__ import annotations

import itertools
from dataclasses import replace
from typing import Mapping, Sequence

from .dependence import (
    DepVector,
    body_dependence_graph,
    condense_sccs,
    nest_direction_vectors,
    permutation_legal,
)
from .ir import (
    Access,
    Affine,
    Array,
    Computation,
    Loop,
    Node,
    Program,
    is_perfect_nest,
    loop_iterators,
    nest_computations,
    walk,
)
from .passes import FixpointPass, FunctionPass, PassPipeline

MAX_ENUM = 7  # exhaustive permutation bound (7! = 5040)


# ---------------------------------------------------------------------------
# Pass 0: scalar expansion (enables fission across scalar temporaries)
# ---------------------------------------------------------------------------
def scalar_expansion(program: Program) -> Program:
    """Expand 0-d scratch containers over their carrying loop (paper Fig. 10:
    ``ZQP`` -> ``ZQP_0(JL)``).

    A 0-d temp written and read inside a loop's body serializes the loop and
    welds otherwise-independent computations into one SCC.  If the first
    access in the subtree is an unguarded plain write (a dominating
    definition), each iteration owns its value and the temp can be promoted
    to an array indexed by the loop iterator.  Applied innermost-first, so
    temps are expanded only over the loop that actually carries them.
    """
    temps = set(program.temps)
    arrays = {a.name: a for a in program.arrays}

    def subtree_accesses(n: Node) -> list[tuple[Computation, Access, bool]]:
        """All (computation, access, is_write) triples under ``n``."""
        out = []
        if isinstance(n, Computation):
            for a in n.reads:
                out.append((n, a, False))
            out.append((n, n.write, True))
        else:
            for ch in n.body:
                out.extend(subtree_accesses(ch))
        return out

    def first_access_order(n: Node) -> list[tuple[Computation, Access, bool]]:
        """program order: within a computation, reads precede the write."""
        out = []
        if isinstance(n, Computation):
            for a in n.reads:
                out.append((n, a, False))
            out.append((n, n.write, True))
        else:
            for ch in n.body:
                out.extend(first_access_order(ch))
        return out

    def used_outside(name: str, inside: Node) -> bool:
        """Whether array ``name`` is accessed anywhere outside ``inside``."""
        cnt_inside = sum(1 for _, a, _ in subtree_accesses(inside) if a.array == name)
        total = 0
        for top in program.body:
            total += sum(1 for _, a, _ in subtree_accesses(top) if a.array == name)
        return total != cnt_inside

    def add_index(n: Node, name: str, it: str) -> Node:
        """Prepend iterator ``it`` to every access of array ``name``."""
        if isinstance(n, Computation):
            def fix(a: Access) -> Access:
                """Rewrite one access of the expanded array."""
                if a.array != name:
                    return a
                return Access(a.array, (Affine.of(it),) + a.index)

            return replace(
                n,
                write=fix(n.write),
                reads=tuple(fix(r) for r in n.reads),
            )
        return replace(n, body=tuple(add_index(b, name, it) for b in n.body))

    def rec(node: Node) -> Node:
        """Expand scalar temps carried by ``node``, innermost loops first."""
        if isinstance(node, Computation):
            return node
        node = replace(node, body=tuple(rec(b) for b in node.body))
        accesses = first_access_order(node)
        cands: dict[str, bool] = {}
        for comp, a, is_write in accesses:
            if a.array in temps and arrays[a.array].shape == ():
                if a.array not in cands:
                    # first access must be a dominating unguarded write
                    cands[a.array] = bool(
                        is_write and comp.accumulate is None and not comp.guards
                    )
                elif is_write and (comp.accumulate is not None or comp.guards):
                    cands[a.array] = False
        for name, ok in cands.items():
            writers = {id(c) for c, a, w in accesses if w and a.array == name}
            readers = {id(c) for c, a, w in accesses if not w and a.array == name}
            if not ok or not writers or not (readers - writers or len(writers) > 1):
                continue
            if used_outside(name, node):
                continue
            arrays[name] = Array(name, (node.stop,), arrays[name].dtype)
            node = replace(node, body=tuple(add_index(b, name, node.iterator) for b in node.body))
        return node

    new_body = tuple(rec(n) for n in program.body)
    return replace(program, body=new_body, arrays=tuple(arrays[a.name] for a in program.arrays))


# ---------------------------------------------------------------------------
# Pass 1: maximal loop fission
# ---------------------------------------------------------------------------
class _Fresh:
    def __init__(self) -> None:
        self.n = 0

    def __call__(self) -> str:
        self.n += 1
        return f"_f{self.n}"


def _trip_counts(node: Node, out: dict[str, int] | None = None) -> dict[str, int]:
    out = out if out is not None else {}
    if isinstance(node, Loop):
        out[node.iterator] = node.trip_count
        for b in node.body:
            _trip_counts(b, out)
    return out


def _fission_loop(loop: Loop, fresh: _Fresh) -> list[Node]:
    """Distribute one loop over the SCCs of its body's dependence graph."""
    # Recurse bottom-up first: fission inner loops.
    new_body: list[Node] = []
    for child in loop.body:
        if isinstance(child, Loop):
            new_body.extend(_fission_loop(child, fresh))
        else:
            new_body.append(child)
    loop = replace(loop, body=tuple(new_body))

    if len(loop.body) <= 1:
        return [loop]

    trip = _trip_counts(loop)
    adj = body_dependence_graph(loop.iterator, trip, loop.body)
    sccs = condense_sccs(adj)
    if len(sccs) == 1:
        return [loop]

    nests: list[Node] = []
    for scc in sccs:
        children = tuple(loop.body[k] for k in scc)
        piece = replace(loop, body=children)
        # clone iterators so each nest owns its own (paper: i'_1 = i_1, ...)
        its = loop_iterators(piece)
        mapping = {it: f"{it}{fresh()}" for it in its}
        nests.append(piece.rename(mapping))
    return nests


def maximal_fission(program: Program) -> Program:
    """Split every top-level loop into the finest legal (SCC-atomic) nests."""
    fresh = _Fresh()
    body: list[Node] = []
    for node in program.body:
        if isinstance(node, Loop):
            body.extend(_fission_loop(node, fresh))
        else:
            body.append(node)
    return replace(program, body=tuple(body))


# ---------------------------------------------------------------------------
# Pass 2: stride minimization
# ---------------------------------------------------------------------------
def access_stride(program: Program, a: Access, iterator: str) -> int:
    """|address delta| of access ``a`` between consecutive ``iterator`` steps."""
    arr = program.array(a.array)
    strides = arr.strides
    delta = 0
    for d, ix in enumerate(a.index):
        delta += strides[d] * ix.coeff(iterator)
    return abs(delta)


def stride_weights(
    program: Program, comps: Sequence[Computation], iterators: Sequence[str]
) -> dict[str, int]:
    """Per-iterator stride weight: the paper's sum over all (computation,
    access) pairs of the address delta between consecutive iterations.

    Computed ONCE per nest — a weight depends only on the iterator, never on
    its position in the loop order, so permutation enumeration can compare
    cost tuples by reordering these precomputed totals instead of re-walking
    every access for each of up to 7! candidate permutations.
    """
    return {
        it: sum(access_stride(program, a, it) for c in comps for a in c.accesses())
        for it in iterators
    }


def stride_cost(
    program: Program,
    comps: Sequence[Computation],
    order: Sequence[str],
    weights: Mapping[str, int] | None = None,
) -> tuple[int, ...]:
    """Cost tuple (innermost, ..., outermost): each entry is the paper's
    sum-of-strides criterion for that loop being the vectorized/fast axis.

    Comparing the tuples lexicographically implements "minimize the stride of
    subsequent accesses" with deterministic tie-breaking on outer levels.
    """
    if weights is None:
        weights = stride_weights(program, comps, order)
    return tuple(weights[it] for it in reversed(order))


def _legal_orders(
    iterators: Sequence[str],
    vectors: Sequence[DepVector],
) -> list[tuple[int, ...]]:
    perms = []
    for perm in itertools.permutations(range(len(iterators))):
        if permutation_legal(vectors, perm):
            perms.append(perm)
    return perms


def _greedy_order(
    iterators: Sequence[str],
    vectors: Sequence[DepVector],
    weights: Mapping[str, int],
) -> tuple[int, ...]:
    """Deep-nest approximation (paper §2.2): sort iterators by descending
    stride weight (largest stride outermost), keeping only legal placements.
    """
    desired = sorted(range(len(iterators)), key=lambda k: (-weights[iterators[k]], k))
    # insertion repair: greedily build a legal prefix
    chosen: list[int] = []
    remaining = list(desired)
    while remaining:
        for k in remaining:
            cand = chosen + [k] + [r for r in remaining if r != k]
            if permutation_legal(vectors, cand):
                chosen.append(k)
                remaining.remove(k)
                break
        else:  # nothing legal (shouldn't happen: identity is legal)
            chosen.extend(remaining)
            break
    return tuple(chosen)


def _permute_perfect_nest(program: Program, root: Loop) -> Loop:
    """Reorder the loop chain of a perfect nest to the minimal-stride order."""
    chain: list[Loop] = [root]
    node: Node = root
    while isinstance(node, Loop) and len(node.body) == 1 and isinstance(node.body[0], Loop):
        node = node.body[0]
        chain.append(node)
    innermost = chain[-1]
    comps = nest_computations(root)
    iterators = [l.iterator for l in chain]
    trip = {l.iterator: l.trip_count for l in chain}
    vectors = nest_direction_vectors(iterators, trip, comps)

    if len(chain) <= 1:
        return root
    # one access walk per nest; enumeration below only reorders these totals
    weights = stride_weights(program, comps, iterators)
    if len(chain) <= MAX_ENUM:
        orders = _legal_orders(iterators, vectors)
        if not orders:
            # '*' directions can make even the identity unprovable — the
            # original order is trivially legal, keep it (paper's fallback:
            # "the loop nest is not optimized").
            orders = [tuple(range(len(iterators)))]
        best = min(
            orders,
            key=lambda p: (
                stride_cost(program, comps, [iterators[k] for k in p], weights), p
            ),
        )
    else:
        best = _greedy_order(iterators, vectors, weights)

    # rebuild the chain in the chosen order
    body = innermost.body
    for k in reversed(best):
        l = chain[k]
        body = (replace(l, body=body),)
    return body[0]


def _minimize_node(program: Program, node: Node) -> Node:
    if isinstance(node, Computation):
        return node
    if is_perfect_nest(node):
        return _permute_perfect_nest(program, node)
    # imperfect nest (an atomic SCC with computations at several levels):
    # recurse into children; the shared outer loop is left in place.
    return replace(node, body=tuple(_minimize_node(program, b) for b in node.body))


def stride_minimization(program: Program) -> Program:
    """Permute each nest so smaller-stride iterators sit innermost."""
    return replace(
        program, body=tuple(_minimize_node(program, n) for n in program.body)
    )


# ---------------------------------------------------------------------------
# Canonical renaming + pipeline
# ---------------------------------------------------------------------------
def canonical_rename(program: Program) -> Program:
    """Rename iterators i0, i1, ... by traversal order (stable fingerprints)."""
    counter = [0]

    def ren(node: Node) -> Node:
        """Rename one nest's iterators from the running counter."""
        if isinstance(node, Computation):
            return node
        its = loop_iterators(node)
        mapping = {}
        for it in its:
            mapping[it] = f"i{counter[0]}"
            counter[0] += 1
        return node.rename(mapping)

    return replace(program, body=tuple(ren(n) for n in program.body))


def normalization_pipeline() -> PassPipeline:
    """The a priori normalization passes (paper Fig. 5) as an explicit,
    editable pipeline.  Fission runs to a fixed point (each application only
    ever splits further); canonical renaming is last so fingerprints are
    stable under whatever passes are inserted before it."""
    return PassPipeline(
        [
            FunctionPass("scalar_expansion", scalar_expansion),
            FixpointPass("maximal_fission", maximal_fission),
            FunctionPass("stride_minimization", stride_minimization),
            FunctionPass("canonical_rename", canonical_rename),
        ],
        name="normalize",
    )


# the canonical instance `normalize()` runs (tools may inspect/extend it via
# `normalization_pipeline()` without touching this shared one)
NORMALIZE_PIPELINE = normalization_pipeline()


def normalize(program: Program) -> Program:
    """The full a priori normalization pipeline (paper Fig. 5)."""
    return NORMALIZE_PIPELINE.run(program)
