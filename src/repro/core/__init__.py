"""repro.core — a priori loop nest normalization + the daisy auto-scheduler.

Public API:
    ir          — the affine loop-nest IR (Program/Loop/Computation/Access)
    normalize   — maximal loop fission + stride minimization (paper §2)
    codegen     — executable lowerings (numpy oracle, as-written, canonical)
    scheduler   — Daisy: normalize -> idioms -> transfer-tune -> compile
"""
from .ir import (  # noqa: F401
    Access,
    Affine,
    Array,
    Computation,
    Loop,
    Program,
    acc,
    aff,
    fingerprint,
    program_fingerprint,
)
from .normalize import maximal_fission, normalize, stride_minimization  # noqa: F401
from .codegen import Schedule, compile_jax, execute_numpy, run_jax  # noqa: F401
from .cache import CacheStats, CompilationCache, fingerprint_obj  # noqa: F401
from .database import TuningDatabase  # noqa: F401
from .recipes import Recipe  # noqa: F401
from .scheduler import Daisy, random_inputs  # noqa: F401
