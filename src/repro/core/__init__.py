"""repro.core — a priori loop nest normalization + the daisy auto-scheduler.

Public API:
    ir          — the affine loop-nest IR (Program/Loop/Computation/Access)
    passes      — the compiler pass pipeline (Pass/PassPipeline/PassContext)
    normalize   — maximal loop fission + stride minimization (paper §2)
    fusion      — canonical-form re-fusion of adjacent elementwise nests
    codegen     — executable lowerings (numpy oracle, as-written, canonical)
    partition   — mesh data-parallel sharding of canonical programs
    scheduler   — Daisy: pipeline -> idioms -> transfer-tune -> compile
"""
from .ir import (  # noqa: F401
    Access,
    Affine,
    Array,
    BinOp,
    Call,
    Computation,
    Const,
    Expr,
    Loop,
    Neg,
    Program,
    Read,
    acc,
    aff,
    as_expr,
    emax,
    emin,
    expr_ops,
    fingerprint,
    program_fingerprint,
)
from .passes import (  # noqa: F401
    FixpointPass,
    FunctionPass,
    Pass,
    PassContext,
    PassPipeline,
    PassRecord,
)
from .normalize import (  # noqa: F401
    maximal_fission,
    normalization_pipeline,
    normalize,
    stride_minimization,
)
from .fusion import FusionPass, fuse_program, optimization_pipeline  # noqa: F401
from .rewrite import (  # noqa: F401
    CSEPass,
    ExpandFactorPass,
    LICMPass,
    program_flops,
    rewrite_passes,
)
from .codegen import Schedule, compile_jax, execute_numpy, run_jax  # noqa: F401
from .partition import (  # noqa: F401
    NestPartition,
    ProgramPartition,
    compile_sharded,
    plan_program_partition,
    run_sharded,
)
from .tiling import TilePlan, TilingError, plan_nest_tiling  # noqa: F401
from .cache import CacheStats, CompilationCache, fingerprint_obj  # noqa: F401
from .database import DatabaseCorruption, TuningDatabase  # noqa: F401
from .recipes import Recipe  # noqa: F401
from .scheduler import Daisy, random_inputs  # noqa: F401
