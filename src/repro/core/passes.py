"""Compiler pass pipeline — the "then-optimize" half of normalize-then-optimize.

The paper's thesis (§2, §4) is that mapping loop nests onto one canonical
form lets a small set of recipes cover many programs.  The passes that build
that canonical form — and every optimization applied after it — are
program -> program transformations; this module gives them an explicit
spine so they can be inserted, inspected, timed, and cached individually
instead of living inside a hardcoded function chain:

* ``Pass``         — the protocol: a named ``run(program) -> Program``.
* ``FunctionPass`` — wraps a plain ``Program -> Program`` function.
* ``FixpointPass`` — re-applies a pass until the program body stops changing
                     (maximal fission only ever splits further).
* ``PassContext``  — per-pass wall time, nest/computation counts, custom
                     stats, optional IR snapshots; ``report()`` renders the
                     table the CLI (``repro.tools.explain``) and the dry-run
                     driver surface.
* ``PassPipeline`` — an ordered, editable pass list; ``run`` threads the
                     program through, optionally memoizing each stage in a
                     ``CompilationCache`` keyed by the *input* program's
                     content fingerprint (so two programs sharing a prefix
                     of identical intermediate forms share the work).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Protocol, Sequence, runtime_checkable

from .ir import Program, program_computations, program_fingerprint


@runtime_checkable
class Pass(Protocol):
    """One program -> program transformation with a stable name."""

    name: str

    def run(self, program: Program, ctx: "PassContext | None" = None) -> Program:
        """Transform ``program``, optionally attaching stats to ``ctx``."""
        ...  # pragma: no cover - protocol


@dataclass
class FunctionPass:
    """Adapts a plain ``Program -> Program`` function to the Pass protocol."""

    name: str
    fn: Callable[[Program], Program]

    def run(self, program: Program, ctx: "PassContext | None" = None) -> Program:
        """Apply the wrapped function once (``ctx`` is unused)."""
        return self.fn(program)


@dataclass
class FixpointPass:
    """Re-applies ``fn`` until the program body is stable (or max_iter)."""

    name: str
    fn: Callable[[Program], Program]
    max_iter: int = 64

    def run(self, program: Program, ctx: "PassContext | None" = None) -> Program:
        """Iterate to a fixed point, recording the iteration count."""
        cur = program
        for it in range(self.max_iter):
            nxt = self.fn(cur)
            if nxt.body == cur.body:
                if ctx is not None:
                    ctx.add_stat(self.name, "iterations", it + 1)
                return nxt
            cur = nxt
        if ctx is not None:  # pragma: no cover - defensive
            ctx.add_stat(self.name, "iterations", self.max_iter)
        return cur


@dataclass
class PassRecord:
    """What one pass did to one program."""

    name: str
    seconds: float
    nests_before: int
    nests_after: int
    comps_before: int
    comps_after: int
    stats: dict[str, Any] = field(default_factory=dict)
    cached: bool = False
    before: Program | None = None  # IR snapshots (ctx.snapshots=True)
    after: Program | None = None


class PassContext:
    """Carries observability across one pipeline run.

    ``records`` accumulate in pass order; passes may attach custom stats
    (e.g. the fusion pass records how many nests it merged) via
    ``add_stat`` while they run.  With ``snapshots=True`` every record also
    keeps the full before/after IR — handy in tests and the explain CLI,
    wasteful in production, hence opt-in.
    """

    def __init__(self, snapshots: bool = False):
        self.snapshots = snapshots
        self.records: list[PassRecord] = []
        self._pending: dict[str, dict[str, Any]] = {}

    # -- recording ----------------------------------------------------------
    def add_stat(self, pass_name: str, key: str, value: Any) -> None:
        """Called by a pass *while it runs*; folded into its record."""
        self._pending.setdefault(pass_name, {})[key] = value

    def record(
        self,
        name: str,
        seconds: float,
        before: Program,
        after: Program,
        cached: bool = False,
    ) -> PassRecord:
        """Finalize one pass run into a ``PassRecord`` (folds pending stats)."""
        rec = PassRecord(
            name=name,
            seconds=seconds,
            nests_before=len(before.body),
            nests_after=len(after.body),
            comps_before=len(program_computations(before)),
            comps_after=len(program_computations(after)),
            stats=self._pending.pop(name, {}),
            cached=cached,
            before=before if self.snapshots else None,
            after=after if self.snapshots else None,
        )
        self.records.append(rec)
        return rec

    # -- introspection ------------------------------------------------------
    def __getitem__(self, pass_name: str) -> PassRecord:
        for rec in reversed(self.records):
            if rec.name == pass_name:
                return rec
        raise KeyError(pass_name)

    @property
    def total_seconds(self) -> float:
        """Wall time summed over all recorded passes."""
        return sum(r.seconds for r in self.records)

    def stat(self, pass_name: str, key: str, default: Any = None) -> Any:
        """A single stat from a pass's latest record (``default`` if absent)."""
        try:
            return self[pass_name].stats.get(key, default)
        except KeyError:
            return default

    def report(self) -> str:
        """Aligned per-pass table (rendered by the CLI and dry-run driver)."""
        header = ("pass", "time", "nests", "comps", "stats")
        rows = [header]
        for r in self.records:
            stats = dict(r.stats)
            if r.cached:
                stats["cached"] = True
            rows.append((
                r.name,
                f"{r.seconds * 1e3:.2f}ms",
                f"{r.nests_before}->{r.nests_after}",
                f"{r.comps_before}->{r.comps_after}",
                " ".join(f"{k}={v}" for k, v in stats.items()),
            ))
        rows.append(("total", f"{self.total_seconds * 1e3:.2f}ms", "", "", ""))
        widths = [max(len(row[c]) for row in rows) for c in range(len(header))]
        lines = []
        for i, row in enumerate(rows):
            lines.append("  ".join(cell.ljust(w) for cell, w in zip(row, widths)).rstrip())
            if i == 0:
                lines.append("  ".join("-" * w for w in widths))
        return "\n".join(lines)


class PassPipeline:
    """An ordered sequence of passes over the loop-nest IR.

    ``run`` threads the program through every pass.  When a
    ``CompilationCache`` is supplied, each stage's output is memoized under
    ``('pass', stage name, fingerprint(stage input))`` — content-addressed,
    so structurally-identical intermediate programs (the paper's A/B
    variants converge after a few passes) share all downstream stage work.
    """

    def __init__(self, passes: Sequence[Pass], name: str = "pipeline"):
        self.name = name
        self._passes: list[Pass] = list(passes)
        seen: set[str] = set()
        for p in self._passes:
            if p.name in seen:
                raise ValueError(f"duplicate pass name: {p.name!r}")
            seen.add(p.name)

    # -- list-like access ---------------------------------------------------
    def __iter__(self) -> Iterator[Pass]:
        return iter(self._passes)

    def __len__(self) -> int:
        return len(self._passes)

    @property
    def names(self) -> tuple[str, ...]:
        """The pass names in execution order."""
        return tuple(p.name for p in self._passes)

    def __getitem__(self, name: str) -> Pass:
        for p in self._passes:
            if p.name == name:
                return p
        raise KeyError(name)

    # -- editing (returns new pipelines; instances stay immutable-ish) ------
    def with_pass(
        self, p: Pass, *, before: str | None = None, after: str | None = None
    ) -> "PassPipeline":
        """A new pipeline with ``p`` inserted (appended when no anchor)."""
        if before is not None and after is not None:
            raise ValueError("give at most one of before/after")
        passes = list(self._passes)
        if before is None and after is None:
            passes.append(p)
        else:
            anchor = before if before is not None else after
            idx = self.names.index(anchor)  # raises ValueError if unknown
            passes.insert(idx if before is not None else idx + 1, p)
        return PassPipeline(passes, name=self.name)

    def without_pass(self, name: str) -> "PassPipeline":
        """A new pipeline with the named pass removed (KeyError if unknown)."""
        if name not in self.names:
            raise KeyError(name)
        return PassPipeline(
            [p for p in self._passes if p.name != name], name=self.name
        )

    # -- execution ----------------------------------------------------------
    def run(
        self,
        program: Program,
        ctx: PassContext | None = None,
        cache: "Any | None" = None,  # CompilationCache-compatible
    ) -> Program:
        """Run every pass in order, recording into ``ctx`` and memoizing
        per-pass results in ``cache`` when one is given."""
        cur = program
        for p in self._passes:
            t0 = time.perf_counter()
            cached = False
            if cache is not None:
                key = ("pass", p.name, program_fingerprint(cur))
                hit = cache.get(key)
                if hit is not None:
                    nxt, cached = hit, True
                else:
                    nxt = p.run(cur, ctx)
                    cache.put(key, nxt)
            else:
                nxt = p.run(cur, ctx)
            if ctx is not None:
                ctx.record(p.name, time.perf_counter() - t0, cur, nxt, cached=cached)
            cur = nxt
        return cur

    def run_with_report(self, program: Program, snapshots: bool = False) -> tuple[Program, PassContext]:
        """Run with a fresh ``PassContext``; returns (program, context)."""
        ctx = PassContext(snapshots=snapshots)
        out = self.run(program, ctx=ctx)
        return out, ctx

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"PassPipeline({self.name}: {' -> '.join(self.names)})"
