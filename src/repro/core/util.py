"""Measurement utilities (Hoefler & Belli-style: warm up, repeat, median)."""
from __future__ import annotations

import time
from typing import Any, Callable

import jax
import numpy as np


def _block(x: Any) -> None:
    jax.tree_util.tree_map(
        lambda l: l.block_until_ready() if hasattr(l, "block_until_ready") else l, x
    )


def time_fn(
    fn: Callable[[], Any],
    repeats: int = 5,
    warmup: int = 1,
    max_seconds: float = 10.0,
) -> float:
    """Median wall time of ``fn`` in microseconds (blocks on JAX outputs)."""
    for _ in range(warmup):
        _block(fn())
    times = []
    t_start = time.perf_counter()
    for _ in range(repeats):
        t0 = time.perf_counter_ns()
        _block(fn())
        times.append((time.perf_counter_ns() - t0) / 1e3)
        if time.perf_counter() - t_start > max_seconds:
            break
    return float(np.median(times))
