"""Performance embeddings of canonical loop nests (paper §4, citing [33]).

The transfer-tuning database is queried by Euclidean distance between these
fixed-length feature vectors.  Features capture exactly what the recipes are
sensitive to: nest shape (depth/trip counts), access structure (stride
profile, reuse), and compute/data volume (arithmetic intensity).
"""
from __future__ import annotations

import math

import numpy as np

from .dependence import EQ, nest_direction_vectors
from .ir import Computation, Loop, Node, Program, loop_iterators, nest_computations
from .normalize import access_stride

DIM = 24
_MAX_DEPTH = 6


def embed_nest(program: Program, nest: Node) -> np.ndarray:
    """Structural feature vector (length ``DIM``) for one canonical nest.

    Features: depth/computation/read/guard counts, carried and reduction
    iterator counts, log-scaled trip counts, per-level stride profile, and
    log flops/footprint/intensity.  Keys the tuning database's
    nearest-neighbour transfer, so the layout is checked at runtime.
    """
    if isinstance(nest, Computation):
        comps: list[Computation] = [nest]
        iterators: list[str] = []
        trips: dict[str, int] = {}
    else:
        comps = nest_computations(nest)
        iterators = list(loop_iterators(nest))
        trips = {}

        def rec(n: Node) -> None:
            """Collect trip counts from every loop in the nest."""
            if isinstance(n, Loop):
                trips[n.iterator] = n.trip_count
                for b in n.body:
                    rec(b)

        rec(nest)

    depth = len(iterators)
    log_trips = sorted((math.log2(max(1, trips[i])) for i in iterators), reverse=True)
    log_trips = (log_trips + [0.0] * _MAX_DEPTH)[:_MAX_DEPTH]

    n_reads = sum(len(c.reads) for c in comps)
    n_acc = sum(1 for c in comps if c.accumulate is not None)
    n_guard = sum(len(c.guards) for c in comps)

    # stride profile: per nest level (inner->outer) the paper's criterion
    stride_prof = []
    for it in reversed(iterators):
        s = sum(access_stride(program, a, it) for c in comps for a in c.accesses())
        stride_prof.append(math.log1p(s))
    stride_prof = (stride_prof + [0.0] * _MAX_DEPTH)[:_MAX_DEPTH]

    # parallel vs reduction/carried iterators
    vectors = nest_direction_vectors(iterators, trips, comps) if iterators else []
    carried = sum(
        1
        for k, _ in enumerate(iterators)
        if any(v.directions[k] != EQ for v in vectors)
    )
    red = sum(
        1
        for it in iterators
        if any(
            it not in set(x for ix in c.write.index for x in ix.iterators())
            and it in c.iterators()
            for c in comps
        )
    )

    iters_total = math.prod(max(1, trips[i]) for i in iterators) if iterators else 1
    flops = iters_total * max(1, n_reads)
    footprint = sum(
        program.array(name).size
        for name in {a.array for c in comps for a in c.accesses()}
    )
    intensity = flops / max(1, footprint)

    vec = np.array(
        [depth, len(comps), n_reads, n_acc, n_guard, carried, red,
         math.log1p(flops), math.log1p(footprint), math.log1p(intensity)]
        + log_trips
        + stride_prof,
        dtype=np.float64,
    )
    # Explicit check (not ``assert``: embeddings key the persisted database,
    # so a layout drift must fail loudly even under ``python -O``).
    if vec.shape != (10 + 2 * _MAX_DEPTH,) or DIM != 10 + 2 * _MAX_DEPTH + 2:
        raise RuntimeError(
            f"embedding layout out of sync: {vec.shape[0]} features with "
            f"_MAX_DEPTH={_MAX_DEPTH} but DIM={DIM}; update DIM when the "
            "feature set changes"
        )
    return np.concatenate([vec, [0.0, 0.0]])  # reserved slots


def distance(a: np.ndarray, b: np.ndarray) -> float:
    """Euclidean distance between two nest embeddings."""
    return float(np.linalg.norm(a - b))
