"""Shared fault-tolerance layer: heartbeats, restarts, fault injection,
backend degradation.

Promoted from ``train/fault.py`` (PR 6) into a subsystem every deployment
surface builds on:

  * **training** — ``Heartbeat`` / ``StragglerMonitor`` / ``RestartPolicy``
    drive ``Trainer.run_resilient`` (restore-from-checkpoint supervision);
  * **serving** — ``serve.engine`` isolates request-scoped failures (raised
    prefill/decode, non-finite logits, deadlines, cancellation) so one bad
    request never kills the continuous batch, and degrades failed Pallas
    compiles across backends via ``compile_with_degradation``;
  * **tuning** — ``tools/tune`` wraps its spawn pool in bounded
    ``RestartPolicy`` retries, quarantines nests that crash workers, and
    checkpoints completed results so a ``BrokenProcessPool`` loses nothing;
  * **persistence** — ``TuningDatabase.save`` is atomic + checksummed with a
    ``.bak`` fallback on corrupted loads.

All of it is proven by deterministic injection: a seeded :class:`FaultPlan`
names *where* (site), *what* (kind) and *when* (key / firing count) a fault
strikes, so tests and ``benchmarks/bench_resilience.py`` replay the exact
same failure schedule every run.

Failure model on a real cluster: (a) hard node loss — missed heartbeats,
restart-from-checkpoint on a re-formed mesh (checkpoints are device-count
agnostic); (b) stragglers — per-step wall time over a multiple of the EMA,
flagged for replacement (synchronous SPMD cannot proceed without the host);
(c) numeric poison — NaN/inf gradients skipped inside the jitted step
(``adamw_update``), NaN logits failing only the poisoned request.

Injecting a deterministic failure schedule into a test or benchmark::

    from repro.fault import FaultPlan

    plan = FaultPlan(seed=7, rate=0.15, sites=("serve.decode",))
    eng = ServingEngine(cfg, params, scfg, fault_plan=plan)
    ...                       # ~15% of requests raise mid-decode
    assert plan.fired         # the log of (site, key) strikes, asserted on

and degrading a fragile compile across backends::

    from repro.fault import compile_with_degradation

    fn, backend, degradations = compile_with_degradation(daisy, program)
    # backend == "xla" if the pallas rung failed compile-or-execute;
    # degradations records (program, failed_backend, final_backend)

See ``docs/architecture.md`` (Deployment layers) for how serving, tuning
and persistence each consume this module.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field, replace
from pathlib import Path
from typing import Any, Callable

import numpy as np

# ---------------------------------------------------------------------------
# heartbeats / stragglers / restarts (the PR-6 trainer scaffolding, shared)
# ---------------------------------------------------------------------------


class Heartbeat:
    """Background thread stamping a file; a supervisor (or test) detects a
    dead/stuck process by file age.  Stamps are written atomically (tmp +
    ``os.replace``) so a reader can never parse a half-written file and
    mistake a live process for a dead one."""

    def __init__(self, path: str | Path, interval: float = 1.0):
        self.path = Path(path)
        self.interval = interval
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self) -> None:
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _stamp(self) -> None:
        tmp = self.path.with_name(f".{self.path.name}.{os.getpid()}.tmp")
        tmp.write_text(json.dumps({"t": time.time(), "pid": os.getpid()}))
        os.replace(tmp, self.path)

    def _run(self) -> None:
        while not self._stop.is_set():
            self._stamp()
            self._stop.wait(self.interval)

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)

    @staticmethod
    def age(path: str | Path) -> float | None:
        p = Path(path)
        if not p.exists():
            return None
        try:
            return time.time() - json.loads(p.read_text())["t"]
        except Exception:
            return None


@dataclass
class StragglerMonitor:
    """EMA step-time tracker; flags steps slower than ``threshold`` x EMA."""

    threshold: float = 3.0
    alpha: float = 0.1
    ema: float | None = None
    flagged: list[tuple[int, float]] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = self.ema is not None and dt > self.threshold * self.ema
        if is_straggler:
            self.flagged.append((step, dt))
        # don't fold outliers into the EMA
        if not is_straggler:
            self.ema = dt if self.ema is None else (1 - self.alpha) * self.ema + self.alpha * dt
        return is_straggler


@dataclass
class RestartPolicy:
    """Bounded retry-with-backoff loop.  Drives ``Trainer.run_resilient``
    (restore-from-checkpoint) and the tune pool's per-task retries."""

    max_restarts: int = 3
    backoff_s: float = 0.0
    restarts: int = 0

    def should_restart(self, exc: Exception) -> bool:
        self.restarts += 1
        if self.restarts > self.max_restarts:
            return False
        if self.backoff_s:
            time.sleep(self.backoff_s * self.restarts)
        return True


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------


class FaultInjected(RuntimeError):
    """The error a ``kind='error'`` fault raises at its injection site."""


@dataclass
class Fault:
    """One scheduled fault.

    ``site`` names the injection point (e.g. ``serve.prefill``,
    ``serve.decode``, ``serve.step``, ``tune.worker``, ``daisy.compile``,
    ``db.save``); ``kind`` what happens there (``error`` raises
    :class:`FaultInjected`, ``nan`` poisons logits, ``crash`` hard-kills a
    pool worker, ``hang`` stalls it, ``truncate`` clips a file); ``key``
    restricts the fault to one request rid / nest fingerprint / backend
    (``None`` matches any); ``times`` is how many firings before the fault
    burns out (< 0 = unlimited).
    """

    site: str
    kind: str = "error"
    key: Any = None
    times: int = 1


class FaultPlan:
    """A seeded, deterministic schedule of faults.

    Explicit :class:`Fault` entries fire when their site/key matches (each
    at most ``times`` times); on top of that, a ``rate`` in (0, 1] arms
    every listed ``sites`` entry with seeded random ``error`` faults —
    the open-loop resilience benchmark's traffic poisoner.  Every firing is
    recorded in ``fired`` so tests can assert the schedule was exercised.
    """

    def __init__(self, faults: tuple[Fault, ...] | list[Fault] = (),
                 seed: int = 0, rate: float = 0.0,
                 sites: tuple[str, ...] = ()):
        self.faults = [replace(f) for f in faults]  # own the mutable counters
        self.rate = float(rate)
        self.sites = tuple(sites)
        self.rng = np.random.default_rng(seed)
        self.fired: list[tuple[str, Any, str]] = []

    def fire(self, site: str, key: Any = None) -> Fault | None:
        """The fault striking ``site`` for ``key`` right now, or None.
        A returned fault's firing is consumed and recorded."""
        for f in self.faults:
            if f.site != site or f.times == 0:
                continue
            if f.key is not None and f.key != key:
                continue
            if f.times > 0:
                f.times -= 1
            self.fired.append((site, key, f.kind))
            return f
        if self.rate > 0.0 and site in self.sites and self.rng.random() < self.rate:
            self.fired.append((site, key, "error"))
            return Fault(site, "error", key=key, times=0)
        return None

    def maybe_raise(self, site: str, key: Any = None) -> Fault | None:
        """``fire``, raising :class:`FaultInjected` for ``error`` faults;
        non-error faults are returned for the site to interpret."""
        f = self.fire(site, key)
        if f is not None and f.kind == "error":
            raise FaultInjected(f"injected fault at {site} (key={key!r})")
        return f

    def count(self, site: str | None = None) -> int:
        return sum(1 for s, _, _ in self.fired if site is None or s == site)


def truncate_file(path: str | Path, keep_fraction: float = 0.5) -> None:
    """Clip a file to a prefix — the ``truncate`` fault: what a crash or a
    full disk leaves behind when a writer was not atomic."""
    p = Path(path)
    data = p.read_bytes()
    p.write_bytes(data[: max(0, int(len(data) * keep_fraction))])


# ---------------------------------------------------------------------------
# backend degradation chain
# ---------------------------------------------------------------------------


@dataclass
class DegradedCompile:
    """Result of :func:`compile_with_degradation`: the compiled fn, its
    plan, which backend finally succeeded, and the per-backend errors the
    chain absorbed on the way (empty = first choice worked)."""

    fn: Callable
    plan: Any
    backend: str
    errors: list[tuple[str, Exception]] = field(default_factory=list)

    @property
    def degraded(self) -> bool:
        return bool(self.errors)


def compile_with_degradation(
    program,
    backends: tuple[str, ...] = ("pallas", "xla"),
    db=None,
    mesh=None,
    shard_axis: str = "data",
    fault_plan: FaultPlan | None = None,
    validate: bool = True,
) -> DegradedCompile:
    """Compile a canonical program, degrading across backends on failure.

    Tries each backend in order through a fresh ``Daisy`` — the existing
    ``Daisy._backend_recipe`` degradation maps Pallas-kind recipes onto
    their XLA equivalents under ``'xla'``, so a kernel that fails to build
    still serves through the library/vector lowering.  Because jit is lazy,
    a compile that "succeeds" can still blow up at first call — so each
    rung is *validated* by executing once on random inputs (hot-swap
    guardrail: never promote an fn that has not run).  Raises the *first*
    backend's error (with the rest chained) only when every rung fails.
    Injection site ``daisy.compile`` (key = backend) simulates compile
    failures per rung.
    """
    from .core.scheduler import Daisy, random_inputs

    if not backends:
        raise ValueError("compile_with_degradation needs at least one backend")
    errors: list[tuple[str, Exception]] = []
    for b in backends:
        try:
            if fault_plan is not None:
                fault_plan.maybe_raise("daisy.compile", key=b)
            d = Daisy(db=db, backend=b, mesh=mesh, shard_axis=shard_axis)
            fn, plan = d.compile(program)
            if validate:
                out = fn(random_inputs(program))
                for v in (out.values() if isinstance(out, dict) else [out]):
                    np.asarray(v)  # force device execution to completion
            return DegradedCompile(fn, plan, b, errors)
        except Exception as e:  # noqa: BLE001 — every rung failure degrades
            errors.append((b, e))
    raise RuntimeError(
        f"all backends failed compiling {getattr(program, 'name', program)!r}: "
        + "; ".join(f"{b}: {e}" for b, e in errors)
    ) from errors[0][1]
