"""Model assembly: init / forward / decode for all 10 assigned architectures.

Families:
  dense / moe / vlm  — decoder-only transformer (GQA, SWA, optional QKV bias,
                       optional MoE FFN), layers run under ``lax.scan`` over
                       stacked parameters (compile once per unique layer).
  audio              — encoder-decoder (stub frame embeddings -> encoder;
                       text decoder with cross-attention).
  hybrid (Jamba)     — periodic layer pattern (1 attention : 7 Mamba, MoE on
                       alternate layers); scanned over periods.
  ssm (xLSTM)        — periodic mLSTM/sLSTM pattern, no FFN.

Frontends ([vlm]/[audio]) are STUBS per the assignment: ``input_specs()``
supplies precomputed patch/frame embeddings.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L

Params = dict[str, Any]


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_block(key, cfg: ModelConfig, kind: str, use_moe: bool, dt) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": jnp.ones((cfg.d_model,), dt)}
    if kind == "attn":
        p["mixer"] = L.init_attention(ks[0], cfg, dt)
    elif kind == "mamba":
        p["mixer"] = L.init_mamba(ks[0], cfg, dt)
    elif kind == "mlstm":
        p["mixer"] = L.init_mlstm(ks[0], cfg, dt)
    elif kind == "slstm":
        p["mixer"] = L.init_slstm(ks[0], cfg, dt)
    if cfg.d_ff:
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
        p["ffn"] = (
            L.init_moe_ffn(ks[1], cfg, dt) if use_moe else L.init_dense_ffn(ks[1], cfg, dt)
        )
    return p


def _stack(trees: list[Params]) -> Params:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg)
    keys = jax.random.split(key, cfg.n_layers + cfg.enc_layers + 4)
    p: Params = {
        "embed": (jax.random.normal(keys[-1], (cfg.vocab, cfg.d_model)) * 0.02).astype(dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (
            jax.random.normal(keys[-2], (cfg.d_model, cfg.vocab)) / math.sqrt(cfg.d_model)
        ).astype(dt)

    if cfg.family in ("dense", "moe", "vlm"):
        blocks = [
            _init_block(keys[l], cfg, "attn", cfg.layer_is_moe(l), dt)
            for l in range(cfg.n_layers)
        ]
        # homogeneity check: scan needs identical treedefs
        p["layers"] = _stack(blocks)
    elif cfg.family == "audio":
        enc = [
            _init_block(keys[l], cfg, "attn", False, dt) for l in range(cfg.enc_layers)
        ]
        dec = []
        for l in range(cfg.n_layers):
            blk = _init_block(keys[cfg.enc_layers + l], cfg, "attn", False, dt)
            blk["norm_x"] = jnp.ones((cfg.d_model,), dt)
            blk["cross"] = L.init_attention(
                jax.random.fold_in(keys[cfg.enc_layers + l], 7), cfg, dt
            )
            dec.append(blk)
        p["encoder"] = _stack(enc)
        p["decoder"] = _stack(dec)
        p["enc_final_norm"] = jnp.ones((cfg.d_model,), dt)
    elif cfg.family == "hybrid":
        period = cfg.attn_period
        n_periods = cfg.n_layers // period
        per_pos: list[list[Params]] = [[] for _ in range(period)]
        for g in range(n_periods):
            for pos in range(period):
                l = g * period + pos
                per_pos[pos].append(
                    _init_block(keys[l], cfg, cfg.layer_kind(l), cfg.layer_is_moe(l), dt)
                )
        p["periods"] = [_stack(blocks) for blocks in per_pos]
    elif cfg.family == "ssm":
        period = len(cfg.block_pattern)
        n_periods = cfg.n_layers // period
        per_pos = [[] for _ in range(period)]
        for g in range(n_periods):
            for pos in range(period):
                l = g * period + pos
                per_pos[pos].append(_init_block(keys[l], cfg, cfg.layer_kind(l), False, dt))
        p["periods"] = [_stack(blocks) for blocks in per_pos]
    else:
        raise ValueError(cfg.family)
    return p


# ---------------------------------------------------------------------------
# block application (sequence form)
# ---------------------------------------------------------------------------
def _apply_block(
    x, blk: Params, cfg: ModelConfig, kind: str, use_moe: bool,
    positions, causal=True, memory=None,
    state=None, write_pos=0, attn_offset=0,
):
    """Returns (x_out, new_state)."""
    from ..kernels import ops

    sp = cfg.seq_parallel and x.shape[1] > 1

    def _sp(t):
        # Megatron SP: sub-block outputs reduce-scatter onto the sequence dim
        # (1x ring bytes); the next column-parallel matmul all-gathers.
        return L.constrain(t, ("pod", "data"), "model", None) if sp else t

    normed = ops.rmsnorm(x, blk["norm1"], eps=cfg.norm_eps)
    new_state = None
    if kind == "attn":
        cache = state
        att, new_state = L.attention(
            normed, blk["mixer"], cfg, positions=positions, causal=causal,
            cache=cache, write_pos=write_pos, attn_offset=attn_offset,
            memory=None,
        )
        x = x + _sp(att)
        if memory is not None:  # cross-attention sub-block (enc-dec decoder)
            normed_x = ops.rmsnorm(x, blk["norm_x"], eps=cfg.norm_eps)
            cross, _ = L.attention(
                normed_x, blk["cross"], cfg, positions=positions,
                causal=False, memory=memory,
            )
            x = x + cross
    elif kind == "mamba":
        out, new_state = L.mamba(normed, blk["mixer"], cfg, state=state)
        x = x + out
    elif kind == "mlstm":
        out, new_state = L.mlstm(normed, blk["mixer"], cfg, state=state)
        x = x + out
    elif kind == "slstm":
        out, new_state = L.slstm(normed, blk["mixer"], cfg, state=state)
        x = x + out
    if cfg.d_ff:
        normed2 = ops.rmsnorm(x, blk["norm2"], eps=cfg.norm_eps)
        if use_moe:
            b, s, d = normed2.shape
            y = L.moe_ffn(normed2.reshape(b * s, d), blk["ffn"], cfg).reshape(b, s, d)
        else:
            y = L.dense_ffn(normed2, blk["ffn"])
        x = x + _sp(y)
    return x, new_state


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------
def embed_tokens(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    return params["embed"][tokens]


def _logits(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    from ..kernels import ops

    x = ops.rmsnorm(x, params["final_norm"], eps=cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head


def forward(cfg: ModelConfig, params: Params, batch: dict[str, jax.Array]) -> jax.Array:
    """batch: tokens (B, S) [+ 'embeds' (B, Sf, D) for vlm/audio frontends].

    Returns logits (B, S_text, V).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    n_front = 0
    if cfg.frontend is not None and cfg.family == "vlm":
        emb = batch["embeds"].astype(x.dtype)  # precomputed patch embeddings
        n_front = emb.shape[1]
        x = jnp.concatenate([emb, x], axis=1)
    x = L.constrain(x, ("pod", "data"), None, None)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :].repeat(b, 0)

    def _maybe_remat(fn):
        if cfg.remat == "block":
            return jax.checkpoint(fn)
        if cfg.remat == "block_save_moe":
            # keep the MoE dispatch/expert outputs across the backward: the
            # EP collectives then run once instead of thrice
            policy = jax.checkpoint_policies.save_only_these_names(
                "moe_dispatch", "moe_expert_out"
            )
            return jax.checkpoint(fn, policy=policy)
        return fn

    if cfg.family in ("dense", "moe", "vlm"):
        is_moe = cfg.layer_is_moe(0)

        @_maybe_remat
        def body(xc, blk):
            out, _ = _apply_block(xc, blk, cfg, "attn", is_moe, positions)
            return out, None

        x, _ = jax.lax.scan(body, x, params["layers"])
    elif cfg.family == "audio":
        memory = encode(cfg, params, batch["embeds"])

        @_maybe_remat
        def body(xc, blk):
            out, _ = _apply_block(xc, blk, cfg, "attn", False, positions, memory=memory)
            return out, None

        x, _ = jax.lax.scan(body, x, params["decoder"])
    elif cfg.family in ("hybrid", "ssm"):
        period_params = params["periods"]
        kinds = [cfg.layer_kind(pos) for pos in range(len(period_params))]
        moes = [cfg.layer_is_moe(pos) for pos in range(len(period_params))]

        if cfg.remat == "layer":
            # per-position remat: during the period backward only ONE
            # layer's intermediates are live (vs all 8 with period remat)
            def apply_pos(xc, blk, pos):
                return _apply_block(xc, blk, cfg, kinds[pos], moes[pos], positions)[0]

            apply_pos = jax.checkpoint(apply_pos, static_argnums=(2,))

            def body(xc, blks):
                for pos, blk in enumerate(blks):
                    xc = apply_pos(xc, blk, pos)
                return xc, None
        else:
            @_maybe_remat
            def body(xc, blks):
                for pos, blk in enumerate(blks):
                    xc, _ = _apply_block(xc, blk, cfg, kinds[pos], moes[pos], positions)
                return xc, None

        x, _ = jax.lax.scan(body, x, tuple(period_params))
    logits = _logits(cfg, params, x)
    if n_front:
        logits = logits[:, n_front:, :]
    return logits


def encode(cfg: ModelConfig, params: Params, embeds: jax.Array) -> jax.Array:
    """Audio encoder over precomputed frame embeddings (bidirectional)."""
    from ..kernels import ops

    b = embeds.shape[0]
    positions = jnp.arange(embeds.shape[1], dtype=jnp.int32)[None, :].repeat(b, 0)

    def body(xc, blk):
        out, _ = _apply_block(xc, blk, cfg, "attn", False, positions, causal=False)
        return out, None

    x, _ = jax.lax.scan(body, embeds, params["encoder"])
    return ops.rmsnorm(x, params["enc_final_norm"], eps=cfg.norm_eps)


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------
def _empty_attn_cache(cfg: ModelConfig, b: int, s_max: int, dt, ring: bool) -> tuple:
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    # SWA archs only ever attend to the last `window` positions: with
    # ring=True the cache is a window-sized ring buffer (the sub-quadratic
    # long-context path); ring=False allocates the full length (serve engine
    # prefill convenience).
    eff = min(s_max, cfg.window) if (ring and cfg.window) else s_max
    shape = (b, eff, kv, dh)
    return (jnp.zeros(shape, dt), jnp.zeros(shape, dt))


def _empty_state(cfg: ModelConfig, kind: str, b: int, s_max: int, dt, ring: bool = True):
    d = cfg.d_model
    if kind == "attn":
        return _empty_attn_cache(cfg, b, s_max, dt, ring)
    if kind == "mamba":
        din = cfg.mamba_expand * d
        return (
            jnp.zeros((b, cfg.mamba_d_conv - 1, din), dt),
            jnp.zeros((b, din, cfg.mamba_d_state), jnp.float32),
        )
    if kind == "mlstm":
        h = cfg.n_heads
        dh = d // h
        return (
            jnp.zeros((b, h, dh, dh), jnp.float32),
            jnp.zeros((b, h, dh), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32),
        )
    if kind == "slstm":
        return (
            jnp.zeros((b, d), jnp.float32),
            jnp.zeros((b, d), jnp.float32),
            jnp.full((b, d), -1e30, jnp.float32),
        )
    raise ValueError(kind)


def init_decode_state(cfg: ModelConfig, b: int, s_max: int, ring: bool = True) -> dict:
    dt = _dtype(cfg)
    state: dict[str, Any] = {"len": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "moe", "vlm"):
        st = _empty_state(cfg, "attn", b, s_max, dt, ring)
        state["layers"] = jax.tree_util.tree_map(
            lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype), st
        )
    elif cfg.family == "audio":
        st = _empty_state(cfg, "attn", b, s_max, dt, ring)
        state["layers"] = jax.tree_util.tree_map(
            lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype), st
        )
        state["memory"] = jnp.zeros((b, cfg.frontend_len, cfg.d_model), dt)
    elif cfg.family in ("hybrid", "ssm"):
        period = cfg.attn_period or len(cfg.block_pattern)
        n_periods = cfg.n_layers // period
        per_pos = []
        for pos in range(period):
            st = _empty_state(cfg, cfg.layer_kind(pos), b, s_max, dt, ring)
            per_pos.append(
                jax.tree_util.tree_map(
                    lambda x: jnp.zeros((n_periods,) + x.shape, x.dtype), st
                )
            )
        state["periods"] = per_pos
    return state


def decode_step(
    cfg: ModelConfig, params: Params, state: dict, tokens: jax.Array
) -> tuple[jax.Array, dict]:
    """Decode/prefill step: tokens (B, s) -> logits (B, s, V) + new state.

    s == 1 is the serve decode step; s > 1 prefills the cache (requires a
    full-length, non-ring cache — the serve engine allocates ring=False).
    """
    b, s = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    clen = state["len"]
    positions = clen + jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    new_state = dict(state)

    # SWA ring buffer: write slot wraps at the cache size; not-yet-written
    # slots are masked because attn_offset caps the causal test
    def _slots(kind: str, s_cache: int):
        if kind != "attn":
            return 0, 0
        ring = cfg.window is not None and s_cache <= cfg.window
        if ring:
            # ring caches decode one token at a time
            return jnp.mod(clen, s_cache), jnp.minimum(clen, s_cache - 1)
        return clen, clen

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        layer_params = params["layers"] if cfg.family != "audio" else params["decoder"]
        memory = state.get("memory")
        is_moe = cfg.layer_is_moe(0)
        wpos, aoff = _slots("attn", state["layers"][0].shape[2])

        def body(xc, inp):
            blk, cache = inp
            out, new_cache = _apply_block(
                xc, blk, cfg, "attn", is_moe, positions,
                state=cache, write_pos=wpos, attn_offset=aoff, memory=memory,
            )
            return out, new_cache

        x, caches = jax.lax.scan(body, x, (layer_params, state["layers"]))
        new_state["layers"] = caches
    else:
        period_params = params["periods"]
        period = len(period_params)
        kinds = [cfg.layer_kind(pos) for pos in range(period)]
        moes = [cfg.layer_is_moe(pos) for pos in range(period)]

        def body(xc, inp):
            blks, sts = inp  # tuples over positions, sliced per period
            new_sts = []
            for pos in range(period):
                sc = sts[pos][0].shape[1] if kinds[pos] == "attn" else 0
                wpos, aoff = _slots(kinds[pos], sc)
                xc, nst = _apply_block(
                    xc, blks[pos], cfg, kinds[pos], moes[pos], positions,
                    state=sts[pos], write_pos=wpos, attn_offset=aoff,
                )
                new_sts.append(nst)
            return xc, tuple(new_sts)

        x, new_per = jax.lax.scan(
            body, x, (tuple(period_params), tuple(state["periods"]))
        )
        new_state["periods"] = list(new_per)

    new_state["len"] = clen + s
    return _logits(cfg, params, x), new_state


# ---------------------------------------------------------------------------
# slot-batched decode (the continuous-batching serve path)
# ---------------------------------------------------------------------------
def init_slot_states(cfg: ModelConfig, n_slots: int, s_max: int) -> dict:
    """Decode states for ``n_slots`` independent request slots, stacked on a
    leading slot axis (each slot is a ``b=1``, ``ring=False`` decode state
    with its own ``len`` scalar).  The serving engine writes a freshly
    prefilled request into one slot with ``write_slot`` while the others are
    mid-stream."""
    st = init_decode_state(cfg, 1, s_max, ring=False)
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros((n_slots,) + x.shape, x.dtype), st
    )


def write_slot(states: dict, i: int, state: dict) -> dict:
    """Insert a single-slot (``b=1``) decode state at slot index ``i`` of a
    slot-stacked state tree (a refill: the new request's prefilled cache and
    length replace whatever the finished request left behind)."""
    return jax.tree_util.tree_map(lambda s, x: s.at[i].set(x), states, state)


def decode_slots(
    cfg: ModelConfig, params: Params, states: dict, tokens: jax.Array
) -> tuple[jax.Array, dict]:
    """One decode step for every slot at once.

    ``states`` is a slot-stacked tree (``init_slot_states``); ``tokens`` is
    ``(N,)`` int32 — the last sampled token per slot.  Returns
    ``(logits (N, V), new states)``.  Each slot advances at its own cache
    length / write offset (``vmap`` over the slot axis), which is what lets
    a freshly admitted request coexist with half-finished ones without any
    retrace: the traced shapes depend only on ``(N, s_max)``.
    """

    def one(state, tok):
        logits, st = decode_step(cfg, params, state, tok.reshape(1, 1))
        return logits[0, -1], st

    return jax.vmap(one)(states, tokens)


def decode_slots_greedy(
    cfg: ModelConfig, params: Params, states: dict, tokens: jax.Array
) -> tuple[jax.Array, dict]:
    """``decode_slots`` with the greedy sample fused on device: returns
    ``((N,) int32 next tokens, new states)``.  Keeping the argmax on device
    means the sampled tokens can feed the *next* dispatched step directly —
    the engine's pipelined dispatch only blocks on them at harvest points."""
    logits, states = decode_slots(cfg, params, states, tokens)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), states
