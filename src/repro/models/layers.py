"""Model building blocks (pure functions over param pytrees).

Every block has a *sequence* form (training/prefill) and a *step* form
(decode with state).  Attention dispatches through ``repro.kernels.ops`` so
the Pallas kernels (validated in interpret mode) and the XLA reference are
interchangeable backends.

Memory-hierarchy notes (TPU adaptation, see DESIGN.md):
  * Mamba / mLSTM scans are CHUNKED — the naive associative scan would
    materialize (B, S, d_inner, d_state), which no HBM holds at the assigned
    shapes; chunking bounds the working set to (B, Q, d_inner, d_state) per
    step, the same a-priori working-set reasoning the paper applies to L1.
  * MoE dispatch is sort-based with static capacity (EP-shardable dense
    (E, C, D) buckets) rather than GPU-style CSR block sparsity.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from ..configs.base import ModelConfig
from ..kernels import ops

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# sharding hints
# ---------------------------------------------------------------------------
def constrain(x: jax.Array, *entries):
    """with_sharding_constraint that degrades gracefully: axes missing from
    the active mesh or non-dividing dims are dropped; no-op without a mesh.
    Model code can therefore state its preferred layout unconditionally."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return x
    try:
        sizes = dict(mesh.shape)
    except Exception:
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    clean = []
    for d, e in enumerate(entries):
        if e is None or d >= x.ndim:
            clean.append(None)
            continue
        axes = [a for a in ((e,) if isinstance(e, str) else tuple(e)) if a in sizes]
        prod = 1
        for a in axes:
            prod *= int(sizes[a])
        if axes and x.shape[d] % prod == 0 and prod > 1:
            clean.append(axes[0] if len(axes) == 1 else tuple(axes))
        else:
            clean.append(None)
    if all(c is None for c in clean):
        return x
    try:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*clean)
        )
    except Exception:
        return x


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * s).astype(dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA + optional SWA + optional bias + optional KV cache)
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h * dh), dtype),
        "wk": _dense_init(ks[1], (d, kv * dh), dtype),
        "wv": _dense_init(ks[2], (d, kv * dh), dtype),
        "wo": _dense_init(ks[3], (h * dh, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((kv * dh,), dtype)
        p["bv"] = jnp.zeros((kv * dh,), dtype)
    return p


def attention(
    x: jax.Array,  # (B, S, D)
    p: Params,
    cfg: ModelConfig,
    *,
    positions: jax.Array,  # (B, S) absolute positions (rope)
    causal: bool = True,
    cache: tuple[jax.Array, jax.Array] | None = None,  # (B, S_cache, KV, Dh)
    write_pos: jax.Array | int = 0,   # cache slot to write (ring for SWA)
    attn_offset: jax.Array | int = 0,  # q_offset for masking vs cache slots
    memory: jax.Array | None = None,  # (B, S_mem, D) for cross-attention
):
    """Sequence attention (cache=None) or single-step decode (cache given).

    SWA decode uses a ring buffer of size ``window``: keys are roped at their
    absolute positions *before* being written, so slot order is irrelevant
    (softmax is permutation-invariant); ``attn_offset = min(len, window-1)``
    masks not-yet-written slots via the causal test and the ring itself
    bounds the window.
    """
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    q = x @ p["wq"] + (p["bq"] if "bq" in p else 0.0)
    src = memory if memory is not None else x
    k = src @ p["wk"] + (p["bk"] if "bk" in p else 0.0)
    v = src @ p["wv"] + (p["bv"] if "bv" in p else 0.0)
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, -1, kv, dh)
    v = v.reshape(b, -1, kv, dh)

    if memory is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    # layout hints: heads over 'model' where they divide (constrain drops the
    # axis otherwise -> KV replicates over model for GQA kv < mesh)
    q = constrain(q, ("pod", "data"), None, "model", None)
    k = constrain(k, ("pod", "data"), None, "model", None)
    v = constrain(v, ("pod", "data"), None, "model", None)

    new_cache = None
    if cache is not None:
        ck, cv = cache  # (B, S_cache, KV, Dh)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), write_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), write_pos, axis=1)
        k, v = ck, cv
        new_cache = (ck, cv)

    # fold heads into batch: q (B*H, S, Dh); k/v (B*KV, Skv, Dh)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, -1, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, -1, dh)
    of = ops.attention(
        qf, kf, vf,
        causal=causal and memory is None,
        window=cfg.window if (memory is None and cache is None) else None,
        q_offset=attn_offset if cache is not None else 0,
    )
    out = of.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# FFN: SwiGLU dense + sort-based MoE
# ---------------------------------------------------------------------------
def init_dense_ffn(key, cfg: ModelConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "wg": _dense_init(ks[0], (d, f), dtype),
        "wu": _dense_init(ks[1], (d, f), dtype),
        "wd": _dense_init(ks[2], (f, d), dtype),
    }


def dense_ffn(x: jax.Array, p: Params) -> jax.Array:
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]


def init_moe_ffn(key, cfg: ModelConfig, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (d, e), jnp.float32),
        "wg": _dense_init(ks[1], (e, d, f), dtype),
        "wu": _dense_init(ks[2], (e, d, f), dtype),
        "wd": _dense_init(ks[3], (e, f, d), dtype),
    }


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(math.ceil(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to sublane multiple


def moe_ffn(x: jax.Array, p: Params, cfg: ModelConfig) -> jax.Array:
    """Top-k token-choice MoE with static capacity (sort-based dispatch).

    x: (T, D) -> (T, D).  Dropped tokens (capacity overflow) contribute 0,
    matching GShard/Mixtral-style capacity semantics.
    """
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = moe_capacity(cfg, t)

    logits = (x.astype(jnp.float32)) @ p["router"]  # (T, E)
    gates, experts = jax.lax.top_k(logits, k)  # (T, K)
    gates = jax.nn.softmax(gates, axis=-1).astype(x.dtype)

    fe = experts.reshape(-1)  # (T*K,)
    ft = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    fg = gates.reshape(-1)
    order = jnp.argsort(fe)  # stable
    se, st, sg = fe[order], ft[order], fg[order]

    counts = jnp.zeros((e,), jnp.int32).at[fe].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[se]
    keep = pos < c
    dest = jnp.where(keep, se * c + pos, e * c)  # overflow slot e*c

    disp = jnp.zeros((e * c + 1, d), x.dtype).at[dest].set(x[st])
    disp = constrain(disp[: e * c].reshape(e, c, d), "model", None, None)  # EP
    # name the dispatched buckets so the 'block_save_moe' remat policy can
    # keep them: recomputing the dispatch in the backward repeats its
    # all-to-all-class collectives (3x the EP bytes)
    disp = checkpoint_name(disp, "moe_dispatch")

    h = ops.grouped_matmul(disp, p["wg"])
    u = ops.grouped_matmul(disp, p["wu"])
    y = ops.grouped_matmul(jax.nn.silu(h) * u, p["wd"])  # (E, C, D)
    y = constrain(y, "model", None, None)
    y = checkpoint_name(y, "moe_expert_out")

    y_flat = jnp.concatenate([y.reshape(e * c, d), jnp.zeros((1, d), y.dtype)], 0)
    contrib = y_flat[dest] * (sg * keep.astype(sg.dtype))[:, None]
    contrib = constrain(contrib, ("pod", "data"), None)
    return jnp.zeros((t, d), x.dtype).at[st].add(contrib.astype(x.dtype))


# ---------------------------------------------------------------------------
# Mamba block (selective SSM, chunked scan)
# ---------------------------------------------------------------------------
def init_mamba(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    din = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    dt_rank = max(1, d // 16)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * din), dtype),
        "conv_w": _dense_init(ks[1], (cfg.mamba_d_conv, din), dtype, scale=0.5),
        "conv_b": jnp.zeros((din,), dtype),
        "x_proj": _dense_init(ks[2], (din, dt_rank + 2 * n), dtype),
        "dt_proj": _dense_init(ks[3], (dt_rank, din), dtype),
        "dt_bias": jnp.full((din,), -2.0, dtype),  # softplus -> small dt
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (din, 1))),
        "Dskip": jnp.ones((din,), dtype),
        "out_proj": _dense_init(ks[4], (din, d), dtype),
    }


def _mamba_scan_chunked(dt, Bm, Cm, xc, A, h0, chunk: int):
    """Selective-SSM scan, chunked for the memory hierarchy.

    The (B, S, Din, N) tensors ``exp(dt*A)`` / ``dt*B*x`` are NEVER
    materialized over the full sequence: each lax.scan step computes them for
    one chunk only — (B, Q, Din, N) is the HBM working set — runs the
    associative scan within the chunk, contracts against C immediately
    (y = C·h), and carries only the (B, Din, N) state.  This is the a-priori
    working-set bounding the paper applies to L1, applied to HBM.

    dt, xc: (B, S, Din) fp32/bf16; Bm, Cm: (B, S, N); A: (Din, N).
    Returns y: (B, S, Din) fp32 and the final state (B, Din, N).
    """
    b, s, din = dt.shape
    n = A.shape[1]
    q = min(chunk, s)
    assert s % q == 0
    nchunks = s // q

    def resh(t):  # (B, S, ...) -> (nchunks, B, Q, ...)
        return t.reshape(b, nchunks, q, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    xs = (resh(dt), resh(Bm), resh(Cm), resh(xc))

    def chunk_step(h, inp):
        dtc, bc, cc, xcc = inp  # (B,Q,Din) / (B,Q,N) / (B,Q,N) / (B,Q,Din)
        a = jnp.exp(dtc[..., None] * A)  # (B,Q,Din,N) — chunk-local only
        bx = dtc[..., None] * bc[:, :, None, :] * xcc[..., None]

        def comb(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        bx = bx.at[:, 0].add(a[:, 0] * h)  # fold carry into first element
        _, hs = jax.lax.associative_scan(comb, (a, bx), axis=1)
        y = jnp.einsum("bqdn,bqn->bqd", hs, cc)  # contract C immediately
        return hs[:, -1], y

    h_last, ys = jax.lax.scan(chunk_step, h0, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, din)
    return y, h_last


def mamba(
    x: jax.Array, p: Params, cfg: ModelConfig,
    state: tuple[jax.Array, jax.Array] | None = None,
    chunk: int = 256,
):
    """x: (B, S, D). state = (conv_buf (B, d_conv-1, Din), h (B, Din, N))."""
    b, s, d = x.shape
    din = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    dt_rank = max(1, d // 16)

    xz = x @ p["in_proj"]
    x1, z = jnp.split(xz, 2, axis=-1)  # (B, S, Din)

    # causal depthwise conv, optionally continuing from a state buffer
    dconv = cfg.mamba_d_conv
    if state is not None:
        conv_buf = state[0]
        x_pad = jnp.concatenate([conv_buf, x1], axis=1)
    else:
        x_pad = jnp.pad(x1, ((0, 0), (dconv - 1, 0), (0, 0)))
    new_conv_buf = x_pad[:, -(dconv - 1):, :] if dconv > 1 else jnp.zeros((b, 0, din), x1.dtype)
    xc = sum(
        x_pad[:, i : i + s, :] * p["conv_w"][i][None, None, :] for i in range(dconv)
    ) + p["conv_b"]
    xc = jax.nn.silu(xc)

    proj = xc @ p["x_proj"]  # (B, S, dt_rank + 2N)
    dt = jax.nn.softplus(proj[..., :dt_rank] @ p["dt_proj"] + p["dt_bias"])
    Bm = proj[..., dt_rank : dt_rank + n].astype(jnp.float32)
    Cm = proj[..., dt_rank + n :].astype(jnp.float32)

    A = -jnp.exp(p["A_log"])  # (Din, N)
    dtf = dt.astype(jnp.float32)
    xcf = xc.astype(jnp.float32)

    h0 = state[1] if state is not None else jnp.zeros((b, din, n), jnp.float32)
    # pad sequence to a chunk multiple (dt=0 => identity transition)
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        dtf = jnp.pad(dtf, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        xcf = jnp.pad(xcf, ((0, 0), (0, pad), (0, 0)))
    y, h_last = _mamba_scan_chunked(dtf, Bm, Cm, xcf, A, h0, q)
    y = y[:, :s].astype(x.dtype)
    y = y + p["Dskip"] * xc
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out, (new_conv_buf, h_last)


# ---------------------------------------------------------------------------
# xLSTM blocks
# ---------------------------------------------------------------------------
def init_mlstm(key, cfg: ModelConfig, dtype) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 6)
    return {
        "wq": _dense_init(ks[0], (d, d), dtype),
        "wk": _dense_init(ks[1], (d, d), dtype),
        "wv": _dense_init(ks[2], (d, d), dtype),
        "wi": _dense_init(ks[3], (d, h), dtype, scale=0.01),
        "wf": _dense_init(ks[4], (d, h), dtype, scale=0.01),
        "bi": jnp.zeros((h,), dtype),
        "bf": jnp.full((h,), 3.0, dtype),  # forget-gate bias -> long memory
        "wo": _dense_init(ks[5], (d, d), dtype),
    }


def mlstm(
    x: jax.Array, p: Params, cfg: ModelConfig,
    state: tuple | None = None, chunk: int = 128,
):
    """Chunkwise-parallel mLSTM (matrix memory linear attention w/ gates).

    Stabilized in log space: within a chunk the decay matrix is computed
    from cumulative log-forget-gates; across chunks a (B, H, Dh, Dh) memory
    and (B, H, Dh) normalizer are carried.
    """
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h

    q = (x @ p["wq"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3) / math.sqrt(dh)
    k = (x @ p["wk"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    logf = jax.nn.log_sigmoid((x @ p["wf"] + p["bf"]).astype(jnp.float32))  # (B,S,H)
    logi = (x @ p["wi"] + p["bi"]).astype(jnp.float32)
    logf = logf.transpose(0, 2, 1)  # (B, H, S)
    logi = logi.transpose(0, 2, 1)

    qc = min(chunk, s)
    pad = (-s) % qc
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        logf = jnp.pad(logf, ((0, 0), (0, 0), (0, pad)))
        logi = jnp.pad(logi, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
    S = q.shape[2]
    nch = S // qc

    def resh(t):
        return t.reshape(b, h, nch, qc, -1).transpose(2, 0, 1, 3, 4)

    qs, ks_, vs = resh(q), resh(k), resh(v)  # (nch, B, H, Q, Dh)
    lf = logf.reshape(b, h, nch, qc).transpose(2, 0, 1, 3)  # (nch, B, H, Q)
    li = logi.reshape(b, h, nch, qc).transpose(2, 0, 1, 3)

    if state is None:
        C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def step(carry, inp):
        C, n, m = carry
        qq, kk, vv, f, i_ = inp  # (B,H,Q,Dh) / (B,H,Q)
        F = jnp.cumsum(f, axis=-1)  # cumulative log-forget within chunk
        logd_inter = F + m[..., None]  # decay applied to carried memory
        # intra-chunk decay matrix: D[t,s] = F_t - F_s + i_s  (s <= t)
        Dm = F[..., :, None] - F[..., None, :] + i_[..., None, :]
        tri = jnp.tril(jnp.ones((qq.shape[2], qq.shape[2]), bool))
        Dm = jnp.where(tri, Dm, -1e30)
        m_intra = jnp.max(Dm, axis=-1)  # (B,H,Q)
        m_new = jnp.maximum(logd_inter, m_intra)  # (B,H,Q) running stabilizer
        sc_inter = jnp.exp(logd_inter - m_new)  # (B,H,Q)
        P = jnp.exp(Dm - m_new[..., None])  # (B,H,Q,Q)
        y_intra = jnp.einsum(
            "bhts,bhsd->bhtd",
            P * jnp.einsum("bhtd,bhsd->bhts", qq.astype(jnp.float32), kk.astype(jnp.float32)),
            vv.astype(jnp.float32),
        )
        y_inter = sc_inter[..., None] * jnp.einsum(
            "bhtd,bhde->bhte", qq.astype(jnp.float32), C
        )
        norm = jnp.einsum(
            "bhts,bhts->bht",
            P, jnp.einsum("bhtd,bhsd->bhts", qq.astype(jnp.float32), kk.astype(jnp.float32)),
        ) + sc_inter * jnp.einsum("bhtd,bhd->bht", qq.astype(jnp.float32), n)
        denom = jnp.maximum(jnp.abs(norm), jnp.exp(-m_new))
        out = (y_intra + y_inter) / denom[..., None]

        # chunk-final state update
        Ftot = F[..., -1:]  # (B,H,1)
        m_next = jnp.maximum(Ftot[..., 0] + m, jnp.max(Ftot - F + i_, axis=-1))
        w_src = jnp.exp(Ftot - F + i_ - m_next[..., None])  # (B,H,Q)
        C_new = jnp.exp(Ftot[..., 0] + m - m_next)[..., None, None] * C + jnp.einsum(
            "bhs,bhsd,bhse->bhde", w_src, kk.astype(jnp.float32), vv.astype(jnp.float32)
        )
        n_new = jnp.exp(Ftot[..., 0] + m - m_next)[..., None] * n + jnp.einsum(
            "bhs,bhsd->bhd", w_src, kk.astype(jnp.float32)
        )
        return (C_new, n_new, m_next), out

    (Cf, nf, mf), ys = jax.lax.scan(step, (C0, n0, m0), (qs, ks_, vs, lf, li))
    ys = ys.transpose(1, 2, 0, 3, 4).reshape(b, h, S, dh)[:, :, :s]
    out = ys.transpose(0, 2, 1, 3).reshape(b, s, d).astype(x.dtype)
    return out @ p["wo"], (Cf, nf, mf)


def init_slstm(key, cfg: ModelConfig, dtype) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 5)
    return {
        "wz": _dense_init(ks[0], (d, d), dtype),
        "wi": _dense_init(ks[1], (d, d), dtype, scale=0.01),
        "wf": _dense_init(ks[2], (d, d), dtype, scale=0.01),
        "wo_gate": _dense_init(ks[3], (d, d), dtype, scale=0.01),
        "bf": jnp.full((d,), 3.0, dtype),
        "wo": _dense_init(ks[4], (d, d), dtype),
    }


def slstm(x: jax.Array, p: Params, cfg: ModelConfig, state=None):
    """Stabilized sLSTM: genuinely sequential scalar recurrence (lax.scan).

    This is the normalizer's 'recurrence' idiom class: the time iterator is
    a loop-carried SCC that fission must keep atomic.
    """
    b, s, d = x.shape
    z = jnp.tanh(x @ p["wz"]).astype(jnp.float32)
    i_ = (x @ p["wi"]).astype(jnp.float32)
    f_ = (x @ p["wf"] + p["bf"]).astype(jnp.float32)
    o_ = jax.nn.sigmoid((x @ p["wo_gate"]).astype(jnp.float32))

    if state is None:
        c0 = jnp.zeros((b, d), jnp.float32)
        n0 = jnp.zeros((b, d), jnp.float32)
        m0 = jnp.full((b, d), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state

    def step(carry, inp):
        c, n, m = carry
        zt, it, ft, ot = inp
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        ig = jnp.exp(it - m_new)
        fg = jnp.exp(logf + m - m_new)
        c_new = fg * c + ig * zt
        n_new = fg * n + ig
        y = ot * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
        return (c_new, n_new, m_new), y

    (cf, nf, mf), ys = jax.lax.scan(
        step, (c0, n0, m0),
        (z.transpose(1, 0, 2), i_.transpose(1, 0, 2),
         f_.transpose(1, 0, 2), o_.transpose(1, 0, 2)),
    )
    out = ys.transpose(1, 0, 2).astype(x.dtype) @ p["wo"]
    return out, (cf, nf, mf)
