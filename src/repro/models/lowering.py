"""daisy ↔ model integration: schedule a model's contractions a priori.

Each architecture's core per-layer contractions (QKV/O projections, FFN
matmuls, expert FFN, attention score/value contractions) are expressed as
loop-nest IR programs, normalized, and resolved against the transfer-tuning
database.  The resolved recipes determine
  * which kernel handles each contraction (Pallas GEMM / flash / XLA dot),
  * the BlockSpec tile sizes (MXU/VMEM-aligned presets), and
  * the mesh axis proposal for the parallel loop (DP on tokens, TP on
    features/heads, EP on experts),
mirroring the paper's flow: normalization first, then a small recipe set
covers every layer of every architecture.

Because all 10 archs' contractions normalize onto the same canonical GEMM
fingerprint family, the database stays tiny — this is the paper's central
claim operating at framework scale.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..configs.base import ModelConfig
from ..core.database import TuningDatabase
from ..core.embedding import embed_nest
from ..core.fusion import optimization_pipeline
from ..core.idioms import classify_nest
from ..core.ir import Array, Computation, Loop, Program, acc, fingerprint
from ..core.passes import PassContext
from ..core.recipes import GEMM_TILE_PRESETS, Recipe

# The same pass pipeline the daisy scheduler runs (normalization +
# canonical-form re-fusion); single-contraction programs pass through the
# fusion stage untouched (blas3 nests stay standalone library calls), but
# sharing the instance keeps model fingerprints aligned with Daisy's.
PIPELINE = optimization_pipeline(fuse=True)


def _matmul_program(name: str, m: int, n: int, k: int, order=("i", "j", "k")) -> Program:
    mac = Computation(
        "mac", acc("Y", "i", "j"), (acc("X", "i", "k"), acc("W", "k", "j")),
        lambda x, w: x * w, accumulate="+",
    )
    dims = {"i": m, "j": n, "k": k}
    nest: tuple = (mac,)
    for it in reversed(order):
        nest = (Loop(it, dims[it], body=nest),)
    return Program(
        name,
        (Array("X", (m, k)), Array("W", (k, n)), Array("Y", (m, n))),
        nest,
    )


@dataclass(frozen=True)
class ContractionPlan:
    name: str
    mnk: tuple[int, int, int]
    fingerprint: str
    idiom: str
    recipe: Recipe
    source: str
    mesh_axis: str  # proposed sharded axis for the parallel loop


def _pick_tile(m: int, n: int, k: int) -> tuple[int, int, int]:
    """VMEM-aligned tile: grow M/N while the working set stays under ~8MB
    (double-buffered halves of a 16MB VMEM)."""
    best = GEMM_TILE_PRESETS[0]
    budget = 8 * 1024 * 1024
    for bm, bn, bk in GEMM_TILE_PRESETS:
        if bm > m or bn > n or bk > k:
            continue
        ws = 4 * (bm * bk + bk * bn + bm * bn)  # fp32 working set
        if ws <= budget and bm * bn >= best[0] * best[1]:
            best = (bm, bn, bk)
    return best


def model_contractions(cfg: ModelConfig, seq: int, batch: int) -> dict[str, tuple[int, int, int]]:
    """(M, N, K) of each distinct per-layer contraction at a given shape."""
    t = seq * batch  # token count (the parallel M dimension)
    d, h, kv, dh, f = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff
    out: dict[str, tuple[int, int, int]] = {
        "q_proj": (t, h * dh, d),
        "kv_proj": (t, kv * dh, d),
        "o_proj": (t, d, h * dh),
        "lm_head": (t, cfg.vocab, d),
    }
    if f:
        if cfg.is_moe:
            from .layers import moe_capacity

            c = moe_capacity(cfg, t)
            out["expert_ffn_in"] = (c, f, d)   # per expert
            out["expert_ffn_out"] = (c, d, f)
        else:
            out["ffn_in"] = (t, f, d)
            out["ffn_out"] = (t, d, f)
    if cfg.family == "hybrid":
        din = cfg.mamba_expand * d
        out["mamba_in_proj"] = (t, 2 * din, d)
        out["mamba_out_proj"] = (t, d, din)
    win = cfg.window or seq
    out["attn_scores"] = (seq, min(win, seq), dh)  # per (batch, head)
    out["attn_values"] = (seq, dh, min(win, seq))
    return out


def seed_model_database(db: TuningDatabase) -> None:
    """Seed the DB with the canonical GEMM recipe (fingerprint-generic via
    the embedding metric: every model contraction normalizes to this family)."""
    probe = _matmul_program("canonical_gemm", 1024, 1024, 1024)
    norm = PIPELINE.run(probe)
    nest = norm.body[0]
    db.add(
        fingerprint(nest),
        embed_nest(norm, nest),
        Recipe(kind="pallas_gemm", tile=(256, 256, 128), notes="canonical GEMM"),
        provenance="model-seed",
    )


_DEPLOYMENT_DBS: dict[str, TuningDatabase] = {}


def deployment_database(backend: str = "xla") -> TuningDatabase:
    """The database a deployment starts from.

    The shipped pretuned transfer database (``data/pretuned_<backend>.json``,
    written offline by ``repro.tools.tune``) when installed — so engines and
    trainers start warm on measured recipes — plus the canonical-GEMM model
    seed on top (``add`` never downgrades a measured entry).

    One *shared* instance per backend: re-created engines and restarted
    trainers resolve against the same object, so content-keyed caches
    (kernel reports, plans) hit across instances; seeding it with new
    recipes bumps its generation and expires those caches coherently.
    """
    from ..core.database import try_load_pretuned

    db = _DEPLOYMENT_DBS.get(backend)
    if db is None:
        db = try_load_pretuned(backend) or TuningDatabase()
        seed_model_database(db)
        _DEPLOYMENT_DBS[backend] = db
    return db


@dataclass
class DeploymentContext:
    """Shared deployment boilerplate for ``ServingEngine`` and ``Trainer``.

    Both constructors need the same three things before their first jit:
    parameters placed onto the mesh with the sharding planner's specs, a
    tuning database (falling back to the warm pretuned
    ``deployment_database``), and config-fingerprint-keyed jitted step
    functions (so re-created engines / restarted trainers share one trace).
    Build it with ``deployment_context``; one helper keeps the two
    constructors from drifting.
    """

    cfg: ModelConfig
    mesh: object
    tuning_db: TuningDatabase
    params: object
    _specs: object = None
    # Live step-timing sink (``repro.autotune.NestTelemetry``); a disabled
    # instance by default, so engines/trainers can observe unconditionally.
    telemetry: object = None

    def place(self, tree):
        """``device_put`` a parameter-shaped tree (e.g. AdamW moments) with
        the same specs used for ``params``; identity without a mesh."""
        import jax

        if self._specs is None:
            return tree
        return jax.device_put(tree, self._specs)

    def jitted(self, name: str, build, *key_parts):
        """A jitted fn from the shared content-addressed cache, keyed on the
        config fingerprint (+ any extra parts): equal-config deployments
        share the function and its jax trace cache — restarts and slot
        refills never retrace."""
        from ..core.cache import fingerprint_obj, jit_cache

        return jit_cache.get_or_build(
            (name, fingerprint_obj(self.cfg), *key_parts), build
        )


def deployment_context(
    cfg: ModelConfig,
    params,
    mesh=None,
    tuning_db: TuningDatabase | None = None,
    telemetry=None,
) -> DeploymentContext:
    """Resolve the deployment-time context: mesh-place ``params`` (any mesh
    with the planner's axes, via ``launch.sharding.param_specs``), pick
    the tuning database (caller-staged, else the shared warm
    ``deployment_database`` instance), and attach a telemetry sink
    (caller-staged for online tuning, else a disabled no-op one)."""
    db = tuning_db if tuning_db is not None else deployment_database()
    specs = None
    if mesh is not None:
        import jax

        from ..launch.sharding import param_specs

        shapes = jax.eval_shape(lambda p: p, params)
        specs = param_specs(shapes, mesh, cfg=cfg)
        params = jax.device_put(params, specs)
    if telemetry is None:
        from ..autotune import NestTelemetry

        telemetry = NestTelemetry(enabled=False)
    return DeploymentContext(cfg, mesh, db, params, specs, telemetry)


def plan_model(cfg: ModelConfig, seq: int, batch: int, db: TuningDatabase | None = None) -> list[ContractionPlan]:
    db = db or TuningDatabase()
    if not db.entries:
        seed_model_database(db)
    plans = []
    for name, (m, n, k) in model_contractions(cfg, seq, batch).items():
        # author the nest in an arbitrary (developer-chosen) order; the
        # normalizer canonicalizes it before the DB lookup
        order = ("k", "i", "j") if hash(name) % 2 else ("i", "j", "k")
        prog = PIPELINE.run(_matmul_program(name, m, n, k, order))
        nest = prog.body[0]
        fp = fingerprint(nest)
        emb = embed_nest(prog, nest)
        idiom = classify_nest(nest)
        recipe, source = db.lookup(fp, emb)
        if recipe is None:
            recipe = Recipe(kind="pallas_gemm", tile=_pick_tile(m, n, k))
            source = "default(blas3)"
        if recipe.tile is None or recipe.tile[0] > m or recipe.tile[1] > n:
            recipe = Recipe(kind=recipe.kind, tile=_pick_tile(m, n, k), notes=recipe.notes)
        mesh_axis = "model" if name in ("expert_ffn_in", "expert_ffn_out") else (
            "data" if m >= n else "model"
        )
        plans.append(ContractionPlan(name, (m, n, k), fp, idiom.kind, recipe, source, mesh_axis))
    return plans


def kernel_report(cfg: ModelConfig, seq: int, batch: int,
                  db: TuningDatabase | None = None,
                  plans: list[ContractionPlan] | None = None) -> str:
    """Human-readable pass-pipeline + per-contraction plan report.

    Rendered by the serving engine / trainer ``explain_kernels`` hooks and
    the dry-run driver: one per-pass table for the largest contraction (they
    all walk the same pipeline) plus one plan row per contraction.  Callers
    that already ran ``plan_model`` pass its result via ``plans``.
    """
    if plans is None:
        plans = plan_model(cfg, seq, batch, db=db)
    name, (m, n, k) = max(
        model_contractions(cfg, seq, batch).items(),
        key=lambda kv: kv[1][0] * kv[1][1] * kv[1][2],
    )
    ctx = PassContext()
    PIPELINE.run(_matmul_program(name, m, n, k), ctx=ctx)
    lines = [
        f"pass pipeline ({PIPELINE.name}) on {name} [{m}x{n}x{k}]:",
        ctx.report(),
        "",
        "contraction plans:",
    ]
    for p in plans:
        m, n, k = p.mnk
        lines.append(
            f"  {p.name:<16} {m:>8}x{n:<8}x{k:<6} idiom={p.idiom} "
            f"recipe={p.recipe.kind}{f' tile={p.recipe.tile}' if p.recipe.tile else ''} "
            f"source={p.source} axis={p.mesh_axis}"
        )
    return "\n".join(lines)
