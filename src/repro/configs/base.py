"""Model configuration schema + the input-shape suite for every arch.

Shapes (assignment):
  train_4k     seq 4096,   global batch 256   (training, lowers train_step)
  prefill_32k  seq 32768,  global batch 32    (inference prefill)
  decode_32k   seq 32768,  global batch 128   (decode: 1 new token, KV cache)
  long_500k    seq 524288, global batch 1     (long-context decode; needs a
                                               sub-quadratic path — see
                                               ``supports_long_context``)
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'vlm' | 'audio' | 'hybrid' | 'ssm'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_period: int = 1        # layer l is MoE iff l % moe_period == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # attention
    window: int | None = None  # sliding-window size (None = full)
    qkv_bias: bool = False
    rope_theta: float = 1e6

    # hybrid (Jamba): one attention layer per `attn_period` layers, rest Mamba
    attn_period: int = 0       # 0 = every layer is attention
    attn_offset: int = 0
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # xLSTM: repeating per-layer block kinds
    block_pattern: tuple[str, ...] = ()  # e.g. ('m','m','m','s')

    # encoder-decoder
    enc_layers: int = 0        # >0 -> enc-dec; n_layers = decoder layers

    # modality frontend stub ('vision' | 'audio' | None): input_specs()
    # provides precomputed patch/frame embeddings of this length
    frontend: str | None = None
    frontend_len: int = 576    # anyres tiles x patches / audio frames

    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    remat: str = "block"  # 'block' | 'none' | 'block_save_moe' (keep dispatch)
    seq_parallel: bool = False  # Megatron SP: seq-shard activations between
    #                             layers (RS+AG instead of all-reduce)

    # --- derived -------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_kind(self, l: int) -> str:
        """'attn' | 'mamba' | 'slstm' | 'mlstm' for layer l."""
        if self.block_pattern:
            return {"m": "mlstm", "s": "slstm"}[
                self.block_pattern[l % len(self.block_pattern)]
            ]
        if self.attn_period and l % self.attn_period != self.attn_offset:
            return "mamba"
        return "attn"

    def layer_is_moe(self, l: int) -> bool:
        return self.is_moe and l % self.moe_period == self.moe_offset

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic path exists: SWA, SSM, or hybrid."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.window is not None

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        pat = self.block_pattern[:4] if self.block_pattern else ()
        return replace(
            self,
            n_layers=max(2, min(4, self.n_layers)) if not self.attn_period
            else self.attn_period,  # keep one full hybrid period
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_head=32,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            window=min(self.window, 64) if self.window else None,
            enc_layers=2 if self.enc_layers else 0,
            frontend_len=8 if self.frontend else self.frontend_len,
            mamba_d_state=8,
            block_pattern=pat,
            dtype="float32",
            remat="none",
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether this (arch, shape) cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure O(L^2) full attention; no sub-quadratic path (see DESIGN.md)"
    return True, ""
