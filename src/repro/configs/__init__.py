"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from .base import SHAPES, ModelConfig, ShapeConfig, shape_applicable  # noqa: F401

from .mixtral_8x7b import CONFIG as _mixtral
from .qwen3_moe_235b_a22b import CONFIG as _qwen3moe
from .minicpm_2b import CONFIG as _minicpm
from .h2o_danube_3_4b import CONFIG as _danube
from .qwen1_5_32b import CONFIG as _qwen15
from .mistral_large_123b import CONFIG as _mistral_large
from .llava_next_mistral_7b import CONFIG as _llava
from .seamless_m4t_large_v2 import CONFIG as _seamless
from .jamba_1_5_large_398b import CONFIG as _jamba
from .xlstm_350m import CONFIG as _xlstm

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _mixtral, _qwen3moe, _minicpm, _danube, _qwen15,
        _mistral_large, _llava, _seamless, _jamba, _xlstm,
    )
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]
