"""xLSTM-350M [arXiv:2405.04517]: sLSTM + mLSTM blocks (7:1 pattern), no FFN."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    block_pattern=("m", "m", "m", "m", "m", "m", "m", "s"),
)
