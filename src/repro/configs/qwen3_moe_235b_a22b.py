"""Qwen3-MoE 235B-A22B [hf:Qwen]: 128-expert top-8, GQA kv=4, d_ff/expert 1536."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, vocab=151936,
    n_experts=128, top_k=8, moe_period=1,
    rope_theta=1e6,
)
