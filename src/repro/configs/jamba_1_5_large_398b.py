"""Jamba-1.5-Large [arXiv:2403.19887]: Mamba+attention 1:7, MoE 16e top-2.

Layer layout: one attention layer per 8 (attn_period=8), the rest Mamba;
every second layer's FFN is MoE (moe_period=2, offset 1).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536,
    n_experts=16, top_k=2, moe_period=2, moe_offset=1,
    attn_period=8, attn_offset=0,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    rope_theta=1e6,
)
