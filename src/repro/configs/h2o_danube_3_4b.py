"""H2O-Danube3-4B [arXiv:2401.16818]: llama+mistral mix, GQA kv=8, SWA."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_head=120,
    d_ff=10240, vocab=32000,
    window=4096, rope_theta=1e4,
)
