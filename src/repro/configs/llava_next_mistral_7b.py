"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

The vision tower is a STUB per the assignment: input_specs() supplies
precomputed anyres patch embeddings (frontend_len tokens of d_model).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000,
    rope_theta=1e6,
    frontend="vision", frontend_len=2880,  # anyres: 5 tiles x 576 patches
)
