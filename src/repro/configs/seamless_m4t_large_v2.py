"""SeamlessM4T-Large v2 [arXiv:2308.11596]: encoder-decoder, audio frontend STUB.

input_specs() supplies precomputed speech frame embeddings to the encoder;
the text decoder (24L) performs self- + cross-attention over encoder memory.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206,
    enc_layers=24, rope_theta=1e4,
    frontend="audio", frontend_len=4096,
)
