"""MiniCPM-2B [arXiv:2404.06395]: llama-like dense, MHA, WSD LR schedule."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab=122753,
    rope_theta=1e4, tie_embeddings=True,
)

# WSD (warmup-stable-decay) is this arch's assigned LR schedule
LR_SCHEDULE = "wsd"
