import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first initialization.  512 host devices back both the single-pod
# (16x16) and multi-pod (2x16x16) production meshes.

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from functools import partial  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ARCHS, SHAPES, get_config, shape_applicable  # noqa: E402
from ..core.cache import fingerprint_obj  # noqa: E402
from ..models import model as M  # noqa: E402
from ..optim.adamw import AdamWConfig, adamw_init  # noqa: E402
from ..train.train_loop import make_train_step  # noqa: E402
from .mesh import dp_axes, make_production_mesh, set_mesh  # noqa: E402
from .sharding import batch_specs, param_specs, replicated, state_specs  # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces the compiled artifact's
  * memory_analysis()  — proves the cell fits per-device HBM,
  * cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective bytes   — parsed from the optimized HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute),
and writes one JSON per cell under --out (default dryrun_out/).

Shape kinds: train_4k lowers train_step; prefill_32k lowers forward;
decode_32k / long_500k lower serve (decode_step) with a materialized-shape
KV cache/state.  long_500k cells exist only for sub-quadratic archs
(DESIGN.md §Arch-applicability); the others record status='skipped'.
"""

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\]\S*)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Bytes moved by collectives, from the optimized (post-SPMD) HLO.

    Ring-cost convention: all-reduce counts 2x its result bytes
    (reduce-scatter + all-gather phases); everything else 1x result bytes.
    """
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_txt)
        out[op] += 2 * b if op == "all-reduce" else b
    return out


# Bump whenever the cell record gains/changes fields, so JSONs written by an
# older revision are recomputed instead of skip-cached without the new data
# (v2: kernel_plans from the compiler pass pipeline).
_RECORD_SCHEMA = 2


def cell_cache_key(arch: str, shape_name: str, multi_pod: bool,
                   fsdp: bool = True, variant: str = "base") -> str:
    """Content address of one dry-run cell: the full config, shape, mesh,
    jax version and record schema.  A cached JSON whose key differs (config
    edit, toolchain bump, schema change) is recomputed instead of silently
    served stale."""
    return fingerprint_obj(
        get_config(arch), SHAPES[shape_name], multi_pod, fsdp, variant,
        jax.__version__, _RECORD_SCHEMA,
    )


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    b, s = shp.global_batch, shp.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    batch = {"tokens": tok, "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.frontend is not None:
        batch["embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), jnp.bfloat16
        )
    return batch


def _eval_shapes(cfg, shape):
    params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    out = {"params": params}
    if shape.kind == "train":
        out["opt"] = jax.eval_shape(partial(adamw_init), params)
    if shape.kind == "decode":
        out["state"] = jax.eval_shape(
            lambda: M.init_decode_state(cfg, shape.global_batch, shape.seq_len)
        )
    return out


def _with_shardings(struct_tree, spec_tree):
    return jax.tree_util.tree_map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sp),
        struct_tree, spec_tree,
    )


def lower_cell(arch: str, shape_name: str, multi_pod: bool, opt_cfg=None,
               fsdp: bool = True, variant: str = "base",
               explain: bool = False) -> dict:
    """variant: 'base' | 'dp_only' (no TP: params replicated, batch over all
    axes) | 'seq_parallel' (Megatron SP) | 'save_moe' (keep MoE dispatch
    across the backward) — the §Perf hillclimb knobs."""
    from dataclasses import replace as _replace

    cfg = get_config(arch)
    if variant == "seq_parallel":
        cfg = _replace(cfg, seq_parallel=True)
    elif variant == "save_moe":
        cfg = _replace(cfg, remat="block_save_moe")
    elif variant == "layer_remat":
        cfg = _replace(cfg, remat="layer")
    dp_only = variant == "dp_only"

    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "kind": shape.kind, "sharding": "fsdp" if fsdp else "tp",
                 "variant": variant,
                 "cache_key": cell_cache_key(arch, shape_name, multi_pod, fsdp, variant)}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    shapes = _eval_shapes(cfg, shape)
    if dp_only:
        pspecs = replicated(mesh, shapes["params"])
    else:
        pspecs = param_specs(shapes["params"], mesh,
                             fsdp=fsdp and shape.kind == "train", cfg=cfg)
    batch = input_specs(arch, shape_name)

    with set_mesh(mesh):
        if shape.kind == "train":
            # opt state m/v shaped like params -> same specs; step scalar repl
            ospecs = {
                "m": pspecs, "v": pspecs,
                "step": NamedSharding(mesh, P()),
            }
            all_axes = tuple(mesh.axis_names)
            bspecs = batch_specs(cfg, shape, mesh, batch,
                                 axes=all_axes if dp_only else None)
            accum = 4 if variant == "accum4" else 1
            step = make_train_step(cfg, opt_cfg or AdamWConfig(), accum_steps=accum)
            metrics_specs = {
                k: NamedSharding(mesh, P())
                for k in ("grad_norm", "lr", "skipped", "loss")
            }
            jitted = jax.jit(
                step,
                out_shardings=(pspecs, ospecs, metrics_specs),
                donate_argnums=(0, 1),
            )
            args = (
                _with_shardings(shapes["params"], pspecs),
                _with_shardings(shapes["opt"], ospecs),
                _with_shardings(batch, bspecs),
            )
        elif shape.kind == "prefill":
            bspecs = batch_specs(cfg, shape, mesh, batch)
            fwd = partial(M.forward, cfg)
            jitted = jax.jit(fwd)
            args = (
                _with_shardings(shapes["params"], pspecs),
                _with_shardings(batch, bspecs),
            )
        else:  # decode
            sspecs = state_specs(cfg, mesh, shapes["state"])
            tok = jax.ShapeDtypeStruct(
                (shape.global_batch, 1), jnp.int32,
                sharding=NamedSharding(
                    mesh,
                    P(dp_axes(mesh) if shape.global_batch % (
                        mesh.devices.size // mesh.shape["model"]) == 0 else None, None),
                ),
            )
            stepf = partial(M.decode_step, cfg)
            jitted = jax.jit(stepf)
            args = (
                _with_shardings(shapes["params"], pspecs),
                _with_shardings(shapes["state"], sspecs),
                tok,
            )

        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    print(mem)  # proves it fits (bytes per device)
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax <= 0.4.x returns [dict]
        cost = cost[0] if cost else None
    print({k: cost.get(k) for k in ("flops", "bytes accessed")} if cost else cost)
    coll = collective_bytes(compiled.as_text())

    rec.update(status="ok", lower_s=round(t_lower, 1), compile_s=round(t_compile, 1))
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            rec[attr] = int(getattr(mem, attr, 0) or 0)
    if cost:
        rec["hlo_flops"] = float(cost.get("flops", 0.0))
        rec["hlo_bytes"] = float(cost.get("bytes accessed", 0.0))
    rec["collective_bytes"] = coll
    rec["collective_total"] = int(sum(coll.values()))
    rec["n_devices"] = int(mesh.devices.size)

    # kernel plans from the compiler pass pipeline: which idiom/recipe each
    # per-layer contraction resolves to at this cell's shape (content-keyed
    # memo: cells differing only in mesh/variant share one pipeline run)
    from ..core.cache import jit_cache
    from ..models.lowering import kernel_report, plan_model

    plans = jit_cache.get_or_build(
        ("dryrun.plans", fingerprint_obj(cfg, shape.seq_len, shape.global_batch)),
        lambda: plan_model(cfg, shape.seq_len, shape.global_batch),
    )
    rec["kernel_plans"] = [
        {"name": p.name, "mnk": list(p.mnk), "idiom": p.idiom,
         "recipe": p.recipe.kind, "source": p.source, "mesh_axis": p.mesh_axis}
        for p in plans
    ]
    if explain:
        print(kernel_report(cfg, shape.seq_len, shape.global_batch, plans=plans))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--sharding", default="fsdp", choices=["fsdp", "tp"])
    ap.add_argument("--out", default="dryrun_out")
    ap.add_argument("--explain", action="store_true",
                    help="print the per-pass pipeline report for each cell")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                path = outdir / f"{tag}.json"
                if path.exists():
                    try:
                        prev = json.loads(path.read_text())
                    except (json.JSONDecodeError, OSError):
                        prev = {}
                    want = cell_cache_key(arch, shape, mp, fsdp=args.sharding == "fsdp")
                    if prev.get("cache_key") == want and prev.get("status") != "failed":
                        print(f"[skip-cached] {tag}")
                        continue
                    print(f"[stale-cache] {tag}: recomputing")
                print(f"[lower] {tag}", flush=True)
                try:
                    rec = lower_cell(arch, shape, mp, fsdp=args.sharding == "fsdp",
                                     explain=args.explain)
                except Exception as e:  # a failure here is a bug in the system
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "failed", "error": repr(e)[:500]}
                    failures += 1
                path.write_text(json.dumps(rec, indent=1))
                print(f"[done] {tag}: {rec['status']}", flush=True)
    print(f"dry-run complete, failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
