"""Roofline analysis from dry-run artifacts (no TPU wall clock needed).

Per (arch, shape, mesh) cell — using the per-device SPMD module numbers the
dry-run recorded (XLA analyses the partitioned module, so flops/bytes/
collective bytes are already per chip):

  compute term    = HLO_FLOPs_per_chip / peak_FLOPs        [s]
  memory term     = HLO_bytes_per_chip / HBM_bw            [s]
  collective term = collective_bytes_per_chip / link_bw    [s]

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
The dominant term is the bottleneck the perf loop iterates on (§Perf).

MODEL_FLOPS uses the 6·N·D (train) / 2·N·D (inference) convention with
N_active for MoE; the ratio MODEL_FLOPS / (HLO_FLOPs × chips) measures how
much compiled compute is "useful" (catches remat/redundant compute).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9       # bytes/s / chip
LINK_BW = 50e9       # bytes/s / link (ICI)


def param_counts(arch: str) -> tuple[int, int]:
    """(total, active) parameter counts via eval_shape (no allocation)."""
    import jax

    from ..configs import get_config
    from ..models import model as M

    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    total = expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        p = "/".join(str(x) for x in path)
        if "ffn" in p and leaf.ndim >= 3 and cfg.is_moe:
            expert += n
    active = total
    if cfg.is_moe and cfg.n_experts:
        active = total - expert * (cfg.n_experts - cfg.top_k) // cfg.n_experts
    return total, active


@dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    kind: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0
    useful_ratio: float = 0.0
    raw: dict | None = None

    @property
    def step_time(self) -> float:
        """No-overlap upper bound on the step time."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """compute term / dominant term: 1.0 = compute-bound at peak."""
        t = self.step_time
        return self.compute_s / t if t else 0.0


def analyse_cell(rec: dict, pcounts: dict[str, tuple[int, int]],
                 analytic: bool = True) -> Cell:
    """Roofline terms for one dry-run cell.

    ``analytic=True`` (default) uses the per-arch cost model
    (launch/analytic.py) because XLA cost_analysis counts while-loop bodies
    once (verified) and every model here scans its layers; the raw HLO
    numbers stay in ``raw`` as the per-body cross-check.
    """
    c = Cell(rec["arch"], rec["shape"], rec["mesh"], rec.get("kind", ""),
             rec["status"], raw=rec)
    if rec["status"] != "ok":
        return c
    from ..configs import SHAPES, get_config

    shp = SHAPES[rec["shape"]]
    chips = rec.get("n_devices", 256)
    if analytic:
        from .analytic import MeshInfo, analytic_cost

        tp = 16
        mi = MeshInfo(chips=chips, dp=chips // tp, tp=tp)
        cost = analytic_cost(get_config(rec["arch"]), shp, mi)
        c.compute_s = cost.flops / PEAK_FLOPS
        c.memory_s = cost.hbm_bytes / HBM_BW
        c.collective_s = cost.coll_bytes / LINK_BW
    else:
        c.compute_s = rec["hlo_flops"] / PEAK_FLOPS
        c.memory_s = rec["hlo_bytes"] / HBM_BW
        c.collective_s = rec["collective_total"] / LINK_BW
    terms = {"compute": c.compute_s, "memory": c.memory_s,
             "collective": c.collective_s}
    c.dominant = max(terms, key=terms.get)

    total, active = pcounts[rec["arch"]]
    tokens = shp.global_batch * (shp.seq_len if shp.kind != "decode" else 1)
    factor = 6 if shp.kind == "train" else 2
    c.model_flops = factor * active * tokens
    hlo_global = c.compute_s * PEAK_FLOPS * chips
    c.useful_ratio = min(1.0, c.model_flops / hlo_global) if hlo_global else 0.0
    return c


def load_cells(outdir: str | Path) -> list[Cell]:
    recs = [json.loads(p.read_text()) for p in sorted(Path(outdir).glob("*.json"))]
    archs = {r["arch"] for r in recs}
    pcounts = {a: param_counts(a) for a in sorted(archs)}
    return [analyse_cell(r, pcounts) for r in recs]


def advice(c: Cell) -> str:
    """One sentence: what would move the dominant term down."""
    if c.status != "ok":
        return ""
    if c.dominant == "compute":
        if c.useful_ratio < 0.5:
            return ("compute-bound with low useful ratio: cut remat recompute "
                    "or redundant einsums (gradient remat policy / fused kernels)")
        return "compute-bound near useful peak: only larger per-chip batch helps"
    if c.dominant == "memory":
        return ("memory-bound: fuse elementwise chains / keep activations bf16 "
                "/ widen per-chip tile reuse (Pallas BlockSpec K-reuse)")
    top = max(c.raw["collective_bytes"], key=c.raw["collective_bytes"].get)
    return (f"collective-bound (mostly {top}): reshard to cut {top} volume, "
            "overlap with compute, or compress the payload (bf16/int8 grads)")


def markdown_table(cells: list[Cell], mesh: str = "16x16") -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful | bottleneck fix |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.mesh != mesh:
            continue
        if c.status == "skipped":
            rows.append(f"| {c.arch} | {c.shape} | — | — | — | skipped | — | "
                        f"{c.raw.get('reason', '')[:60]} |")
            continue
        rows.append(
            f"| {c.arch} | {c.shape} | {c.compute_s:.3e} | {c.memory_s:.3e} | "
            f"{c.collective_s:.3e} | **{c.dominant}** | {c.useful_ratio:.2f} | "
            f"{advice(c)[:80]} |"
        )
    return "\n".join(rows)
