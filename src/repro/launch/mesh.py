"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: (data=16, model=16) = 256 chips (TPU v5e
pod).  Multi-pod: (pod=2, data=16, model=16) = 512 chips — the ``pod`` axis
composes with ``data`` for the gradient all-reduce (hierarchical: ICI ring
inside the pod, DCN across pods) and carries the compressed-gradient
collective (optim/compression.py).

The axes generalize: any (pod, data, model) product works, which is the
1000+-node posture — scale `pod` out over DCN, keep `model` inside the ICI
domain.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests use small host-device meshes, e.g. (2, 4))."""
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes (pod folds into DP for the batch dimension)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def set_mesh(mesh):
    """``with set_mesh(mesh):`` on any jax: ``jax.set_mesh`` where it exists,
    else the Mesh object itself (a context manager on jax <= 0.4.x)."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
