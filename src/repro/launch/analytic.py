"""Analytic per-cell cost model: FLOPs / HBM bytes / collective bytes.

WHY THIS EXISTS (methodology, see EXPERIMENTS.md §Roofline): XLA's
``cost_analysis`` counts a ``while`` body ONCE — verified on this container:
a 10-step scan of matmuls reports the flops of one body.  Every model here
scans its layers (and chunks its attention/SSM scans), so the raw HLO
numbers undercount by the trip counts.  The dry-run artifacts remain the
compile proof + collective-schedule evidence; the roofline *terms* come from
this analytic model, whose per-layer-body predictions are cross-checked
against the HLO counts.

Conventions (bf16 activations/weights, fp32 optimizer):
  * train accounts fwd (2NT) + bwd (4NT) + block-remat recompute (2NT);
  * the Pallas flash kernel keeps scores in VMEM -> attention contributes
    FLOPs but no O(S^2) HBM traffic;
  * ring collectives: all-reduce moves 2x payload, AG/RS 1x;
  * TP Megatron pairs: 2 activation all-reduces per layer fwd, 2 in bwd;
  * FSDP: per-layer weight all-gather (fwd + bwd re-gather) + gradient
    reduce-scatter; optimizer state touched once per step.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class MeshInfo:
    chips: int
    dp: int     # data-parallel ways (pod * data)
    tp: int     # model-parallel ways


@dataclass
class CellCost:
    flops: float          # per chip
    hbm_bytes: float      # per chip
    coll_bytes: float     # per chip (ring-adjusted)
    detail: dict


def _matmul_params(cfg: ModelConfig) -> tuple[float, float]:
    """(total, active) matmul params per layer-average x n_layers + head."""
    d, dh, h, kv, f = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    per_layer_attn = d * (h * dh) + 2 * d * (kv * dh) + (h * dh) * d
    total = active = 0.0
    for l in range(cfg.n_layers):
        kind = cfg.layer_kind(l)
        if kind == "attn":
            total += per_layer_attn
            active += per_layer_attn
        elif kind == "mamba":
            din = cfg.mamba_expand * d
            m = d * 2 * din + din * d + din * (max(1, d // 16) + 2 * cfg.mamba_d_state)
            total += m
            active += m
        elif kind in ("mlstm", "slstm"):
            total += 5 * d * d
            active += 5 * d * d
        if cfg.d_ff:
            ffn = 3 * d * f
            if cfg.layer_is_moe(l):
                total += cfg.n_experts * ffn
                active += cfg.top_k * ffn
            else:
                total += ffn
                active += ffn
    if cfg.is_encdec:
        enc = cfg.enc_layers * (per_layer_attn + 3 * d * f)
        dec_cross = cfg.n_layers * per_layer_attn  # cross-attention blocks
        total += enc + dec_cross
        active += enc + dec_cross
    total += d * cfg.vocab  # lm head (embedding gather is traffic, not flops)
    active += d * cfg.vocab
    return total, active


def _attn_layers(cfg: ModelConfig) -> int:
    n = sum(1 for l in range(cfg.n_layers) if cfg.layer_kind(l) == "attn")
    if cfg.is_encdec:
        n += cfg.enc_layers + cfg.n_layers  # encoder self + decoder cross
    return n


def analytic_cost(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshInfo) -> CellCost:
    d, dh, h, kv = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    B, S = shape.global_batch, shape.seq_len
    train = shape.kind == "train"
    decode = shape.kind == "decode"
    sq = 1 if decode else S
    T = B * sq                      # tokens this step
    B_loc = max(1, B // mesh.dp)
    T_loc = B_loc * sq
    n_total, n_active = _matmul_params(cfg)

    fb = 8 if train else 2          # fwd(2) + bwd(4) + remat(2)
    mm_flops = fb * n_active * T / mesh.chips

    # attention score/value flops (flash: compute yes, HBM no)
    w_eff = min(cfg.window or S, S)
    if shape.kind != "decode" and cfg.window is None:
        w_eff = S / 2  # causal average
    attn_per_layer = 4 * B * sq * w_eff * h * dh
    attn_flops = (4 if train else 1) * attn_per_layer * _attn_layers(cfg) / mesh.chips
    # ssm scan flops
    ssm_flops = 0.0
    for l in range(cfg.n_layers):
        kind = cfg.layer_kind(l)
        if kind == "mamba":
            din = cfg.mamba_expand * d
            ssm_flops += 10 * B * sq * din * cfg.mamba_d_state
        elif kind == "mlstm":
            q = min(128, sq)
            ssm_flops += 4 * B * sq * (q + 2 * (d // max(1, h))) * d
        elif kind == "slstm":
            ssm_flops += 12 * B * sq * d
    ssm_flops *= (3 if train else 1) / mesh.chips

    flops = mm_flops + attn_flops + ssm_flops

    # ---- HBM bytes per chip -------------------------------------------------
    n_loc_total = n_total / mesh.chips if train else n_total / mesh.tp
    if not train and n_total * 2 / mesh.tp > 16e9:
        n_loc_total = n_total / mesh.chips  # big models: weights fully sharded
    w_bytes = (3 if train else 1) * 2 * n_loc_total  # weight reads (bf16)
    opt_bytes = (20 * n_total / mesh.chips) if train else 0.0  # m,v fp32 r/w + grads
    act_bytes = 0.0
    if sq > 1:
        act_bytes = (3 if train else 1) * 12 * T_loc * d * 2 * cfg.n_layers / mesh.tp
    logits_bytes = 3 * T_loc * cfg.vocab * 2 / mesh.tp
    kv_bytes = 0.0
    if decode:
        cache_w = min(cfg.window or S, S)
        kv_bytes = 2 * B_loc * cache_w * kv * dh * 2 * (
            sum(1 for l in range(cfg.n_layers) if cfg.layer_kind(l) == "attn")
            + (cfg.n_layers if cfg.is_encdec else 0)
        )
    hbm = w_bytes + opt_bytes + act_bytes + logits_bytes + kv_bytes

    # ---- collective bytes per chip (ring-adjusted) ---------------------------
    act = T_loc * d * 2
    tp_layers = cfg.n_layers + (cfg.enc_layers if cfg.is_encdec else 0)
    tp_coll = (3 if train else 1) * 2 * (2 * act) * tp_layers  # 2 AR/layer, 2x ring
    fsdp_coll = 0.0
    dp_coll = 0.0
    if train:
        layer_w = 2 * (n_total - d * cfg.vocab) / max(1, mesh.tp)  # bf16, per dp group
        fsdp_coll = 2 * layer_w  # AG fwd + AG bwd (per chip, (dp-1)/dp ~ 1)
        dp_coll = 2 * layer_w    # grad reduce-scatter + update all-gather
    ep_coll = 0.0
    if cfg.is_moe and sq > 1:
        moe_layers = sum(1 for l in range(cfg.n_layers) if cfg.layer_is_moe(l))
        # dispatch + combine of top_k token copies per MoE layer
        ep_coll = (3 if train else 1) * 2 * cfg.top_k * T_loc * d * 2 * moe_layers
    coll = tp_coll + fsdp_coll + dp_coll + ep_coll

    return CellCost(
        flops=flops, hbm_bytes=hbm, coll_bytes=coll,
        detail=dict(mm=mm_flops, attn=attn_flops, ssm=ssm_flops,
                    w=w_bytes, opt=opt_bytes, act=act_bytes,
                    logits=logits_bytes, kvc=kv_bytes,
                    tp=tp_coll, fsdp=fsdp_coll, dp=dp_coll, ep=ep_coll,
                    n_total=n_total, n_active=n_active),
    )
