import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

"""§Perf hillclimbing driver — the three chosen cells, per the assignment:

  1. xlstm-350m    x train_4k  — worst roofline fraction (TP overhead swamps
                                 a 350M model)        -> variant 'dp_only'
  2. qwen3-moe     x train_4k  — most collective-bound (EP dispatch bytes
                                 x3 from remat)       -> variant 'save_moe'
  3. mistral-large x train_4k  — most paper-representative (canonical dense
                                 GEMM TP pairs)       -> variant 'seq_parallel'

Each variant is LOWERED FOR REAL on the single-pod mesh and its HLO
collective bytes / memory compared against the base cell (per-body HLO is a
valid A/B because the loop structure is unchanged).  Results land in
hillclimb_out/ and are summarized in EXPERIMENTS.md §Perf.
"""

CELLS = [
    ("xlstm-350m", "train_4k", "dp_only"),
    ("qwen3-moe-235b-a22b", "train_4k", "save_moe"),
    ("mistral-large-123b", "train_4k", "seq_parallel"),
    # beyond the required three: the worst remaining memory cell
    ("jamba-1.5-large-398b", "train_4k", "accum4"),
    ("jamba-1.5-large-398b", "train_4k", "layer_remat"),
]


def main() -> None:
    from .dryrun import lower_cell

    out = Path("hillclimb_out")
    out.mkdir(exist_ok=True)
    for arch, shape, variant in CELLS:
        for v in ("base", variant):
            tag = f"{arch}__{shape}__{v}"
            p = out / f"{tag}.json"
            if p.exists():
                print(f"[skip-cached] {tag}")
                continue
            print(f"[lower] {tag}", flush=True)
            try:
                rec = lower_cell(arch, shape, multi_pod=False, variant=v)
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "variant": v,
                       "status": "failed", "error": repr(e)[:400]}
            p.write_text(json.dumps(rec, indent=1))
            print(f"[done] {tag}: {rec['status']}", flush=True)

    # summary
    print(f"\n{'cell':40s} {'variant':14s} {'coll GB':>9s} {'temp GiB':>9s} {'args GiB':>9s}")
    for arch, shape, variant in CELLS:
        for v in ("base", variant):
            p = out / f"{arch}__{shape}__{v}.json"
            if not p.exists():
                continue
            r = json.loads(p.read_text())
            if r["status"] != "ok":
                print(f"{arch + ' ' + shape:40s} {v:14s} {r['status']}")
                continue
            print(f"{arch + ' ' + shape:40s} {v:14s} "
                  f"{r['collective_total'] / 1e9:9.1f} "
                  f"{r['temp_size_in_bytes'] / 2**30:9.1f} "
                  f"{r['argument_size_in_bytes'] / 2**30:9.1f}")


if __name__ == "__main__":
    main()
