"""CLI launcher: ``python -m repro.launch.train --arch <id> [options]``.

Runs real training on the available devices (reduced configs on CPU; the
full configs target the production mesh).  For multi-host launches, each
host runs this entrypoint with jax.distributed initialization (coordinator
env vars) and the data pipeline shards by process index.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd", "const"])
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..configs import get_config
    from ..data.pipeline import DataConfig
    from ..optim.adamw import AdamWConfig
    from ..train.train_loop import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    # MiniCPM's assigned schedule is WSD
    schedule = "wsd" if cfg.name == "minicpm-2b" and args.schedule == "cosine" else args.schedule

    dcfg = DataConfig(
        seq_len=args.seq_len, global_batch=args.batch, vocab=cfg.vocab,
        source=args.data, path=args.data_path, seed=args.seed,
    )
    ocfg = AdamWConfig(lr=args.lr, schedule=schedule, total_steps=args.steps,
                       warmup_steps=max(1, args.steps // 20))
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         accum_steps=args.accum)
    tr = Trainer(cfg, ocfg, dcfg, tcfg, seed=args.seed)
    if args.resume:
        tr.try_restore()
    hist = tr.run(args.steps)
    last = hist[-min(10, len(hist)):]
    avg = sum(h["loss"] for h in last) / len(last)
    print(f"final step {tr.step}: loss(last10)={avg:.4f}")


if __name__ == "__main__":
    main()
