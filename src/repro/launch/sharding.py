"""The sharding planner: DP/TP/EP/SP specs for every tensor of every arch.

Rules (Megatron-style TP pairs, EP for divisible expert counts, SP fallback
for the batch=1 long-context cells), all divisibility-checked against the
mesh — a dimension that does not divide falls back to replication rather
than failing to lower.  This is the "parallelize" recipe of the daisy
scheduler operating at the framework level: the canonical contraction of
each layer determines which axis its parallel loop maps to.

  column-parallel (wq/wg/wu/in_proj/...):  (..., D, F) -> (..., None, model)
  row-parallel    (wo/wd/out_proj/...):    (..., F, D) -> (..., model, None)
  expert weights  (E, D, F): EP (model, None, None) when E%model==0,
                             else TP on the trailing dims
  embed (V, D): vocab-parallel when V%model==0 else feature-parallel
  batch dims: (pod, data); KV caches: batch -> DP, heads -> model when
              divisible; batch=1 decode shards the cache *sequence* (SP)
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from .mesh import dp_axes

Pytree = Any


def _msize(mesh) -> int:
    # a mesh without a model axis (e.g. the pure-DP column mesh of the
    # sharded canonical-program path) has TP size 0: every divisibility
    # check fails and all rules fall back to replication instead of
    # emitting specs that name a nonexistent axis
    return mesh.shape.get("model", 0)


def _dpsize(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------
_COLUMN = ("wq", "wk", "wv", "wg", "wu", "in_proj", "dt_proj", "wz", "wi",
           "wf", "wo_gate", "conv_w")
_ROW = ("wo", "wd", "out_proj", "x_proj")


def _param_rule(path: str, shape: tuple[int, ...], mesh, cfg=None) -> P:
    m = _msize(mesh)
    nd = len(shape)
    leaf = path.split("/")[-1].strip("'[]")

    def pad(spec: list) -> P:
        return P(*([None] * (nd - len(spec)) + spec))

    # GQA: a head-count that does not divide the model axis cannot keep its
    # (B, S, heads, dh) reshape sharded (XLA "involuntary full remat" —
    # replicates the tensor).  Shard the *contracting* dim instead
    # (row-parallel: psum'd, output replicated over model).
    if cfg is not None and leaf in ("wq", "wk", "wv") and nd >= 2 and m > 0:
        heads = cfg.n_heads if leaf == "wq" else cfg.n_kv_heads
        if heads % m != 0:
            return pad(["model" if _div(shape[-2], m) else None, None])

    if leaf == "embed":
        if _div(shape[0], m):
            return P("model", None)
        return P(None, "model" if _div(shape[1], m) else None)
    if leaf == "lm_head":
        return P(None, "model" if _div(shape[1], m) else None)
    # MoE expert tensors: (..., E, D, F) with E the -3rd dim
    if "ffn" in path and leaf in ("wg", "wu", "wd") and nd >= 3:
        e = shape[-3]
        if _div(e, m):
            return pad(["model", None, None])  # EP
        if leaf in ("wg", "wu"):
            return pad([None, None, "model" if _div(shape[-1], m) else None])
        return pad([None, "model" if _div(shape[-2], m) else None, None])
    if leaf == "router":
        return P(*([None] * nd))
    if leaf in _COLUMN and nd >= 2:
        return pad([None, "model" if _div(shape[-1], m) else None])
    if leaf in _ROW and nd >= 2:
        return pad(["model" if _div(shape[-2], m) else None, None])
    if leaf in ("bq", "bk", "bv") and nd >= 1:
        return pad(["model" if _div(shape[-1], m) else None])
    if leaf in ("A_log", "Dskip", "conv_b", "dt_bias"):
        # mamba per-channel tensors: shard d_inner (first trailing dim)
        if nd >= 2:
            return pad(["model" if _div(shape[-2], m) else None, None])
        return pad(["model" if _div(shape[-1], m) else None])
    return P(*([None] * nd))  # norms, biases, scalars


def _add_fsdp(spec: P, shape: tuple[int, ...], mesh, exclude_last: bool = False) -> P:
    """Shard one more dim over the DP axes (ZeRO-3/FSDP): parameters and
    optimizer state then scale 1/(dp*model) per device; XLA all-gathers each
    scanned layer's weights on use and reduce-scatters its gradients."""
    dp = dp_axes(mesh)
    dpn = _dpsize(mesh)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    # candidate dims: largest first; skip already-sharded; skip the leading
    # stack dim of scanned layers (slicing a sharded stack dim regathers)
    cands = sorted(range(len(shape)), key=lambda d: -shape[d])
    for d in cands:
        if entries[d] is not None:
            continue
        if d == 0 and len(shape) >= 3:
            continue
        if exclude_last and d == len(shape) - 1:
            continue
        if _div(shape[d], dpn) and shape[d] >= dpn:
            entries[d] = dp if len(dp) > 1 else dp[0]
            break
    return P(*entries)


def param_specs(params_shape: Pytree, mesh, fsdp: bool = False, cfg=None) -> Pytree:
    def spec_of(path, leaf):
        p = "/".join(str(x) for x in path)
        leafname = p.split("/")[-1].strip("'[]")
        spec = _param_rule(p, tuple(leaf.shape), mesh, cfg)
        if fsdp and leaf.ndim >= 2:
            # qkv head-flat output dims excluded: FSDP there would reshard
            # across the (heads, dh) reshape (the involuntary-remat trap)
            spec = _add_fsdp(spec, tuple(leaf.shape), mesh,
                             exclude_last=leafname in ("wq", "wk", "wv"))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(spec_of, params_shape)


# ---------------------------------------------------------------------------
# batch / state / metric specs
# ---------------------------------------------------------------------------
def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, batch_shape: Pytree,
                axes: tuple[str, ...] | None = None) -> Pytree:
    dp = axes if axes is not None else dp_axes(mesh)
    dpn = int(np.prod([mesh.shape[a] for a in dp]))

    def spec_of(path, leaf):
        b = leaf.shape[0] if leaf.ndim else 0
        first = dp if _div(b, dpn) else None
        return NamedSharding(mesh, P(first, *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map_with_path(spec_of, batch_shape)


def state_specs(cfg: ModelConfig, mesh, state_shape: Pytree) -> Pytree:
    """Decode-state sharding: batch -> DP; KV heads -> model if divisible;
    batch=1 (long-context): shard cache sequence over DP instead (SP)."""
    dp = dp_axes(mesh)
    dpn = _dpsize(mesh)
    m = _msize(mesh)

    def spec_of(path, leaf):
        p = "/".join(str(x) for x in path)
        nd = leaf.ndim
        if nd == 0:
            return NamedSharding(mesh, P())
        if "memory" in p and nd == 3:  # (B, S_mem, D)
            b = leaf.shape[0]
            return NamedSharding(
                mesh, P(dp if _div(b, dpn) else None, None,
                        "model" if _div(leaf.shape[2], m) else None))
        # KV caches: (L, B, S, KV, dh) or mamba/mlstm states (L, B, ...)
        if nd >= 3:
            b = leaf.shape[1]
            spec = [None] * nd
            if _div(b, dpn):
                spec[1] = dp
                # shard a feature dim over model when possible
                for d in range(2, nd):
                    if d != 2 and _div(leaf.shape[d], m):
                        spec[d] = "model"
                        break
            elif nd >= 4:
                # SP: batch too small -> shard the sequence dim of the cache
                if _div(leaf.shape[2], dpn):
                    spec[2] = dp
                for d in range(3, nd):
                    if _div(leaf.shape[d], m):
                        spec[d] = "model"
                        break
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(spec_of, state_shape)


def replicated(mesh, tree_shape: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, P(*([None] * leaf.ndim))), tree_shape
    )
